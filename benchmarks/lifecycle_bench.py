"""Lifecycle benchmark: the paper's green-consolidation story, over time.

Runs the churn scenarios (finite pod lifetimes) under the default
kube-scheduler, a churn-mixture-trained SDQN, and SDQN-n with the in-episode
consolidation pass, and reports the time-resolved metrics the static bursts
cannot measure: time-averaged active nodes, node-seconds, and energy billed
to the workload.  SDQN-n consolidating onto fewer nodes — so idle nodes
appear and can be powered down — is the paper's §1 contribution 2 / §6
claim; ``BENCH_lifecycle.json`` is its regression record.

    PYTHONPATH=src python -m benchmarks.run --lifecycle          # full
    PYTHONPATH=src python -m benchmarks.run --lifecycle-smoke    # CI-sized
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Tuple

import jax

from repro import scenarios
from repro.core import presets, schedulers, train_rl
from repro.eval import engine as eval_engine
from repro.sched import elastic, topsis

LIFECYCLE_SCENARIOS = presets.LIFECYCLE_MIX_NAMES
CONSOLIDATE_EVERY_S = 30.0
POLICIES = ("kube", "sdqn", "sdqnn")

# energy_weight grid of the green Pareto sweep: 0 (pure Table-5 + efficiency)
# through 2x the lifecycle preset's operating point (15.0)
PARETO_ENERGY_WEIGHTS = (0.0, 7.5, 15.0, 30.0)
PARETO_SMOKE_WEIGHTS = (0.0, 15.0, 30.0)


@functools.lru_cache(maxsize=None)
def lifecycle_policies(train_episodes: int = 120):
    """(sdqn, sdqn_n) Q-nets trained across the churn mixture (cached).

    SDQN-n trains with the Table-5 consolidation reward plus the
    energy/node-count term — the policy the consolidation pass reuses.
    """
    cfgs = scenarios.training_mixture(presets.LIFECYCLE_MIX_NAMES)
    rl = dataclasses.replace(presets.SDQN_LIFECYCLE_PRESET, episodes=train_episodes)
    rln = dataclasses.replace(presets.SDQN_N_LIFECYCLE_PRESET, episodes=train_episodes)
    qp, _ = train_rl.train_mixture(jax.random.PRNGKey(42), cfgs, rl)
    qpn, _ = train_rl.train_mixture(jax.random.PRNGKey(43), cfgs, rln)
    return qp, qpn


def bench_lifecycle_scenario(
    name: str,
    trials: int = 3,
    n_pods: Optional[int] = None,
    train_episodes: int = 120,
) -> List[Tuple[str, float, float]]:
    """Rows for one churn scenario under every policy.

    The headline ``lifecycle_<scenario>_<policy>`` row carries the
    time-averaged active-node count in ``derived`` (what ``check_smoke
    --lifecycle`` gates as the sdqnn/kube ratio); the ``_energy_wh`` /
    ``_avg_cpu`` / ``_retired`` companions are informational.
    """
    env_cfg = scenarios.make_env(name)
    qp, qpn = lifecycle_policies(train_episodes)
    n = n_pods or env_cfg.scenario.n_pods
    rows = []
    for policy in POLICIES:
        cfg, consolidate = env_cfg, None
        if policy == "kube":
            sel = schedulers.make_kube_selector(cfg)
        elif policy == "sdqn":
            sel = schedulers.make_sdqn_selector(qp, cfg)
        else:  # sdqnn: consolidation-trained net + the in-episode green pass
            cfg = dataclasses.replace(env_cfg,
                                      consolidate_every_s=CONSOLIDATE_EVERY_S)
            sel = schedulers.make_sdqn_selector(qpn, cfg)
            consolidate = elastic.make_consolidator(qpn, cfg)
        ep = eval_engine.make_batch_episode(cfg, sel, n, consolidate)
        keys = eval_engine.trial_keys(jax.random.PRNGKey(100), trials)
        jax.block_until_ready(ep(keys))  # compile outside the timing window
        t0 = time.time()
        res = jax.block_until_ready(ep(keys))
        us = (time.time() - t0) / trials * 1e6
        s = eval_engine.summarize(res)
        rows += [
            (f"lifecycle_{name}_{policy}", us, s["nodes_active_mean"]),
            (f"lifecycle_{name}_{policy}_energy_wh", 0.0, s["energy_wh_mean"]),
            (f"lifecycle_{name}_{policy}_avg_cpu", 0.0, s["metric_mean"]),
            (f"lifecycle_{name}_{policy}_retired", 0.0, s["retired_mean"]),
        ]
        print(f"  {name:22s} {policy:5s}  nodes_active={s['nodes_active_mean']:5.2f}"
              f"  energy={s['energy_wh_mean']:7.2f}Wh"
              f"  avg_cpu={s['metric_mean']:6.2f}%"
              f"  retired={s['retired_mean']:.0f}  dropped={s['dropped_mean']:.1f}")
    return rows


@functools.lru_cache(maxsize=None)
def pareto_policy(energy_weight: float, train_episodes: int = 120):
    """SDQN-n Q-net trained across the churn mixture at one energy_weight
    (cached per weight; the 15.0 point reuses the lifecycle preset's net)."""
    if energy_weight == presets.SDQN_N_LIFECYCLE_PRESET.energy_weight:
        return lifecycle_policies(train_episodes)[1]
    cfgs = scenarios.training_mixture(presets.LIFECYCLE_MIX_NAMES)
    rln = dataclasses.replace(presets.SDQN_N_LIFECYCLE_PRESET,
                              episodes=train_episodes,
                              energy_weight=float(energy_weight))
    qpn, _ = train_rl.train_mixture(jax.random.PRNGKey(43), cfgs, rln)
    return qpn


def _pareto_eval(cfg, sel, consolidate, trials: int, n: int) -> dict:
    """One (scenario, policy) frontier point: summarized batched episodes."""
    ep = eval_engine.make_batch_episode(cfg, sel, n, consolidate)
    keys = eval_engine.trial_keys(jax.random.PRNGKey(100), trials)
    return eval_engine.summarize(jax.block_until_ready(ep(keys)))


def _wtag(w: float) -> str:
    return f"w{w:g}".replace(".", "p")


def _dominates_or_matches(a: dict, b: dict, tol: float = 0.02) -> bool:
    """Point ``a`` is no worse than ``b`` on ALL three Pareto axes
    (avg-CPU, energy, drops), with ``tol`` relative slack (plus half a pod
    of absolute slack on drops, which are small integers)."""
    return (a["metric_mean"] <= b["metric_mean"] * (1 + tol)
            and a["energy_wh_mean"] <= b["energy_wh_mean"] * (1 + tol)
            and a["dropped_mean"] <= b["dropped_mean"] * (1 + tol) + 0.5)


def pareto_rows(
    trials: int = 3,
    n_pods: Optional[int] = None,
    train_episodes: int = 120,
    energy_weights=PARETO_ENERGY_WEIGHTS,
) -> List[Tuple[str, float, float]]:
    """The green Pareto frontier: CPU vs energy vs drops per energy_weight.

    Per churn scenario, evaluates the kube baseline, the TOPSIS
    multi-objective baseline (``sched.topsis``, GreenPod-shaped), and one
    consolidation-trained SDQN-n per ``energy_weight`` — each point is
    (avg-CPU%, energy Wh, drops), emitted as ``pareto_<scenario>_<arm>_*``
    rows.  The gated row per scenario is ``pareto_<scenario>_sdqnn_dominates``:
    how many SDQN-n frontier points dominate-or-match the TOPSIS point on
    all three axes — the paper-level claim that the learned green policy is
    at least as good as a principled non-RL multi-objective scorer.
    """
    out: List[Tuple[str, float, float]] = []
    print("\n--- green Pareto frontier (avg-CPU% / energy Wh / drops) ---")
    for name in LIFECYCLE_SCENARIOS:
        env_cfg = scenarios.make_env(name)
        n = n_pods or env_cfg.scenario.n_pods
        points = {
            "kube": _pareto_eval(env_cfg, schedulers.make_kube_selector(env_cfg),
                                 None, trials, n),
            "topsis": _pareto_eval(env_cfg, topsis.make_topsis_selector(env_cfg),
                                   None, trials, n),
        }
        for w in energy_weights:
            qpn = pareto_policy(w, train_episodes)
            cfg = dataclasses.replace(env_cfg,
                                      consolidate_every_s=CONSOLIDATE_EVERY_S)
            points[f"sdqnn_{_wtag(w)}"] = _pareto_eval(
                cfg, schedulers.make_sdqn_selector(qpn, cfg),
                elastic.make_consolidator(qpn, cfg), trials, n)
        for arm, s in points.items():
            tag = f"pareto_{name}_{arm}"
            out += [
                (f"{tag}_cpu", 0.0, s["metric_mean"]),
                (f"{tag}_energy_wh", 0.0, s["energy_wh_mean"]),
                (f"{tag}_dropped", 0.0, s["dropped_mean"]),
            ]
            print(f"  {name:22s} {arm:12s}  cpu={s['metric_mean']:6.2f}%"
                  f"  energy={s['energy_wh_mean']:7.2f}Wh"
                  f"  dropped={s['dropped_mean']:.1f}")
        dom = sum(1 for arm, s in points.items()
                  if arm.startswith("sdqnn_")
                  and _dominates_or_matches(s, points["topsis"]))
        out.append((f"pareto_{name}_sdqnn_dominates", 0.0, float(dom)))
        print(f"  {name:22s} sdqnn dominates/matches topsis on {dom} of "
              f"{len(energy_weights)} frontier points")
    return out


def pareto_smoke_rows(
    trials: int = 2,
    n_pods: int = 40,
    train_episodes: int = 48,
) -> List[Tuple[str, float, float]]:
    """CI-sized Pareto sweep — the sizing ``baseline_pareto.json`` was
    committed with (three energy weights).  48 training episodes is the
    smoke floor where the green nets actually reach the TOPSIS frontier on
    longrun-train-mix (at 16 the undertrained policies tie it on energy but
    trail on CPU and the per-scenario dominates gate has no headroom)."""
    return pareto_rows(trials=trials, n_pods=n_pods,
                       train_episodes=train_episodes,
                       energy_weights=PARETO_SMOKE_WEIGHTS)


def episode_throughput(trials: int = 16) -> List[Tuple[str, float, float]]:
    """Lifecycle-episode throughput: batched churn episodes per second.

    The ledger scatter-adds run inside the scanned loop, so this row guards
    against the lifecycle machinery de-optimizing the episode hot path
    (gated as a conservative floor by ``check_smoke --throughput-row``).
    """
    cfg = scenarios.make_env("short-job-burst")
    sel = schedulers.make_kube_selector(cfg)
    n = cfg.scenario.n_pods
    ep = eval_engine.make_batch_episode(cfg, sel, n)
    keys = eval_engine.trial_keys(jax.random.PRNGKey(0), trials)
    jax.block_until_ready(ep(keys))
    t0 = time.time()
    for _ in range(3):
        out = ep(keys)
    jax.block_until_ready(out)
    dt = (time.time() - t0) / 3
    return [("lifecycle_episode_throughput", dt / trials * 1e6, trials / dt)]


def rows(
    trials: int = 3,
    n_pods: Optional[int] = None,
    train_episodes: int = 120,
) -> List[Tuple[str, float, float]]:
    """The full lifecycle sweep: every churn scenario + the throughput row."""
    out = []
    print("\n--- lifecycle sweep (time-averaged active nodes, lower = greener) ---")
    for name in LIFECYCLE_SCENARIOS:
        out += bench_lifecycle_scenario(name, trials=trials, n_pods=n_pods,
                                        train_episodes=train_episodes)
    out += episode_throughput()
    return out


def smoke_rows(
    trials: int = 2,
    n_pods: int = 40,
    train_episodes: int = 16,
) -> List[Tuple[str, float, float]]:
    """CI-sized lifecycle bench — the sizing ``baseline_lifecycle.json`` was
    committed with; keep the two in sync or the gate compares apples to
    oranges."""
    return rows(trials=trials, n_pods=n_pods, train_episodes=train_episodes)
