"""Lifecycle benchmark: the paper's green-consolidation story, over time.

Runs the churn scenarios (finite pod lifetimes) under the default
kube-scheduler, a churn-mixture-trained SDQN, and SDQN-n with the in-episode
consolidation pass, and reports the time-resolved metrics the static bursts
cannot measure: time-averaged active nodes, node-seconds, and energy billed
to the workload.  SDQN-n consolidating onto fewer nodes — so idle nodes
appear and can be powered down — is the paper's §1 contribution 2 / §6
claim; ``BENCH_lifecycle.json`` is its regression record.

    PYTHONPATH=src python -m benchmarks.run --lifecycle          # full
    PYTHONPATH=src python -m benchmarks.run --lifecycle-smoke    # CI-sized
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Tuple

import jax

from repro import scenarios
from repro.core import presets, schedulers, train_rl
from repro.eval import engine as eval_engine
from repro.sched import elastic

LIFECYCLE_SCENARIOS = presets.LIFECYCLE_MIX_NAMES
CONSOLIDATE_EVERY_S = 30.0
POLICIES = ("kube", "sdqn", "sdqnn")


@functools.lru_cache(maxsize=None)
def lifecycle_policies(train_episodes: int = 120):
    """(sdqn, sdqn_n) Q-nets trained across the churn mixture (cached).

    SDQN-n trains with the Table-5 consolidation reward plus the
    energy/node-count term — the policy the consolidation pass reuses.
    """
    cfgs = scenarios.training_mixture(presets.LIFECYCLE_MIX_NAMES)
    rl = dataclasses.replace(presets.SDQN_LIFECYCLE_PRESET, episodes=train_episodes)
    rln = dataclasses.replace(presets.SDQN_N_LIFECYCLE_PRESET, episodes=train_episodes)
    qp, _ = train_rl.train_mixture(jax.random.PRNGKey(42), cfgs, rl)
    qpn, _ = train_rl.train_mixture(jax.random.PRNGKey(43), cfgs, rln)
    return qp, qpn


def bench_lifecycle_scenario(
    name: str,
    trials: int = 3,
    n_pods: Optional[int] = None,
    train_episodes: int = 120,
) -> List[Tuple[str, float, float]]:
    """Rows for one churn scenario under every policy.

    The headline ``lifecycle_<scenario>_<policy>`` row carries the
    time-averaged active-node count in ``derived`` (what ``check_smoke
    --lifecycle`` gates as the sdqnn/kube ratio); the ``_energy_wh`` /
    ``_avg_cpu`` / ``_retired`` companions are informational.
    """
    env_cfg = scenarios.make_env(name)
    qp, qpn = lifecycle_policies(train_episodes)
    n = n_pods or env_cfg.scenario.n_pods
    rows = []
    for policy in POLICIES:
        cfg, consolidate = env_cfg, None
        if policy == "kube":
            sel = schedulers.make_kube_selector(cfg)
        elif policy == "sdqn":
            sel = schedulers.make_sdqn_selector(qp, cfg)
        else:  # sdqnn: consolidation-trained net + the in-episode green pass
            cfg = dataclasses.replace(env_cfg,
                                      consolidate_every_s=CONSOLIDATE_EVERY_S)
            sel = schedulers.make_sdqn_selector(qpn, cfg)
            consolidate = elastic.make_consolidator(qpn, cfg)
        ep = eval_engine.make_batch_episode(cfg, sel, n, consolidate)
        keys = eval_engine.trial_keys(jax.random.PRNGKey(100), trials)
        jax.block_until_ready(ep(keys))  # compile outside the timing window
        t0 = time.time()
        res = jax.block_until_ready(ep(keys))
        us = (time.time() - t0) / trials * 1e6
        s = eval_engine.summarize(res)
        rows += [
            (f"lifecycle_{name}_{policy}", us, s["nodes_active_mean"]),
            (f"lifecycle_{name}_{policy}_energy_wh", 0.0, s["energy_wh_mean"]),
            (f"lifecycle_{name}_{policy}_avg_cpu", 0.0, s["metric_mean"]),
            (f"lifecycle_{name}_{policy}_retired", 0.0, s["retired_mean"]),
        ]
        print(f"  {name:22s} {policy:5s}  nodes_active={s['nodes_active_mean']:5.2f}"
              f"  energy={s['energy_wh_mean']:7.2f}Wh"
              f"  avg_cpu={s['metric_mean']:6.2f}%"
              f"  retired={s['retired_mean']:.0f}  dropped={s['dropped_mean']:.1f}")
    return rows


def episode_throughput(trials: int = 16) -> List[Tuple[str, float, float]]:
    """Lifecycle-episode throughput: batched churn episodes per second.

    The ledger scatter-adds run inside the scanned loop, so this row guards
    against the lifecycle machinery de-optimizing the episode hot path
    (gated as a conservative floor by ``check_smoke --throughput-row``).
    """
    cfg = scenarios.make_env("short-job-burst")
    sel = schedulers.make_kube_selector(cfg)
    n = cfg.scenario.n_pods
    ep = eval_engine.make_batch_episode(cfg, sel, n)
    keys = eval_engine.trial_keys(jax.random.PRNGKey(0), trials)
    jax.block_until_ready(ep(keys))
    t0 = time.time()
    for _ in range(3):
        out = ep(keys)
    jax.block_until_ready(out)
    dt = (time.time() - t0) / 3
    return [("lifecycle_episode_throughput", dt / trials * 1e6, trials / dt)]


def rows(
    trials: int = 3,
    n_pods: Optional[int] = None,
    train_episodes: int = 120,
) -> List[Tuple[str, float, float]]:
    """The full lifecycle sweep: every churn scenario + the throughput row."""
    out = []
    print("\n--- lifecycle sweep (time-averaged active nodes, lower = greener) ---")
    for name in LIFECYCLE_SCENARIOS:
        out += bench_lifecycle_scenario(name, trials=trials, n_pods=n_pods,
                                        train_episodes=train_episodes)
    out += episode_throughput()
    return out


def smoke_rows(
    trials: int = 2,
    n_pods: int = 40,
    train_episodes: int = 16,
) -> List[Tuple[str, float, float]]:
    """CI-sized lifecycle bench — the sizing ``baseline_lifecycle.json`` was
    committed with; keep the two in sync or the gate compares apples to
    oranges."""
    return rows(trials=trials, n_pods=n_pods, train_episodes=train_episodes)
