"""CI gate: fail when a scheduler-vs-kube avg-CPU row regresses vs baseline.

    PYTHONPATH=src python -m benchmarks.check_smoke bench-smoke.json \
        benchmarks/baseline_smoke.json [--tolerance 0.10]

For every scenario present in both runs, compares the sdqn/kube ratio of the
avg-CPU metric (``derived`` column of the ``scenario_<name>_<policy>`` rows).
The ratio — not the absolute percentage — is gated, so container-speed noise
and calibration drift cancel out; what must not regress is *how much better
than the default scheduler* the learned policy stays.  A current ratio more
than ``tolerance`` (default 10%) above the committed baseline ratio fails.
Timing columns are informational only (CI machines vary too much to gate).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple


def scenario_ratios(rows) -> Dict[str, Tuple[float, float, float]]:
    """{scenario: (kube_cpu, sdqn_cpu, sdqn/kube)} from benchmark rows."""
    metric: Dict[Tuple[str, str], float] = {}
    for row in rows:
        name = row["name"]
        if not name.startswith("scenario_"):
            continue
        scenario, _, policy = name[len("scenario_"):].rpartition("_")
        metric[(scenario, policy)] = float(row["derived"])
    out = {}
    for (scenario, policy), kube_cpu in metric.items():
        if policy != "kube":
            continue
        sdqn_cpu = metric.get((scenario, "sdqn"))
        if sdqn_cpu is None or kube_cpu <= 0.0:
            continue
        out[scenario] = (kube_cpu, sdqn_cpu, sdqn_cpu / kube_cpu)
    return out


def compare(current: dict, baseline: dict, tolerance: float) -> int:
    cur = scenario_ratios(current["rows"])
    base = scenario_ratios(baseline["rows"])
    if not base:
        print("check_smoke: baseline has no scenario rows", file=sys.stderr)
        return 2
    failures = []
    print(f"{'scenario':20s} {'base sdqn/kube':>14s} {'cur sdqn/kube':>14s}  verdict")
    for scenario, (_, _, base_ratio) in sorted(base.items()):
        if scenario not in cur:
            failures.append(f"{scenario}: missing from current run")
            print(f"{scenario:20s} {base_ratio:14.3f} {'MISSING':>14s}  FAIL")
            continue
        ratio = cur[scenario][2]
        ok = ratio <= base_ratio * (1.0 + tolerance)
        print(f"{scenario:20s} {base_ratio:14.3f} {ratio:14.3f}  "
              f"{'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"{scenario}: sdqn/kube {ratio:.3f} vs baseline "
                f"{base_ratio:.3f} (> +{tolerance:.0%})")
    if failures:
        print("\nREGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nall {len(base)} scenario ratios within +{tolerance:.0%} of baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="JSON from benchmarks.run --smoke --json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression of sdqn/kube (default 0.10)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    return compare(current, baseline, args.tolerance)


if __name__ == "__main__":
    raise SystemExit(main())
