"""CI gate: fail when a scheduler-vs-kube avg-CPU row regresses vs baseline.

    PYTHONPATH=src python -m benchmarks.check_smoke bench-smoke.json \
        benchmarks/baseline_smoke.json [--tolerance 0.10]
    PYTHONPATH=src python -m benchmarks.check_smoke BENCH_sched_scale.json \
        benchmarks/baseline_sched_scale.json \
        --throughput-row sdqn_train_ondevice [--throughput-tolerance 0.25]

For every scenario present in both runs, compares the sdqn/kube ratio of the
avg-CPU metric (``derived`` column of the ``scenario_<name>_<policy>`` rows).
The ratio — not the absolute percentage — is gated, so container-speed noise
and calibration drift cancel out; what must not regress is *how much better
than the default scheduler* the learned policy stays.  A current ratio more
than ``tolerance`` (default 10%) above the committed baseline ratio fails.

``--throughput-row NAME`` (repeatable) additionally gates that row's
``derived`` column (a rate: transitions/s, nodes/s, ...) against the same
row in the baseline: current below ``baseline * (1 - throughput_tolerance)``
fails.  The committed throughput baselines are deliberately conservative
floors — the gate exists to catch order-of-magnitude regressions (a de-jitted
hot loop, a silent fallback to per-step dispatch), not CI-machine jitter.
Other timing columns stay informational only.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple


def scenario_ratios(rows) -> Dict[str, Tuple[float, float, float]]:
    """{scenario: (kube_cpu, sdqn_cpu, sdqn/kube)} from benchmark rows."""
    metric: Dict[Tuple[str, str], float] = {}
    for row in rows:
        name = row["name"]
        if not name.startswith("scenario_"):
            continue
        scenario, _, policy = name[len("scenario_"):].rpartition("_")
        metric[(scenario, policy)] = float(row["derived"])
    out = {}
    for (scenario, policy), kube_cpu in metric.items():
        if policy != "kube":
            continue
        sdqn_cpu = metric.get((scenario, "sdqn"))
        if sdqn_cpu is None or kube_cpu <= 0.0:
            continue
        out[scenario] = (kube_cpu, sdqn_cpu, sdqn_cpu / kube_cpu)
    return out


def _row_map(rows) -> Dict[str, float]:
    return {row["name"]: float(row["derived"]) for row in rows}


def compare(current: dict, baseline: dict, tolerance: float,
            throughput_rows=(), throughput_tolerance: float = 0.25) -> int:
    cur = scenario_ratios(current["rows"])
    base = scenario_ratios(baseline["rows"])
    if not base and not throughput_rows:
        print("check_smoke: baseline has no scenario rows", file=sys.stderr)
        return 2
    failures = []
    if base:
        print(f"{'scenario':20s} {'base sdqn/kube':>14s} {'cur sdqn/kube':>14s}  verdict")
    for scenario, (_, _, base_ratio) in sorted(base.items()):
        if scenario not in cur:
            failures.append(f"{scenario}: missing from current run")
            print(f"{scenario:20s} {base_ratio:14.3f} {'MISSING':>14s}  FAIL")
            continue
        ratio = cur[scenario][2]
        ok = ratio <= base_ratio * (1.0 + tolerance)
        print(f"{scenario:20s} {base_ratio:14.3f} {ratio:14.3f}  "
              f"{'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"{scenario}: sdqn/kube {ratio:.3f} vs baseline "
                f"{base_ratio:.3f} (> +{tolerance:.0%})")

    if throughput_rows:
        cur_rows, base_rows = _row_map(current["rows"]), _row_map(baseline["rows"])
        # %g keeps small ratios readable (seed_parallel_speedup ~ 0.9-4) and
        # large rates compact (transitions/s ~ 1e5) in the same column
        print(f"{'throughput row':28s} {'baseline':>12s} {'current':>12s}  verdict")
        for name in throughput_rows:
            if name not in base_rows:
                failures.append(f"{name}: missing from committed baseline")
                print(f"{name:28s} {'MISSING':>12s} {'-':>12s}  FAIL")
                continue
            if name not in cur_rows:
                failures.append(f"{name}: missing from current run")
                print(f"{name:28s} {base_rows[name]:12g} {'MISSING':>12s}  FAIL")
                continue
            floor = base_rows[name] * (1.0 - throughput_tolerance)
            ok = cur_rows[name] >= floor
            print(f"{name:28s} {base_rows[name]:12g} {cur_rows[name]:12.6g}  "
                  f"{'ok' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"{name}: {cur_rows[name]:g} vs baseline "
                    f"{base_rows[name]:g} (> -{throughput_tolerance:.0%})")

    if failures:
        print("\nREGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    gated = []
    if base:
        gated.append(f"{len(base)} scenario ratios within +{tolerance:.0%}")
    if throughput_rows:
        gated.append(f"{len(throughput_rows)} throughput rows within "
                     f"-{throughput_tolerance:.0%}")
    print(f"\nall {' and '.join(gated)} of baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="JSON from benchmarks.run --smoke --json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression of sdqn/kube (default 0.10)")
    ap.add_argument("--throughput-row", action="append", default=[],
                    metavar="NAME",
                    help="also gate this row's derived rate against the "
                         "baseline (repeatable), e.g. sdqn_train_ondevice")
    ap.add_argument("--throughput-tolerance", type=float, default=0.25,
                    help="allowed relative throughput regression (default 0.25)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    return compare(current, baseline, args.tolerance,
                   throughput_rows=args.throughput_row,
                   throughput_tolerance=args.throughput_tolerance)


if __name__ == "__main__":
    raise SystemExit(main())
