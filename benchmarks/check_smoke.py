"""CI gate: fail when a scheduler-vs-kube benchmark row regresses vs baseline.

    PYTHONPATH=src python -m benchmarks.check_smoke bench-smoke.json \
        benchmarks/baseline_smoke.json [--tolerance 0.10]
    PYTHONPATH=src python -m benchmarks.check_smoke BENCH_sched_scale.json \
        benchmarks/baseline_sched_scale.json \
        --throughput-row sdqn_train_ondevice [--throughput-tolerance 0.25]
    PYTHONPATH=src python -m benchmarks.check_smoke BENCH_lifecycle.json \
        benchmarks/baseline_lifecycle.json --lifecycle \
        --throughput-row lifecycle_episode_throughput
    PYTHONPATH=src python -m benchmarks.check_smoke \
        --manifest benchmarks/gates.json          # gate EVERY smoke suite

``--manifest`` is how CI runs this: benchmarks/gates.json names every
suite's run flag, committed baseline, and gated rows in one place; the
workflow runs ``benchmarks.run --manifest`` once, uploads the
``BENCH_*.json`` artifacts, and gates them all with one call here, instead
of maintaining a run→upload→gate step triplet per suite.

For every scenario present in both runs, compares the sdqn/kube ratio of the
avg-CPU metric (``derived`` column of the ``scenario_<name>_<policy>`` rows).
The ratio — not the absolute percentage — is gated, so container-speed noise
and calibration drift cancel out; what must not regress is *how much better
than the default scheduler* the learned policy stays.  A current ratio more
than ``tolerance`` (default 10%) above the committed baseline ratio fails.

``--lifecycle`` additionally gates the green-consolidation story: for every
``lifecycle_<scenario>_<policy>`` headline row (``derived`` = time-averaged
active nodes), the sdqnn/kube ratio must stay within ``tolerance`` of the
committed baseline ratio — SDQN-n keeping fewer nodes awake than the default
scheduler is the paper's §6 claim, and this is its regression gate.

``--policy-compare`` gates the policy-class registry story: for every
``policy_compare_<scenario>_<class>`` row (``derived`` = avg-CPU) each
registered class's <class>/kube ratio must stay within ``tolerance`` of the
committed baseline — no policy class silently stops beating the default
scheduler.  Pair it with ``--throughput-row policy_train_step_<class>`` to
also floor each class's learner-step rate.

``--chaos`` gates the fault-tolerance story: every ``chaos_*_lost_ratio``
row (``benchmarks.run --chaos-smoke``) must stay within ``chaos_slack`` of
the committed baseline — *absolute* slack, because a calm cell's baseline
lost ratio is legitimately 0.0 and a relative tolerance would degenerate to
an exact-zero gate.  Pair it with ``--throughput-row
chaos_degraded_throughput`` to also floor the degraded-mode (kube-heuristic)
serving rate.

``--throughput-row NAME`` (repeatable) additionally gates that row's
``derived`` column (a rate: transitions/s, episodes/s, ...) against the same
row in the baseline: current below ``baseline * (1 - throughput_tolerance)``
fails.  The committed throughput baselines are deliberately conservative
floors — the gate exists to catch order-of-magnitude regressions (a de-jitted
hot loop, a silent fallback to per-step dispatch), not CI-machine jitter.
Other timing columns stay informational only.

``--latency-row NAME`` (repeatable) is the mirror-image *ceiling* gate for
rows whose ``derived`` is a latency (placement_serve p99 ms, ...): current
above ``baseline * (1 + latency_tolerance)`` fails.  Same philosophy —
committed ceilings are generous; the gate catches a de-batched serving loop
or a per-bind device launch, not scheduler jitter.

Every gated row prints measured vs baseline vs the allowed threshold, pass or
fail, so a red CI log is diagnosable without downloading the artifacts.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

LIFECYCLE_POLICIES = ("kube", "sdqn", "sdqnn")
POLICY_CLASSES = ("kube", "mlp", "attention", "mamba")


def _policy_ratios(rows, prefix: str, baseline_policy: str,
                   policy: str, policies) -> Dict[str, Tuple[float, float, float]]:
    """{scenario: (baseline_policy_val, policy_val, ratio)} from bench rows."""
    metric: Dict[Tuple[str, str], float] = {}
    for row in rows:
        name = row["name"]
        if not name.startswith(prefix):
            continue
        scenario, _, pol = name[len(prefix):].rpartition("_")
        if pol not in policies:
            continue  # companion rows (_energy_wh, _avg_cpu, ...) and others
        metric[(scenario, pol)] = float(row["derived"])
    out = {}
    for (scenario, pol), denom in metric.items():
        if pol != baseline_policy:
            continue
        num = metric.get((scenario, policy))
        if num is None or denom <= 0.0:
            continue
        out[scenario] = (denom, num, num / denom)
    return out


def scenario_ratios(rows) -> Dict[str, Tuple[float, float, float]]:
    """{scenario: (kube_cpu, sdqn_cpu, sdqn/kube)} from smoke benchmark rows."""
    return _policy_ratios(rows, "scenario_", "kube", "sdqn", ("kube", "sdqn"))


def lifecycle_ratios(rows) -> Dict[str, Tuple[float, float, float]]:
    """{scenario: (kube_nodes_active, sdqnn_nodes_active, ratio)}."""
    return _policy_ratios(rows, "lifecycle_", "kube", "sdqnn", LIFECYCLE_POLICIES)


def policy_class_ratios(rows, policy: str) -> Dict[str, Tuple[float, float, float]]:
    """{scenario: (kube_cpu, <class>_cpu, ratio)} from policy_compare rows."""
    return _policy_ratios(rows, "policy_compare_", "kube", policy,
                          POLICY_CLASSES)


def _row_map(rows) -> Dict[str, float]:
    return {row["name"]: float(row["derived"]) for row in rows}


def chaos_lost_rows(rows) -> Dict[str, float]:
    """{row_name: lost_ratio} for every ``chaos_*_lost_ratio`` bench row."""
    return {row["name"]: float(row["derived"]) for row in rows
            if row["name"].startswith("chaos_")
            and row["name"].endswith("_lost_ratio")}


def _gate_chaos(cur_rows, base_rows, slack: float,
                failures: List[str]) -> int:
    """Gate lost-pod ratios with ABSOLUTE slack: current must stay within
    ``baseline + slack``.  Absolute, not relative — the calm cells' baseline
    ratio is legitimately 0.0, where any relative tolerance degenerates to
    an exact-zero requirement."""
    cur, base = chaos_lost_rows(cur_rows), chaos_lost_rows(base_rows)
    print(f"{'chaos lost-ratio row':36s} {'baseline':>10s} {'current':>10s} "
          f"{'allowed':>10s}  verdict")
    for name, base_ratio in sorted(base.items()):
        allowed = base_ratio + slack
        if name not in cur:
            failures.append(f"{name}: missing from current run")
            print(f"{name:36s} {base_ratio:10.3f} {'MISSING':>10s} "
                  f"{allowed:10.3f}  FAIL")
            continue
        ok = cur[name] <= allowed
        print(f"{name:36s} {base_ratio:10.3f} {cur[name]:10.3f} "
              f"{allowed:10.3f}  {'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"{name}: lost ratio {cur[name]:.3f} vs baseline "
                f"{base_ratio:.3f} (allowed <= {allowed:.3f})")
    return len(base)


def _gate_ratios(label: str, cur: dict, base: dict, tolerance: float,
                 failures: List[str]) -> None:
    """Print the per-scenario ratio table (measured vs baseline vs allowed)."""
    print(f"{label:24s} {'baseline':>10s} {'current':>10s} {'allowed':>10s}  verdict")
    for scenario, (_, _, base_ratio) in sorted(base.items()):
        allowed = base_ratio * (1.0 + tolerance)
        if scenario not in cur:
            failures.append(f"{label} {scenario}: missing from current run")
            print(f"{scenario:24s} {base_ratio:10.3f} {'MISSING':>10s} "
                  f"{allowed:10.3f}  FAIL")
            continue
        ratio = cur[scenario][2]
        ok = ratio <= allowed
        print(f"{scenario:24s} {base_ratio:10.3f} {ratio:10.3f} {allowed:10.3f}  "
              f"{'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"{label} {scenario}: ratio {ratio:.3f} vs baseline "
                f"{base_ratio:.3f} (allowed <= {allowed:.3f})")


def compare(current: dict, baseline: dict, tolerance: float,
            throughput_rows=(), throughput_tolerance: float = 0.25,
            latency_rows=(), latency_tolerance: float = 1.0,
            lifecycle: bool = False, policy_compare: bool = False,
            chaos: bool = False, chaos_slack: float = 0.10) -> int:
    cur = scenario_ratios(current["rows"])
    base = scenario_ratios(baseline["rows"])
    cur_life = lifecycle_ratios(current["rows"]) if lifecycle else {}
    base_life = lifecycle_ratios(baseline["rows"]) if lifecycle else {}
    pol_classes = [p for p in POLICY_CLASSES if p != "kube"] if policy_compare else []
    base_pol = {p: policy_class_ratios(baseline["rows"], p) for p in pol_classes}
    base_chaos = chaos_lost_rows(baseline["rows"]) if chaos else {}
    if (not base and not throughput_rows and not latency_rows and not base_life
            and not any(base_pol.values()) and not base_chaos):
        print("check_smoke: baseline has no gated rows", file=sys.stderr)
        return 2
    failures: List[str] = []
    n_chaos = 0
    if chaos:
        if not base_chaos:
            failures.append("chaos: baseline has no chaos_*_lost_ratio rows")
        else:
            n_chaos = _gate_chaos(current["rows"], baseline["rows"],
                                  chaos_slack, failures)
    if base:
        _gate_ratios("sdqn/kube avg-CPU", cur, base, tolerance, failures)
    if lifecycle:
        if not base_life:
            failures.append("lifecycle: baseline has no lifecycle rows")
        else:
            _gate_ratios("sdqnn/kube nodes-active", cur_life, base_life,
                         tolerance, failures)
    if policy_compare:
        if not any(base_pol.values()):
            failures.append("policy-compare: baseline has no policy_compare rows")
        for pol in pol_classes:
            if not base_pol[pol]:
                failures.append(
                    f"policy-compare: baseline has no {pol} rows")
                continue
            _gate_ratios(f"{pol}/kube avg-CPU",
                         policy_class_ratios(current["rows"], pol),
                         base_pol[pol], tolerance, failures)

    if throughput_rows:
        cur_rows, base_rows = _row_map(current["rows"]), _row_map(baseline["rows"])
        # %g keeps small ratios readable (seed_parallel_speedup ~ 0.9-4) and
        # large rates compact (transitions/s ~ 1e5) in the same column
        print(f"{'throughput row':28s} {'baseline':>12s} {'current':>12s} "
              f"{'floor':>12s}  verdict")
        for name in throughput_rows:
            if name not in base_rows:
                failures.append(f"{name}: missing from committed baseline")
                print(f"{name:28s} {'MISSING':>12s} {'-':>12s} {'-':>12s}  FAIL")
                continue
            floor = base_rows[name] * (1.0 - throughput_tolerance)
            if name not in cur_rows:
                failures.append(f"{name}: missing from current run")
                print(f"{name:28s} {base_rows[name]:12g} {'MISSING':>12s} "
                      f"{floor:12.6g}  FAIL")
                continue
            ok = cur_rows[name] >= floor
            print(f"{name:28s} {base_rows[name]:12g} {cur_rows[name]:12.6g} "
                  f"{floor:12.6g}  {'ok' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"{name}: {cur_rows[name]:g} vs baseline "
                    f"{base_rows[name]:g} (floor {floor:g})")

    if latency_rows:
        cur_rows, base_rows = _row_map(current["rows"]), _row_map(baseline["rows"])
        print(f"{'latency row':28s} {'baseline':>12s} {'current':>12s} "
              f"{'ceiling':>12s}  verdict")
        for name in latency_rows:
            if name not in base_rows:
                failures.append(f"{name}: missing from committed baseline")
                print(f"{name:28s} {'MISSING':>12s} {'-':>12s} {'-':>12s}  FAIL")
                continue
            ceiling = base_rows[name] * (1.0 + latency_tolerance)
            if name not in cur_rows:
                failures.append(f"{name}: missing from current run")
                print(f"{name:28s} {base_rows[name]:12g} {'MISSING':>12s} "
                      f"{ceiling:12.6g}  FAIL")
                continue
            ok = cur_rows[name] <= ceiling
            print(f"{name:28s} {base_rows[name]:12g} {cur_rows[name]:12.6g} "
                  f"{ceiling:12.6g}  {'ok' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"{name}: {cur_rows[name]:g} vs baseline "
                    f"{base_rows[name]:g} (ceiling {ceiling:g})")

    if failures:
        print("\nREGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    gated = []
    if base:
        gated.append(f"{len(base)} scenario ratios within +{tolerance:.0%}")
    if lifecycle and base_life:
        gated.append(f"{len(base_life)} lifecycle nodes-active ratios within "
                     f"+{tolerance:.0%}")
    if policy_compare:
        n_pol = sum(len(v) for v in base_pol.values())
        gated.append(f"{n_pol} policy-class avg-CPU ratios within "
                     f"+{tolerance:.0%}")
    if chaos and n_chaos:
        gated.append(f"{n_chaos} chaos lost-pod ratios within "
                     f"+{chaos_slack:.2f} absolute")
    if throughput_rows:
        gated.append(f"{len(throughput_rows)} throughput rows within "
                     f"-{throughput_tolerance:.0%}")
    if latency_rows:
        gated.append(f"{len(latency_rows)} latency rows within "
                     f"+{latency_tolerance:.0%}")
    print(f"\nall {' and '.join(gated)} of baseline")
    return 0


def check_manifest(path: str, bench_dir: str = ".",
                   only: str = None) -> int:
    """Gate every suite of a gates manifest (benchmarks/gates.json).

    For each manifest suite, loads ``<bench_dir>/BENCH_<name>.json`` (the
    file ``benchmarks.run --manifest`` wrote) and the suite's committed
    baseline, then runs :func:`compare` with the suite's gating fields —
    the manifest is the ONE place a suite's run flag, baseline file, and
    gated rows live, instead of six copy-pasted run→upload→gate step
    triplets in the workflow.  ``only`` restricts to a single suite.
    Returns 1 if any suite regressed (or a bench/baseline file is
    missing), else 0.
    """
    import os

    with open(path) as f:
        manifest = json.load(f)
    suites = [s for s in manifest["suites"]
              if only is None or s["name"] == only]
    if only is not None and not suites:
        print(f"check_smoke: no suite named {only!r} in {path}",
              file=sys.stderr)
        return 2
    failed = []
    for suite in suites:
        name = suite["name"]
        bench = os.path.join(bench_dir, f"BENCH_{name}.json")
        print(f"\n=== gate {name}: {bench} vs {suite['baseline']} ===")
        try:
            with open(bench) as f:
                current = json.load(f)
            with open(suite["baseline"]) as f:
                baseline = json.load(f)
        except OSError as e:
            print(f"check_smoke: {name}: {e}", file=sys.stderr)
            failed.append(name)
            continue
        rc = compare(current, baseline,
                     tolerance=suite.get("tolerance", 0.10),
                     throughput_rows=suite.get("throughput_rows", ()),
                     throughput_tolerance=suite.get("throughput_tolerance",
                                                    0.25),
                     latency_rows=suite.get("latency_rows", ()),
                     latency_tolerance=suite.get("latency_tolerance", 1.0),
                     lifecycle=suite.get("lifecycle", False),
                     policy_compare=suite.get("policy_compare", False),
                     chaos=suite.get("chaos", False),
                     chaos_slack=suite.get("chaos_slack", 0.10))
        if rc != 0:
            failed.append(name)
    if failed:
        print(f"\ncheck_smoke: {len(failed)}/{len(suites)} suites FAILED: "
              f"{', '.join(failed)}", file=sys.stderr)
        return 1
    print(f"\ncheck_smoke: all {len(suites)} manifest suites within baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?",
                    help="JSON from benchmarks.run --smoke --json "
                         "(omit with --manifest)")
    ap.add_argument("baseline", nargs="?", help="committed baseline JSON")
    ap.add_argument("--manifest", metavar="PATH",
                    help="gate every suite of a gates manifest "
                         "(benchmarks/gates.json) against its committed "
                         "baseline — replaces the positional current/baseline "
                         "pair")
    ap.add_argument("--suite", metavar="NAME",
                    help="with --manifest: gate only this suite")
    ap.add_argument("--bench-dir", default=".", metavar="DIR",
                    help="with --manifest: directory holding the "
                         "BENCH_<suite>.json files (default: cwd)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression of gated ratios "
                         "(default 0.10)")
    ap.add_argument("--lifecycle", action="store_true",
                    help="also gate the lifecycle sdqnn/kube nodes-active "
                         "ratios (BENCH_lifecycle.json runs)")
    ap.add_argument("--policy-compare", action="store_true",
                    help="also gate each policy class's <class>/kube avg-CPU "
                         "ratio (policy_compare_<scenario>_<class> rows from "
                         "benchmarks.run --policy-compare)")
    ap.add_argument("--chaos", action="store_true",
                    help="also gate every chaos_*_lost_ratio row with "
                         "ABSOLUTE slack (benchmarks.run --chaos-smoke runs; "
                         "pair with --throughput-row "
                         "chaos_degraded_throughput for the degraded-mode "
                         "serving floor)")
    ap.add_argument("--chaos-slack", type=float, default=0.10,
                    help="allowed absolute lost-ratio increase over baseline "
                         "(default 0.10 — calm cells have baseline 0.0, so "
                         "the slack must be absolute, not relative)")
    ap.add_argument("--throughput-row", action="append", default=[],
                    metavar="NAME",
                    help="also gate this row's derived rate against the "
                         "baseline (repeatable), e.g. sdqn_train_ondevice")
    ap.add_argument("--throughput-tolerance", type=float, default=0.25,
                    help="allowed relative throughput regression (default 0.25)")
    ap.add_argument("--latency-row", action="append", default=[],
                    metavar="NAME",
                    help="also gate this row's derived latency against the "
                         "baseline ceiling (repeatable), e.g. "
                         "placement_serve_rate500_p99_ms")
    ap.add_argument("--latency-tolerance", type=float, default=1.0,
                    help="allowed relative latency regression (default 1.0 — "
                         "p99 on a shared CI runner is noisy; the gate is for "
                         "order-of-magnitude blowups)")
    args = ap.parse_args(argv)
    if args.manifest:
        return check_manifest(args.manifest, bench_dir=args.bench_dir,
                              only=args.suite)
    if args.current is None or args.baseline is None:
        ap.error("current and baseline are required without --manifest")
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    return compare(current, baseline, args.tolerance,
                   throughput_rows=args.throughput_row,
                   throughput_tolerance=args.throughput_tolerance,
                   latency_rows=args.latency_row,
                   latency_tolerance=args.latency_tolerance,
                   lifecycle=args.lifecycle,
                   policy_compare=args.policy_compare,
                   chaos=args.chaos, chaos_slack=args.chaos_slack)


if __name__ == "__main__":
    raise SystemExit(main())
