"""Fleet-scale scoring benchmark: two-stage hierarchical sharded selection
over the cluster-of-clusters scenario family (4k -> 128k nodes).

    PYTHONPATH=src python -m benchmarks.run --fleet-scale

Each size builds the ``cluster-of-clusters-<label>`` fleet, plans a forced
8-shard ``FleetLayout`` (single-device two-stage execution — the same
reduction tree a device mesh would run, so the bench is meaningful on one
CPU), and times the jitted end-to-end decision: per-shard fused scoring
with in-kernel top-k, then the global candidate merge
(``sched.api.select(shard=layout, fused=True)``).  No full N-length score
vector is materialized at any size — the largest intermediate is
``shards × k`` candidates.

Rows (gated via benchmarks/gates.json):

  * ``fleet_scale_n<N>_score_throughput`` — ``derived`` = nodes scored per
    second (a floor gate: catches a de-fused or de-jitted scoring path);
  * ``fleet_scale_n<N>_decision_ms``    — ``derived`` = one placement
    decision's latency in ms (a ceiling gate).

The smallest size also asserts sharded-vs-flat selection parity, so the
committed baseline can never drift onto a layout that picks different nodes
than the reference argmax.
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import scenarios
from repro.core import dqn, env as kenv
from repro.launch.mesh import plan_fleet_layout
from repro.sched import api

SIZES = (4096, 16384, 65536, 131072)
_LABEL = {4096: "4k", 16384: "16k", 65536: "64k", 131072: "128k"}
SHARDS = 8
TOPK = 4


def _bench_size(n: int, repeats: int, check_parity: bool) -> List[Tuple[str, float, float]]:
    cfg = scenarios.make_env(f"cluster-of-clusters-{_LABEL[n]}")
    key = jax.random.PRNGKey(0)
    state = kenv.reset(key, cfg)
    pod = kenv.default_pod(cfg)
    params = dqn.init_qnet(key)
    layout = plan_fleet_layout(n, shards=SHARDS)
    assert layout is not None and layout.shards == SHARDS

    # fused=True forces the in-kernel top-k scoring path at every shard size
    # (the "auto" threshold is a dispatch-overhead heuristic, not a
    # correctness knob) — this bench exists to measure exactly that path
    select = jax.jit(lambda st: api.select(st, pod, params=params, cfg=cfg,
                                           shard=layout, fused=True))
    choice = int(jax.block_until_ready(select(state)))   # compile + warm

    if check_parity:
        flat = int(api.select(state, pod, params=params, cfg=cfg,
                              shard=False))
        assert choice == flat, (
            f"sharded selection diverged from flat argmax at n={n}: "
            f"{choice} != {flat}")

    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(select(state))
    dt = (time.perf_counter() - t0) / repeats
    us = dt * 1e6
    print(f"  n={n:7d} shards={SHARDS} k={TOPK}  decision={dt * 1e3:8.3f} ms"
          f"  scoring={n / dt:12.0f} nodes/s  (choice={choice})")
    return [
        (f"fleet_scale_n{n}_score_throughput", us, n / dt),
        (f"fleet_scale_n{n}_decision_ms", us, dt * 1e3),
    ]


def rows(sizes: Sequence[int] = SIZES,
         repeats: int = 10) -> List[Tuple[str, float, float]]:
    print("\n--- fleet-scale two-stage sharded scoring (4k -> 128k nodes) ---")
    out: List[Tuple[str, float, float]] = []
    for i, n in enumerate(sizes):
        out += _bench_size(n, repeats=repeats, check_parity=(i == 0))
    return out


# the CI smoke lane runs the full sweep: scoring-only decisions stay cheap
# even at 128k, and a gate that skips the largest size would miss the point
smoke_rows = rows
