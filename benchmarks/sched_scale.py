"""Fleet-scale scheduler benchmarks (beyond-paper: 1000+ nodes).

1. SDQN scoring throughput vs fleet size (the scheduler's hot loop) —
   XLA path vs the fused Pallas kernel in interpret mode (CPU container;
   on TPU the compiled kernel path is selected automatically).
2. Afterstate feature construction: the O(N) incremental scorer vs the
   vmap-of-place reference (O(N^2)) it replaced.
3. Fused afterstate *scoring*: features + Q-net in one pass
   (``ops.sdqn_score_afterstate``) vs the unfused
   ``hypothetical_place`` -> normalize -> ``qvalues`` chain.
4. Batched evaluation engine: 64 vmapped trials in one launch vs the
   per-trial Python dispatch loop it replaced.
5. End-to-end placement throughput (pods/s) on 1024-node clusters,
   homogeneous and heterogeneous (fleet-hetero scenario).
6. On-device RL training throughput (Anakin-style, transitions/s).
7. Seed-parallel training: `train_and_select`'s candidates as ONE vmapped,
   mesh-sharded launch vs the sequential Python seed loop it replaced.
   Runs in a child process with the host platform split into
   ``min(cpu_count, n_seeds)`` devices so the engine's seed-axis sharding
   is actually exercised on CPU; on a real accelerator mesh the same code
   shards over the ``data`` axis.
8. Joint seed×env sharding: the 2-D ``("seed", "data")`` layout vs pure
   seed sharding at ``n_seeds < n_devices`` (a force-split 4-device host,
   where seed-only sharding's ceiling is 2 busy devices at n_seeds=2 and
   the joint planner runs a (2, 2) grid over all 4).
9. Replay marginal cost: the fused-ring add + one-gather sample exactly as
   the training loop drives them — the residual per-seed cost the
   struct-of-arrays rework targets.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import dqn, env as kenv, schedulers, train_rl
from repro.core.types import fleet_cluster, paper_cluster, training_cluster
from repro.eval import engine as eval_engine
from repro.kernels import ops
from repro.scenarios import make_env


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def scoring_throughput() -> List[Tuple[str, float, float]]:
    rows = []
    params = dqn.init_qnet(jax.random.PRNGKey(0))
    score = jax.jit(lambda f: dqn.qvalues(params, f))
    for n in (1024, 16384, 131072):
        feats = jax.random.normal(jax.random.PRNGKey(1), (n, 6))
        dt = _time(score, feats)
        rows.append((f"sdqn_score_xla_n{n}", dt * 1e6, n / dt))
    return rows


def afterstate_throughput() -> List[Tuple[str, float, float]]:
    """The scoring hot path: O(N) incremental afterstates vs vmap reference.

    ``derived`` is nodes scored per second for the timed rows and the
    measured speedup for the summary rows.  The reference materializes N
    full cluster states per call, so it is only timed up to 2048 nodes.
    """
    rows = []
    pod = kenv.default_pod(fleet_cluster(4))
    fast_times = {}
    for n in (1024, 4096, 16384):
        cfg = fleet_cluster(n)
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        fast = jax.jit(lambda s, _cfg=cfg: kenv.hypothetical_place(s, pod, _cfg))
        dt = _time(fast, state)
        fast_times[n] = dt
        rows.append((f"afterstate_incremental_n{n}", dt * 1e6, n / dt))
    for n in (1024, 2048):
        cfg = fleet_cluster(n)
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        ref = jax.jit(lambda s, _cfg=cfg: kenv.hypothetical_place_reference(s, pod, _cfg))
        dt_ref = _time(ref, state, iters=5, warmup=2)
        rows.append((f"afterstate_vmap_ref_n{n}", dt_ref * 1e6, n / dt_ref))
        dt_fast = fast_times.get(n) or _time(
            jax.jit(lambda s, _cfg=cfg: kenv.hypothetical_place(s, pod, _cfg)), state)
        rows.append((f"afterstate_speedup_n{n}", 0.0, dt_ref / dt_fast))
    return rows


def fused_scoring() -> List[Tuple[str, float, float]]:
    """Fused in-kernel afterstate scoring vs the unfused jnp chain.

    The unfused baseline is ``schedulers.score_afterstates``'s small-N path
    (``hypothetical_place`` -> normalize -> ``qvalues``), jitted as one
    program; the fused path computes the features inside the scorer
    (Pallas on TPU, the fused-XLA twin on CPU — the interpret-safe
    fallback) without materializing the (N, 6) matrix.  ``derived`` is
    nodes/s for timed rows and measured speedup for summary rows.
    """
    rows = []
    params = dqn.init_qnet(jax.random.PRNGKey(0))
    mode = None if jax.default_backend() == "tpu" else "xla"
    for n in (4096, 16384, 131072):
        cfg = fleet_cluster(n)
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        pod = kenv.default_pod(cfg)
        unfused = jax.jit(lambda s, _cfg=cfg: ops.sdqn_score_afterstate(
            s, pod, _cfg, params, mode="ref"))
        fused = jax.jit(lambda s, _cfg=cfg: ops.sdqn_score_afterstate(
            s, pod, _cfg, params, mode=mode))
        dt_un = _time(unfused, state)
        dt_fu = _time(fused, state)
        rows.append((f"afterscore_unfused_n{n}", dt_un * 1e6, n / dt_un))
        rows.append((f"afterscore_fused_n{n}", dt_fu * 1e6, n / dt_fu))
        rows.append((f"afterscore_fused_speedup_n{n}", 0.0, dt_un / dt_fu))
    return rows


def eval_engine_speedup(trials: int = 64) -> List[Tuple[str, float, float]]:
    """Batched evaluation engine vs the per-trial Python dispatch loop.

    Same episodes (identical trial keys), same jitted episode body; the only
    difference is one vmapped launch vs ``trials`` sequential dispatches.
    ``derived`` is episodes/s for the timed rows, speedup for the summary.
    """
    cfg = paper_cluster()
    sel = schedulers.make_kube_selector(cfg)
    n_pods = 50
    keys = eval_engine.trial_keys(jax.random.PRNGKey(0), trials)

    loop_ep = jax.jit(lambda kk: kenv.run_episode(kk, cfg, sel, n_pods).metric)

    def loop(keys):
        return [loop_ep(keys[t]) for t in range(trials)]

    batch = eval_engine.make_batch_episode(cfg, sel, n_pods)
    dt_loop = _time(loop, keys, iters=3, warmup=1)
    dt_batch = _time(batch, keys, iters=3, warmup=1)
    return [
        (f"eval_loop_{trials}trials", dt_loop * 1e6, trials / dt_loop),
        (f"eval_batched_{trials}trials", dt_batch * 1e6, trials / dt_batch),
        (f"eval_engine_speedup_{trials}trials", 0.0, dt_loop / dt_batch),
    ]


def placement_throughput() -> List[Tuple[str, float, float]]:
    rows = []
    cfg = fleet_cluster(1024)
    qp = dqn.init_qnet(jax.random.PRNGKey(0))
    sel = schedulers.make_sdqn_selector(qp, cfg)
    n_pods = 200
    ep = jax.jit(lambda kk: kenv.run_episode(kk, cfg, sel, n_pods).metric)
    dt = _time(ep, jax.random.PRNGKey(0), iters=3, warmup=1)
    rows.append(("sdqn_place_1024node_ep", dt * 1e6, n_pods / dt))

    # heterogeneous 1024-node pool with a mixed Poisson stream
    hcfg = make_env("fleet-hetero")
    hsel = schedulers.make_sdqn_selector(qp, hcfg)
    hn = hcfg.scenario.n_pods
    hep = jax.jit(lambda kk: kenv.run_episode(kk, hcfg, hsel, hn).metric)
    dt = _time(hep, jax.random.PRNGKey(0), iters=3, warmup=1)
    rows.append(("sdqn_place_fleet_hetero_ep", dt * 1e6, hn / dt))
    return rows


def training_throughput(smoke: bool = False) -> List[Tuple[str, float, float]]:
    """On-device RL training transitions/s.  ``smoke`` shrinks the episode
    budget for CI; the row name stays ``sdqn_train_ondevice`` because
    ``check_smoke`` gates its ``derived`` column against the committed
    ``benchmarks/baseline_sched_scale.json``."""
    tcfg = training_cluster()
    rl = train_rl.RLConfig(variant="sdqn", episodes=10 if smoke else 50,
                           n_envs=16, batch_size=256)
    fn = jax.jit(lambda k: train_rl.train(k, tcfg, rl)[1]["loss"][-1])
    dt = _time(fn, jax.random.PRNGKey(0), iters=2, warmup=1)
    transitions = rl.episodes * rl.pods_per_episode * rl.n_envs
    return [("sdqn_train_ondevice", dt * 1e6, transitions / dt)]


def _pick_seed_devices(n_seeds: int, cpus: int) -> int:
    """Largest divisor of ``n_seeds`` that fits the core count (the seed
    axis shards evenly or not at all)."""
    for d in range(min(n_seeds, max(cpus, 1)), 0, -1):
        if n_seeds % d == 0:
            return d
    return 1


def _seed_parallel_measurements(n_seeds: int, episodes: int) -> List[Tuple[str, float, float]]:
    """Measure sequential-vs-engine in THIS process (child of
    ``seed_parallel_speedup``, which forces the multi-device host platform).
    """
    from repro.launch import mesh as meshmod
    from repro.train import engine

    tcfg = training_cluster()
    rl = train_rl.RLConfig(variant="sdqn", episodes=episodes, n_envs=16,
                           batch_size=256)
    key = jax.random.PRNGKey(0)
    # the pre-engine train_and_select loop: jit once, dispatch per seed.
    # Return (params, metrics) whole — indexing [0] inside the jit would let
    # XLA dead-code-eliminate the per-episode metrics the engine computes,
    # skewing the comparison in the baseline's favor.
    train_fn = jax.jit(lambda k: train_rl.train(k, tcfg, rl))

    def sequential(k):
        return [train_fn(jax.random.fold_in(k, s)) for s in range(n_seeds)]

    n_dev = len(jax.devices())
    mesh = meshmod.make_train_mesh(n_dev) if n_dev > 1 else None

    def parallel(k):
        return engine.train_seeds(k, tcfg, rl, n_seeds, mesh=mesh)

    dt_seq = _time(sequential, key, iters=3, warmup=1)
    dt_par = _time(parallel, key, iters=3, warmup=1)
    per_seed = rl.episodes * rl.pods_per_episode * rl.n_envs
    return [
        (f"seed_sequential_s{n_seeds}", dt_seq * 1e6, n_seeds * per_seed / dt_seq),
        (f"seed_parallel_s{n_seeds}_d{n_dev}", dt_par * 1e6,
         n_seeds * per_seed / dt_par),
        ("seed_parallel_speedup", 0.0, dt_seq / dt_par),
    ]


def seed_parallel_speedup(n_seeds: int = 4, episodes: int = 20) -> List[Tuple[str, float, float]]:
    """Seed-parallel training engine vs the sequential Python seed loop.

    Spawns a child with ``--xla_force_host_platform_device_count`` set to a
    divisor of ``n_seeds`` that fits the machine, so the engine's seed-axis
    ``data`` sharding actually executes in parallel (the flag only takes
    effect before jax initializes, hence the subprocess).  The ceiling is
    ``min(cpu_count, n_seeds) x`` the vmap amortization; a 2-core container
    tops out near 2x while a >=4-device training cluster reaches the full
    n_seeds multiple.
    """
    devices = _pick_seed_devices(n_seeds, os.cpu_count() or 1)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}").strip()
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.sched_scale",
         "--seed-parallel-child", str(n_seeds), str(episodes)],
        env=env, capture_output=True, text=True)
    if out.returncode != 0:
        raise RuntimeError(
            f"seed-parallel child failed ({out.returncode}):\n{out.stderr}")
    return [tuple(r) for r in json.loads(out.stdout.strip().splitlines()[-1])]


def _joint_sharding_measurements(n_seeds: int, episodes: int) -> List[Tuple[str, float, float]]:
    """Measure seed-only vs joint seed×env sharding in THIS process (child of
    ``joint_sharding_speedup``, which forces a 4-device host platform).

    Seed-only sharding at ``n_seeds=2`` can occupy at most 2 devices however
    many exist (its ceiling: one whole replica per device); the joint layout
    splits the remaining factor across the env axis — here a (2, 2) grid
    over all 4.  Both run through ``engine.train_seeds``; only the mesh
    handed to the planner differs.

    The workload is a 256-node fleet, not the 4-node paper cluster: env-axis
    sharding splits the per-step environment work (O(N) afterstate scoring,
    feature stacks) but pays fixed per-step partition/collective overhead
    (the replay add all-gathers one (n_envs, 8) row into the replicated
    ring, and every env-batched op forks across devices), so it is only
    profitable when the sharded env work dominates the replicated learner —
    on the 4-node cluster the overhead measures ~7x *slower*, at 256 nodes
    env stepping dominates and the layout wins.  That threshold is a
    property of the program, not the host: callers should hand
    ``train_seeds`` a multi-device mesh for fleet-scale configs and leave
    ``mesh=None`` for toy ones.
    """
    from repro.launch import mesh as meshmod
    from repro.train import engine

    tcfg = fleet_cluster(256)
    rl = train_rl.RLConfig(variant="sdqn", episodes=episodes, n_envs=16,
                           batch_size=256)
    key = jax.random.PRNGKey(0)
    n_dev = len(jax.devices())
    n_seed_dev = min(n_seeds, n_dev)

    def seed_only(k):
        return engine.train_seeds(k, tcfg, rl, n_seeds,
                                  mesh=meshmod.make_train_mesh(n_seed_dev))

    def joint(k):
        return engine.train_seeds(k, tcfg, rl, n_seeds,
                                  mesh=meshmod.make_train_mesh(n_dev))

    dt_seed = _time(seed_only, key, iters=3, warmup=1)
    dt_joint = _time(joint, key, iters=3, warmup=1)
    per_seed = rl.episodes * rl.pods_per_episode * rl.n_envs
    return [
        (f"seedonly_s{n_seeds}_d{n_seed_dev}", dt_seed * 1e6,
         n_seeds * per_seed / dt_seed),
        (f"joint_s{n_seeds}_d{n_dev}", dt_joint * 1e6,
         n_seeds * per_seed / dt_joint),
        ("joint_sharding_speedup", 0.0, dt_seed / dt_joint),
    ]


def joint_sharding_speedup(n_seeds: int = 2, episodes: int = 20,
                           devices: int = 4) -> List[Tuple[str, float, float]]:
    """Joint seed×env layout vs pure seed sharding on a force-split host.

    Spawns a child with ``--xla_force_host_platform_device_count=4``
    regardless of the physical core count: the *layout* question is how many
    devices the program keeps busy, and forcing 4 exposes it on any host.
    The measured speedup only materializes with >= 4 physical cores backing
    the 4 devices (CI runners; any real multi-core/TPU host) — on a 2-core
    container both programs time-share the same 2 cores and the ratio sits
    near 1x, which is why the committed gate floor is conservative.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}").strip()
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.sched_scale",
         "--joint-sharding-child", str(n_seeds), str(episodes)],
        env=env, capture_output=True, text=True)
    if out.returncode != 0:
        raise RuntimeError(
            f"joint-sharding child failed ({out.returncode}):\n{out.stderr}")
    return [tuple(r) for r in json.loads(out.stdout.strip().splitlines()[-1])]


def replay_marginal_cost(lane: int = 16, batch: int = 256, steps: int = 512,
                         cap: int = 4096) -> List[Tuple[str, float, float]]:
    """The replay slice of the training step, exactly as the loop drives it:
    one lane-wide ``replay_add`` + one ``replay_sample`` per scanned step.

    This is the residual per-seed marginal cost the fused ring targets (one
    contiguous slot write + one gather per step, vs three scatters + three
    gathers in the per-column layout).  ``derived`` is stored transitions/s.
    """
    from repro.core.replay import replay_add, replay_init, replay_sample

    key = jax.random.PRNGKey(0)

    def run(k):
        def step(buf, t):
            tf = t.astype(jnp.float32)
            feats = jnp.broadcast_to(tf, (lane, 6))
            targets = jnp.broadcast_to(tf, (lane,))
            weights = (jnp.arange(lane) % 7 != 0).astype(jnp.float32)
            buf = replay_add(buf, feats, targets, weights)
            f, tg, w = replay_sample(buf, jax.random.fold_in(k, t), batch)
            return buf, f.sum() + tg.sum() + w.sum()
        _, acc = jax.lax.scan(step, replay_init(cap, lane=lane),
                              jnp.arange(steps))
        return acc.sum()

    dt = _time(jax.jit(run), key, iters=5, warmup=2)
    return [("replay_marginal_cost", dt * 1e6, steps * lane / dt)]


def ci_rows() -> List[Tuple[str, float, float]]:
    """The CI-sized sweep behind ``benchmarks.run --sched-scale``: only the
    training rows (the hot-path benches already run — and are archived — in
    the ``--smoke`` job; re-timing the 131072-node sweeps per push would buy
    nothing but wall-clock)."""
    return (training_throughput(smoke=True) + seed_parallel_speedup(episodes=10)
            + joint_sharding_speedup(episodes=10) + replay_marginal_cost())


def run_all() -> List[Tuple[str, float, float]]:
    out = []
    out += scoring_throughput()
    out += afterstate_throughput()
    out += fused_scoring()
    out += eval_engine_speedup()
    out += placement_throughput()
    out += training_throughput()
    out += seed_parallel_speedup()
    out += joint_sharding_speedup()
    out += replay_marginal_cost()
    return out


if __name__ == "__main__":
    if len(sys.argv) >= 2 and sys.argv[1] == "--seed-parallel-child":
        child_rows = _seed_parallel_measurements(int(sys.argv[2]), int(sys.argv[3]))
        print(json.dumps(child_rows))
    elif len(sys.argv) >= 2 and sys.argv[1] == "--joint-sharding-child":
        child_rows = _joint_sharding_measurements(int(sys.argv[2]), int(sys.argv[3]))
        print(json.dumps(child_rows))
    else:
        for name, us, derived in run_all():
            print(f"{name},{us:.1f},{derived}")
