"""Fleet-scale scheduler benchmarks (beyond-paper: 1000+ nodes).

1. SDQN scoring throughput vs fleet size (the scheduler's hot loop) —
   XLA path vs the fused Pallas kernel in interpret mode (CPU container;
   on TPU the compiled kernel path is selected automatically).
2. End-to-end placement throughput (pods/s) on a 1024-node cluster.
3. On-device RL training throughput (Anakin-style, transitions/s).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dqn, env as kenv, schedulers, train_rl
from repro.core.types import fleet_cluster, training_cluster


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def scoring_throughput() -> List[Tuple[str, float, float]]:
    rows = []
    params = dqn.init_qnet(jax.random.PRNGKey(0))
    score = jax.jit(lambda f: dqn.qvalues(params, f))
    for n in (1024, 16384, 131072):
        feats = jax.random.normal(jax.random.PRNGKey(1), (n, 6))
        dt = _time(score, feats)
        rows.append((f"sdqn_score_xla_n{n}", dt * 1e6, n / dt))
    return rows


def placement_throughput() -> List[Tuple[str, float, float]]:
    cfg = fleet_cluster(1024)
    qp = dqn.init_qnet(jax.random.PRNGKey(0))
    sel = schedulers.make_sdqn_selector(qp, cfg)
    n_pods = 200
    ep = jax.jit(lambda kk: kenv.run_episode(kk, cfg, sel, n_pods)[2])
    dt = _time(ep, jax.random.PRNGKey(0), iters=3, warmup=1)
    return [("sdqn_place_1024node_ep", dt * 1e6, n_pods / dt)]


def training_throughput() -> List[Tuple[str, float, float]]:
    tcfg = training_cluster()
    rl = train_rl.RLConfig(variant="sdqn", episodes=50, n_envs=16, batch_size=256)
    fn = jax.jit(lambda k: train_rl.train(k, tcfg, rl)[1]["loss"][-1])
    dt = _time(fn, jax.random.PRNGKey(0), iters=2, warmup=1)
    transitions = rl.episodes * rl.pods_per_episode * rl.n_envs
    return [("sdqn_train_ondevice", dt * 1e6, transitions / dt)]


def run_all() -> List[Tuple[str, float, float]]:
    out = []
    out += scoring_throughput()
    out += placement_throughput()
    out += training_throughput()
    return out
