"""Fleet-scale scheduler benchmarks (beyond-paper: 1000+ nodes).

1. SDQN scoring throughput vs fleet size (the scheduler's hot loop) —
   XLA path vs the fused Pallas kernel in interpret mode (CPU container;
   on TPU the compiled kernel path is selected automatically).
2. Afterstate feature construction: the O(N) incremental scorer vs the
   vmap-of-place reference (O(N^2)) it replaced.
3. Fused afterstate *scoring*: features + Q-net in one pass
   (``ops.sdqn_score_afterstate``) vs the unfused
   ``hypothetical_place`` -> normalize -> ``qvalues`` chain.
4. Batched evaluation engine: 64 vmapped trials in one launch vs the
   per-trial Python dispatch loop it replaced.
5. End-to-end placement throughput (pods/s) on 1024-node clusters,
   homogeneous and heterogeneous (fleet-hetero scenario).
6. On-device RL training throughput (Anakin-style, transitions/s).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax

from repro.core import dqn, env as kenv, schedulers, train_rl
from repro.core.types import fleet_cluster, paper_cluster, training_cluster
from repro.eval import engine as eval_engine
from repro.kernels import ops
from repro.scenarios import make_env


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def scoring_throughput() -> List[Tuple[str, float, float]]:
    rows = []
    params = dqn.init_qnet(jax.random.PRNGKey(0))
    score = jax.jit(lambda f: dqn.qvalues(params, f))
    for n in (1024, 16384, 131072):
        feats = jax.random.normal(jax.random.PRNGKey(1), (n, 6))
        dt = _time(score, feats)
        rows.append((f"sdqn_score_xla_n{n}", dt * 1e6, n / dt))
    return rows


def afterstate_throughput() -> List[Tuple[str, float, float]]:
    """The scoring hot path: O(N) incremental afterstates vs vmap reference.

    ``derived`` is nodes scored per second for the timed rows and the
    measured speedup for the summary rows.  The reference materializes N
    full cluster states per call, so it is only timed up to 2048 nodes.
    """
    rows = []
    pod = kenv.default_pod(fleet_cluster(4))
    fast_times = {}
    for n in (1024, 4096, 16384):
        cfg = fleet_cluster(n)
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        fast = jax.jit(lambda s, _cfg=cfg: kenv.hypothetical_place(s, pod, _cfg))
        dt = _time(fast, state)
        fast_times[n] = dt
        rows.append((f"afterstate_incremental_n{n}", dt * 1e6, n / dt))
    for n in (1024, 2048):
        cfg = fleet_cluster(n)
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        ref = jax.jit(lambda s, _cfg=cfg: kenv.hypothetical_place_reference(s, pod, _cfg))
        dt_ref = _time(ref, state, iters=5, warmup=2)
        rows.append((f"afterstate_vmap_ref_n{n}", dt_ref * 1e6, n / dt_ref))
        dt_fast = fast_times.get(n) or _time(
            jax.jit(lambda s, _cfg=cfg: kenv.hypothetical_place(s, pod, _cfg)), state)
        rows.append((f"afterstate_speedup_n{n}", 0.0, dt_ref / dt_fast))
    return rows


def fused_scoring() -> List[Tuple[str, float, float]]:
    """Fused in-kernel afterstate scoring vs the unfused jnp chain.

    The unfused baseline is ``schedulers.score_afterstates``'s small-N path
    (``hypothetical_place`` -> normalize -> ``qvalues``), jitted as one
    program; the fused path computes the features inside the scorer
    (Pallas on TPU, the fused-XLA twin on CPU — the interpret-safe
    fallback) without materializing the (N, 6) matrix.  ``derived`` is
    nodes/s for timed rows and measured speedup for summary rows.
    """
    rows = []
    params = dqn.init_qnet(jax.random.PRNGKey(0))
    mode = None if jax.default_backend() == "tpu" else "xla"
    for n in (4096, 16384, 131072):
        cfg = fleet_cluster(n)
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        pod = kenv.default_pod(cfg)
        unfused = jax.jit(lambda s, _cfg=cfg: ops.sdqn_score_afterstate(
            s, pod, _cfg, params, mode="ref"))
        fused = jax.jit(lambda s, _cfg=cfg: ops.sdqn_score_afterstate(
            s, pod, _cfg, params, mode=mode))
        dt_un = _time(unfused, state)
        dt_fu = _time(fused, state)
        rows.append((f"afterscore_unfused_n{n}", dt_un * 1e6, n / dt_un))
        rows.append((f"afterscore_fused_n{n}", dt_fu * 1e6, n / dt_fu))
        rows.append((f"afterscore_fused_speedup_n{n}", 0.0, dt_un / dt_fu))
    return rows


def eval_engine_speedup(trials: int = 64) -> List[Tuple[str, float, float]]:
    """Batched evaluation engine vs the per-trial Python dispatch loop.

    Same episodes (identical trial keys), same jitted episode body; the only
    difference is one vmapped launch vs ``trials`` sequential dispatches.
    ``derived`` is episodes/s for the timed rows, speedup for the summary.
    """
    cfg = paper_cluster()
    sel = schedulers.make_kube_selector(cfg)
    n_pods = 50
    keys = eval_engine.trial_keys(jax.random.PRNGKey(0), trials)

    loop_ep = jax.jit(lambda kk: kenv.run_episode(kk, cfg, sel, n_pods)[2])

    def loop(keys):
        return [loop_ep(keys[t]) for t in range(trials)]

    batch = eval_engine.make_batch_episode(cfg, sel, n_pods)
    dt_loop = _time(loop, keys, iters=3, warmup=1)
    dt_batch = _time(batch, keys, iters=3, warmup=1)
    return [
        (f"eval_loop_{trials}trials", dt_loop * 1e6, trials / dt_loop),
        (f"eval_batched_{trials}trials", dt_batch * 1e6, trials / dt_batch),
        (f"eval_engine_speedup_{trials}trials", 0.0, dt_loop / dt_batch),
    ]


def placement_throughput() -> List[Tuple[str, float, float]]:
    rows = []
    cfg = fleet_cluster(1024)
    qp = dqn.init_qnet(jax.random.PRNGKey(0))
    sel = schedulers.make_sdqn_selector(qp, cfg)
    n_pods = 200
    ep = jax.jit(lambda kk: kenv.run_episode(kk, cfg, sel, n_pods)[2])
    dt = _time(ep, jax.random.PRNGKey(0), iters=3, warmup=1)
    rows.append(("sdqn_place_1024node_ep", dt * 1e6, n_pods / dt))

    # heterogeneous 1024-node pool with a mixed Poisson stream
    hcfg = make_env("fleet-hetero")
    hsel = schedulers.make_sdqn_selector(qp, hcfg)
    hn = hcfg.scenario.n_pods
    hep = jax.jit(lambda kk: kenv.run_episode(kk, hcfg, hsel, hn)[2])
    dt = _time(hep, jax.random.PRNGKey(0), iters=3, warmup=1)
    rows.append(("sdqn_place_fleet_hetero_ep", dt * 1e6, hn / dt))
    return rows


def training_throughput() -> List[Tuple[str, float, float]]:
    tcfg = training_cluster()
    rl = train_rl.RLConfig(variant="sdqn", episodes=50, n_envs=16, batch_size=256)
    fn = jax.jit(lambda k: train_rl.train(k, tcfg, rl)[1]["loss"][-1])
    dt = _time(fn, jax.random.PRNGKey(0), iters=2, warmup=1)
    transitions = rl.episodes * rl.pods_per_episode * rl.n_envs
    return [("sdqn_train_ondevice", dt * 1e6, transitions / dt)]


def run_all() -> List[Tuple[str, float, float]]:
    out = []
    out += scoring_throughput()
    out += afterstate_throughput()
    out += fused_scoring()
    out += eval_engine_speedup()
    out += placement_throughput()
    out += training_throughput()
    return out
