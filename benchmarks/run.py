"""Benchmark harness — one function per paper table + fleet-scale and
roofline benches.  Prints ``name,us_per_call,derived`` CSV at the end.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --fast     # skip RL training
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip policy training benches")
    args = ap.parse_args()

    rows = []

    from benchmarks import roofline_report, sched_scale

    if not args.fast:
        from benchmarks import paper_tables

        for fn in (paper_tables.table8, paper_tables.table9, paper_tables.table10,
                   paper_tables.table11, paper_tables.table12):
            name, us, derived = fn()
            rows.append((f"paper_{fn.__name__}_{name}", us, derived))
        (fname, us, derived), claims, _ = paper_tables.figure6()
        rows.append((fname, us, derived))
        rows.append(("claims_validated", 0.0,
                     float(sum(claims.values())) / len(claims)))
        name, us, derived = paper_tables.literal_ablation()
        rows.append((name, us, derived))

    rows += sched_scale.run_all()
    rows += roofline_report.report(mesh="16x16")

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
