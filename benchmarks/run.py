"""Benchmark harness — one function per paper table + scenario, fleet-scale
and roofline benches.  Prints ``name,us_per_call,derived`` CSV at the end.

    PYTHONPATH=src python -m benchmarks.run                     # everything
    PYTHONPATH=src python -m benchmarks.run --fast              # skip RL training
    PYTHONPATH=src python -m benchmarks.run --scenario spot-flaky
    PYTHONPATH=src python -m benchmarks.run --smoke --json out.json   # CI job
"""
from __future__ import annotations

import argparse
import json
import platform
import sys


def _write_json(path: str, rows) -> None:
    payload = {
        "schema": "repro-bench-v1",
        "python": platform.python_version(),
        "argv": sys.argv[1:],
        "rows": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {len(payload['rows'])} rows to {path}")


def _run_manifest(path: str, nightly: bool = False) -> int:
    """Run every suite of a gates manifest (benchmarks/gates.json).

    Each suite runs as its own subprocess — ``python -m benchmarks.run
    <run_args> --json BENCH_<suite>.json`` — so one suite's crash (or
    memory) cannot poison the others, and each bench JSON lands where the
    CI gate step (``check_smoke --manifest``) and the artifact upload
    expect it.  Suites that fail to run are reported at the end; the exit
    code is the number of failed suites.
    """
    import subprocess

    with open(path) as f:
        manifest = json.load(f)
    suites = manifest["nightly"] if nightly else manifest["suites"]
    lane = "nightly" if nightly else "smoke"
    failed = []
    for suite in suites:
        name, run_args = suite["name"], list(suite["run_args"])
        cmd = [sys.executable, "-m", "benchmarks.run", *run_args,
               "--json", f"BENCH_{name}.json"]
        print(f"\n=== [{lane}] suite {name}: {' '.join(cmd)} ===", flush=True)
        if subprocess.call(cmd) != 0:
            failed.append(name)
    if failed:
        print(f"\nmanifest: {len(failed)}/{len(suites)} suites failed: "
              f"{', '.join(failed)}", file=sys.stderr)
    else:
        print(f"\nmanifest: all {len(suites)} {lane} suites completed")
    return len(failed)


def main() -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--fast", action="store_true", help="skip policy training benches")
    mode.add_argument("--scenario", metavar="NAME",
                      help="run one registry scenario (see repro.scenarios)")
    mode.add_argument("--list-scenarios", action="store_true",
                      help="print the scenario registry and exit")
    mode.add_argument("--smoke", action="store_true",
                      help="CI-sized run: scenario sweep + hot-path benches, tiny configs")
    mode.add_argument("--sched-scale", action="store_true",
                      help="CI-sized benchmarks/sched_scale.py sweep (training "
                           "throughput + seed-parallel engine speedup included)")
    mode.add_argument("--sweep", action="store_true",
                      help="full (non-smoke) scenario sweep over the whole "
                           "registry — the nightly CI lane")
    mode.add_argument("--lifecycle", action="store_true",
                      help="pod-lifecycle / green-consolidation benchmark "
                           "(SDQN vs SDQN-n vs kube on churn scenarios)")
    mode.add_argument("--lifecycle-smoke", action="store_true",
                      help="CI-sized lifecycle benchmark (the sizing "
                           "benchmarks/baseline_lifecycle.json is gated at)")
    mode.add_argument("--pareto", action="store_true",
                      help="full green-Pareto-frontier sweep: kube / TOPSIS / "
                           "SDQN-n across the energy_weight grid on every "
                           "churn scenario — the nightly lane")
    mode.add_argument("--pareto-smoke", action="store_true",
                      help="CI-sized Pareto sweep (the sizing "
                           "benchmarks/baseline_pareto.json is gated at)")
    mode.add_argument("--online-serve", action="store_true",
                      help="online-learning serving benchmark: p99 with the "
                           "refresher on/off (overhead ratio) + served "
                           "avg-CPU gain of the refreshed policy (the sizing "
                           "baseline_online.json is gated at)")
    mode.add_argument("--policy-compare", action="store_true",
                      help="CI-sized policy-class comparison: every "
                           "core.policy registry class vs kube on two "
                           "scenarios + per-class train-step throughput (the "
                           "sizing baseline_policy_compare.json is gated at)")
    mode.add_argument("--placement-serve", action="store_true",
                      help="placement-daemon serving benchmark: decisions/sec "
                           "and p50/p99 latency at several offered rates (the "
                           "sizing baseline_placement_serve.json is gated at)")
    mode.add_argument("--chaos", action="store_true",
                      help="full chaos grid (offered rate x node failures, "
                           "SDQN-with-fallback vs kube) — the nightly lane")
    mode.add_argument("--chaos-smoke", action="store_true",
                      help="CI-sized chaos benchmark (the sizing "
                           "benchmarks/baseline_chaos.json is gated at)")
    mode.add_argument("--fleet-scale", action="store_true",
                      help="two-stage hierarchical sharded scoring sweep over "
                           "the cluster-of-clusters family, 4k -> 128k nodes "
                           "(the sizing baseline_fleet_scale.json is gated at)")
    mode.add_argument("--manifest", metavar="PATH",
                      help="run every suite in a benchmarks/gates.json "
                           "manifest (each as a subprocess, writing "
                           "BENCH_<suite>.json next to the cwd); gate the "
                           "results separately with check_smoke --manifest")
    ap.add_argument("--nightly", action="store_true",
                    help="with --manifest: run the manifest's nightly lane "
                         "instead of the gated smoke suites")
    ap.add_argument("--trials", type=int, default=None,
                    help="episodes per measurement (default: 3, or 1 with --smoke)")
    ap.add_argument("--pods", type=int, default=None,
                    help="override pods per episode (default: scenario's n_pods, "
                         "or 20 with --smoke)")
    ap.add_argument("--train-episodes", type=int, default=None,
                    help="episodes for the mixture-trained SDQN policy "
                         "(default: 120, or 12 with --smoke)")
    ap.add_argument("--json", metavar="PATH", help="also dump rows as JSON")
    args = ap.parse_args()
    for flag in ("trials", "pods", "train_episodes"):
        val = getattr(args, flag)
        if val is not None and val < 1:
            ap.error(f"--{flag.replace('_', '-')} must be >= 1")
    if args.fast and (args.pods is not None or args.train_episodes is not None):
        ap.error("--fast skips the training/scenario benches; "
                 "--pods/--train-episodes have no effect with it")
    if args.nightly and not args.manifest:
        ap.error("--nightly only applies to --manifest runs")

    if args.manifest:
        raise SystemExit(_run_manifest(args.manifest, nightly=args.nightly))

    if args.list_scenarios:
        from repro import scenarios

        for name in scenarios.scenario_names():
            scn = scenarios.get_scenario(name)
            classes = "+".join(f"{c.count}x{c.name}" for c in scn.node_classes)
            pods = "/".join(p.name for p in scn.pod_types)
            print(f"{name:18s} nodes=[{classes}] pods=[{pods}] "
                  f"arrival={scn.arrival.kind} n_pods={scn.n_pods}")
        return

    rows = []

    if args.scenario:
        from benchmarks import scenario_bench
        from repro import scenarios

        try:  # validate only the name here: real bench errors must traceback
            scenarios.get_scenario(args.scenario)
        except KeyError as e:
            ap.error(str(e.args[0]) if e.args else str(e))
        rows += scenario_bench.bench_scenario(
            args.scenario, trials=args.trials or 3, n_pods=args.pods,
            train_episodes=args.train_episodes or 120)
    elif args.smoke:
        from benchmarks import scenario_bench, sched_scale

        rows += scenario_bench.smoke_rows(
            trials=args.trials or 1, n_pods=args.pods or 20,
            train_episodes=args.train_episodes or 12)
        rows += sched_scale.afterstate_throughput()
        rows += sched_scale.scoring_throughput()
        rows += sched_scale.fused_scoring()
        rows += sched_scale.eval_engine_speedup(trials=16)
    elif args.sched_scale:
        from benchmarks import sched_scale

        rows += sched_scale.ci_rows()
    elif args.sweep:
        from benchmarks import scenario_bench

        rows += scenario_bench.sweep(
            trials=args.trials or 3, n_pods=args.pods,
            train_episodes=args.train_episodes or 120)
    elif args.lifecycle:
        from benchmarks import lifecycle_bench

        rows += lifecycle_bench.rows(
            trials=args.trials or 3, n_pods=args.pods,
            train_episodes=args.train_episodes or 120)
    elif args.lifecycle_smoke:
        from benchmarks import lifecycle_bench

        rows += lifecycle_bench.smoke_rows()
    elif args.pareto:
        from benchmarks import lifecycle_bench

        rows += lifecycle_bench.pareto_rows(
            trials=args.trials or 3, n_pods=args.pods,
            train_episodes=args.train_episodes or 120)
    elif args.pareto_smoke:
        from benchmarks import lifecycle_bench

        rows += lifecycle_bench.pareto_smoke_rows()
    elif args.online_serve:
        from benchmarks import online_bench

        rows += online_bench.rows()
    elif args.policy_compare:
        from benchmarks import policy_compare

        rows += policy_compare.smoke_rows(
            trials=args.trials or 1, n_pods=args.pods or 20,
            train_episodes=args.train_episodes or 12)
    elif args.placement_serve:
        from benchmarks import placement_serve

        rows += placement_serve.serve_rows()
    elif args.chaos:
        from benchmarks import chaos_bench

        rows += chaos_bench.rows()
    elif args.chaos_smoke:
        from benchmarks import chaos_bench

        rows += chaos_bench.smoke_rows()
    elif args.fleet_scale:
        from benchmarks import fleet_scale

        rows += fleet_scale.rows()
    else:
        from benchmarks import roofline_report, sched_scale

        if not args.fast:
            from benchmarks import paper_tables

            for fn in (paper_tables.table8, paper_tables.table9, paper_tables.table10,
                       paper_tables.table11, paper_tables.table12):
                name, us, derived = fn()
                rows.append((f"paper_{fn.__name__}_{name}", us, derived))
            (fname, us, derived), claims, _ = paper_tables.figure6()
            rows.append((fname, us, derived))
            rows.append(("claims_validated", 0.0,
                         float(sum(claims.values())) / len(claims)))
            name, us, derived = paper_tables.literal_ablation()
            rows.append((name, us, derived))
            rows += paper_tables.scenario_generalization(
                trials=args.trials or 3, n_pods=args.pods,
                train_episodes=args.train_episodes)
            rows += paper_tables.policy_class_table()

        rows += sched_scale.run_all()
        rows += roofline_report.report(mesh="16x16")

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json:
        _write_json(args.json, rows)


if __name__ == "__main__":
    main()
