"""Online-learning serving benchmark: adaptation gain + refresh overhead.

Two claims from the online-refresh loop (``repro.sched.online``), each gated
through ``benchmarks/gates.json`` against ``baseline_online.json``:

**Adaptation gain** — a daemon serving with a *stale* policy adapts to the
cluster it is actually serving.  The stale Q-net is trained on yesterday's
cluster economics (image pulls free: every node warm, so spreading a burst
was harmless) and then serves a cluster where cold pulls are expensive and
super-additive under concurrency (``env.pull_cost_now``).  Frozen, it keeps
spreading pods across cold nodes; with the ``OnlineRefresher`` training on
the realized transitions the daemon records, it learns pull-avoidance /
consolidation from the live reward stream.  Rows (avg-CPU over the trace,
lower = better, as a ratio vs the kube-heuristic daemon on the same trace):

  * ``online_serve_kube_cpu``       — kube-arm avg-CPU%, the denominator
  * ``online_serve_frozen_ratio``   — stale policy, refresher off
  * ``online_serve_refreshed_ratio``— same policy + online refresh
  * ``online_avg_cpu_gain``         — frozen_ratio - refreshed_ratio (GATED
                                      floor: the refreshed daemon must keep
                                      beating its frozen self)

**Refresh overhead** — the refresher must not block serving: transitions are
recorded as O(1) host-side appends (zero added scoring launches) and params
swap by atomic reference flip at batch-cut boundaries, so p99 decision
latency with the refresher thread running must stay within ~1.1x of the
refresher-off daemon.  Rows:

  * ``online_off_p99_ms`` / ``online_on_p99_ms`` — informational
  * ``online_refresh_overhead``     — p99 on / p99 off (GATED ceiling)

    PYTHONPATH=src python -m benchmarks.run --online-serve \
        --json BENCH_online.json
"""
from __future__ import annotations

import dataclasses
import gc
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dqn, env as kenv, presets, rewards, train_rl
from repro.core.types import fleet_cluster
from repro.scenarios import arrival_trace
from repro.sched.daemon import (
    ClusterSubstrate,
    DaemonConfig,
    PlacementDaemon,
    replay_trace,
)
from repro.sched.online import OnlineRefresher, TransitionRecorder

# Serving trace sizing: 400 pods in waves of 32 onto a 64-node cluster stays
# comfortably below saturation (no drops in any arm, so the avg-CPU ratios
# compare equal served load), while the per-wave tick lets pull transients
# decay exactly as wall-clock would.
N_NODES = 64
N_PODS = 400
WAVE = 32
BATCH = 8
TICK_DT_S = 10.0
REFRESH_STEPS_PER_WAVE = 8
REFRESH_BATCH = 256
# the online reward: consolidation (Table 5) plus a heavy shaping term on the
# paper's objective itself, so the realized-reward stream the refresher
# trains on points at exactly what the bench measures (cluster-average CPU)
EFFICIENCY_WEIGHT = 50.0


def _stale_policy() -> dict:
    """A competent-but-stale Q-net: trained where image pulls cost nothing.

    On that cluster the Table-3 distribution reward makes spreading optimal;
    served against default pull economics the same policy is systematically
    wrong — the headroom the online refresher is expected to recover.
    """
    cfg_old = dataclasses.replace(fleet_cluster(N_NODES),
                                  image_pull_cost=0.0, warm_start_cost=0.0)
    rl = dataclasses.replace(presets.SDQN_PRESET, episodes=24)
    qp, _ = train_rl.train(jax.random.PRNGKey(7), cfg_old, rl)
    return qp


def _serve_arm(arm: str, qp: dict, state0, cfg) -> Tuple[float, int, int]:
    """One wave-driven serving run; returns (mean avg-CPU%, dropped, steps).

    Deterministic by construction: submissions carry a fixed ``now``, the
    refresher runs inline between waves (no thread scheduling in the metric),
    and the wave tick advances wall-clock physics by a fixed dt.
    """
    table = kenv.sample_pod_table(jax.random.PRNGKey(101), cfg, N_PODS)
    pods = [jax.tree.map(lambda x: x[i], table.specs) for i in range(N_PODS)]
    sub = ClusterSubstrate(state0, cfg)
    rec = ref = hook = None
    dc = DaemonConfig(batch_size=BATCH, max_wait_s=0.0,
                      conflict_policy="next-best",
                      heuristic_only=(arm == "kube"))
    if arm == "online":
        rec = TransitionRecorder(
            state0, cfg,
            reward_fn=rewards.make_reward_fn(
                "sdqn_n", efficiency_weight=EFFICIENCY_WEIGHT))
        hook = rec.record
    daemon = PlacementDaemon(sub, qp, dc, decision_hook=hook)
    if arm != "kube":
        daemon.warmup()
    if arm == "online":
        ref = OnlineRefresher(daemon, rec, batch_size=REFRESH_BATCH)
    tick = jax.jit(kenv.tick, static_argnums=(1,))
    cpus: List[float] = []
    for i, pod in enumerate(pods):
        daemon.submit(pod, now=0.0)
        if (i + 1) % BATCH == 0:
            daemon.flush(now=0.0)
        if (i + 1) % WAVE == 0:
            live = tick(jax.tree.map(jnp.asarray, sub.live), cfg, TICK_DT_S)
            sub.live = jax.tree.map(lambda x: np.array(x), live)
            if rec is not None:
                rec.resync(live)
            if ref is not None:
                for _ in range(REFRESH_STEPS_PER_WAVE):
                    ref.step()
            cpus.append(float(kenv.average_cpu_utilization(live, cfg)))
    daemon.drain()
    m = daemon.metrics
    assert m.bound + m.dropped == N_PODS
    if arm == "online":
        # the recorder is pure host-side bookkeeping on the serving path:
        # enabling online learning must add no scoring launches
        assert m.device_launches == m.batches, "online recorder added launches"
    return float(np.mean(cpus)), m.dropped, (ref.steps if ref else 0)


def gain_rows() -> List[Tuple[str, float, float]]:
    cfg = fleet_cluster(N_NODES)
    state0 = kenv.reset(jax.random.PRNGKey(1), cfg)
    qp = _stale_policy()
    out = {}
    for arm in ("kube", "frozen", "online"):
        cpu, dropped, steps = _serve_arm(arm, qp, state0, cfg)
        out[arm] = cpu
        print(f"  online-serve {arm:7s} avg_cpu={cpu:6.2f}%"
              f"  dropped={dropped}  refresh_steps={steps}")
    kube = out["kube"]
    frozen_ratio = out["frozen"] / kube
    refreshed_ratio = out["online"] / kube
    print(f"  online-serve gain: frozen={frozen_ratio:.3f} "
          f"refreshed={refreshed_ratio:.3f} "
          f"gain={frozen_ratio - refreshed_ratio:+.3f}")
    return [
        ("online_serve_kube_cpu", 0.0, kube),
        ("online_serve_frozen_ratio", 0.0, frozen_ratio),
        ("online_serve_refreshed_ratio", 0.0, refreshed_ratio),
        ("online_avg_cpu_gain", 0.0, frozen_ratio - refreshed_ratio),
    ]


def overhead_rows(rate_per_s: float = 500.0,
                  n_requests: int = 2500,
                  n_nodes: int = 256) -> List[Tuple[str, float, float]]:
    """p99 decision latency with the refresher thread on vs off.

    Same offered rate as the gated ``placement_serve_rate500`` row, over a
    ~5s trace on a 256-node cluster (sized so the trace never saturates —
    a requeue backlog would swamp both arms and measure queueing, not the
    refresher).  The on-run records every decision AND trains concurrently.
    On the shared CPU device a refresh cycle's launches queue ahead of
    scoring launches, so the fraction of requests a cycle can delay is
    ~``cycle_window / min_interval`` — the refresher is sized (warm-compiled
    via ``warmup()``, drain bounded to 2 chunks/cycle, 3s throttle) to keep
    that under the p99 index, and the on/off ratio is gated at a ~1.1x
    ceiling.
    """
    cfg = fleet_cluster(n_nodes)
    state0 = kenv.reset(jax.random.PRNGKey(1), cfg)
    qp = dqn.init_qnet(jax.random.PRNGKey(0))
    trace = arrival_trace(jax.random.PRNGKey(2), cfg, n_requests,
                          rate_per_s=rate_per_s)

    def one_run(mode: str) -> float:
        sub = ClusterSubstrate(state0, cfg)
        rec = ref = hook = None
        if mode == "on":
            rec = TransitionRecorder(
                state0, cfg, capacity=8192,
                reward_fn=rewards.make_reward_fn(
                    "sdqn_n", efficiency_weight=EFFICIENCY_WEIGHT))
            hook = rec.record
        # 20ms batch-cut: ~10-pod batches at 500/s keep service throughput
        # well above the offered rate (tiny 5ms batches sit exactly at the
        # sustainable edge and random-walk into a backlog on long traces)
        daemon = PlacementDaemon(
            sub, qp, DaemonConfig(batch_size=32, max_wait_s=0.02),
            decision_hook=hook)
        daemon.warmup()
        if mode == "on":
            ref = OnlineRefresher(daemon, rec, batch_size=REFRESH_BATCH,
                                  min_interval_s=3.0,
                                  drain_chunks_per_step=1)
            ref.warmup()         # compile drain/train paths off the clock
            ref.start()
        # GC pauses are the dominant latency pollutant on a long paced
        # trace (a gen-2 pass over a bench-inflated heap stalls for
        # hundreds of ms); collect up front, then keep the collector out
        # of the measurement window for both arms alike
        gc.collect()
        gc.disable()
        try:
            replay_trace(daemon, trace.t_s, trace.pods)
        finally:
            gc.enable()
            if ref is not None:
                ref.stop()
        m = daemon.metrics
        assert m.device_launches == m.batches, "refresher added scoring launches"
        assert m.bound + m.dropped == n_requests
        if mode == "on":
            assert ref.steps > 0, "refresher thread never ran"
        return float(np.percentile(np.asarray(m.bind_latencies_s), 99)) * 1e3

    # best-of-2 per arm: a one-off machine stall (noisy CI neighbor, THP
    # compaction) inflates one trace by seconds; it must not decide a
    # gated ~1.1x ratio in either direction
    p99 = {mode: min(one_run(mode) for _ in range(2))
           for mode in ("off", "on")}
    print(f"  online-overhead off: p99={p99['off']:.3f}ms / "
          f"on: p99={p99['on']:.3f}ms")
    return [
        ("online_off_p99_ms", 0.0, p99["off"]),
        ("online_on_p99_ms", 0.0, p99["on"]),
        ("online_refresh_overhead", 0.0, p99["on"] / p99["off"]),
    ]


def rows() -> List[Tuple[str, float, float]]:
    print("\n--- online-learning serving bench ---")
    # latency first: the gain arms inflate the heap and compile caches, and
    # p99 measurement deserves the cleanest process state available
    return overhead_rows() + gain_rows()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
