"""Roofline report: aggregates the dry-run JSONs into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os
from typing import List


def load_cells(dryrun_dir: str = "experiments/dryrun") -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        if path.endswith("summary.json"):
            continue
        cells.append(json.load(open(path)))
    return cells


def report(dryrun_dir: str = "experiments/dryrun", mesh: str = "16x16") -> List[tuple]:
    cells = [c for c in load_cells(dryrun_dir) if c.get("mesh") == mesh]
    rows = []
    print(f"\n--- Roofline table ({mesh}, TPU v5e: 197TF bf16 / 819GB/s HBM / 50GB/s ICI) ---")
    print(f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>9s} "
          f"{'dominant':>10s} {'useful':>7s} {'frac':>6s} {'fits16G':>8s}")
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        if c["status"] == "skipped":
            print(f"{c['arch']:22s} {c['shape']:12s} {'—— skipped (documented): sub-quadratic rule ——':>40s}")
            continue
        if c["status"] != "ok":
            print(f"{c['arch']:22s} {c['shape']:12s} FAILED")
            continue
        r = c["roofline"]
        rows.append((f"{c['arch']}|{c['shape']}|{mesh}", 0.0, r["roofline_fraction"]))
        print(f"{c['arch']:22s} {c['shape']:12s} {r['compute_s']:10.3g} {r['memory_s']:10.3g} "
              f"{r['collective_s']:9.3g} {r['dominant']:>10s} {r['useful_flops_ratio']:7.2f} "
              f"{r['roofline_fraction']:6.2f} {str(c.get('fits_hbm_16g')):>8s}")
    return rows


def pick_hillclimb_cells(dryrun_dir: str = "experiments/dryrun") -> dict:
    """Worst roofline fraction, most collective-bound, most paper-representative."""
    cells = [c for c in load_cells(dryrun_dir)
             if c.get("mesh") == "16x16" and c.get("status") == "ok"]
    worst = min(cells, key=lambda c: c["roofline"]["roofline_fraction"] or 1e9)
    coll = max(cells, key=lambda c: c["roofline"]["collective_s"]
               / max(c["roofline"]["step_time_lower_bound_s"], 1e-12))
    return {
        "worst_fraction": f"{worst['arch']}×{worst['shape']}",
        "most_collective_bound": f"{coll['arch']}×{coll['shape']}",
    }
