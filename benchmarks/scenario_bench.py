"""Scenario benchmarks: run registry scenarios under the default
kube-scheduler and a scenario-mixture-trained SDQN.

    PYTHONPATH=src python -m benchmarks.run --scenario hetero-bigsmall
    PYTHONPATH=src python -m benchmarks.run --smoke          # CI-sized sweep
"""
from __future__ import annotations

import functools
import time
from typing import List, Optional, Tuple

import jax

from repro import scenarios
from repro.core import presets, schedulers, train_rl
from repro.eval import engine as eval_engine


@functools.lru_cache(maxsize=None)
def mixture_policy(episodes: int = 120):
    """One Q-net trained across the standard scenario mixture (cached)."""
    import dataclasses

    rl = dataclasses.replace(presets.SDQN_SCENARIO_MIX_PRESET, episodes=episodes)
    cfgs = scenarios.training_mixture(presets.SCENARIO_MIX_NAMES)
    params, _ = train_rl.train_mixture(jax.random.PRNGKey(42), cfgs, rl)
    return params


def bench_scenario(
    name: str,
    trials: int = 3,
    n_pods: Optional[int] = None,
    train_episodes: int = 120,
    policies: Tuple[str, ...] = ("kube", "sdqn"),
) -> List[Tuple[str, float, float]]:
    """CSV rows (name, us_per_episode, avg-CPU metric) for one scenario."""
    env_cfg = scenarios.make_env(name)
    rows = []
    for policy in policies:
        if policy == "kube":
            sel = schedulers.make_kube_selector(env_cfg)
        elif policy == "sdqn":
            sel = schedulers.make_sdqn_selector(mixture_policy(train_episodes), env_cfg)
        else:
            raise ValueError(f"unknown policy {policy!r}; expected 'kube' or 'sdqn'")
        # batched trial runner: all trials are ONE vmapped XLA launch
        ep = scenarios.batch_episode(env_cfg, sel, n_pods)
        jax.block_until_ready(ep(eval_engine.trial_keys(jax.random.PRNGKey(0), trials)))
        t0 = time.time()
        res = scenarios.evaluate_scenario(
            jax.random.PRNGKey(100), env_cfg, sel, trials=trials, n_pods=n_pods,
            episode=ep)
        us = (time.time() - t0) / trials * 1e6
        rows.append((f"scenario_{name}_{policy}", us, res["metric_mean"]))
        print(f"  {name:18s} {policy:5s}  avg_cpu={res['metric_mean']:6.2f}%"
              f" (+-{res['metric_std']:.2f})  placed={res['pods_placed_mean']:.0f}"
              f"/{res['n_pods']:.0f}  dropped={res['dropped_mean']:.1f}"
              f"  nodes={res['n_nodes']:.0f}")
    return rows


def sweep(
    trials: int = 3,
    n_pods: Optional[int] = None,
    train_episodes: int = 120,
    policies: Tuple[str, ...] = ("kube", "sdqn"),
    names: Optional[Tuple[str, ...]] = None,
) -> List[Tuple[str, float, float]]:
    """Every registry scenario under every policy (scoring-only scenarios —
    the cluster-of-clusters fleet-scale family — are excluded: they are
    driven per-decision by benchmarks/fleet_scale.py, not as episodes)."""
    rows = []
    print("\n--- scenario sweep (avg CPU %, lower = better) ---")
    if names is None:
        names = tuple(n for n in scenarios.scenario_names()
                      if n not in scenarios.SCORING_ONLY)
    for name in names:
        rows += bench_scenario(name, trials=trials, n_pods=n_pods,
                               train_episodes=train_episodes, policies=policies)
    return rows


def smoke_rows(
    trials: int = 1,
    n_pods: int = 20,
    train_episodes: int = 12,
) -> List[Tuple[str, float, float]]:
    """CI-sized benchmark: tiny training, one trial, capped pod counts.

    Excludes fleet-hetero (1024 nodes) to keep the smoke job under a minute
    of compute; the full sweep covers it.
    """
    names = tuple(n for n in scenarios.scenario_names()
                  if n != "fleet-hetero" and n not in scenarios.SCORING_ONLY)
    return sweep(trials=trials, n_pods=n_pods, train_episodes=train_episodes,
                 names=names)
