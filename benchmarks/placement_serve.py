"""Placement-daemon serving benchmark: decisions/sec and latency vs load.

Replays scenario arrival traces through ``repro.sched.daemon`` in real time
at several offered rates and reports sustained placements/sec plus p50/p99
decision latency (measured from each request's *scheduled* arrival, so
queueing delay under overload shows up as latency, not as a slower clock).

Rows (per offered rate R, requests/sec):
  * ``placement_serve_rate<R>_throughput`` — derived = decisions/sec served
  * ``placement_serve_rate<R>_p50_ms`` / ``_p99_ms`` — decision latency
  * ``placement_serve_rate<R>_bound`` — requests bound (vs dropped)

The lower rate's throughput floor and p99 ceiling are gated in CI against
``benchmarks/baseline_placement_serve.json`` (see ``check_smoke
--latency-row``); the committed numbers are deliberately conservative — the
gate catches a de-batched scoring loop or a per-bind device launch, not
CI-machine jitter.

    PYTHONPATH=src python -m benchmarks.run --placement-serve \
        --json BENCH_placement_serve.json
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import numpy as np

from repro.core import dqn, env as kenv
from repro.core.types import fleet_cluster
from repro.scenarios import arrival_trace
from repro.sched.daemon import (
    ClusterSubstrate,
    DaemonConfig,
    PlacementDaemon,
    replay_trace,
)

# Offered rates to sweep (requests/sec).  The low rate is comfortably inside
# a 2-core CI container's capacity (its throughput floor + p99 ceiling are
# the committed gates); the high rate oversubscribes the daemon so the bench
# also exercises the queueing/backpressure path.
RATES_PER_S = (500.0, 4000.0)


def serve_rows(n_nodes: int = 64, n_requests: int = 400,
               batch_size: int = 32, max_wait_s: float = 0.005,
               rates=RATES_PER_S) -> List[Tuple[str, float, float]]:
    qparams = dqn.init_qnet(jax.random.PRNGKey(0))
    cfg = fleet_cluster(n_nodes)
    state = kenv.reset(jax.random.PRNGKey(1), cfg)
    rows: List[Tuple[str, float, float]] = []
    for rate in rates:
        sub = ClusterSubstrate(state, cfg)
        daemon = PlacementDaemon(
            sub, qparams,
            DaemonConfig(batch_size=batch_size, max_wait_s=max_wait_s))
        daemon.warmup()          # compile outside the timing window
        trace = arrival_trace(jax.random.PRNGKey(2), cfg, n_requests,
                              rate_per_s=rate)
        dur = replay_trace(daemon, trace.t_s, trace.pods)
        m = daemon.metrics
        assert m.device_launches == m.batches, "batched scoring de-fused"
        assert m.bound + m.dropped == n_requests
        lat = np.asarray(m.bind_latencies_s)   # served decisions only
        tag = f"placement_serve_rate{int(rate)}"
        rows += [
            (f"{tag}_throughput", dur / n_requests * 1e6, n_requests / dur),
            (f"{tag}_p50_ms", 0.0, float(np.percentile(lat, 50)) * 1e3),
            (f"{tag}_p99_ms", 0.0, float(np.percentile(lat, 99)) * 1e3),
            (f"{tag}_bound", 0.0, float(m.bound)),
            (f"{tag}_conflicts", 0.0, float(m.conflicts)),
            (f"{tag}_batches", 0.0, float(m.batches)),
        ]
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in serve_rows():
        print(f"{name},{us:.1f},{derived}")
