"""Reproduction of the paper's experimental tables (8, 9, 10, 11, 12) and
Figure 6, with claim validation.

Protocol (paper §5): 50 compute-intensive no-op pods per trial, 5 trials,
4-slave cluster; metric = cluster-wide average CPU utilization per node.
Policies are trained from scratch (seed-selected on held-out validation
bursts, disjoint from the benchmark trials) using the canonical presets.

Tables 11/12 reproduce the paper's LSTM/Transformer comparison as published:
separately built supervised scorers, so "no advantage over SDQN" (claim 3)
conflates architecture with training recipe.  ``policy_class_table`` is the
controlled version of that comparison — the ``repro.core.policy`` registry
trains attention and Mamba variants through the *same* Q-learning engine and
budget as the MLP, isolating the architecture variable.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Tuple

import jax
import numpy as np

from repro.core import baselines, presets, schedulers, train_rl
from repro.core.types import paper_cluster, training_cluster
from repro.eval import engine as eval_engine

CFG = paper_cluster()
TCFG = training_cluster()

PAPER = {
    "default": 30.87, "sdqn": 27.21, "sdqn_n": 22.35,
    "lstm": 30.53, "transformer": 30.15,
}


def _trials(select: Callable, n_trials: int = 5, n_pods: int = 50):
    """All trials of one scheduler as a single vmapped XLA launch.

    Keys stay ``PRNGKey(100 + t)`` — the benchmark protocol's trial ladder —
    so the batched engine reproduces the per-trial loop's episodes exactly.
    """
    batch = eval_engine.make_batch_episode(CFG, select, n_pods)
    keys = eval_engine.fixed_trial_keys(100, n_trials)
    t0 = time.time()
    res = jax.block_until_ready(batch(keys))
    dt_us = (time.time() - t0) / n_trials * 1e6
    rows = [[int(x) for x in row] for row in np.asarray(res.exp_pods)]
    mets = [float(m) for m in np.asarray(res.metric)]
    mean = float(np.mean(mets))
    cv = float(np.std(mets) / mean * 100.0)
    return rows, mets, mean, cv, dt_us


@functools.lru_cache(maxsize=None)
def policies() -> Dict[str, dict]:
    """Train every scheduler once (cached across table functions)."""
    key = jax.random.PRNGKey(0)
    out: Dict[str, dict] = {}
    qp, _ = train_rl.train_and_select(key, TCFG, CFG, presets.SDQN_PRESET,
                                      n_seeds=presets.N_SELECTION_SEEDS)
    out["sdqn"] = qp
    qpn, _ = train_rl.train_and_select(jax.random.fold_in(key, 1), TCFG, CFG,
                                       presets.SDQN_N_PRESET,
                                       n_seeds=presets.N_SELECTION_SEEDS)
    out["sdqn_n"] = qpn

    def pick_supervised(init_fn, score_fn, salt):
        best, bestm = None, np.inf
        # one compilation for all seeds: params flow through the evaluator
        evaluator = eval_engine.make_param_evaluator(
            CFG, lambda p: schedulers.make_neural_selector(p, score_fn, CFG), 50)
        val_keys = eval_engine.fixed_trial_keys(5000, 6)
        for s in range(presets.N_SUPERVISED_SEEDS):
            p = train_rl.train_supervised_scorer(
                jax.random.fold_in(key, salt + s), TCFG, init_fn, score_fn,
                episodes=presets.SUPERVISED_EPISODES)
            m = float(np.mean(np.asarray(evaluator(p, val_keys).metric)))
            if m < bestm:
                best, bestm = p, m
        return best

    out["lstm"] = pick_supervised(baselines.init_lstm, baselines.lstm_score, 70)
    out["transformer"] = pick_supervised(baselines.init_transformer,
                                         baselines.transformer_score, 90)
    return out


def _selector(name: str):
    if name == "default":
        return schedulers.make_kube_selector(CFG)
    pol = policies()
    if name in ("sdqn", "sdqn_n"):
        return schedulers.make_sdqn_selector(pol[name], CFG)
    score_fn = baselines.lstm_score if name == "lstm" else baselines.transformer_score
    return schedulers.make_neural_selector(pol[name], score_fn, CFG)


def _table(name: str, label: str) -> Tuple[str, float, float]:
    rows, mets, mean, cv, dt_us = _trials(_selector(name))
    print(f"\n--- {label} ({name}) ---")
    print("trial | slave1 slave2 slave3 slave4 | avg CPU util")
    for i, (dist, m) in enumerate(zip(rows, mets)):
        print(f"  {i + 1}   | {dist[0]:6d} {dist[1]:6d} {dist[2]:6d} {dist[3]:6d} | {m:6.2f}%")
    print(f"  mean={mean:.2f}%  CV={cv:.2f}%   (paper: {PAPER[name]:.2f}%)")
    return name, dt_us, mean


def table8():
    return _table("default", "Table 8: default kube-scheduler, 5 trials")


def table9():
    return _table("sdqn", "Table 9: SDQN scheduler, 5 trials")


def table10():
    return _table("sdqn_n", "Table 10: SDQN-n (n=2) scheduler, 5 trials")


def table11():
    return _table("lstm", "Table 11: LSTM-based scheduler, 5 trials")


def table12():
    return _table("transformer", "Table 12: Transformer-based scheduler, 5 trials")


def figure6():
    """Comparison chart + validation of the paper's headline claims."""
    means = {}
    for name in ("default", "sdqn", "sdqn_n", "lstm", "transformer"):
        _, _, mean, _, _ = _trials(_selector(name))
        means[name] = mean
    d = means["default"]
    print("\n--- Figure 6: comparison of schedulers (avg CPU %, lower=better) ---")
    print(f"{'scheduler':14s} {'ours':>8s} {'paper':>8s} {'rel-to-default':>15s}")
    for name in means:
        rel = 100.0 * (means[name] / d - 1.0)
        print(f"{name:14s} {means[name]:7.2f}% {PAPER[name]:7.2f}% {rel:+14.1f}%")

    sdqn_rel = means["sdqn"] / d - 1.0
    sdqnn_rel = means["sdqn_n"] / d - 1.0
    claims = {
        "claim1_sdqn_reduces_~10pct": sdqn_rel <= -0.05,
        "claim2_sdqn_n_exceeds_20pct": sdqnn_rel <= -0.20,
        "claim3_lstm_tr_no_advantage": (
            means["lstm"] >= means["sdqn"] and means["transformer"] >= means["sdqn_n"]
        ),
    }
    print("claims:", {k: ("PASS" if v else "FAIL") for k, v in claims.items()})
    return ("figure6", 0.0, d), claims, means


def literal_ablation():
    """EXPERIMENTS.md §Perf ablation: the literal Table-4 bandit update."""
    rl = presets.SDQN_LITERAL_PRESET
    qp, _ = train_rl.train_and_select(jax.random.PRNGKey(7), TCFG, CFG, rl, n_seeds=3)
    _, mets, mean, _, dt_us = _trials(schedulers.make_sdqn_selector(qp, CFG))
    print(f"\n--- Ablation: literal Table-4 (bandit, unshaped) SDQN: {mean:.2f}% ---")
    return "sdqn_literal", dt_us, mean


def policy_class_table(train_episodes: int = 40, trials: int = 5,
                       n_pods: int = 50):
    """Beyond-paper: the policy-class registry head-to-head on the Table-8
    protocol.

    The paper's Tables 11/12 compare SDQN against *separately built* LSTM and
    Transformer schedulers (supervised scorers with their own training
    loops).  The registry (``repro.core.policy``) makes that comparison
    apples-to-apples: kube vs the Table-4 MLP vs the set-attention scorer vs
    the Mamba arrival-history encoder, every learned class trained through
    the SAME seed-parallel Q-learning engine with an equal episode budget and
    evaluated on the same fixed trial keys.  Rows:
    ``policy_class_<kube|mlp|attention|mamba>``, derived = avg-CPU mean.
    """
    import dataclasses

    from repro.core import policy as policy_mod

    rows = []
    print("\n--- Policy-class table: registry head-to-head, Table-8 protocol ---")
    _, _, mean, cv, dt_us = _trials(schedulers.make_kube_selector(CFG),
                                    trials, n_pods)
    print(f"  {'kube':10s} avg_cpu={mean:6.2f}%  CV={cv:.2f}%")
    rows.append(("policy_class_kube", dt_us, mean))
    rl0 = dataclasses.replace(presets.SDQN_PRESET, episodes=train_episodes)
    for i, name in enumerate(sorted(policy_mod.names())):
        rl = dataclasses.replace(rl0, policy=name)
        qp, _ = train_rl.train_and_select(
            jax.random.fold_in(jax.random.PRNGKey(11), i), TCFG, CFG, rl,
            n_seeds=2)
        sel = schedulers.make_policy_selector(policy_mod.get(name), qp, CFG)
        _, _, mean, cv, dt_us = _trials(sel, trials, n_pods)
        print(f"  {name:10s} avg_cpu={mean:6.2f}%  CV={cv:.2f}%")
        rows.append((f"policy_class_{name}", dt_us, mean))
    return rows


def scenario_generalization(trials: int = 3, n_pods=None, train_episodes=None):
    """Beyond-paper: one mixture-trained SDQN vs the default scheduler across
    every registry scenario (the paper's closing claim — strategies must be
    tailored per scenario — measured rather than asserted)."""
    from benchmarks import scenario_bench

    print("\n--- Scenario generalization: default vs mixture-trained SDQN ---")
    return scenario_bench.sweep(
        trials=trials,
        n_pods=n_pods,
        train_episodes=train_episodes or presets.SDQN_SCENARIO_MIX_PRESET.episodes,
    )
