"""Chaos serving benchmark: placements/sec, p99, and lost-pod rate under
mid-replay node failures — SDQN-with-fallback vs the kube heuristic.

Sweeps an offered-rate x failure-count grid.  Each cell replays one scenario
arrival trace through ``repro.sched.daemon`` while a deterministic chaos
schedule fails (and later recovers) random nodes mid-replay; every failure
evicts the node's bound pods through the daemon's health watchdog and
auto-requeues them.  Two arms per cell:

  * ``sdqn`` — the Q-net daemon with the full robustness stack on:
    admission backpressure (``queue_cap``), conflict backoff
    (``backoff_base_s``), and the per-batch scoring deadline with graceful
    degradation to the kube heuristic (``score_deadline_s``).
  * ``kube`` — ``heuristic_only=True``: every batch served by the
    closed-form LeastRequested+Balanced scorer.  This arm doubles as the
    degraded-mode floor — it is exactly what the sdqn arm degrades to.

Rows (per arm A, rate R req/s, F injected failures):
  * ``chaos_<A>_rate<R>_fail<F>_throughput`` — derived = requests/sec served
  * ``chaos_<A>_rate<R>_fail<F>_p99_ms``     — decision latency p99
  * ``chaos_<A>_rate<R>_fail<F>_lost_ratio`` — (dropped + shed) / submitted
  * ``chaos_<A>_rate<R>_fail<F>_evictions``  — pods evicted off failed nodes
plus ``chaos_degraded_throughput`` — the kube arm's zero-failure throughput
at the base rate, the committed degraded-mode serving floor.

CI gates (see ``check_smoke --chaos``): every ``*_lost_ratio`` row against
the committed baseline with ABSOLUTE slack (lost ratios are legitimately 0.0
in calm cells, so relative tolerance is meaningless), and
``chaos_degraded_throughput`` as a ``--throughput-row`` floor.

    PYTHONPATH=src python -m benchmarks.run --chaos-smoke --json out.json
    PYTHONPATH=src python -m benchmarks.run --chaos            # nightly grid
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import numpy as np

from repro.core import dqn, env as kenv
from repro.core.types import fleet_cluster
from repro.scenarios import arrival_trace
from repro.sched.daemon import (
    ClusterSubstrate,
    DaemonConfig,
    PlacementDaemon,
    replay_trace,
)

# Full (nightly) grid; the smoke grid is a single-rate subset sized for the
# CI container.  Failure counts are absolute (injected per replay) rather
# than rates — a replay lasts under a second, so a per-second rate would
# round to zero events and the chaos path would never run.
RATES_PER_S = (500.0, 2000.0)
FAILURES = (0, 8, 32)
MTTR_FRAC = 0.2            # node comes back after 20% of the replay window

ARM_CONFIGS = {
    "sdqn": dict(score_deadline_s=0.25, degrade_batches=4,
                 queue_cap=256, backoff_base_s=0.0005),
    "kube": dict(heuristic_only=True, queue_cap=256),
}


def chaos_events(seed: int, n_nodes: int, n_failures: int,
                 duration_s: float) -> List[Tuple[float, str, int]]:
    """Deterministic fail/recover schedule: ``n_failures`` distinct nodes go
    down at times spread through the middle of the replay window, each
    recovering ``MTTR_FRAC * duration_s`` later (possibly after the replay —
    ``replay_trace`` applies leftovers before the final drain)."""
    rng = np.random.default_rng(seed)
    nodes = rng.choice(n_nodes, size=min(n_failures, n_nodes), replace=False)
    events: List[Tuple[float, str, int]] = []
    for node in nodes:
        t = float(rng.uniform(0.1, 0.9) * duration_s)
        events.append((t, "fail", int(node)))
        events.append((t + MTTR_FRAC * duration_s, "recover", int(node)))
    return sorted(events)


def _serve_cell(arm: str, rate: float, n_failures: int, n_nodes: int,
                n_requests: int, batch_size: int,
                max_wait_s: float) -> List[Tuple[str, float, float]]:
    qparams = dqn.init_qnet(jax.random.PRNGKey(0))
    cfg = fleet_cluster(n_nodes)
    state = kenv.reset(jax.random.PRNGKey(1), cfg)
    sub = ClusterSubstrate(state, cfg)
    daemon = PlacementDaemon(
        sub, qparams,
        DaemonConfig(batch_size=batch_size, max_wait_s=max_wait_s,
                     **ARM_CONFIGS[arm]))
    if arm != "kube":
        daemon.warmup()          # compile outside the timing window
    trace = arrival_trace(jax.random.PRNGKey(2), cfg, n_requests,
                          rate_per_s=rate)
    duration = n_requests / rate
    events = chaos_events(seed=7 * n_failures + 3, n_nodes=n_nodes,
                          n_failures=n_failures, duration_s=duration)
    dur = replay_trace(daemon, trace.t_s, trace.pods, events=events)
    m = daemon.metrics
    assert m.bound + m.dropped + m.shed == m.submitted, \
        "request accounting broken: every submit must resolve exactly once"
    assert len(daemon.decisions) == m.submitted
    tag = f"chaos_{arm}_rate{int(rate)}_fail{n_failures}"
    return [
        (f"{tag}_throughput", dur / n_requests * 1e6, n_requests / dur),
        (f"{tag}_p99_ms", 0.0, m.bind_latencies_s.p99() * 1e3),
        (f"{tag}_lost_ratio", 0.0, (m.dropped + m.shed) / m.submitted),
        (f"{tag}_evictions", 0.0, float(m.evictions)),
    ]


def grid_rows(rates: Sequence[float], failures: Sequence[int],
              n_nodes: int = 64, n_requests: int = 400,
              batch_size: int = 32,
              max_wait_s: float = 0.005) -> List[Tuple[str, float, float]]:
    rows: List[Tuple[str, float, float]] = []
    for rate in rates:
        for n_fail in failures:
            for arm in ARM_CONFIGS:
                rows += _serve_cell(arm, rate, n_fail, n_nodes, n_requests,
                                    batch_size, max_wait_s)
    # the committed degraded-mode serving floor: kube-heuristic throughput
    # at the base rate with no chaos (what a fully degraded daemon sustains)
    base = f"chaos_kube_rate{int(rates[0])}_fail0_throughput"
    floor = next(r for r in rows if r[0] == base)
    rows.append(("chaos_degraded_throughput", floor[1], floor[2]))
    return rows


def rows() -> List[Tuple[str, float, float]]:
    """The full nightly grid."""
    return grid_rows(RATES_PER_S, FAILURES)


def smoke_rows() -> List[Tuple[str, float, float]]:
    """CI-sized grid: one rate, calm + stormy cells (the sizing
    ``benchmarks/baseline_chaos.json`` is gated at)."""
    return grid_rows(rates=(500.0,), failures=(0, 8), n_requests=300)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in smoke_rows():
        print(f"{name},{us:.1f},{derived}")
