"""Policy-class comparison bench: every registered scheduler policy class
(``repro.core.policy``) vs the default kube-scheduler, plus per-class
train-step throughput.

    PYTHONPATH=src python -m benchmarks.run --policy-compare --json out.json

Two row families (``name,us_per_call,derived`` like every bench here):

* ``policy_train_step_<class>`` — one learner step of that class's Q-net on a
  replay batch; ``derived`` = transitions/s.  Gated as a throughput floor in
  CI: a de-jitted loss or an accidentally sequential attention/Mamba forward
  shows up as an order-of-magnitude drop.
* ``policy_compare_<scenario>_<class>`` — avg-CPU metric (the paper's
  objective, lower = better) of a tiny-budget net of that class on two
  registry scenarios, next to a ``..._kube`` row.  CI gates the
  ``<class>/kube`` ratio per class, so container speed cancels and what must
  not regress is each policy class still beating (or at worst matching) the
  default scheduler at smoke scale.

Training budgets here are CI-sized (seconds, not the paper's presets) — the
rows rank policy *classes* under an equal tiny budget; the paper-fidelity
numbers live in ``paper_tables.policy_class_table``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro import scenarios
from repro.core import policy as policy_mod, schedulers, train_rl
from repro.core.types import training_cluster
from repro.eval import engine as eval_engine
from repro.train import engine as train_engine

# the smoke pair: the paper's own cluster shape + a heterogeneous one, so the
# gate sees both the reproduction setting and a generalization setting
SCENARIOS = ("paper-burst", "hetero-bigsmall")
POLICY_CLASSES = tuple(sorted(policy_mod.names()))


@functools.lru_cache(maxsize=None)
def trained(policy: str, episodes: int = 12):
    """One tiny-budget net per policy class (cached across scenarios)."""
    rl = dataclasses.replace(train_rl.RLConfig(), policy=policy,
                             episodes=episodes, n_envs=4,
                             pods_per_episode=20, buffer_capacity=1024,
                             batch_size=64)
    stacked, _ = train_engine.train_seeds(jax.random.PRNGKey(42),
                                          training_cluster(), rl, 1)
    return jax.tree.map(lambda x: x[0], stacked)


def train_step_rows(batch_size: int = 256,
                    iters: int = 30) -> List[Tuple[str, float, float]]:
    """``policy_train_step_<class>`` learner-step throughput rows."""
    rows = []
    key = jax.random.PRNGKey(0)
    for i, name in enumerate(POLICY_CLASSES):
        spec = policy_mod.get(name)
        params, opt_state = policy_mod.init_train_state(
            spec, jax.random.fold_in(key, 10 + i))
        step = jax.jit(policy_mod.make_train_step(spec))
        feats = jax.random.normal(jax.random.fold_in(key, 1),
                                  (batch_size, spec.feature_dim),
                                  dtype=jnp.float32)
        targets = jax.random.normal(jax.random.fold_in(key, 2),
                                    (batch_size,), dtype=jnp.float32)
        params, opt_state, loss, _ = step(params, opt_state, feats, targets)
        jax.block_until_ready(loss)  # compile outside the timed window
        t0 = time.time()
        for _ in range(iters):
            params, opt_state, loss, _ = step(params, opt_state, feats,
                                              targets)
        jax.block_until_ready(loss)
        us = (time.time() - t0) / iters * 1e6
        rows.append((f"policy_train_step_{name}", us, batch_size / us * 1e6))
        print(f"  train_step {name:10s} {us:8.1f} us/step "
              f"({batch_size / us * 1e6:,.0f} transitions/s)")
    return rows


def bench_scenario(
    name: str,
    trials: int = 1,
    n_pods: int = 20,
    train_episodes: int = 12,
) -> List[Tuple[str, float, float]]:
    """kube + every policy class on one scenario, batched-trial protocol."""
    env_cfg = scenarios.make_env(name)
    rows = []
    for pol in ("kube",) + POLICY_CLASSES:
        if pol == "kube":
            sel = schedulers.make_kube_selector(env_cfg)
        else:
            sel = schedulers.make_policy_selector(
                policy_mod.get(pol), trained(pol, train_episodes), env_cfg)
        # batched trial runner: all trials are ONE vmapped XLA launch
        # (make_policy_selector's (select, carry0) pairs thread through)
        ep = scenarios.batch_episode(env_cfg, sel, n_pods)
        jax.block_until_ready(
            ep(eval_engine.trial_keys(jax.random.PRNGKey(0), trials)))
        t0 = time.time()
        res = scenarios.evaluate_scenario(
            jax.random.PRNGKey(100), env_cfg, sel, trials=trials,
            n_pods=n_pods, episode=ep)
        us = (time.time() - t0) / trials * 1e6
        rows.append((f"policy_compare_{name}_{pol}", us, res["metric_mean"]))
        print(f"  {name:18s} {pol:10s} avg_cpu={res['metric_mean']:6.2f}%"
              f" (+-{res['metric_std']:.2f})"
              f"  placed={res['pods_placed_mean']:.0f}/{res['n_pods']:.0f}")
    return rows


def smoke_rows(
    trials: int = 1,
    n_pods: int = 20,
    train_episodes: int = 12,
) -> List[Tuple[str, float, float]]:
    """CI-sized policy-class comparison: throughput + both smoke scenarios."""
    print("\n--- policy-class comparison (avg CPU %, lower = better) ---")
    rows = train_step_rows()
    for name in SCENARIOS:
        rows += bench_scenario(name, trials=trials, n_pods=n_pods,
                               train_episodes=train_episodes)
    return rows
