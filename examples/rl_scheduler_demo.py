"""Full paper-reproduction demo: all five schedulers on the paper cluster,
printed side-by-side against the paper's published numbers (Tables 8-12).

    PYTHONPATH=src python examples/rl_scheduler_demo.py
"""
from benchmarks import paper_tables

if __name__ == "__main__":
    paper_tables.table8()
    paper_tables.table9()
    paper_tables.table10()
    paper_tables.table11()
    paper_tables.table12()
    paper_tables.figure6()
