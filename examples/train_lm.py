"""End-to-end driver: train a ~100M-parameter OLMo-style LM for a few hundred
steps on the synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    # olmo-1b family, shrunk to ~100M params: 8 layers x d_model 768
    losses = train_mod.main([
        "--arch", "olmo-1b",
        "--d-model", "768",
        "--layers", "8",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "256",
        "--lr", "1e-3",
        "--microbatches", "2",
        "--ckpt-dir", "/tmp/repro_train_lm",
        "--ckpt-every", "100",
        "--log-every", "25",
    ])
    first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
    assert last < first, "loss should decrease"
    print(f"OK: loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
