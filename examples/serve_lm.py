"""Serve a small LM with batched requests routed across replicas by SDQN —
the paper's scheduler reused at the serving tier.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as serve_mod

if __name__ == "__main__":
    serve_mod.main([
        "--arch", "olmo-1b", "--smoke",
        "--replicas", "4",
        "--requests", "32",
        "--wave-size", "8",
        "--prompt-len", "32",
        "--gen-tokens", "16",
    ])
