"""Green-datacenter demo (paper §1 contribution 2 / §6): train SDQN-n, run
consolidation at fleet scale, and report the hosts that can be powered down.

    PYTHONPATH=src python examples/green_datacenter.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import presets, train_rl
from repro.core.types import paper_cluster, training_cluster
from repro.sched import JobSpec, PlacementEngine
from repro.sched.elastic import consolidation_plan
from repro.sched.placement import fresh_fleet

# 1. train the consolidating SDQN-n policy
print("training SDQN-n (Table-5 top-2 consolidation reward)...")
qparams, val = train_rl.train_and_select(
    jax.random.PRNGKey(0), training_cluster(), paper_cluster(),
    presets.SDQN_N_PRESET, n_seeds=3,
)
print(f"  validation avg-CPU: {val:.2f}%")

# 2. a 32-host fleet with a long tail of under-utilized hosts
engine = PlacementEngine(qparams, consolidate=True)
fleet = fresh_fleet(32, jax.random.PRNGKey(1))
job = JobSpec(cpu_pct_demand=4.0)
fleet, _ = engine.place_batch(fleet, 60, job)
# sprinkle a few stragglers of 1-2 jobs each (fragmentation)
for h in (3, 11, 19, 27):
    fleet = engine.place(fleet, h, job)

print(f"\nbefore: {int((np.asarray(fleet.num_jobs) > 0).sum())} active hosts, "
      f"fleet avg CPU {float(jnp.mean(fleet.cpu_pct)):.1f}%")

# 3. consolidation plan: migrate jobs off nearly-idle hosts
plan = consolidation_plan(engine, fleet, job, idle_threshold_jobs=2)
print(f"plan: migrate {len(plan.migrations)} jobs, free {plan.hosts_freed} hosts "
      f"{plan.drain_hosts}")
print(f"fleet avg CPU: {plan.projected_avg_cpu_before:.1f}% -> "
      f"{plan.projected_avg_cpu_after:.1f}% (freed hosts can be POWERED DOWN)")
