"""Quickstart: train SDQN on the paper cluster, schedule a pod burst, compare
with the default kube-scheduler — the paper's core result in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import env as kenv, presets, schedulers, train_rl
from repro.core.types import paper_cluster, training_cluster

cfg = paper_cluster()          # 4 slave nodes, the paper's experimental cluster

# 1. train the SDQN scheduler (DQN over Table-2 node features, Table-3 rewards)
print("training SDQN (seed-selected on validation bursts)...")
qparams, val = train_rl.train_and_select(
    jax.random.PRNGKey(0), training_cluster(), cfg, presets.SDQN_PRESET, n_seeds=3
)
print(f"  best validation avg-CPU: {val:.2f}%")

# 2. schedule a 50-pod compute-intensive burst with both schedulers
for name, select in [
    ("default kube-scheduler", schedulers.make_kube_selector(cfg)),
    ("SDQN", schedulers.make_sdqn_selector(qparams, cfg)),
]:
    mets, dists = [], []
    episode = jax.jit(lambda k: kenv.run_episode(k, cfg, select, 50))
    for trial in range(3):
        res = episode(jax.random.PRNGKey(100 + trial))
        mets.append(float(res.metric))
        dists.append(np.asarray(res.state.exp_pods).tolist())
    print(f"{name:24s} avg CPU = {np.mean(mets):5.2f}%   pod distributions: {dists}")

print("\nSDQN places pods by learned Q-values over real-time node state —")
print("the default scheduler only sees resource *requests* (paper §3.2).")
