"""Dev scratch: train SDQN/SDQN-n quickly, compare all schedulers on the paper cluster."""
import time

import jax
import jax.numpy as jnp

from repro.core import baselines, dqn, env as kenv, schedulers, train_rl
from repro.core.types import paper_cluster, training_cluster

cfg = paper_cluster()
train_cfg = training_cluster()
key = jax.random.PRNGKey(0)


def evaluate(name, select, trials=5, n_pods=50):
    dists, mets = [], []
    for t in range(trials):
        k = jax.random.PRNGKey(100 + t)
        res = jax.jit(
            lambda kk: kenv.run_episode(kk, cfg, select, n_pods)
        )(k)
        dists.append([int(x) for x in res.placements])
        mets.append(float(res.metric))
    avg = sum(mets) / len(mets)
    print(f"{name:18s} avg_cpu={avg:6.2f}%  trials={[f'{m:.2f}' for m in mets]}")
    for d, m in zip(dists, mets):
        print(f"    dist={d} -> {m:.2f}%")
    return avg


t0 = time.time()
rl = train_rl.RLConfig(variant="sdqn", episodes=1200, n_envs=16, eps_end=0.1, batch_size=256)
qp_sdqn, m1 = jax.jit(lambda k: train_rl.train(k, train_cfg, rl))(key)
print(f"SDQN trained in {time.time()-t0:.1f}s; last-ep avg_cpu={float(m1['avg_cpu'][-1]):.2f} loss={float(m1['loss'][-1]):.1f}")

t0 = time.time()
rl_n = train_rl.RLConfig(variant="sdqn_n", episodes=1200, n_envs=16, eps_end=0.1, batch_size=256)
qp_sdqnn, m2 = jax.jit(lambda k: train_rl.train(k, train_cfg, rl_n))(key)
print(f"SDQN-n trained in {time.time()-t0:.1f}s; last-ep avg_cpu={float(m2['avg_cpu'][-1]):.2f} loss={float(m2['loss'][-1]):.1f}")

t0 = time.time()
lstm_p = train_rl.train_supervised_scorer(key, train_cfg, baselines.init_lstm, baselines.lstm_score, episodes=30)
tr_p = train_rl.train_supervised_scorer(key, train_cfg, baselines.init_transformer, baselines.transformer_score, episodes=30)
print(f"baselines trained in {time.time()-t0:.1f}s")

default_avg = evaluate("default", schedulers.make_kube_selector(cfg))
sdqn_avg = evaluate("SDQN", schedulers.make_sdqn_selector(qp_sdqn, cfg))
sdqnn_avg = evaluate("SDQN-n", schedulers.make_sdqn_selector(qp_sdqnn, cfg))
lstm_avg = evaluate("LSTM", schedulers.make_neural_selector(lstm_p, baselines.lstm_score, cfg))
tr_avg = evaluate("Transformer", schedulers.make_neural_selector(tr_p, baselines.transformer_score, cfg))

print(f"\npaper:  default 30.87 | SDQN 27.21 (-11.9% rel) | SDQN-n 22.35 (-27.6% rel) | LSTM 30.53 | TR 30.15")
print(f"ours:   default {default_avg:.2f} | SDQN {sdqn_avg:.2f} ({100*(sdqn_avg/default_avg-1):+.1f}% rel) | "
      f"SDQN-n {sdqnn_avg:.2f} ({100*(sdqnn_avg/default_avg-1):+.1f}% rel) | LSTM {lstm_avg:.2f} | TR {tr_avg:.2f}")
