"""Diagnose one episode step-by-step: node profiles, Q spreads, placements, metric."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, dqn, env as kenv, rewards, schedulers, train_rl
from repro.core.types import paper_cluster

cfg = paper_cluster()
key = jax.random.PRNGKey(0)

rl = train_rl.RLConfig(variant="sdqn", episodes=80, n_envs=8)
qp, m1 = jax.jit(lambda k: train_rl.train(k, cfg, rl))(key)
rl_n = train_rl.RLConfig(variant="sdqn_n", episodes=80, n_envs=8)
qpn, _ = jax.jit(lambda k: train_rl.train(k, cfg, rl_n))(key)

for trial_key, name in [(jax.random.PRNGKey(100), "trial100"), (jax.random.PRNGKey(101), "trial101")]:
    print(f"\n=== {name} ===")
    st = kenv.reset(trial_key, cfg)
    pod = kenv.default_pod(cfg)
    print("base_cpu   :", np.round(np.asarray(st.base_cpu), 0))
    print("requested  :", np.round(np.asarray(st.cpu_requested), 0))
    print("uptime_h   :", np.round(np.asarray(st.uptime_hours), 1))

    for sched_name, select in [
        ("default", schedulers.make_kube_selector(cfg)),
        ("sdqn", schedulers.make_sdqn_selector(qp, cfg)),
        ("sdqn_n", schedulers.make_sdqn_selector(qpn, cfg)),
    ]:
        s = kenv.reset(trial_key, cfg)
        traj = []
        mets = []
        for t in range(50):
            k = jax.random.fold_in(trial_key, t)
            if sched_name != "default":
                ok = kenv.feasible(s, pod, cfg)
                q = schedulers.score_afterstates(qp if sched_name == "sdqn" else qpn, s, pod, cfg)
                if t in (0, 1, 5, 20, 49):
                    print(f"  [{sched_name} t={t}] q={np.round(np.asarray(q),2)} ok={np.asarray(ok).astype(int)} cpu%={np.round(np.asarray(kenv.cpu_pct(s,cfg)),1)}")
            a = int(select(k, s, pod))
            s = kenv.place(s, a, pod, cfg)
            s = kenv.tick(s, cfg, cfg.schedule_dt_s)
            traj.append(a)
            mets.append(float(kenv.average_cpu_utilization(s, cfg)))
        for t in range(cfg.settle_steps):
            s = kenv.tick(s, cfg, cfg.schedule_dt_s)
            mets.append(float(kenv.average_cpu_utilization(s, cfg)))
        dist = np.asarray(s.num_pods)
        print(f"  {sched_name:8s} dist={dist} metric={np.mean(mets):.2f}% final_cpu%={np.round(np.asarray(kenv.cpu_pct(s,cfg)),1)}")
