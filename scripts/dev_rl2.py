"""Seed-selected training comparison."""
import time
import jax
from repro.core import baselines, env as kenv, schedulers, train_rl
from repro.core.types import paper_cluster, training_cluster

cfg = paper_cluster()
tcfg = training_cluster()
key = jax.random.PRNGKey(0)

def evaluate(name, select, trials=5, n_pods=50):
    mets, dists = [], []
    ep = jax.jit(lambda kk: kenv.run_episode(kk, cfg, select, n_pods))
    for t in range(trials):
        res = ep(jax.random.PRNGKey(100 + t))
        mets.append(float(res.metric))
        dists.append([int(x) for x in res.state.exp_pods])
    avg = sum(mets) / len(mets)
    print(f"{name:12s} avg={avg:6.2f}%  trials={[f'{m:.1f}' for m in mets]} dists={dists}")
    return avg

t0=time.time()
rl = train_rl.RLConfig(variant="sdqn", episodes=500, n_envs=16, eps_end=0.05, batch_size=256, efficiency_weight=5.0)
qp, vm = train_rl.train_and_select(key, tcfg, cfg, rl, n_seeds=6)
print(f"SDQN selected val={vm:.2f} ({time.time()-t0:.0f}s)")
t0=time.time()
rln = train_rl.RLConfig(variant="sdqn_n", episodes=500, n_envs=16, eps_end=0.05, batch_size=256)
qpn, vmn = train_rl.train_and_select(key, tcfg, cfg, rln, n_seeds=6)
print(f"SDQN-n selected val={vmn:.2f} ({time.time()-t0:.0f}s)")

def select_scorer(init_fn, score_fn, n_seeds=4):
    best, bestm = None, 1e9
    for sd in range(n_seeds):
        p = train_rl.train_supervised_scorer(jax.random.fold_in(key, 70+sd), tcfg, init_fn, score_fn, episodes=30)
        sel = schedulers.make_neural_selector(p, score_fn, cfg)
        ep = jax.jit(lambda kk: kenv.run_episode(kk, cfg, sel, 50).metric)
        m = float(sum(ep(jax.random.PRNGKey(5000+t)) for t in range(6)) / 6)
        if m < bestm: best, bestm = p, m
    return best

lstm_p = select_scorer(baselines.init_lstm, baselines.lstm_score)
tr_p = select_scorer(baselines.init_transformer, baselines.transformer_score)

d = evaluate("default", schedulers.make_kube_selector(cfg))
s1 = evaluate("SDQN", schedulers.make_sdqn_selector(qp, cfg))
s2 = evaluate("SDQN-n", schedulers.make_sdqn_selector(qpn, cfg))
l = evaluate("LSTM", schedulers.make_neural_selector(lstm_p, baselines.lstm_score, cfg))
tr = evaluate("Transformer", schedulers.make_neural_selector(tr_p, baselines.transformer_score, cfg))
print(f"\npaper: default 30.87 | SDQN -11.9% | SDQN-n -27.6% | LSTM -1.1% | TR -2.3%")
print(f"ours:  default {d:.2f} | SDQN {100*(s1/d-1):+.1f}% | SDQN-n {100*(s2/d-1):+.1f}% | LSTM {100*(l/d-1):+.1f}% | TR {100*(tr/d-1):+.1f}%")
