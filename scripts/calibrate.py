"""Random-search calibration of the cluster-simulation constants against the
paper's measured bands (Tables 8-12):

    default 30.87% | SDQN -11.9% rel | SDQN-n -27.6% rel | LSTM ~-1.1% | TR ~-2.3%

For each candidate EnvConfig we TRAIN SDQN and SDQN-n from scratch (the
policies must emerge from learning, not be scripted) plus the supervised
baselines, evaluate 5 trials each on the clean paper cluster, and score the
match.  Writes the best config to scripts/calib_best.json.
"""
import dataclasses
import json
import sys
import time

import jax
import numpy as np

from repro.core import baselines, env as kenv, schedulers, train_rl
from repro.core.types import EnvConfig, paper_cluster

PAPER = {"default": 30.87, "sdqn_rel": -0.119, "sdqnn_rel": -0.276,
         "lstm_rel": -0.011, "tr_rel": -0.023}


def evaluate(select, trials=5, n_pods=50, cfg=None):
    mets, dists = [], []
    ep = jax.jit(lambda kk: kenv.run_episode(kk, cfg, select, n_pods))
    for t in range(trials):
        res = ep(jax.random.PRNGKey(100 + t))
        mets.append(float(res.metric))
        dists.append(np.asarray(res.placements))
    return float(np.mean(mets)), dists


def run_config(cfg: EnvConfig, seed=0, episodes=300):
    tcfg = dataclasses.replace(cfg, randomize_workload=True)
    key = jax.random.PRNGKey(seed)
    rl = train_rl.RLConfig(variant="sdqn", episodes=episodes, n_envs=16,
                           eps_end=0.05, batch_size=256)
    qp, _ = jax.jit(lambda k: train_rl.train(k, tcfg, rl))(key)
    rln = dataclasses.replace(rl, variant="sdqn_n")
    qpn, _ = jax.jit(lambda k: train_rl.train(k, tcfg, rln))(key)
    lstm_p = train_rl.train_supervised_scorer(key, tcfg, baselines.init_lstm,
                                              baselines.lstm_score, episodes=40)
    tr_p = train_rl.train_supervised_scorer(key, tcfg, baselines.init_transformer,
                                            baselines.transformer_score, episodes=40)
    out = {}
    out["default"], d_def = evaluate(schedulers.make_kube_selector(cfg), cfg=cfg)
    out["sdqn"], d_sdqn = evaluate(schedulers.make_sdqn_selector(qp, cfg), cfg=cfg)
    out["sdqnn"], d_sdqnn = evaluate(schedulers.make_sdqn_selector(qpn, cfg), cfg=cfg)
    out["lstm"], _ = evaluate(schedulers.make_neural_selector(lstm_p, baselines.lstm_score, cfg), cfg=cfg)
    out["tr"], _ = evaluate(schedulers.make_neural_selector(tr_p, baselines.transformer_score, cfg), cfg=cfg)
    out["dists"] = {"default": [d.tolist() for d in d_def],
                    "sdqn": [d.tolist() for d in d_sdqn],
                    "sdqnn": [d.tolist() for d in d_sdqnn]}
    return out


def score(out):
    d = out["default"]
    rels = {
        "sdqn_rel": out["sdqn"] / d - 1,
        "sdqnn_rel": out["sdqnn"] / d - 1,
        "lstm_rel": out["lstm"] / d - 1,
        "tr_rel": out["tr"] / d - 1,
    }
    loss = ((d - PAPER["default"]) / 10.0) ** 2
    loss += 8.0 * (rels["sdqn_rel"] - PAPER["sdqn_rel"]) ** 2 / 0.01
    loss += 8.0 * (rels["sdqnn_rel"] - PAPER["sdqnn_rel"]) ** 2 / 0.01
    loss += 2.0 * (rels["lstm_rel"] - PAPER["lstm_rel"]) ** 2 / 0.01
    loss += 2.0 * (rels["tr_rel"] - PAPER["tr_rel"]) ** 2 / 0.01
    return loss, rels


def sample_config(rng: np.random.RandomState) -> EnvConfig:
    busy = rng.uniform(1000, 2100)
    rest = rng.uniform(80, 420, size=3)
    return dataclasses.replace(
        paper_cluster(),
        pod_cpu_demand=float(rng.uniform(15, 40)),
        node_active_overhead=float(rng.uniform(100, 380)),
        image_pull_cost=float(rng.uniform(900, 2600)),
        warm_start_cost=float(rng.uniform(20, 80)),
        startup_decay=float(rng.uniform(0.82, 0.93)),
        pull_concurrency_coeff=float(rng.uniform(0.0, 0.8)),
        contention_knee=float(rng.uniform(0.55, 0.72)),
        contention_coeff=float(rng.uniform(40, 260)),
        crowd_knee=int(rng.randint(18, 28)),
        crowd_coeff=float(rng.uniform(2, 18)),
        base_cpu_profile=(busy, float(max(rest)), float(np.median(rest)), float(min(rest))),
    )


def main():
    n_iter = int(sys.argv[1]) if len(sys.argv) > 1 else 60
    rng = np.random.RandomState(0)
    results = []
    t0 = time.time()
    # iteration 0 = current defaults
    candidates = [paper_cluster()] + [sample_config(rng) for _ in range(n_iter - 1)]
    for i, cfg in enumerate(candidates):
        try:
            out = run_config(cfg)
            loss, rels = score(out)
        except Exception as e:  # noqa: BLE001
            print(f"[{i}] FAILED {e}")
            continue
        results.append((loss, i, out, dataclasses.asdict(cfg)))
        print(f"[{i}] loss={loss:7.2f} default={out['default']:5.2f} "
              f"sdqn={100*rels['sdqn_rel']:+5.1f}% sdqnn={100*rels['sdqnn_rel']:+5.1f}% "
              f"lstm={100*rels['lstm_rel']:+5.1f}% tr={100*rels['tr_rel']:+5.1f}% "
              f"({time.time()-t0:5.0f}s)", flush=True)
    results.sort(key=lambda r: r[0])
    print("\nTOP 5:")
    for loss, i, out, _ in results[:5]:
        print(f"  iter {i}: loss={loss:.2f} default={out['default']:.2f} "
              f"sdqn={out['sdqn']:.2f} sdqnn={out['sdqnn']:.2f} lstm={out['lstm']:.2f} tr={out['tr']:.2f}")
        print(f"    dists sdqn={out['dists']['sdqn'][:3]} sdqnn={out['dists']['sdqnn'][:3]}")
    best = results[0]
    with open("scripts/calib_best.json", "w") as f:
        json.dump({"loss": best[0], "iter": best[1], "metrics": {k: v for k, v in best[2].items() if k != "dists"},
                   "dists": best[2]["dists"], "config": best[3]}, f, indent=2)
    print("\nwrote scripts/calib_best.json")


if __name__ == "__main__":
    main()
