"""Dev scratch: fast check that every smoke arch runs fwd/train/prefill/decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs
from repro.models import model

def run(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    b, s = 2, 16
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_vision_tokens:
        batch["patch_embeds"] = jnp.ones((b, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16)

    loss, metrics = jax.jit(lambda p, bt: model.loss_and_metrics(p, cfg, bt, q_chunk=8, mamba_chunk=8))(params, batch)
    grads = jax.jit(jax.grad(lambda p, bt: model.loss_and_metrics(p, cfg, bt, q_chunk=8, mamba_chunk=8)[0]))(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))

    # prefill + decode
    logits, cache = jax.jit(lambda p, bt: model.prefill(p, cfg, bt["tokens"], bt, q_chunk=8, mamba_chunk=8))(params, batch)
    cache2 = model.init_cache(cfg, b, s + 4)
    lg2, cache2 = jax.jit(lambda p, t, c: model.decode_step(p, cfg, t, c, jnp.int32(s)))(params, tokens[:, :1], cache2)
    ok = bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gnorm)) and bool(jnp.all(jnp.isfinite(logits))) and bool(jnp.all(jnp.isfinite(lg2)))
    print(f"{arch:24s} params={n:9d} loss={float(loss):7.3f} gnorm={float(gnorm):9.3f} "
          f"prefill={logits.shape} decode={lg2.shape} finite={ok}")
    assert ok, arch

if __name__ == "__main__":
    archs = sys.argv[1:] or list_archs()
    for a in archs:
        run(a)
    print("ALL OK")
