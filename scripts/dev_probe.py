"""Probe the trained Q-net response to each feature, and audit training targets."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dqn, env as kenv, rewards, train_rl
from repro.core.types import paper_cluster, training_cluster

cfg = paper_cluster()
train_cfg = training_cluster()
key = jax.random.PRNGKey(0)

rl = train_rl.RLConfig(variant="sdqn", episodes=500, n_envs=16, eps_end=0.05, batch_size=256)
qp, m = jax.jit(lambda k: train_rl.train(k, train_cfg, rl))(key)
print("loss tail:", np.asarray(m["loss"][-5:]).round(1))

# Q vs cpu% sweep (other features fixed: mem 1%, podutil 10/110, healthy, uptime 50h, pods 10)
cpus = np.arange(0, 101, 10)
feats = np.stack([
    cpus,
    np.full_like(cpus, 1.0),
    np.full_like(cpus, 9.0),
    np.ones_like(cpus),
    np.full_like(cpus, 50.0),
    np.full_like(cpus, 10.0),
], -1).astype(np.float32)
q = dqn.qvalues(qp, kenv.normalize_features(jnp.asarray(feats)))
print("\ncpu%  -> Q:")
for c, qq in zip(cpus, np.asarray(q)):
    # true immediate reward for this afterstate (ignoring distribution term)
    r = float(rewards.node_points(jnp.asarray(feats[list(cpus).index(c)])))
    print(f"  cpu={c:3d}  Q={qq:8.2f}  node_points={r:7.1f}")

# Q vs num_pods sweep at fixed cpu 30%
pods = np.arange(0, 41, 5)
feats2 = np.stack([
    np.full_like(pods, 30.0), np.full_like(pods, 1.0),
    100.0 * pods / 110, np.ones_like(pods),
    np.full_like(pods, 50.0), pods,
], -1).astype(np.float32)
q2 = dqn.qvalues(qp, kenv.normalize_features(jnp.asarray(feats2)))
print("\nnum_pods -> Q (cpu fixed 30%):")
for p, qq in zip(pods, np.asarray(q2)):
    print(f"  pods={p:3d}  Q={qq:8.2f}")

# audit a batch of actual training transitions
st = kenv.reset(jax.random.PRNGKey(3), train_cfg)
pod = kenv.default_pod(train_cfg)
print("\nsample transition targets across actions:")
after_all = kenv.hypothetical_place(st, pod, train_cfg)
for a in range(4):
    st2 = kenv.place(st, a, pod, train_cfg)
    r = rewards.sdqn_reward(kenv.features(st2, train_cfg), a)
    qq = dqn.qvalues(qp, kenv.normalize_features(after_all[a]))
    print(f"  a={a} after_cpu={float(kenv.cpu_pct(st2,train_cfg)[a]):6.1f}% r={float(r):7.1f} Q={float(qq):7.2f}")
