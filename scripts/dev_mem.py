"""Bisect dry-run temp memory: remat policy x microbatches x metrics."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_config
from repro.launch import shapes as shp, sharding, steps
from repro.launch.mesh import make_production_mesh
from repro.optim import adam_init

arch = sys.argv[1] if len(sys.argv) > 1 else "olmo-1b"
shape = SHAPES["train_4k"]

for remat, micro in [("full", 256), ("full", 64), ("full", 16), ("dots", 64), ("none", 64)]:
    cfg = dataclasses.replace(get_config(arch), remat=remat)
    mesh = make_production_mesh()
    with mesh:
        params_shape = shp.params_specs(cfg)
        p_named = sharding.to_named(sharding.param_specs(params_shape, cfg, mesh), mesh)
        batch = shp.train_batch_specs(cfg, shape)
        b_named = sharding.to_named(sharding.input_sharding(mesh, batch), mesh)
        adam_cfg = steps.default_adam(cfg)
        opt_shape = jax.eval_shape(lambda p: adam_init(p, adam_cfg), params_shape)
        o_named = sharding.to_named(sharding.opt_state_specs(opt_shape, sharding.param_specs(params_shape, cfg, mesh), mesh), mesh)
        nm = max(1, shape.global_batch // micro)
        fn, _ = steps.make_train_step(cfg, adam_cfg, num_microbatches=nm, q_chunk=512)
        jitted = jax.jit(fn, in_shardings=(p_named, o_named, b_named), donate_argnums=(0, 1))
        compiled = jitted.lower(params_shape, opt_shape, batch).compile()
        ma = compiled.memory_analysis()
        print(f"remat={remat:5s} micro={micro:4d} temp={ma.temp_size_in_bytes/2**30:8.2f} GiB "
              f"args={ma.argument_size_in_bytes/2**20:7.1f} MiB flops={compiled.cost_analysis()['flops']:.3g}")
