"""Dev check: kernels in interpret mode vs ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 10)

# flash attention (GQA, causal)
b, sq, hq, hkv, d = 2, 128, 4, 2, 32
q = jax.random.normal(ks[0], (b, sq, hq, d), jnp.float32)
k = jax.random.normal(ks[1], (b, sq, hkv, d), jnp.float32)
v = jax.random.normal(ks[2], (b, sq, hkv, d), jnp.float32)
out_k = ops.flash_attention(q, k, v, causal=True, mode="interpret", block_q=32, block_k=32)
out_r = ref.flash_attention_ref(q, k, v, causal=True)
np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)
print("flash_attention causal OK", float(jnp.abs(out_k - out_r).max()))

out_k = ops.flash_attention(q, k, v, causal=False, mode="interpret", block_q=32, block_k=64)
out_r = ref.flash_attention_ref(q, k, v, causal=False)
np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)
print("flash_attention non-causal OK")

# XLA path matches ref too
from repro.models import layers
out_x = layers.attention(q, k, v, causal=True, q_chunk=32)
np.testing.assert_allclose(np.asarray(out_x), np.asarray(out_r := ref.flash_attention_ref(q, k, v, causal=True)), rtol=2e-5, atol=2e-5)
print("xla chunked attention OK")

# decode attention
skv = 256
qd = jax.random.normal(ks[3], (b, hq, d), jnp.float32)
kd = jax.random.normal(ks[4], (b, hkv, skv, d), jnp.float32)
vd = jax.random.normal(ks[5], (b, hkv, skv, d), jnp.float32)
for kv_len in [1, 100, 256]:
    out_k = ops.decode_attention(qd, kd, vd, jnp.int32(kv_len), mode="interpret", block_k=64)
    out_r = ref.decode_attention_ref(qd, kd, vd, jnp.int32(kv_len))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)
print("decode_attention OK")

# mamba scan
bsz, s, di, n = 2, 64, 16, 8
x = jax.random.normal(ks[6], (bsz, s, di), jnp.float32) * 0.5
dt = jax.nn.softplus(jax.random.normal(ks[7], (bsz, s, di), jnp.float32) * 0.3 - 1)
a = -jnp.exp(jax.random.normal(ks[8], (di, n), jnp.float32) * 0.3)
bm = jax.random.normal(ks[9], (bsz, s, n), jnp.float32) * 0.5
cm = jax.random.normal(ks[0], (bsz, s, n), jnp.float32) * 0.5
dsk = jnp.ones((di,), jnp.float32)
h0 = jnp.zeros((bsz, di, n), jnp.float32)
y_k, h_k = ops.mamba_scan(x, dt, a, bm, cm, dsk, h0, mode="interpret", block_d=8, block_s=16)
y_r, h_r = ref.mamba_scan_ref(x, dt, a, bm, cm, dsk, h0)
np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=3e-5, atol=3e-5)
np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), rtol=3e-5, atol=3e-5)
# XLA chunked path
from repro.models import mamba as mmod
y_x, h_x = mmod.selective_scan(x, dt, a, bm, cm, dsk, h0, chunk=16)
np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_r), rtol=3e-5, atol=3e-5)
print("mamba_scan OK")

# sdqn score
from repro.core import dqn
qp = dqn.init_qnet(jax.random.PRNGKey(1))
feats = jax.random.normal(ks[1], (1000, 6), jnp.float32)
s_k = ops.sdqn_score(feats, qp, mode="interpret", block_n=128)
s_r = ref.sdqn_score_ref(feats, qp["w1"], qp["b1"], qp["w2"], qp["b2"])
np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=2e-5, atol=2e-5)
s_d = dqn.qvalues(qp, feats)
np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_r), rtol=2e-5, atol=2e-5)
print("sdqn_score OK")
print("ALL KERNELS OK")
