"""Pytree types for the cluster scheduling environment and its scenarios."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp

# Unified "no feasible target" sentinel.  Historically ``env.NO_NODE`` (pod
# scheduling) and ``sched.placement.NO_HOST`` (job->host placement) were two
# independently-defined -1 constants; both are now re-exports of this one.
# Selectors return it when the filtering phase leaves no candidate; ``place``
# treats it as a no-op bind and episode accounting counts it as a drop.
NO_PLACEMENT = -1

# Width of the Table-2 afterstate feature row.  This is THE canonical
# definition: ``env.FEATURE_SCALE``, the replay ring's row layout, the MLP
# Q-net's input width and the fused kernels all derive from it (policy
# classes with history embeddings store ``FEATURE_DIM + embed_dim`` rows —
# see ``core.policy.PolicySpec.feature_dim``).
FEATURE_DIM = 6


class ClusterState(NamedTuple):
    """Vectorized node state. All arrays have leading dim N (nodes).

    The environment distinguishes *requested* resources (what the k8s control
    plane accounts: used by filtering and by the default scheduler's scoring)
    from *used* resources (what metrics-server/Grafana would report: used by
    the RL state features and by the paper's evaluation metric).
    """

    cpu_capacity: jnp.ndarray    # (N,) millicores
    mem_capacity: jnp.ndarray    # (N,) MiB
    max_pods: jnp.ndarray        # (N,) int32
    healthy: jnp.ndarray         # (N,) bool
    uptime_hours: jnp.ndarray    # (N,) fp32
    num_pods: jnp.ndarray        # (N,) int32 — ALL pods (tenant + experiment)
    exp_pods: jnp.ndarray        # (N,) int32 — experiment pods (our image)
    cpu_requested: jnp.ndarray   # (N,) millicores booked by requests
    mem_requested: jnp.ndarray   # (N,) MiB booked by requests
    pods_cpu: jnp.ndarray        # (N,) millicores of actual pod compute demand
    mem_used: jnp.ndarray        # (N,) MiB actually used
    base_cpu: jnp.ndarray        # (N,) pre-existing (non-experiment) load
    startup_cpu: jnp.ndarray     # (N,) transient startup/image-pull CPU, decays
    image_cached: jnp.ndarray    # (N,) bool — experiment image present on node
    time_s: jnp.ndarray          # () seconds since episode start

    @property
    def n_nodes(self) -> int:
        return self.cpu_capacity.shape[-1]


class PodSpec(NamedTuple):
    """One compute-intensive pod (the paper's no-op CPU burner)."""

    cpu_request: jnp.ndarray   # millicores (scheduling request)
    cpu_demand: jnp.ndarray    # millicores actually burned while running
    mem_request: jnp.ndarray   # MiB
    mem_demand: jnp.ndarray    # MiB


# ---------------------------------------------------------------------------
# scenario description (heterogeneous node pools × pod catalogs × arrivals)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NodeClass:
    """A homogeneous slice of a heterogeneous node pool.

    ``base_cpu_frac`` / ``requested_frac`` are uniform ranges *as fractions of
    this class's capacity*, so a big node and a small node with the same
    fraction carry proportionate pre-existing load.

    ``idle_watts`` / ``peak_watts`` parameterize the energy model: a node the
    experiment workload keeps alive draws ``idle + (peak - idle) * cpu_util``
    watts; nodes hosting none of our pods are releasable (could be powered
    down), so they bill nothing to the workload.
    """

    name: str
    count: int
    cpu_capacity: float               # millicores
    mem_capacity: float               # MiB
    max_pods: int = 110
    unhealthy_prob: float = 0.0       # spot / flaky pools set this > 0
    base_cpu_frac: tuple = (0.02, 0.2)
    requested_frac: tuple = (0.05, 0.5)
    image_cached_prob: float = 0.0    # chance the experiment image is pre-pulled
    idle_watts: float = 120.0         # draw of a powered-on but idle node
    peak_watts: float = 350.0         # draw at 100% CPU utilization
    # mid-episode chaos: mean time between failures / to recovery (seconds),
    # exponentially distributed per node (a Poisson fail/recover process).
    # ``inf`` (the default) = the node never fails mid-episode, which keeps
    # every pre-chaos scenario bit-identical (see env.sample_failure_trace).
    mtbf_s: float = float("inf")
    mttr_s: float = 60.0


@dataclasses.dataclass(frozen=True)
class PodType:
    """One entry of the workload catalog (mixture component of the stream).

    ``lifetime_mean_s`` / ``lifetime_cv`` give the pod's running-duration
    distribution (lognormal with that mean and coefficient of variation;
    ``cv=0`` is deterministic).  The default ``inf`` never completes, which
    reproduces the static-table episodes exactly (see ``env.retire_expired``).
    """

    name: str
    weight: float                     # mixture weight in the arrival stream
    cpu_request: float                # millicores (scheduling request)
    cpu_demand: float                 # millicores actually burned
    mem_request: float                # MiB
    mem_demand: float                 # MiB
    lifetime_mean_s: float = float("inf")  # mean running duration; inf = forever
    lifetime_cv: float = 0.0          # lognormal coefficient of variation


@dataclasses.dataclass(frozen=True)
class ArrivalConfig:
    """Pod arrival process.

    * ``burst``   — fixed inter-arrival gap (the paper's 50-pod burst);
    * ``poisson`` — exponential inter-arrival times at ``rate_per_s``;
    * ``diurnal`` — Poisson stream whose rate is modulated by a sine wave of
      ``period_s`` and relative amplitude ``depth`` (daily traffic wave).
    """

    kind: str = "burst"               # "burst" | "poisson" | "diurnal"
    rate_per_s: float = 0.5           # mean arrival rate (poisson / diurnal)
    period_s: float = 1200.0          # diurnal wave period
    depth: float = 0.8                # diurnal modulation depth in [0, 1)


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Declarative scenario: node pool + pod catalog + arrival process.

    Static (hashable) so an ``EnvConfig`` carrying it can stay a jit static
    argument; all sampled quantities (which pod type arrives when, per-node
    jitter) are drawn inside jit from explicit PRNG keys.
    """

    name: str
    node_classes: tuple               # tuple[NodeClass, ...]
    pod_types: tuple                  # tuple[PodType, ...]
    arrival: ArrivalConfig = ArrivalConfig()
    n_pods: int = 50                  # default arrivals per episode
    settle_steps: Optional[int] = None  # post-arrival drain window override
    #   (churn scenarios need a longer settle so pods actually finish and
    #   the consolidation/energy story becomes measurable)

    @property
    def n_nodes(self) -> int:
        return sum(c.count for c in self.node_classes)


class PodTable(NamedTuple):
    """Pre-sampled arrival stream: everything ``lax.scan`` needs per step.

    ``specs`` holds one ``PodSpec`` per arrival (leading dim n_pods);
    ``dt_s`` is the wall-clock gap *after* each placement; ``type_idx``
    indexes the scenario's pod catalog (for logging / per-type metrics);
    ``lifetime_s`` is each pod's sampled running duration (``inf`` = the
    pod never completes — the pre-lifecycle static table).
    """

    specs: PodSpec                    # each field (n_pods,)
    dt_s: jnp.ndarray                 # (n_pods,) float32
    type_idx: jnp.ndarray             # (n_pods,) int32
    lifetime_s: jnp.ndarray           # (n_pods,) float32, inf = runs forever


class PodLedger(NamedTuple):
    """Fixed-shape expiry ledger: one slot per episode arrival.

    The jit/vmap-safe lifecycle bookkeeping: slot ``t`` is written when
    arrival ``t`` binds (``node`` = chosen node, ``expiry_s`` = absolute
    episode time the pod completes, ``spec`` = the exact resources to hand
    back), and ``env.retire_expired`` scatter-releases every due slot per
    step.  ``node == -1`` marks empty, dropped, or already-retired slots.
    All arrays have leading dim K = arrivals per episode (a static shape),
    so episodes batch under ``vmap`` / ``lax.scan`` unchanged.
    """

    node: jnp.ndarray                 # (K,) int32; -1 = empty / retired
    expiry_s: jnp.ndarray             # (K,) float32 absolute completion time
    spec: PodSpec                     # each field (K,): resources to release


class FailureTrace(NamedTuple):
    """Fixed-shape mid-episode node fail/recover schedule (jit/vmap-safe).

    ``fail_s[c, n]`` / ``recover_s[c, n]`` bound node ``n``'s ``c``-th outage
    window: the node is down whenever ``fail_s <= t < recover_s``.  ``inf``
    marks an unused cycle, so node health at any time ``t`` is a pure
    function of the trace — no event queue, no dynamic shapes.  Sampled per
    node from each ``NodeClass``'s Poisson MTBF/MTTR
    (``env.sample_failure_trace``); an all-``inf`` trace
    (``env.empty_failure_trace``) injects nothing and episodes reproduce the
    chaos-free trajectories (parity pinned in tests/test_chaos.py).
    """

    fail_s: jnp.ndarray               # (C, N) float32 outage start times
    recover_s: jnp.ndarray            # (C, N) float32 matching recovery times


class EpisodeStats(NamedTuple):
    """Time-resolved lifecycle metrics of one episode (all scalars).

    ``nodes_active`` counts nodes hosting >= 1 experiment pod — the nodes the
    workload prevents from being drained/powered down (the paper's SDQN-n
    green-consolidation objective, §1 contribution 2 / §6).

    The chaos counters account for mid-episode node failures (see
    ``env.sample_failure_trace``): every eviction resolves to exactly one of
    rescheduled or lost, so ``evicted == rescheduled + lost`` at episode end
    and a reward can charge failures (e.g. penalize ``lost``).  All three are
    zero for episodes without an active failure trace.
    """

    nodes_active_mean: jnp.ndarray    # time-averaged active-node count
    nodes_active_final: jnp.ndarray   # int32, active nodes at episode end
    nodes_active_peak: jnp.ndarray    # int32, max active nodes over the episode
    node_seconds: jnp.ndarray         # integral of nodes_active over wall-clock
    energy_wh: jnp.ndarray            # integral of active-node power draw
    retired: jnp.ndarray              # int32, pods that completed + released
    evicted: jnp.ndarray = jnp.int32(0)      # pods killed by node failures
    rescheduled: jnp.ndarray = jnp.int32(0)  # evicted pods re-placed in-episode
    lost: jnp.ndarray = jnp.int32(0)         # evicted pods never re-placed


class EpisodeResult(NamedTuple):
    """The public return value of ``env.run_episode``.

    Replaces the positional 5-tuple the function historically returned.  The
    field order is exactly the old positional order, so legacy
    ``state, dist, metric, dropped, stats = run_episode(...)`` unpacking
    keeps working through the NamedTuple (the one-release deprecation shim);
    new code should use the named fields.
    """

    state: "ClusterState"             # final cluster state after settle
    placements: jnp.ndarray           # (N,) final pods per node (the paper's
    #                                   "pod distribution"; tenant + ours)
    metric: jnp.ndarray               # dt-weighted cluster-average CPU% — the
    #                                   paper's objective (its reward signal)
    dropped: jnp.ndarray              # int32, arrivals with no feasible node
    stats: "EpisodeStats"             # time-resolved lifecycle metrics


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    """Cluster simulation constants (calibrated against the paper's Tables 8–10).

    Mechanisms follow the paper §4.3.2: image caching and shared I/O reduce
    startup overhead for co-located pods; active nodes carry a base system
    overhead; overloading a node (>70% CPU) costs super-linear contention.
    """

    n_nodes: int = 4
    cpu_capacity: float = 4000.0       # millicores (4 vCPU slaves)
    mem_capacity: float = 16384.0      # MiB
    max_pods: int = 110                # k8s default
    # pod workload (no-op CPU burner)
    pod_cpu_request: float = 140.0
    pod_cpu_demand: float = 20.0       # no-op pods burn less than they request
    pod_mem_request: float = 128.0
    pod_mem_demand: float = 100.0
    # overhead model
    node_active_overhead: float = 500.0   # kubelet/cadvisor/runtime while pods run
    image_pull_cost: float = 4200.0       # transient CPU of a cold image pull (docker
    #                                       pull+unpack saturates small nodes for ~30s)
    warm_start_cost: float = 40.0         # transient CPU of a warm (cached) start
    startup_decay: float = 0.88           # per-step geometric decay of transients
    pull_concurrency_coeff: float = 0.7   # extra pull cost per concurrent pull
    contention_knee: float = 0.68         # utilization where contention kicks in
    #                                       (aligned with the paper's 70% threshold)
    contention_coeff: float = 120.0       # super-linear contention multiplier
    crowd_knee: int = 26                  # pods per node before CFS crowding costs
    crowd_coeff: float = 8.0              # millicores per (pods - knee)^2
    # episode
    schedule_dt_s: float = 2.0            # seconds between pod arrivals
    settle_steps: int = 20                # post-placement steps in the metric window
    # energy model (homogeneous pools; scenario node classes override per class)
    idle_watts: float = 120.0             # powered-on idle draw per node
    peak_watts: float = 350.0             # draw at 100% CPU utilization
    # in-episode SDQN-n consolidation cadence: every `consolidate_every_s`
    # seconds of episode time, run the jit-safe consolidation pass (see
    # sched.elastic.make_consolidator) passed to env.run_episode.  0 = off.
    consolidate_every_s: float = 0.0
    # initial conditions.  Per-trial, the per-node *usage* profile and the
    # per-node *requests* profile are independently permuted + jittered: the
    # cluster-wide totals stay stable (paper CVs are 1.6–5.4%) while which
    # node is busy/booked varies.  Pre-existing usage (system daemons,
    # co-located services) is NOT reflected in pre-existing requests — that
    # is exactly the blindness of request-based kube-scheduler scoring that
    # the RL schedulers exploit.
    # one "busy" node (co-located services / control-plane components) whose
    # load is invisible to request-based scoring — the paper's cluster shows
    # exactly this asymmetry in its default-scheduler distributions.
    base_cpu_profile: tuple = (720.0, 200.0, 120.0, 70.0)
    base_cpu_jitter: float = 40.0
    requested_frac_profile: tuple = (0.05, 0.12, 0.45, 0.80)
    requested_frac_jitter: float = 0.03
    init_uptime_range_h: tuple = (1.0, 200.0)
    unhealthy_prob: float = 0.0           # paper cluster: all Ready; tests override
    # domain randomization for TRAINING resets only (decorrelates node state
    # from episode time so the Q-net learns the actual reward structure, not
    # the on-policy time correlation).  Evaluation uses the clean cluster.
    randomize_workload: bool = False
    randomize_max_pods: int = 26
    randomize_empty_prob: float = 0.45    # chance a node starts with no pods
    randomize_cached_prob: float = 0.3    # chance an empty node has the image
    # chaos (mid-episode node failures, see env.sample_failure_trace): the
    # fixed capacity of the in-episode reschedule ring evicted pods re-enter
    # the arrival stream through, and how many fail/recover cycles per node
    # a sampled FailureTrace can hold.  Both are static shape parameters.
    chaos_requeue_cap: int = 32
    chaos_cycles: int = 4
    # scenario mode: when set, reset() builds the heterogeneous node pool from
    # scenario.node_classes (n_nodes/capacity fields above are overridden) and
    # episodes draw per-arrival PodSpecs from the scenario's pod catalog.
    scenario: Optional[ScenarioConfig] = None


def training_cluster() -> "EnvConfig":
    """Domain-randomized variant of the paper cluster for policy training."""
    return dataclasses.replace(paper_cluster(), randomize_workload=True)


def paper_cluster() -> EnvConfig:
    """The paper's experimental cluster: 4 slave nodes, 50-pod batches."""
    return EnvConfig()


def fleet_cluster(n_nodes: int = 1024) -> EnvConfig:
    """A fleet-scale cluster for the 1000+-node scheduling benchmarks."""
    return dataclasses.replace(paper_cluster(), n_nodes=n_nodes, max_pods=110)


def scenario_env(scn: ScenarioConfig, randomize: bool = False, **overrides) -> EnvConfig:
    """EnvConfig for a scenario: n_nodes tracks the node pool; capacity and
    pod fields become per-class / per-arrival at reset/episode time."""
    if scn.settle_steps is not None:
        overrides.setdefault("settle_steps", scn.settle_steps)
    return dataclasses.replace(
        paper_cluster(),
        n_nodes=scn.n_nodes,
        scenario=scn,
        randomize_workload=randomize,
        **overrides,
    )
