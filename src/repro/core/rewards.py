"""Reward functions — paper Tables 3 (SDQN) and 5 (SDQN-n), implemented exactly.

Both operate on the *afterstate*: the cluster state right after the pod was
bound.  ``feats`` rows are the Table-2 features (raw units: percentages,
hours, counts).

Table 5's SDQN-n row is truncated in the paper; we implement the only reading
consistent with its stated goal and Table-10 distributions (see DESIGN.md §2):
top-2 = the two candidate nodes with the most running pods.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BASE_POINTS = 100.0


def _resource_points(pct: jnp.ndarray) -> jnp.ndarray:
    """>70%: -2/percent above; 40–70%: +10; otherwise (<40%): -10."""
    return jnp.where(
        pct > 70.0,
        -2.0 * (pct - 70.0),
        jnp.where(pct >= 40.0, 10.0, -10.0),
    )


def node_points(feats_row: jnp.ndarray) -> jnp.ndarray:
    """Shared per-node terms of Tables 3/5 (everything except distribution)."""
    cpu, mem, pod_util, health, uptime, _ = (feats_row[i] for i in range(6))
    pts = jnp.float32(BASE_POINTS)
    pts = pts + jnp.where(health < 0.5, -100.0, 0.0)
    pts = pts + _resource_points(cpu)
    pts = pts + _resource_points(mem)
    pts = pts + jnp.where((pod_util >= 60.0) & (pod_util <= 90.0), 20.0, -10.0)
    pts = pts + jnp.where(uptime >= 24.0, 5.0, -5.0)
    return pts


def sdqn_reward(after_feats: jnp.ndarray, action: jnp.ndarray,
                exp_pods: jnp.ndarray = None,
                efficiency_weight: float = 0.0,
                before_feats: jnp.ndarray = None) -> jnp.ndarray:
    """Table 3. after_feats: (N, 6) afterstate features; action: chosen node.

    Pod Distribution: +5 points for each node currently in the pod
    distribution (nodes running the experiment's pods, post-placement).

    ``efficiency_weight`` > 0 enables the *aligned* reward mode: Table 3 plus
    the paper's own optimization objective (minimize cluster-average CPU
    utilization, paper (§1, §4.3.2, §5.1.3)) as a shaped term
    -w * avg_cpu_after.  The literal Table-3 reward (w=0) is kept as an
    ablation: as EXPERIMENTS.md documents, its mid-band attraction does not
    by itself reproduce the paper's SDQN gains in simulation.
    """
    chosen = after_feats[action]
    dist_src = exp_pods if exp_pods is not None else after_feats[:, 5]
    n_distributed = jnp.sum(dist_src > 0)
    pts = node_points(chosen) + 5.0 * n_distributed.astype(jnp.float32)
    if efficiency_weight and before_feats is not None:
        # potential-based shaping on the paper's objective: penalize the
        # cluster-average-CPU increase this placement causes (telescopes to
        # minimizing the integral of average CPU over the burst)
        delta = jnp.mean(after_feats[:, 0]) - jnp.mean(before_feats[:, 0])
        pts = pts - efficiency_weight * delta
    return pts


def sdqn_n_reward(
    after_feats: jnp.ndarray,
    before_feats: jnp.ndarray,
    feasible_mask: jnp.ndarray,
    action: jnp.ndarray,
    n: int = 2,
    exp_pods_before: jnp.ndarray = None,
    efficiency_weight: float = 0.0,
) -> jnp.ndarray:
    """Table 5 (n=2): consolidation term replaces the distribution term.

    If #candidate nodes >= n: placement on one of the top-n candidates
    (by the experiment's running pods, among feasible nodes) => +20,
    outside => -50.  If #candidates < n: chosen node already running our
    pods => +20, else -10.
    """
    chosen = after_feats[action]
    pts = node_points(chosen)

    n_candidates = jnp.sum(feasible_mask)
    pods_before = (exp_pods_before.astype(jnp.float32)
                   if exp_pods_before is not None else before_feats[:, 5])
    # rank candidates by running pods (non-candidates sink to -inf)
    ranked = jnp.where(feasible_mask, pods_before, -jnp.inf)
    top_n_vals, top_n_idx = jax.lax.top_k(ranked, n)
    in_top_n = jnp.any(top_n_idx == action)

    consolidated = jnp.where(in_top_n, 20.0, -50.0)
    fallback = jnp.where(pods_before[action] > 0.0, 20.0, -10.0)
    pts = pts + jnp.where(n_candidates >= n, consolidated, fallback)
    if efficiency_weight:
        delta = jnp.mean(after_feats[:, 0]) - jnp.mean(before_feats[:, 0])
        pts = pts - efficiency_weight * delta
    return pts


def energy_term(exp_pods_before: jnp.ndarray, exp_pods_after: jnp.ndarray) -> jnp.ndarray:
    """Active-node delta of one placement: +1 when it woke an idle node.

    Shaping on the count of nodes hosting experiment pods — the quantity
    ``env.EpisodeStats.node_seconds`` integrates and the green consolidation
    story (paper §1 contribution 2, §6) minimizes.  The undiscounted deltas
    telescope over an episode to (final - initial) active nodes; note this
    is deliberate objective shaping, not Ng-style policy-invariant shaping
    (that would need the gamma-weighted ``gamma*phi(s') - phi(s)`` form
    under the bootstrapped gamma=0.9 targets) — with ``energy_weight`` > 0
    the learned optimum is *meant* to trade some CPU efficiency for fewer
    woken nodes.
    """
    before = jnp.sum(exp_pods_before > 0).astype(jnp.float32)
    after = jnp.sum(exp_pods_after > 0).astype(jnp.float32)
    return after - before


def _validate_energy_weight(w) -> float:
    """Coerce ``energy_weight`` to a plain float; reject bools, arrays, < 0."""
    if isinstance(w, bool) or not isinstance(w, (int, float)):
        raise TypeError(
            f"energy_weight must be a plain Python number, got {type(w).__name__}")
    w = float(w)
    if w < 0.0:
        raise ValueError(f"energy_weight must be >= 0, got {w}")
    return w


def make_reward_fn(variant: str = "sdqn", consolidation_n: int = 2,
                   efficiency_weight: float = 0.0,
                   energy_weight: float = 0.0):
    """Uniform reward interface for the training loop (and scenario mixtures):

        fn(after_feats, before_feats, ok, action, exp_pods_before, exp_pods_after)

    Both variants see the same arguments so one transition function can train
    either head across any scenario; the features already carry the
    heterogeneity (percentages are relative to each node's own capacity).

    ``energy_weight`` > 0 adds the green-consolidation term: each placement
    pays ``energy_weight`` points per node it newly activates (see
    ``energy_term``), so packing onto already-active nodes is rewarded over
    waking idle ones — the node-count analogue of the avg-CPU efficiency
    shaping.

    ``energy_weight`` must be a plain non-negative Python number (exactly
    ``0.0`` disables the term).  Bools and 0-d arrays are rejected: a
    ``jnp.float32(0.)`` is truthy under ``not`` on some paths and an array
    weight would silently bake a traced constant into the closure during the
    Pareto sweep.
    """
    energy_weight = _validate_energy_weight(energy_weight)
    if variant == "sdqn":

        def base_fn(after_feats, before_feats, ok, action, exp_pods_before, exp_pods_after):
            return sdqn_reward(after_feats, action, exp_pods=exp_pods_after,
                               efficiency_weight=efficiency_weight,
                               before_feats=before_feats)

    elif variant == "sdqn_n":

        def base_fn(after_feats, before_feats, ok, action, exp_pods_before, exp_pods_after):
            return sdqn_n_reward(after_feats, before_feats, ok, action,
                                 consolidation_n, exp_pods_before=exp_pods_before,
                                 efficiency_weight=efficiency_weight)

    else:
        raise ValueError(f"unknown reward variant: {variant!r}")

    if energy_weight == 0.0:
        return base_fn

    def fn(after_feats, before_feats, ok, action, exp_pods_before, exp_pods_after):
        pts = base_fn(after_feats, before_feats, ok, action,
                      exp_pods_before, exp_pods_after)
        return pts - energy_weight * energy_term(exp_pods_before, exp_pods_after)

    return fn
