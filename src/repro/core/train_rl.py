"""On-device RL training for SDQN / SDQN-n (and supervised training for the
LSTM/Transformer baselines).

The whole loop — environment stepping, afterstate scoring, epsilon-greedy
action selection, reward shaping (Tables 3/5), replay, and the Adam/MSE
learner (Table 4) — is one XLA program: ``lax.scan`` over pod arrivals inside
``lax.scan`` over episodes, ``vmap``-ed over ``n_envs`` parallel simulated
clusters.  The actual sharded topology (the Anakin/Podracer pattern):

  * ``train(..., mesh=...)`` pins the ``n_envs`` environment batch to the
    mesh ``data`` axis with ``NamedSharding`` constraints — each device
    steps its slice of the clusters, the replay write and the (replicated)
    learner update are the only cross-device points, and XLA inserts the
    one all-gather they need.  ``mesh=None`` (or an ``n_envs`` that does
    not divide the ``data`` axis) falls back to the single-device program
    unchanged, so CPU tests and the 1-device container run the same code.
  * ``repro.train.engine.train_seeds`` vmaps this whole program over the
    seed ladder (``fold_in(key, seed)``), so ``train_and_select``'s
    candidates compile once and run as ONE launch; on a mesh
    ``launch.mesh.plan_seed_env_layout`` shards the joint (seed, env) batch
    over a 2-D ``("seed", "data")`` grid — whole replicas per device group,
    envs split inside each group — so all devices stay busy even when
    ``n_seeds`` alone is smaller than the device count.
  * In-loop afterstate scoring routes through
    ``schedulers.score_afterstates`` — the same fused-kernel dispatch the
    serving path uses (Pallas on TPU at fleet scale, where the (N, 6)
    feature matrix never hits HBM); the replay stores the single realized
    (6,) afterstate via ``env.hypothetical_place_one``.
  * The ``TrainCarry`` (fused replay ring of cap x 8 floats, Adam moments,
    params) is donated across ``train_mixture`` segments: buffers are
    updated in place at scenario hand-offs, not copied.

The default is full DQN semantics (the paper builds SDQN "on the Deep
Q-Network framework"): targets r + γ·max Q_target(s′) with a periodically
refreshed target network.  ``bootstrap=False`` recovers the literal Table-4
"target rewards" (contextual-bandit) update for ablation.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import dqn, env as kenv, policy as policy_mod, rewards, \
    schedulers
from repro.core.replay import Replay, replay_add, replay_init, replay_sample
from repro.core.schedulers import masked_argmax
from repro.core.types import EnvConfig

# Rewards are ~100-point scale (Table 3 base = 100); scale them down so the
# bootstrapped Q (~ r/(1-gamma)) stays O(1-10) under Adam(1e-3) + MSE.
REWARD_SCALE = 0.01


@dataclasses.dataclass(frozen=True)
class RLConfig:
    variant: str = "sdqn"          # "sdqn" | "sdqn_n"
    consolidation_n: int = 2       # the paper's n (n=2)
    episodes: int = 60
    pods_per_episode: int = 50
    n_envs: int = 8                # parallel simulated clusters
    buffer_capacity: int = 4096
    batch_size: int = 128
    eps_start: float = 0.5
    eps_end: float = 0.02
    learn_every: int = 1
    # DQN bootstrapping (the paper builds on "the Deep Q-Network framework",
    # so r + gamma*max Q(s') targets are the default; bandit=False recovers
    # the literal Table-4 "target rewards" update)
    bootstrap: bool = True
    gamma: float = 0.9
    target_update_every: int = 200
    # reward mode: efficiency_weight > 0 adds the paper's objective (minimize
    # cluster-average CPU) as a shaping term; 0 = literal Table 3/5 ablation.
    efficiency_weight: float = 10.0
    # green-consolidation shaping: points paid per node a placement newly
    # activates (rewards.energy_term); 0 = off.  Pair with churn scenarios so
    # the policy sees nodes actually emptying out over an episode.
    energy_weight: float = 0.0
    # policy class (core.policy registry): "mlp" is the paper's Table-4 net
    # and reproduces the pre-registry trainer bit-for-bit; "attention" /
    # "mamba" train through the identical loop (sequence specs thread their
    # arrival-history carry through the scanned episode and store wider
    # [afterstate | embed] replay rows).
    policy: str = "mlp"


class TrainCarry(NamedTuple):
    params: dict
    opt_state: dict
    target_params: dict
    buffer: Replay
    key: jax.Array
    learn_step: jnp.ndarray


def realized_transition(env_state, pod, action, env_cfg: EnvConfig,
                        reward_fn):
    """The action-agnostic transition body: bind a REALIZED action, shape the
    reward, build the stored replay row.

    Returns (new_env_state, stored_feats (6,), scaled reward).  Shared by the
    training loops (which pick ``action`` via a selector) and the serving
    daemon's online recorder (``sched.online.TransitionRecorder``, which
    replays the daemon's committed decisions) — using one body is what makes
    the online ring stream bit-identical to the offline one.

    action == NO_NODE (drop): there is no realized afterstate — the gather
    is clamped (a negative index would wrap to the LAST node's features) and
    the caller must zero-weight the stored transition.
    """
    before_feats = kenv.features(env_state, env_cfg)
    ok = kenv.feasible(env_state, pod, env_cfg)
    new_state = kenv.place(env_state, action, pod, env_cfg)
    after_feats = kenv.features(new_state, env_cfg)
    r = reward_fn(after_feats, before_feats, ok, action,
                  env_state.exp_pods, new_state.exp_pods)
    # only the realized afterstate is stored: a single row, never the (N, 6)
    # matrix (any scoring pass that picked `action` goes through the fused
    # kernel dispatch and does not materialize it either)
    stored = kenv.normalize_features(
        kenv.hypothetical_place_one(env_state, pod, env_cfg,
                                    jnp.maximum(action, 0)))
    return new_state, stored, r * REWARD_SCALE


def transition_step(key, select, env_state, pod, dt_s, env_cfg: EnvConfig,
                    reward_fn):
    """One pod arrival in one env, shared by the RL and supervised loops:
    act via ``select``, bind, shape the reward, advance wall-clock.

    Returns (new_env_state, stored_feats (6,), scaled reward, action).
    ``select(key, state, pod) -> node`` is any episode-compatible selector
    (epsilon-greedy SDQN for RL, ``kube_select`` for behavior cloning);
    ``reward_fn`` follows the ``rewards.make_reward_fn`` interface.
    """
    action = select(key, env_state, pod)
    new_state, stored, r = realized_transition(env_state, pod, action,
                                               env_cfg, reward_fn)
    new_state = kenv.tick(new_state, env_cfg, dt_s)
    return new_state, stored, r, action


def _transition(key, qparams, env_state, pod, dt_s, env_cfg: EnvConfig,
                epsilon, reward_fn, spec=None, embed=None):
    """One RL pod arrival: epsilon-greedy over ``schedulers.score_afterstates``
    (the shared fused-kernel dispatch) + the common transition body.

    ``spec``/``embed`` route scoring through a registered policy class
    (``core.policy``); sequence specs append their history ``embed`` to the
    stored replay row.  The defaults reproduce the pre-registry MLP trainer
    exactly (pinned in tests/test_train_engine.py).
    """

    def select(k, st, p):
        ok = kenv.feasible(st, p, env_cfg)
        q = schedulers.score_afterstates(qparams, st, p, env_cfg,
                                         policy=spec, embed=embed)
        return masked_argmax(k, q, ok, epsilon)

    new_state, stored, r, action = transition_step(
        key, select, env_state, pod, dt_s, env_cfg, reward_fn)
    if embed is not None:
        stored = jnp.concatenate([stored, embed])
    return new_state, stored, r, action


def _bootstrap_bonus(online_params, target_params, env_state, pod, env_cfg,
                     rl: RLConfig, spec=None, embed=None):
    """Double-DQN bonus: gamma * Q_target(s', argmax_a Q_online(s', a)).

    0 when s' has no feasible action (terminal for this workload burst).
    Double-DQN (action chosen by the online net, valued by the target net)
    avoids the max-operator over-estimation of rarely-visited states — e.g.
    cold-pull afterstates that look mid-band attractive.  Scoring goes
    through the fused dispatch; only the argmax afterstate is gathered for
    the target net (one (6,) row, not the (N, 6) matrix).  For sequence
    policy classes ``embed`` is the history embedding AT the next arrival
    (the online carry stepped by the next pod's workload), appended to the
    target row exactly as stored transitions are.
    """
    ok = kenv.feasible(env_state, pod, env_cfg)
    q_online = schedulers.score_afterstates(online_params, env_state, pod,
                                            env_cfg, policy=spec, embed=embed)
    a_star = jnp.argmax(jnp.where(ok, q_online, -jnp.inf))
    after_star = kenv.normalize_features(
        kenv.hypothetical_place_one(env_state, pod, env_cfg, a_star))
    if embed is not None:
        after_star = jnp.concatenate([after_star, embed])
    qfn = dqn.qvalues if spec is None else spec.qvalues
    q_tgt = qfn(target_params, after_star)
    return jnp.where(jnp.any(ok), rl.gamma * q_tgt, 0.0)


def _env_constraint(mesh, n_envs: int):
    """Sharding-constraint applier for env-batched pytrees, or identity.

    With a mesh whose ``data`` axis divides ``n_envs``, pins the environment
    batch dimension to ``data`` (``NamedSharding``); the learner stays
    replicated, which is exactly the Anakin/Podracer layout.  Any other case
    (``mesh=None``, no ``data`` axis, indivisible batch) returns identity so
    the single-device program is untouched.
    """
    if (mesh is None or "data" not in mesh.axis_names
            or n_envs % mesh.shape["data"] != 0):
        return lambda tree, time_leading=False: tree
    from jax.sharding import NamedSharding, PartitionSpec as P

    def constrain(tree, time_leading=False):
        spec = P(None, "data") if time_leading else P("data")
        return jax.lax.with_sharding_constraint(tree, NamedSharding(mesh, spec))

    return constrain


def _make_episode_fn(env_cfg: EnvConfig, rl: RLConfig, n_steps_total: int,
                     mesh=None):
    """Episode body for ``lax.scan``: (TrainCarry, global episode idx) -> carry.

    Per-arrival ``PodSpec``s come from the scenario's pod table (the
    homogeneous default pod when ``env_cfg.scenario`` is None), so the same
    Q-net trains across heterogeneous workload mixtures.  ``n_steps_total``
    anchors the epsilon schedule, which lets scenario-mixture training thread
    one schedule through interleaved per-scenario segments.  ``mesh`` shards
    the ``n_envs`` batch over the ``data`` axis (see ``_env_constraint``).
    """
    reward_fn = rewards.make_reward_fn(rl.variant, rl.consolidation_n,
                                       rl.efficiency_weight, rl.energy_weight)
    shard = _env_constraint(mesh, rl.n_envs)
    spec = policy_mod.get(rl.policy)
    # Python-level static: sequence specs (embed_dim > 0) thread per-env
    # encoder carries through the pod scan; stateless specs thread an empty
    # pytree, which adds no arrays — the "mlp" trace is byte-identical to the
    # pre-registry trainer.
    seq = spec.embed_dim > 0
    step_fn = policy_mod.make_train_step(spec)

    def epsilon_at(step):
        frac = step.astype(jnp.float32) / max(n_steps_total, 1)
        return rl.eps_start + (rl.eps_end - rl.eps_start) * jnp.minimum(frac, 1.0)

    def episode(carry: TrainCarry, ep_idx):
        key_ep = jax.random.fold_in(carry.key, ep_idx)
        k_reset, k_pods, k_steps = jax.random.split(key_ep, 3)
        env_states = shard(jax.vmap(lambda k: kenv.reset(k, env_cfg))(
            jax.random.split(k_reset, rl.n_envs)
        ))
        # pre-sample each env's arrival stream; scan wants leading dim = time
        tables = jax.vmap(
            lambda k: kenv.sample_pod_table(k, env_cfg, rl.pods_per_episode)
        )(jax.random.split(k_pods, rl.n_envs))
        pods_t = shard(jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), tables.specs),
                       time_leading=True)
        dt_t = shard(jnp.swapaxes(tables.dt_s, 0, 1), time_leading=True)
        life_t = shard(jnp.swapaxes(tables.lifetime_s, 0, 1), time_leading=True)
        # the arrival after this one, for bootstrapped Q(s') scoring (the last
        # row wraps, but its bonus is masked out below)
        pods_next_t = jax.tree.map(lambda x: jnp.roll(x, -1, axis=0), pods_t)
        # per-env expiry ledgers: the training envs churn exactly like eval
        # episodes — placed pods retire mid-episode and release resources, so
        # the Q-net learns on clusters where idle nodes actually appear.
        # Skipped at trace time for all-immortal catalogs (has_lifecycle is a
        # static property): the hot loop pays for retirement scatters only
        # on churn scenarios.
        use_ledger = kenv.has_lifecycle(env_cfg)
        ledgers = jax.vmap(lambda _: kenv.ledger_init(
            rl.pods_per_episode if use_ledger else 1))(jnp.arange(rl.n_envs))
        # per-env arrival-history carries (fresh each episode, like the env
        # reset); () for stateless specs keeps the scan signature unchanged
        if seq:
            carries0 = jax.tree.map(
                lambda z: jnp.zeros((rl.n_envs,) + z.shape, z.dtype),
                spec.carry_init(carry.params))
        else:
            carries0 = ()

        def pod_step(inner, xs):
            t, pod_t, pod_next_t, dt_row, life_row = xs
            c, env_states, ledgers, carries = inner
            kt = jax.random.fold_in(k_steps, t)
            step_no = ep_idx * rl.pods_per_episode + t
            eps = epsilon_at(step_no)
            keys = jax.random.split(kt, rl.n_envs + 2)
            expiry = env_states.time_s + life_row  # pods start at bind time
            if seq:
                # advance every env's history with this arrival's workload;
                # the resulting embedding conditions both scoring and the
                # stored replay row (wide [afterstate | embed] features)
                wf = jax.vmap(policy_mod.pod_workload_features)(pod_t)
                carries, embeds = jax.vmap(
                    spec.encode_step, in_axes=(None, 0, 0)
                )(c.params, carries, wf)
                new_states, stored, r, actions = jax.vmap(
                    lambda kk, st, pod, dt, emb: _transition(
                        kk, c.params, st, pod, dt, env_cfg, eps, reward_fn,
                        spec=spec, embed=emb)
                )(keys[: rl.n_envs], env_states, pod_t, dt_row, embeds)
            else:
                new_states, stored, r, actions = jax.vmap(
                    lambda kk, st, pod, dt: _transition(
                        kk, c.params, st, pod, dt, env_cfg, eps, reward_fn,
                        spec=spec)
                )(keys[: rl.n_envs], env_states, pod_t, dt_row)
            if use_ledger:
                ledgers = jax.vmap(
                    lambda led, a, e, pod: kenv.ledger_record(led, t, a, e, pod)
                )(ledgers, actions, expiry, pod_t)
                new_states, ledgers, _ = jax.vmap(kenv.retire_expired)(
                    new_states, ledgers)
            new_states = shard(new_states)

            targets = r
            if rl.bootstrap:
                if seq:
                    # peek the next arrival's embedding (carry stepped but NOT
                    # committed — the real advance happens next iteration)
                    wf_next = jax.vmap(policy_mod.pod_workload_features)(
                        pod_next_t)
                    _, embeds_next = jax.vmap(
                        spec.encode_step, in_axes=(None, 0, 0)
                    )(c.params, carries, wf_next)
                    bonus = jax.vmap(
                        lambda st, pod, emb: _bootstrap_bonus(
                            c.params, c.target_params, st, pod, env_cfg, rl,
                            spec=spec, embed=emb)
                    )(new_states, pod_next_t, embeds_next)
                else:
                    bonus = jax.vmap(
                        lambda st, pod: _bootstrap_bonus(
                            c.params, c.target_params, st, pod, env_cfg, rl,
                            spec=spec)
                    )(new_states, pod_next_t)
                targets = r + jnp.where(t + 1 < rl.pods_per_episode, bonus, 0.0)

            # dropped arrivals (all-infeasible burst) store with weight 0:
            # their features/reward describe a placement that never happened
            buf = replay_add(c.buffer, stored, targets,
                             (actions >= 0).astype(jnp.float32))
            feats_b, targets_b, w = replay_sample(buf, keys[-1], rl.batch_size)
            params_, opt_, loss, _ = step_fn(c.params, c.opt_state, feats_b, targets_b, w)

            learn_step = c.learn_step + 1
            tgt = jax.tree.map(
                lambda new, old: jnp.where(
                    learn_step % rl.target_update_every == 0, new, old
                ),
                params_,
                c.target_params,
            )
            c = TrainCarry(params_, opt_, tgt, buf, c.key, learn_step)
            return (c, new_states, ledgers, carries), (loss, jnp.mean(r))

        (carry2, env_states, _, _), (losses, rews) = jax.lax.scan(
            pod_step, (carry, env_states, ledgers, carries0),
            (jnp.arange(rl.pods_per_episode), pods_t, pods_next_t, dt_t, life_t),
        )
        metric = jax.vmap(lambda st: kenv.average_cpu_utilization(st, env_cfg))(env_states)
        return carry2, {
            "loss": losses.mean(),
            "reward": rews.mean(),
            "avg_cpu": metric.mean(),
        }

    return episode


def _init_carry(key: jax.Array, rl: RLConfig) -> TrainCarry:
    k_init, k_train = jax.random.split(key)
    spec = policy_mod.get(rl.policy)
    params, opt_state = policy_mod.init_train_state(spec, k_init)
    # lane = the env batch: every in-loop add is one whole (n_envs, F) row,
    # so the ring write is a contiguous slice update, not a scatter (replay
    # contents and sampling are identical either way — lane is layout only).
    # F = spec.feature_dim: sequence specs store [afterstate | embed] rows.
    lane = rl.n_envs if rl.buffer_capacity % rl.n_envs == 0 else 1
    buffer = replay_init(rl.buffer_capacity, n_features=spec.feature_dim,
                         lane=lane)
    # the target net starts equal to the online net but must own its buffers:
    # the TrainCarry is donated across jitted segments, and XLA refuses to
    # donate the same buffer twice
    target = jax.tree.map(jnp.copy, params)
    return TrainCarry(params, opt_state, target, buffer, k_train,
                      jnp.zeros((), jnp.int32))


def train(
    key: jax.Array,
    env_cfg: EnvConfig,
    rl: RLConfig,
    mesh=None,
) -> Tuple[dict, dict]:
    """Train SDQN/SDQN-n. Returns (qparams, metrics dict of per-episode arrays).

    ``mesh`` (e.g. ``launch.mesh.make_train_mesh()``) shards the ``n_envs``
    environment batch over the ``data`` axis; ``None`` or a 1-device mesh
    runs the identical single-device program.  For multi-candidate training
    prefer ``repro.train.engine.train_seeds``, which vmaps this whole
    function over the seed ladder in one launch.
    """
    carry = _init_carry(key, rl)
    episode = _make_episode_fn(env_cfg, rl, rl.episodes * rl.pods_per_episode,
                               mesh)
    carry, metrics = jax.lax.scan(episode, carry, jnp.arange(rl.episodes))
    return carry.params, metrics


train_jit = jax.jit(train, static_argnames=("env_cfg", "rl", "mesh"))


def train_mixture(
    key: jax.Array,
    env_cfgs,
    rl: RLConfig,
    rounds: int = 4,
    mesh=None,
) -> Tuple[dict, dict]:
    """Train ONE Q-net across a scenario mixture.

    ``rl.episodes`` is split evenly across the scenario ``EnvConfig``s and
    interleaved over ``rounds`` visits, so late training (low epsilon) still
    sees every scenario.  Params, target net, replay buffer, learn-step and
    the epsilon schedule all thread through: the replay stores (6,)-feature
    afterstates, which are node-count-independent, so transitions from a
    4-node paper cluster and a 1024-node heterogeneous fleet mix freely in
    one buffer.

    Returns (qparams, metrics dict of per-episode arrays concatenated in
    training order).  The episode budget is honored to within one chunk
    (= episodes // (len(cfgs) * rounds), min 1): scenarios are visited in
    cycle until ``rl.episodes`` episodes have run, so a budget smaller than
    one full cycle trains exactly that many episodes rather than inflating
    to a whole round.
    """
    env_cfgs = list(env_cfgs)
    chunk = max(rl.episodes // (len(env_cfgs) * rounds), 1)
    schedule = []
    total_eps = 0
    cycle = itertools.cycle(env_cfgs)
    while total_eps < rl.episodes:
        schedule.append(next(cycle))
        total_eps += chunk
    n_steps_total = total_eps * rl.pods_per_episode

    segments = {}
    for cfg in env_cfgs:
        if cfg in segments:
            continue
        ep_fn = _make_episode_fn(cfg, rl, n_steps_total, mesh)

        def _segment(carry, ep0, _episode=ep_fn):
            return jax.lax.scan(_episode, carry, ep0 + jnp.arange(chunk))

        # the TrainCarry is donated: the fused replay ring (cap x 8 floats),
        # the Adam moments and both parameter sets are updated in place at
        # every scenario hand-off instead of being copied per segment
        segments[cfg] = jax.jit(_segment, donate_argnums=(0,))

    carry = _init_carry(key, rl)
    per_ep = []
    ep0 = 0
    for cfg in schedule:
        carry, m = segments[cfg](carry, jnp.int32(ep0))
        per_ep.append(m)
        ep0 += chunk
    metrics = {
        k: jnp.concatenate([m[k] for m in per_ep]) for k in per_ep[0]
    }
    return carry.params, metrics


# ---------------------------------------------------------------------------
# supervised training for the LSTM / Transformer baselines (Tables 6/7)
# ---------------------------------------------------------------------------


def train_supervised_scorer(
    key: jax.Array,
    env_cfg: EnvConfig,
    init_fn: Callable,
    score_fn: Callable,
    episodes: int = 40,
    pods_per_episode: int = 50,
    n_envs: int = 8,
    efficiency_weight: float = 10.0,
) -> dict:
    """Train a scorer by regression onto Table-3 rewards along kube-scheduler
    trajectories (the paper trains its LSTM/Transformer on the same reward
    signal; they are behavior-cloning value estimators, not RL agents).

    The act/place/reward/clamp body is the same ``transition_step`` the RL
    loop scans — only the selector (``kube_select``) and the learner (MSE
    regression instead of Q-learning) differ.  Dropped arrivals
    (``action == NO_NODE``) zero-weight their sample exactly as in RL.
    """
    from repro.core import baselines

    params, opt_state = baselines.init_regression_state(init_fn, key)
    step_fn = baselines.make_regression_trainer(score_fn)
    pod = kenv.default_pod(env_cfg)
    select = schedulers.make_kube_selector(env_cfg)
    reward_fn = rewards.make_reward_fn("sdqn", efficiency_weight=efficiency_weight)

    def episode(carry, ep_idx):
        params, opt_state = carry
        key_ep = jax.random.fold_in(key, ep_idx)
        env_states = jax.vmap(lambda k: kenv.reset(k, env_cfg))(
            jax.random.split(key_ep, n_envs)
        )

        def pod_step(inner, t):
            (params, opt_state), env_states = inner
            kt = jax.random.split(jax.random.fold_in(key_ep, 1000 + t), n_envs)
            env_states, feats, targs, actions = jax.vmap(
                lambda k, st: transition_step(k, select, st, pod,
                                              env_cfg.schedule_dt_s, env_cfg,
                                              reward_fn)
            )(kt, env_states)
            valid = (actions >= 0).astype(jnp.float32)
            params, opt_state, loss = step_fn(params, opt_state, feats, targs, valid)
            return ((params, opt_state), env_states), loss

        ((params, opt_state), _), losses = jax.lax.scan(
            pod_step, ((params, opt_state), env_states), jnp.arange(pods_per_episode)
        )
        return (params, opt_state), losses.mean()

    (params, _), _ = jax.lax.scan(episode, (params, opt_state), jnp.arange(episodes))
    return params


# ---------------------------------------------------------------------------
# multi-seed training with validation-based selection (the paper's
# "Algorithm Selection and Scheduler Development" step: train candidate
# models, keep the one that schedules best on held-out validation bursts)
# ---------------------------------------------------------------------------


def train_and_select(
    key: jax.Array,
    train_cfg: EnvConfig,
    eval_cfg: EnvConfig,
    rl: RLConfig,
    n_seeds: int = 4,
    val_trials: int = 12,
    val_pods: int = 50,
    mesh=None,
):
    """Train `n_seeds` independent policies, return the one with the lowest
    average-CPU metric on validation episodes (seeds disjoint from the
    benchmark trials, which use PRNGKey(100+)).

    Delegates to ``repro.train.engine``: the seed dimension is vmapped over
    the whole training scan (one compilation, ONE launch for all candidates
    — the old path dispatched ``train`` per seed from Python), validation
    runs all (seed, trial) episodes batched, and the winner is a NaN-guarded
    on-device argmin (an all-NaN validation falls back to seed 0 instead of
    returning ``(None, inf)``).  The seed ladder is ``fold_in(key, s)``,
    identical to the sequential path, so the same candidate wins selection
    (per-seed params agree to float-reassociation tolerance, ~1e-9/step).
    """
    from repro.train import engine

    return engine.train_and_select(key, train_cfg, eval_cfg, rl,
                                   n_seeds=n_seeds, val_trials=val_trials,
                                   val_pods=val_pods, mesh=mesh)
