"""On-device circular replay buffer (static shapes, scan-friendly)."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Replay(NamedTuple):
    feats: jnp.ndarray     # (cap, 6)
    targets: jnp.ndarray   # (cap,)
    ptr: jnp.ndarray       # () int32
    size: jnp.ndarray      # () int32


def replay_init(capacity: int, n_features: int = 6) -> Replay:
    return Replay(
        feats=jnp.zeros((capacity, n_features), jnp.float32),
        targets=jnp.zeros((capacity,), jnp.float32),
        ptr=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def replay_add(buf: Replay, feats: jnp.ndarray, targets: jnp.ndarray) -> Replay:
    """feats: (B, 6); targets: (B,)."""
    cap = buf.feats.shape[0]
    b = feats.shape[0]
    idx = (buf.ptr + jnp.arange(b, dtype=jnp.int32)) % cap
    return Replay(
        feats=buf.feats.at[idx].set(feats),
        targets=buf.targets.at[idx].set(targets),
        ptr=(buf.ptr + b) % cap,
        size=jnp.minimum(buf.size + b, cap),
    )


def replay_sample(
    buf: Replay, key: jax.Array, batch: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Uniform sample with replacement; weights mask out the empty-buffer case."""
    cap = buf.feats.shape[0]
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(buf.size, 1))
    valid = (jnp.arange(batch) < buf.size).astype(jnp.float32) * (buf.size > 0)
    return buf.feats[idx % cap], buf.targets[idx % cap], valid
