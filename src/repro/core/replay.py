"""On-device circular replay buffer (static shapes, scan-friendly)."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Replay(NamedTuple):
    feats: jnp.ndarray     # (cap, 6)
    targets: jnp.ndarray   # (cap,)
    weights: jnp.ndarray   # (cap,) per-entry sample weight (0 = masked out)
    ptr: jnp.ndarray       # () int32
    size: jnp.ndarray      # () int32


def replay_init(capacity: int, n_features: int = 6) -> Replay:
    return Replay(
        feats=jnp.zeros((capacity, n_features), jnp.float32),
        targets=jnp.zeros((capacity,), jnp.float32),
        weights=jnp.zeros((capacity,), jnp.float32),
        ptr=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def replay_add(buf: Replay, feats: jnp.ndarray, targets: jnp.ndarray,
               weights: jnp.ndarray = None) -> Replay:
    """feats: (B, 6); targets: (B,); weights: (B,) or None (= all 1).

    A zero weight stores a transition that never contributes to the loss —
    used for dropped arrivals (``action == env.NO_NODE``), whose "afterstate"
    is fabricated and must not train the Q-net.
    """
    cap = buf.feats.shape[0]
    b = feats.shape[0]
    if weights is None:
        weights = jnp.ones((b,), jnp.float32)
    idx = (buf.ptr + jnp.arange(b, dtype=jnp.int32)) % cap
    return Replay(
        feats=buf.feats.at[idx].set(feats),
        targets=buf.targets.at[idx].set(targets),
        weights=buf.weights.at[idx].set(weights.astype(jnp.float32)),
        ptr=(buf.ptr + b) % cap,
        size=jnp.minimum(buf.size + b, cap),
    )


def replay_sample(
    buf: Replay, key: jax.Array, batch: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Uniform sample with replacement; weights mask out the empty-buffer case.

    Every draw from ``randint(0, size)`` indexes a live entry once the buffer
    is non-empty, so validity is the scalar ``size > 0`` broadcast over the
    batch — NOT a per-position ``arange(batch) < size`` mask, which would
    silently zero-weight the tail of every batch while ``size < batch``.

    ``size <= cap`` always (``replay_add`` clamps), so the draws are already
    in-range and index the live prefix directly — no ``% cap`` re-wrap.
    """
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(buf.size, 1))
    valid = buf.weights[idx] * (buf.size > 0)
    return buf.feats[idx], buf.targets[idx], valid
