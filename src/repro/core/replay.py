"""On-device circular replay buffer (static shapes, scan-friendly).

The store is ONE fused ``(n_slots, lane, n_features + 2)`` ring: every
transition's feature row, regression target and sample weight live in a
single array (``[feats | target | weight]``), so a training step touches the
buffer with exactly one write and one gather instead of three scatters plus
three gathers — the measured residual per-seed marginal cost of the
seed-parallel engine on XLA:CPU lived in that scatter/gather traffic.

``lane`` is the caller's batch width (``n_envs`` for the RL loop).  With
``lane > 1`` every add is one whole lane row, the write pointer stays
lane-aligned, and the write lowers to a ``dynamic_update_slice`` on the slot
axis — a contiguous in-place update, not an element-indexed scatter.  The
default ``lane=1`` keeps the fully general transition-at-a-time ring (adds
of any size, scatter writes), bit-identical in contents and sampling to the
lane>1 layout: linear index ``i`` always means the ``i``-th stored
transition, row-major over ``(slot, lane)``.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import FEATURE_DIM


class Replay(NamedTuple):
    data: jnp.ndarray      # (n_slots, lane, n_features + 2): [feats|target|weight]
    ptr: jnp.ndarray       # () int32 — next write position, in transitions
    size: jnp.ndarray      # () int32 — live transitions (<= capacity)

    # flat column views, for tests/introspection (the hot paths below slice
    # the fused rows directly and never materialize these)
    @property
    def capacity(self) -> int:
        return self.data.shape[0] * self.data.shape[1]

    @property
    def lane(self) -> int:
        return self.data.shape[1]

    @property
    def n_features(self) -> int:
        return self.data.shape[2] - 2

    @property
    def feats(self) -> jnp.ndarray:
        return self.data.reshape(self.capacity, -1)[:, : self.n_features]

    @property
    def targets(self) -> jnp.ndarray:
        return self.data.reshape(self.capacity, -1)[:, self.n_features]

    @property
    def weights(self) -> jnp.ndarray:
        return self.data.reshape(self.capacity, -1)[:, self.n_features + 1]


def replay_init(capacity: int, n_features: int = FEATURE_DIM,
                lane: int = 1) -> Replay:
    """Empty ring of ``capacity`` transitions.

    ``n_features`` defaults to the canonical afterstate width
    (``types.FEATURE_DIM``); sequence policy classes pass their wider
    ``PolicySpec.feature_dim`` (afterstate + history embed) instead.

    ``lane`` is the fixed add width (``n_envs`` for the training loop): it
    must divide ``capacity`` so the ring is a whole number of slots, and
    every subsequent ``replay_add`` must be a multiple of it (the pointer
    stays lane-aligned, which is what lets the write be a contiguous slice
    update instead of a scatter).  ``lane=1`` accepts adds of any size.
    """
    if lane < 1 or capacity % lane != 0:
        raise ValueError(f"lane {lane} must divide capacity {capacity}")
    return Replay(
        data=jnp.zeros((capacity // lane, lane, n_features + 2), jnp.float32),
        ptr=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def replay_add(buf: Replay, feats: jnp.ndarray, targets: jnp.ndarray,
               weights: jnp.ndarray = None,
               n_valid: jnp.ndarray = None) -> Replay:
    """feats: (B, F); targets: (B,); weights: (B,) or None (= all 1).

    A zero weight stores a transition that never contributes to the loss —
    used for dropped arrivals (``action == env.NO_NODE``), whose "afterstate"
    is fabricated and must not train the Q-net.

    ``B == lane`` (the training loop's env batch) writes one whole slot via
    ``dynamic_update_slice`` — the pointer is always lane-aligned, so the
    row never straddles the wrap.  Any other ``B`` (multiples of ``lane``
    only; enforced) falls back to the general modular scatter on the flat
    transition view, which stores to the identical linear positions.

    ``n_valid`` (a traced () int32) stores only the FIRST ``n_valid`` of the
    ``B`` rows: pad rows leave the ring bit-untouched and the pointer/size
    advance by ``n_valid``.  This is how fixed-shape producers (the online
    recorder's padded drain chunks, ``sched.online``) add a variable number
    of transitions through ONE jitted executable.  Lane-1 rings only: a
    partial add would break the lane alignment invariant otherwise.
    """
    b = feats.shape[0]
    lane = buf.lane
    if b % lane != 0:
        raise ValueError(
            f"add of {b} transitions into a lane-{lane} ring (adds must be "
            f"multiples of the lane to keep the write pointer aligned)")
    if weights is None:
        weights = jnp.ones((b,), jnp.float32)
    rows = jnp.concatenate(
        [feats.astype(jnp.float32),
         targets.astype(jnp.float32)[:, None],
         weights.astype(jnp.float32)[:, None]], axis=1)
    cap = buf.capacity
    if n_valid is not None:
        if lane != 1:
            raise ValueError("n_valid masked adds require a lane-1 ring")
        if b > cap:
            raise ValueError(f"masked add of {b} rows exceeds capacity {cap}")
        n_valid = jnp.asarray(n_valid, jnp.int32)
        # gather-then-select: pad rows write back the value already there,
        # so the ring (and its wrap order) is bit-identical to n_valid
        # sequential one-row adds
        idx = (buf.ptr + jnp.arange(b, dtype=jnp.int32)) % cap
        flat = buf.data.reshape(cap, -1)
        keep = (jnp.arange(b) < n_valid)[:, None]
        data = flat.at[idx].set(jnp.where(keep, rows, flat[idx]))
        return Replay(
            data=data.reshape(buf.data.shape),
            ptr=(buf.ptr + n_valid) % cap,
            size=jnp.minimum(buf.size + n_valid, cap),
        )
    if b == lane and lane > 1:
        # one aligned slot: contiguous in-place update, no per-element indices
        slot = (buf.ptr // lane) % buf.data.shape[0]
        data = jax.lax.dynamic_update_slice_in_dim(
            buf.data, rows[None], slot, axis=0)
    else:
        # an add wider than the ring keeps only its last `cap` transitions —
        # sliced up front so the scatter indices are unique (jnp's .at[].set
        # leaves repeated-index application order undefined)
        skip = max(b - cap, 0)
        idx = (buf.ptr + skip + jnp.arange(b - skip, dtype=jnp.int32)) % cap
        data = (buf.data.reshape(cap, -1).at[idx].set(rows[skip:])
                .reshape(buf.data.shape))
    return Replay(
        data=data,
        ptr=(buf.ptr + b) % cap,
        size=jnp.minimum(buf.size + b, cap),
    )


def replay_sample(
    buf: Replay, key: jax.Array, batch: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Uniform sample with replacement; weights mask out the empty-buffer case.

    Every draw from ``randint(0, size)`` indexes a live entry once the buffer
    is non-empty, so validity is the scalar ``size > 0`` broadcast over the
    batch — NOT a per-position ``arange(batch) < size`` mask, which would
    silently zero-weight the tail of every batch while ``size < batch``.

    ``size <= cap`` always (``replay_add`` clamps), so the draws are already
    in-range and index the live prefix directly — no ``% cap`` re-wrap.  The
    fused layout makes this ONE gather: features, targets and weights come
    back as columns of the same sampled rows.
    """
    nf = buf.n_features
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(buf.size, 1))
    rows = buf.data.reshape(buf.capacity, -1)[idx]
    valid = rows[:, nf + 1] * (buf.size > 0)
    return rows[:, :nf], rows[:, nf], valid
