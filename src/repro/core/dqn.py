"""The SDQN value network — paper Table 4, exactly.

Input: 6 state features.  Hidden: one fully-connected 6→32 layer, ReLU.
Output: 32→1 estimated Q-value.  Loss: MSE against target rewards.
Optimizer: Adam, lr = 0.001.

The network is evaluated on *afterstates* (the node's Table-2 features as if
the pod were placed there), so Q(s, a) = net(afterstate_features(s, a)).
At fleet scale the batched scoring pass is the scheduler's hot loop — the
Pallas kernel ``repro.kernels.sdqn_score`` fuses it (see kernels/).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import FEATURE_DIM
from repro.optim import AdamConfig, adam_init, adam_update

HIDDEN = 32
N_FEATURES = FEATURE_DIM


def init_qnet(key: jax.Array, hidden: int = HIDDEN) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (N_FEATURES, hidden), jnp.float32) * (2.0 / N_FEATURES) ** 0.5,
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, 1), jnp.float32) * (1.0 / hidden) ** 0.5,
        "b2": jnp.zeros((1,), jnp.float32),
    }


def qvalues(params: dict, feats: jnp.ndarray) -> jnp.ndarray:
    """feats: (..., 6) normalized features -> Q: (...)."""
    h = jax.nn.relu(feats @ params["w1"] + params["b1"])
    return (h @ params["w2"] + params["b2"])[..., 0]


def mse_loss(params: dict, feats: jnp.ndarray, targets: jnp.ndarray,
             weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    q = qvalues(params, feats)
    err = jnp.square(q - targets)
    if weights is not None:
        return jnp.sum(err * weights) / jnp.maximum(jnp.sum(weights), 1e-9)
    return jnp.mean(err)


ADAM = AdamConfig(lr=1e-3, master_dtype="")  # paper Table 4


def init_train_state(key: jax.Array) -> Tuple[dict, dict]:
    params = init_qnet(key)
    return params, adam_init(params, ADAM)


def train_step(params: dict, opt_state: dict, feats: jnp.ndarray,
               targets: jnp.ndarray, weights: Optional[jnp.ndarray] = None):
    """One forward + MSE backprop + Adam update (paper Table 4 training loop)."""
    loss, grads = jax.value_and_grad(mse_loss)(params, feats, targets, weights)
    params, opt_state, stats = adam_update(params, grads, opt_state, ADAM)
    return params, opt_state, loss, stats
