"""Comparison schedulers from the paper.

1. The default kube-scheduler (filter + score).  Scoring follows the two
   classic kube-scheduler priorities the paper's §3.2 describes:
   LeastRequestedPriority + BalancedResourceAllocation, with random
   tie-breaking among top scorers (paper §3.2 "selected at random").
2. The LSTM-based scorer (Table 6): (1, 1, 6) input, single LSTM layer with
   32 hidden units, FC to one score, MSE vs target rewards, Adam(1e-3).
3. The Transformer-based scorer (Table 7): 6→32 projection (d_model=32),
   one encoder layer with 4 heads, final-position FC to one score.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import env as kenv
from repro.core.types import ClusterState, EnvConfig, PodSpec
from repro.optim import AdamConfig, adam_init, adam_update

# ---------------------------------------------------------------------------
# 1. default kube-scheduler
# ---------------------------------------------------------------------------


def kube_scores(state: ClusterState, pod: PodSpec, cfg: EnvConfig) -> jnp.ndarray:
    """Scoring phase on *requested* resources (what kube-scheduler sees)."""
    cpu_free = (state.cpu_capacity - state.cpu_requested - pod.cpu_request) / state.cpu_capacity
    mem_free = (state.mem_capacity - state.mem_requested - pod.mem_request) / state.mem_capacity
    least_requested = 10.0 * (cpu_free + mem_free) / 2.0
    balanced = 10.0 * (1.0 - jnp.abs(cpu_free - mem_free))
    return least_requested + balanced


def kube_select(key: jax.Array, state: ClusterState, pod: PodSpec, cfg: EnvConfig) -> jnp.ndarray:
    ok = kenv.feasible(state, pod, cfg)
    scores = jnp.where(ok, kube_scores(state, pod, cfg), -jnp.inf)
    top = ok & (scores >= jnp.max(scores) - 1e-6)
    # random tie-break among top scorers; with no feasible node every score is
    # -inf and `top` would be all-True, making the tie-break bind the pod to a
    # *random* infeasible node — return the drop sentinel instead.
    noise = jax.random.uniform(key, scores.shape)
    choice = jnp.argmax(jnp.where(top, noise, -jnp.inf)).astype(jnp.int32)
    return jnp.where(jnp.any(ok), choice, jnp.int32(kenv.NO_NODE))


# ---------------------------------------------------------------------------
# 2. LSTM scorer (Table 6)
# ---------------------------------------------------------------------------

LSTM_HIDDEN = 32


def init_lstm(key: jax.Array, hidden: int = LSTM_HIDDEN) -> dict:
    k = jax.random.split(key, 3)
    scale = 1.0 / math.sqrt(hidden)
    return {
        "wx": jax.random.uniform(k[0], (6, 4 * hidden), minval=-scale, maxval=scale),
        "wh": jax.random.uniform(k[1], (hidden, 4 * hidden), minval=-scale, maxval=scale),
        "b": jnp.zeros((4 * hidden,)),
        "w_out": jax.random.normal(k[2], (hidden, 1)) * scale,
        "b_out": jnp.zeros((1,)),
    }


def lstm_score(params: dict, feats: jnp.ndarray) -> jnp.ndarray:
    """feats: (..., 6) — one time step, shaped (1, 1, 6) in the paper."""
    hidden = params["wh"].shape[0]
    h0 = jnp.zeros(feats.shape[:-1] + (hidden,), feats.dtype)
    c0 = h0
    gates = feats @ params["wx"] + h0 @ params["wh"] + params["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c0 + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h @ params["w_out"] + params["b_out"])[..., 0]


# ---------------------------------------------------------------------------
# 3. Transformer scorer (Table 7)
# ---------------------------------------------------------------------------

TR_DMODEL = 32
TR_HEADS = 4


def init_transformer(key: jax.Array) -> dict:
    k = jax.random.split(key, 8)
    d = TR_DMODEL

    def lin(kk, shape):
        return jax.random.normal(kk, shape) / math.sqrt(shape[0])

    return {
        "w_in": lin(k[0], (6, d)),
        "b_in": jnp.zeros((d,)),
        "wq": lin(k[1], (d, d)),
        "wk": lin(k[2], (d, d)),
        "wv": lin(k[3], (d, d)),
        "wo": lin(k[4], (d, d)),
        "ln1_s": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
        "ln2_s": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
        "ff1": lin(k[5], (d, 4 * d)),
        "ff1_b": jnp.zeros((4 * d,)),
        "ff2": lin(k[6], (4 * d, d)),
        "ff2_b": jnp.zeros((d,)),
        "w_out": lin(k[7], (d, 1)),
        "b_out": jnp.zeros((1,)),
    }


def _ln(x, s, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * s + b


def transformer_score(params: dict, feats: jnp.ndarray) -> jnp.ndarray:
    """Single-time-step encoder (seq len 1, 4 heads, 1 layer)."""
    d, h = TR_DMODEL, TR_HEADS
    x = feats @ params["w_in"] + params["b_in"]  # (..., d)
    # self-attention over a length-1 sequence: softmax over one key = identity
    q = x @ params["wq"]
    k_ = x @ params["wk"]
    v = x @ params["wv"]
    hd = d // h
    # scores (.., h, 1, 1) -> softmax == 1 -> attends to itself
    attn_out = v  # exact for seq_len == 1
    x = _ln(x + attn_out @ params["wo"], params["ln1_s"], params["ln1_b"])
    ff = jax.nn.relu(x @ params["ff1"] + params["ff1_b"]) @ params["ff2"] + params["ff2_b"]
    x = _ln(x + ff, params["ln2_s"], params["ln2_b"])
    del q, k_, hd
    return (x @ params["w_out"] + params["b_out"])[..., 0]


# ---------------------------------------------------------------------------
# shared supervised training (Tables 6/7: MSE vs target rewards, Adam 1e-3)
# ---------------------------------------------------------------------------

ADAM = AdamConfig(lr=1e-3, master_dtype="")


def make_regression_trainer(score_fn):
    def loss_fn(params, feats, targets, weights):
        err = jnp.square(score_fn(params, feats) - targets)
        return jnp.sum(err * weights) / jnp.maximum(jnp.sum(weights), 1e-9)

    def step(params, opt_state, feats, targets, weights=None):
        if weights is None:
            weights = jnp.ones(targets.shape, targets.dtype)
        loss, grads = jax.value_and_grad(loss_fn)(params, feats, targets, weights)
        params, opt_state, _ = adam_update(params, grads, opt_state, ADAM)
        return params, opt_state, loss

    return step


def init_regression_state(init_fn, key) -> Tuple[dict, dict]:
    params = init_fn(key)
    return params, adam_init(params, ADAM)
