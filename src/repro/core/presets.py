"""Canonical training presets — the configurations that reproduce the
paper's Tables 8-12 (see EXPERIMENTS.md §Paper-reproduction).

Calibration summary (5 trials on the paper cluster, seeds 100-104):
    default scheduler   30.42%   (paper: 30.87%)
    SDQN                -9.2% relative   (paper: -11.9%, claim "~10%")
    SDQN-n              -23.0% relative  (paper: -27.6%, claim ">20%")
    LSTM / Transformer  no significant advantage (paper: same finding)
"""
from __future__ import annotations

from repro.core.train_rl import RLConfig

# SDQN keeps a lower efficiency weight: its Table-3 distribution term
# (+5/node) must stay competitive, which yields the paper's spread-but-
# balanced distributions (13/13/21/3-style) instead of full consolidation.
SDQN_PRESET = RLConfig(
    variant="sdqn",
    episodes=500,
    n_envs=16,
    eps_end=0.05,
    batch_size=256,
    efficiency_weight=5.0,
)

# SDQN-n: the Table-5 top-2 consolidation term + full efficiency shaping
# produces the paper's 25/25/0/0-style two-node packing.
SDQN_N_PRESET = RLConfig(
    variant="sdqn_n",
    episodes=1000,
    n_envs=16,
    eps_end=0.05,
    batch_size=256,
    efficiency_weight=10.0,
)

# Literal Table-4 ablation: bandit targets (no bootstrap), unshaped rewards.
SDQN_LITERAL_PRESET = RLConfig(
    variant="sdqn",
    episodes=500,
    n_envs=16,
    eps_end=0.05,
    batch_size=256,
    bootstrap=False,
    efficiency_weight=0.0,
)

N_SELECTION_SEEDS = 10      # policies trained per variant; best-on-validation deployed
N_SUPERVISED_SEEDS = 4
SUPERVISED_EPISODES = 30

# ---------------------------------------------------------------------------
# scenario-mixture training (one Q-net across heterogeneous workloads)
# ---------------------------------------------------------------------------

# Scenario names the generalist SDQN trains across (resolved via
# ``repro.scenarios.training_mixture`` — kept as names here so presets stay
# import-light and the registry remains the single source of truth).
SCENARIO_MIX_NAMES = (
    "paper-burst",
    "hetero-bigsmall",
    "train-serve-mix",
    "memory-pressure",
    "spot-flaky",
    "diurnal-serve",
)

# One net over the whole mixture: more episodes than the single-scenario
# presets (they are split across scenarios), bandit-safe efficiency shaping.
SDQN_SCENARIO_MIX_PRESET = RLConfig(
    variant="sdqn",
    episodes=720,
    n_envs=16,
    eps_end=0.05,
    batch_size=256,
    efficiency_weight=5.0,
)

# ---------------------------------------------------------------------------
# lifecycle / churn training (finite pod lifetimes, green consolidation)
# ---------------------------------------------------------------------------

# Churn scenarios the lifecycle policies train across: pods finish and
# release nodes mid-episode, so the consolidation signal actually exists.
LIFECYCLE_MIX_NAMES = (
    "short-job-burst",
    "longrun-train-mix",
    "diurnal-churn",
    "consolidation-stress",
)

# Generalist SDQN over the churn mixture (for the lifecycle benchmark's
# spread-style RL row; no node-count shaping).
SDQN_LIFECYCLE_PRESET = RLConfig(
    variant="sdqn",
    episodes=720,
    n_envs=16,
    eps_end=0.05,
    batch_size=256,
    efficiency_weight=5.0,
)

# SDQN-n over the churn mixture: Table-5 consolidation + efficiency shaping
# + the energy/node-count term (rewards.energy_term), producing the paper's
# green packing *over time* — few active nodes, low node-seconds/energy.
SDQN_N_LIFECYCLE_PRESET = RLConfig(
    variant="sdqn_n",
    episodes=720,
    n_envs=16,
    eps_end=0.05,
    batch_size=256,
    efficiency_weight=10.0,
    energy_weight=15.0,
)

# ---------------------------------------------------------------------------
# chaos training (mid-episode node failures, eviction/reschedule churn)
# ---------------------------------------------------------------------------

# Chaos scenarios (finite-MTBF node classes): nodes fail mid-episode, their
# pods are evicted into the reschedule ring, and EpisodeStats charges
# evicted/rescheduled/lost — the mixture a failure-aware policy trains on.
CHAOS_MIX_NAMES = (
    "preemptible-flaky",
    "batch-flaky",
    "train-flaky",
)

# Generalist SDQN over the chaos mixture.  Placements on flaky capacity get
# wiped mid-episode, so the realized CPU-efficiency reward already penalizes
# parking work on short-MTBF nodes — no extra shaping term is needed for the
# policy to learn failure-aware placement.
SDQN_CHAOS_PRESET = RLConfig(
    variant="sdqn",
    episodes=720,
    n_envs=16,
    eps_end=0.05,
    batch_size=256,
    efficiency_weight=5.0,
)
