"""Pluggable policy-class registry: one abstraction from train to serve.

The paper's headline comparison (SDQN vs Transformer/LSTM alternatives) needs
more than the hardcoded 2-layer MLP in ``core.dqn``.  A ``PolicySpec`` is the
contract every scheduler policy class implements:

  * ``init(key) -> params`` — any pytree (nested dicts welcome);
  * ``qvalues(params, feats) -> scores`` — pointwise Q over ``(..., F)``
    feature rows (the replay/learner path; F == ``feature_dim``);
  * ``score_set(params, feats) -> scores`` — Q over the WHOLE ``(N, F)``
    candidate-node set (the selection path).  Defaults to ``qvalues``;
    set-attention policies mix context across the node axis here;
  * optional arrival-history encoding for sequence policies
    (``embed_dim > 0``): ``carry_init(params) -> carry`` and
    ``encode_step(params, carry, workload) -> (carry, embed)``, where
    ``workload`` is the ``ENCODER_IN``-vector of the arriving pod/job
    (``pod_workload_features``).  The embed is appended to every afterstate
    row before scoring, and the carry threads jit-safely through scanned
    episodes, the eval engine and the serving daemon's batched launch.

Three entries ship in-registry:

  * ``"mlp"`` — the paper's Table-4 SDQN net (``core.dqn``), fused-kernel
    capable (``kernels.sdqn_score``);
  * ``"attention"`` — a set-attention scorer over the node feature columns
    (AGMARL-style): embeds each candidate afterstate, mixes context with one
    multi-head attention pass over the node set (``kernels.flash_attention``
    on TPU, the XLA online-attention twin elsewhere), then projects to a
    scalar Q per node.  On a singleton set the softmax over one key is the
    identity, so the pointwise ``qvalues`` path is exact, not approximate;
  * ``"mamba"`` — a selective-state-space arrival-history encoder
    (``models.mamba`` recurrence; batch re-encoding goes through
    ``kernels.mamba_scan``) feeding an MLP Q-head over
    ``[afterstate | history embed]`` rows.

Training is generic over the spec: ``init_train_state``/``make_train_step``
are the Table-4 Adam/MSE learner for ANY registered policy, and the
seed-parallel engine (``train.engine``) vmaps whatever params pytree the
spec produces.  Checkpoints record a versioned metadata record
(``save_checkpoint``/``restore_checkpoint``) so ``launch/serve.py`` restores
any variant; manifests without the record fall back to the legacy MLP.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dqn
from repro.core.types import FEATURE_DIM
from repro.optim import adam_init, adam_update

__all__ = [
    "ENCODER_IN", "PolicySpec", "checkpoint_metadata", "get",
    "init_train_state", "make_opt_state", "make_train_step", "mse_loss",
    "names",
    "pod_workload_features", "register", "restore_checkpoint",
    "save_checkpoint",
]

# Input width of the sequence encoders: the arriving workload's intrinsic
# demand vector (cpu_request, cpu_demand, mem_request, mem_demand), known at
# decision time on every substrate (train loop, eval episodes, both daemon
# substrates) — unlike afterstate features, which depend on the chosen node.
ENCODER_IN = 4
_WORKLOAD_SCALE = (1000.0, 1000.0, 1024.0, 1024.0)  # millicores / MiB


def pod_workload_features(pod) -> jnp.ndarray:
    """``(..., ENCODER_IN)`` normalized demand vector of an arriving pod."""
    return jnp.stack(
        [pod.cpu_request, pod.cpu_demand, pod.mem_request, pod.mem_demand],
        axis=-1) / jnp.asarray(_WORKLOAD_SCALE, jnp.float32)


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """One scheduler policy class (see module docstring for the contract).

    ``feature_dim`` is the replay-row width F = ``FEATURE_DIM + embed_dim``;
    ``fused_kernel`` marks specs whose ``qvalues`` is exactly the Table-4
    MLP, eligible for the fused afterstate/column kernels.  ``hyperparams``
    is the architecture record checkpoints persist (widths, head counts).
    """

    name: str
    feature_dim: int
    embed_dim: int
    init: Callable[[jax.Array], Any]
    qvalues: Callable[[Any, jnp.ndarray], jnp.ndarray]
    score_set: Callable[[Any, jnp.ndarray], jnp.ndarray]
    encode_step: Optional[Callable] = None
    carry_init: Optional[Callable] = None
    fused_kernel: bool = False
    hyperparams: Tuple[Tuple[str, Any], ...] = ()


_REGISTRY: Dict[str, PolicySpec] = {}


def register(spec: PolicySpec) -> PolicySpec:
    if spec.embed_dim > 0 and (spec.encode_step is None or
                               spec.carry_init is None):
        raise ValueError(f"policy {spec.name!r} declares embed_dim="
                         f"{spec.embed_dim} but no encoder")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> PolicySpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown policy class {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# generic Table-4 learner: Adam(1e-3) + MSE over any spec's qvalues
# ---------------------------------------------------------------------------

ADAM = dqn.ADAM  # every policy class trains with the paper's optimizer


def mse_loss(spec: PolicySpec, params, feats, targets, weights=None):
    q = spec.qvalues(params, feats)
    err = jnp.square(q - targets)
    if weights is not None:
        return jnp.sum(err * weights) / jnp.maximum(jnp.sum(weights), 1e-9)
    return jnp.mean(err)


def init_train_state(spec: PolicySpec, key: jax.Array):
    params = spec.init(key)
    return params, adam_init(params, ADAM)


def make_opt_state(params) -> dict:
    """Fresh Adam moments for an EXISTING parameter pytree — warm-starting a
    learner from served/checkpointed params (the online refresher starts
    from the daemon's deployed policy, not a fresh init)."""
    return adam_init(params, ADAM)


def make_train_step(spec: PolicySpec) -> Callable:
    """``(params, opt_state, feats, targets, weights) -> (params, opt_state,
    loss, stats)`` — ``dqn.train_step`` generic over the spec (for the "mlp"
    entry the traced computation is identical)."""

    def loss_fn(params, feats, targets, weights):
        return mse_loss(spec, params, feats, targets, weights)

    def step(params, opt_state, feats, targets, weights=None):
        loss, grads = jax.value_and_grad(loss_fn)(params, feats, targets,
                                                  weights)
        params, opt_state, stats = adam_update(params, grads, opt_state, ADAM)
        return params, opt_state, loss, stats

    return step


# ---------------------------------------------------------------------------
# "mlp" — the paper's Table-4 SDQN net (core.dqn), first registry entry
# ---------------------------------------------------------------------------

MLP = register(PolicySpec(
    name="mlp",
    feature_dim=FEATURE_DIM,
    embed_dim=0,
    init=dqn.init_qnet,
    qvalues=dqn.qvalues,
    score_set=dqn.qvalues,       # pointwise net: the set path IS the row path
    fused_kernel=True,
    hyperparams=(("hidden", dqn.HIDDEN),),
))


# ---------------------------------------------------------------------------
# "attention" — set-attention scorer over the candidate-node feature set
# ---------------------------------------------------------------------------

ATTN_DMODEL = 16
ATTN_HEADS = 2


def init_attention(key: jax.Array, d_model: int = ATTN_DMODEL) -> dict:
    ks = jax.random.split(key, 6)

    def dense(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) * (1.0 / fan_in) ** 0.5

    d = d_model
    return {
        "w_in": dense(ks[0], FEATURE_DIM, (FEATURE_DIM, d)),
        "b_in": jnp.zeros((d,), jnp.float32),
        "wq": dense(ks[1], d, (d, d)),
        "wk": dense(ks[2], d, (d, d)),
        "wv": dense(ks[3], d, (d, d)),
        "wo": dense(ks[4], d, (d, d)),
        "w_out": dense(ks[5], d, (d, 1)),
        "b_out": jnp.zeros((1,), jnp.float32),
    }


def _attn_embed(params, feats):
    return jnp.tanh(feats @ params["w_in"] + params["b_in"])


def _attn_head(params, x, attn_out):
    h = jax.nn.relu(x + attn_out @ params["wo"])   # residual mix of set context
    return (h @ params["w_out"] + params["b_out"])[..., 0]


def attention_qvalues(params: dict, feats: jnp.ndarray) -> jnp.ndarray:
    """Pointwise Q over ``(..., F)`` rows == the set scorer on singleton sets:
    softmax over one key is the identity, so ``attn_out == v`` exactly (the
    same seq-len-1 precedent as ``baselines.transformer_score``)."""
    x = _attn_embed(params, feats)
    return _attn_head(params, x, x @ params["wv"])


def attention_score_set(params: dict, feats: jnp.ndarray,
                        mode: Optional[str] = None) -> jnp.ndarray:
    """(N, F) candidate set -> (N,) scores with one MHA mix over the node
    axis, through the shared ``kernels.ops.flash_attention`` dispatch
    (Pallas on TPU, the XLA online-attention twin elsewhere — the same
    interpret-safe fallback story as ``sdqn_score``)."""
    from repro.kernels import ops

    x = _attn_embed(params, feats)                          # (N, d)
    n, d = x.shape
    hd = d // ATTN_HEADS

    def heads(t):
        return t.reshape(1, n, ATTN_HEADS, hd)              # (B=1, S=N, H, hd)

    out = ops.flash_attention(heads(x @ params["wq"]), heads(x @ params["wk"]),
                              heads(x @ params["wv"]), causal=False, mode=mode)
    return _attn_head(params, x, out.reshape(n, d))


ATTENTION = register(PolicySpec(
    name="attention",
    feature_dim=FEATURE_DIM,
    embed_dim=0,
    init=init_attention,
    qvalues=attention_qvalues,
    score_set=attention_score_set,
    hyperparams=(("d_model", ATTN_DMODEL), ("heads", ATTN_HEADS)),
))


# ---------------------------------------------------------------------------
# "mamba" — selective-state-space arrival-history encoder + MLP Q-head
# ---------------------------------------------------------------------------

MAMBA_DI = 8        # encoder inner channels
MAMBA_STATE = 4     # SSM state size per channel
MAMBA_DT_RANK = 2
MAMBA_EMBED = 8     # history-embed width appended to afterstate rows
MAMBA_HIDDEN = 32   # Q-head hidden width (Table 4)


def init_mamba(key: jax.Array) -> dict:
    di, n, r, e = MAMBA_DI, MAMBA_STATE, MAMBA_DT_RANK, MAMBA_EMBED
    f = FEATURE_DIM + e
    ks = jax.random.split(key, 6)

    def dense(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) * (1.0 / fan_in) ** 0.5

    return {
        "enc": {
            "in_proj": dense(ks[0], ENCODER_IN, (ENCODER_IN, di)),
            "x_proj": dense(ks[1], di, (di, r + 2 * n)),
            "dt_proj": dense(ks[2], r, (r, di)),
            # softplus(dt_bias) ~ 0.05: a gentle default discretization step
            "dt_bias": jnp.full((di,), jnp.log(jnp.expm1(0.05)), jnp.float32),
            # S4D-real init: A = -(1..n) per channel
            "A_log": jnp.broadcast_to(
                jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), (di, n)
            ) + jnp.zeros((di, n), jnp.float32),
            "D": jnp.ones((di,), jnp.float32),
            "out_proj": dense(ks[3], di, (di, e)),
        },
        "head": {
            "w1": jax.random.normal(ks[4], (f, MAMBA_HIDDEN), jnp.float32)
            * (2.0 / f) ** 0.5,
            "b1": jnp.zeros((MAMBA_HIDDEN,), jnp.float32),
            "w2": jax.random.normal(ks[5], (MAMBA_HIDDEN, 1), jnp.float32)
            * (1.0 / MAMBA_HIDDEN) ** 0.5,
            "b2": jnp.zeros((1,), jnp.float32),
        },
    }


def mamba_qvalues(params: dict, feats: jnp.ndarray) -> jnp.ndarray:
    """Q-head over ``(..., FEATURE_DIM + MAMBA_EMBED)`` rows."""
    head = params["head"]
    h = jax.nn.relu(feats @ head["w1"] + head["b1"])
    return (h @ head["w2"] + head["b2"])[..., 0]


def mamba_carry_init(params: dict) -> jnp.ndarray:
    return jnp.zeros((MAMBA_DI, MAMBA_STATE), jnp.float32)


def _mamba_ssm_params(enc: dict, x: jnp.ndarray):
    """x: (..., di) -> (dt (..., di), b (..., n), c (..., n)), fp32."""
    proj = x @ enc["x_proj"]
    r, n = MAMBA_DT_RANK, MAMBA_STATE
    dt_raw, b, c = (proj[..., :r], proj[..., r:r + n], proj[..., r + n:])
    dt = jax.nn.softplus(dt_raw @ enc["dt_proj"] + enc["dt_bias"])
    return dt, b, c


def mamba_encode_step(params: dict, carry: jnp.ndarray,
                      workload: jnp.ndarray):
    """One arrival: ``(carry (di, n), workload (ENCODER_IN,)) ->
    (new_carry, embed (MAMBA_EMBED,))`` — the ``models.mamba.decode_mamba``
    recurrence (``h = exp(dt·a)·h + (dt·x)·B; y = h·C + x·D``) at O(1) per
    step, jit-safe inside any scanned episode."""
    enc = params["enc"]
    x = jax.nn.silu(workload @ enc["in_proj"])              # (di,)
    dt, b, c = _mamba_ssm_params(enc, x)
    a = -jnp.exp(enc["A_log"])                              # (di, n)
    da = jnp.exp(dt[:, None] * a)
    h = da * carry + (dt * x)[:, None] * b[None, :]
    y = h @ c + x * enc["D"]                                # (di,)
    return h, jnp.tanh(y @ enc["out_proj"])


def mamba_encode_sequence(params: dict, workloads: jnp.ndarray,
                          h0: Optional[jnp.ndarray] = None,
                          mode: Optional[str] = None):
    """Batch re-encode a ``(T, ENCODER_IN)`` arrival history in one pass via
    the chunked selective-scan kernel (``kernels.ops.mamba_scan``: Pallas on
    TPU, the XLA associative-scan twin elsewhere).  Returns
    ``(embeds (T, MAMBA_EMBED), h_final (di, n))`` — step-for-step equal to
    folding ``mamba_encode_step`` (pinned in tests/test_policy.py)."""
    from repro.kernels import ops

    enc = params["enc"]
    x = jax.nn.silu(workloads @ enc["in_proj"])[None]       # (1, T, di)
    dt, b, c = _mamba_ssm_params(enc, x)
    a = -jnp.exp(enc["A_log"])
    if h0 is None:
        h0 = mamba_carry_init(params)
    y, h_final = ops.mamba_scan(x, dt.astype(jnp.float32), a,
                                b.astype(jnp.float32), c.astype(jnp.float32),
                                enc["D"], h0[None], mode=mode)
    return jnp.tanh(y[0] @ enc["out_proj"]), h_final[0]


MAMBA = register(PolicySpec(
    name="mamba",
    feature_dim=FEATURE_DIM + MAMBA_EMBED,
    embed_dim=MAMBA_EMBED,
    init=init_mamba,
    qvalues=mamba_qvalues,
    score_set=mamba_qvalues,     # pointwise head; context lives in the embed
    encode_step=mamba_encode_step,
    carry_init=mamba_carry_init,
    hyperparams=(("d_inner", MAMBA_DI), ("ssm_state", MAMBA_STATE),
                 ("dt_rank", MAMBA_DT_RANK), ("embed", MAMBA_EMBED),
                 ("hidden", MAMBA_HIDDEN)),
))


# ---------------------------------------------------------------------------
# versioned policy checkpoints (legacy-MLP fallback for old manifests)
# ---------------------------------------------------------------------------

POLICY_CKPT_VERSION = 1


def checkpoint_metadata(spec: PolicySpec) -> dict:
    return {
        "policy_ckpt_version": POLICY_CKPT_VERSION,
        "policy": spec.name,
        "feature_dim": spec.feature_dim,
        "hyperparams": dict(spec.hyperparams),
    }


def save_checkpoint(ckpt_dir: str, step: int, params,
                    spec: PolicySpec, extra: Optional[dict] = None) -> str:
    """``ckpt.save`` with the versioned policy metadata record attached, so
    any variant restores without the caller knowing its class up front."""
    from repro.checkpoint import ckpt

    meta = dict(extra or {})
    meta.update(checkpoint_metadata(spec))
    return ckpt.save(ckpt_dir, step, params, extra=meta)


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       default_policy: str = "mlp",
                       on_corrupt: str = "raise"):
    """Restore ``(params, spec)`` from a checkpoint directory.

    The manifest's policy record picks the spec; manifests written before
    the record existed (any pre-registry trainer run) fall back to
    ``default_policy`` — the legacy-MLP path, so old checkpoints and
    ``--qnet-path`` keep loading.

    ``on_corrupt`` controls what a damaged checkpoint does.  ``"raise"``
    (the default) propagates the integrity error.  ``"fallback"`` — the
    serving-daemon setting — logs a warning and returns a FRESH init of the
    declared (or default) policy class instead: a placement service must
    come up with a sane scorer rather than crash on (or silently serve) a
    truncated shard, a checksum mismatch, or a garbled manifest.
    """
    import warnings
    import zipfile

    from repro.checkpoint import ckpt

    def fresh(spec, why: str):
        warnings.warn(
            f"checkpoint under {ckpt_dir!r} is unusable ({why}); "
            f"falling back to a fresh {spec.name!r} init",
            RuntimeWarning, stacklevel=2)
        return spec.init(jax.random.PRNGKey(0)), spec

    # integrity failure classes: shard/manifest checksum mismatch (IOError),
    # missing leaves (KeyError), shape drift (ValueError), truncated npz
    # (zipfile.BadZipFile), garbled manifest json (json.JSONDecodeError, a
    # ValueError subclass).  FileNotFoundError — no checkpoint at all — is
    # NOT integrity damage and always propagates.
    _CORRUPT = (IOError, KeyError, ValueError, zipfile.BadZipFile)

    try:
        meta = ckpt.read_extra(ckpt_dir, step=step)
    except FileNotFoundError:
        raise
    except _CORRUPT as e:
        if on_corrupt != "fallback":
            raise
        return fresh(get(default_policy), f"unreadable manifest: {e}")
    spec = get(meta.get("policy", default_policy))
    template = jax.eval_shape(lambda: spec.init(jax.random.PRNGKey(0)))
    try:
        return ckpt.restore(ckpt_dir, template, step=step), spec
    except FileNotFoundError:
        raise
    except _CORRUPT as e:
        if on_corrupt != "fallback":
            raise
        return fresh(spec, str(e))
