"""The paper's primary contribution: SDQN / SDQN-n reinforcement-learning
schedulers for compute-intensive pods, plus the paper's baselines
(default kube-scheduler, LSTM, Transformer) and their training loops."""
from repro.core import baselines, dqn, env, replay, rewards, schedulers, train_rl  # noqa: F401
from repro.core.types import (  # noqa: F401
    ArrivalConfig,
    ClusterState,
    EnvConfig,
    NodeClass,
    PodSpec,
    PodTable,
    PodType,
    ScenarioConfig,
    fleet_cluster,
    paper_cluster,
    scenario_env,
    training_cluster,
)
