"""Scheduler policies: SDQN, SDQN-n, and the neural baselines, as
``(key, state, pod) -> node`` selectors compatible with ``env.run_episode``.

All policies apply the k8s *filtering* phase first (paper §3.2) and only
score feasible nodes; SDQN/SDQN-n score afterstates with the DQN.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import dqn, env as kenv
from repro.core.types import ClusterState, EnvConfig, PodSpec

NEG_INF = -jnp.inf


def masked_argmax(key: jax.Array, scores: jnp.ndarray, ok: jnp.ndarray,
                  epsilon: jnp.ndarray | float = 0.0) -> jnp.ndarray:
    """Greedy over feasible nodes, with epsilon-greedy exploration."""
    scores = jnp.where(ok, scores, NEG_INF)
    greedy = jnp.argmax(scores).astype(jnp.int32)
    ke, kr = jax.random.split(key)
    explore = jax.random.uniform(ke) < epsilon
    noise = jnp.where(ok, jax.random.uniform(kr, scores.shape), NEG_INF)
    rand = jnp.argmax(noise).astype(jnp.int32)
    return jnp.where(explore, rand, greedy)


def score_afterstates(qparams: dict, state: ClusterState, pod: PodSpec,
                      cfg: EnvConfig, score_fn=None) -> jnp.ndarray:
    """(N,) scores: Q(afterstate_i) for each candidate node i."""
    after = kenv.hypothetical_place(state, pod, cfg)        # (N, 6) raw
    fn = score_fn or dqn.qvalues
    return fn(qparams, kenv.normalize_features(after))


def make_sdqn_selector(qparams: dict, cfg: EnvConfig, epsilon: float = 0.0,
                       score_fn=None) -> Callable:
    def select(key, state, pod):
        ok = kenv.feasible(state, pod, cfg)
        q = score_afterstates(qparams, state, pod, cfg, score_fn)
        return masked_argmax(key, q, ok, epsilon)

    return select


# SDQN-n uses the same scoring machinery; consolidation comes from the reward
# the network was trained on (Table 5), not from a different selector.
make_sdqn_n_selector = make_sdqn_selector


def make_neural_selector(params: dict, score_fn, cfg: EnvConfig) -> Callable:
    """LSTM / Transformer baselines: same afterstate scoring protocol."""

    def select(key, state, pod):
        ok = kenv.feasible(state, pod, cfg)
        scores = score_afterstates(params, state, pod, cfg, score_fn)
        return masked_argmax(key, scores, ok, 0.0)

    return select


def make_kube_selector(cfg: EnvConfig) -> Callable:
    from repro.core import baselines

    def select(key, state, pod):
        return baselines.kube_select(key, state, pod, cfg)

    return select
