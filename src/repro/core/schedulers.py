"""Scheduler policies: SDQN, SDQN-n, and the neural baselines, as
``(key, state, pod) -> node`` selectors compatible with ``env.run_episode``.

All policies apply the k8s *filtering* phase first (paper §3.2) and only
score feasible nodes; SDQN/SDQN-n score afterstates with the DQN.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import dqn, env as kenv
from repro.core.types import ClusterState, EnvConfig, PodSpec

NEG_INF = -jnp.inf

# Above this node count, SDQN scoring goes through the fused afterstate
# kernel (repro.kernels.ops.sdqn_score_afterstate): afterstate features are
# computed *inside* the scoring kernel, so the (N, 6) feature matrix is never
# materialized in HBM.  Below it, the plain O(N) jnp path wins on dispatch
# overhead.  n_nodes is a static shape, so the branch resolves at trace time.
FUSED_SCORE_MIN_NODES = 4096


def masked_argmax(key: jax.Array, scores: jnp.ndarray, ok: jnp.ndarray,
                  epsilon: jnp.ndarray | float = 0.0) -> jnp.ndarray:
    """Greedy over feasible nodes, with epsilon-greedy exploration.

    Returns ``env.NO_NODE`` (-1) when no node is feasible: an argmax over
    all ``-inf`` scores would silently return node 0, binding pods to
    full/unhealthy nodes during infeasible bursts.  ``env.place`` treats the
    sentinel as a no-op and ``env.run_episode`` counts it as a drop.
    """
    scores = jnp.where(ok, scores, NEG_INF)
    greedy = jnp.argmax(scores).astype(jnp.int32)
    ke, kr = jax.random.split(key)
    explore = jax.random.uniform(ke) < epsilon
    noise = jnp.where(ok, jax.random.uniform(kr, scores.shape), NEG_INF)
    rand = jnp.argmax(noise).astype(jnp.int32)
    choice = jnp.where(explore, rand, greedy)
    return jnp.where(jnp.any(ok), choice, jnp.int32(kenv.NO_NODE))


def score_afterstates(qparams: dict, state: ClusterState, pod: PodSpec,
                      cfg: EnvConfig, score_fn=None,
                      fused: bool | str = "auto", policy=None,
                      embed=None, pull_cost=None) -> jnp.ndarray:
    """(N,) scores: Q(afterstate_i) for each candidate node i.

    This is the ONE scoring dispatch the trainer, the serving daemon, the
    consolidator, and the public ``repro.sched.api.score`` entry point share.

    ``fused`` selects the backend:
      * ``"auto"`` (default) — the fused kernel path (Pallas on TPU, a fused
        XLA twin elsewhere; afterstate features are computed in-kernel and
        the (N, 6) matrix never hits HBM) when the default Table-4 Q-net is
        used and ``N >= FUSED_SCORE_MIN_NODES``; the plain O(N) jnp path
        below that, where dispatch overhead dominates;
      * ``True`` — force the fused path at any N;
      * ``"interpret"`` — the Pallas kernel body in interpret mode (kernel
        correctness sweeps on CPU);
      * ``False`` — force the unfused jnp path.

    ``policy`` (a ``core.policy.PolicySpec``) swaps the scorer for a
    registered policy class: candidates are scored through
    ``policy.score_set`` over the whole (N, F) set, with ``embed`` (the
    policy's history embedding, for ``embed_dim > 0`` specs) appended to
    every row.  Fused-capable specs ("mlp") keep the kernel path; every
    other spec — like a custom ``score_fn`` (LSTM/Transformer baselines) —
    always takes the jnp path, since it cannot be fused into the afterstate
    kernel.

    ``pull_cost`` pins the image-pull contention scalar instead of reducing
    it from ``state`` — sharded scoring (``sched.shard``) computes this
    GLOBAL reduction once over the full fleet and threads it into each
    per-shard call, keeping shard-local scores identical to the unsharded
    program.
    """
    if score_fn is not None and policy is not None:
        raise ValueError("pass either score_fn or policy, not both")
    fusable = score_fn is None and (policy is None or policy.fused_kernel)
    if fused in (True, "interpret") and not fusable:
        raise ValueError("custom score_fn / non-fusable policy cannot take "
                         "the fused kernel path")
    use_fused = fused in (True, "interpret") or (
        fused == "auto" and fusable
        and state.n_nodes >= FUSED_SCORE_MIN_NODES)
    if use_fused:
        from repro.kernels import ops

        mode = "interpret" if fused == "interpret" else None
        return ops.sdqn_score_afterstate(state, pod, cfg, qparams, mode=mode,
                                         pull_cost=pull_cost)
    after = kenv.hypothetical_place(state, pod, cfg,
                                    pull_cost=pull_cost)   # (N, 6) raw
    feats = kenv.normalize_features(after)
    if policy is not None:
        if embed is not None:
            feats = jnp.concatenate(
                [feats, jnp.broadcast_to(embed, feats.shape[:-1] + embed.shape)],
                axis=-1)
        return policy.score_set(qparams, feats)
    fn = score_fn or dqn.qvalues
    return fn(qparams, feats)


def score_afterstates_batch(qparams: dict, state: ClusterState, pods: PodSpec,
                            cfg: EnvConfig, score_fn=None,
                            fused: bool | str = "auto",
                            policy=None) -> jnp.ndarray:
    """(B, N) scores for a *batch* of candidate pods against one snapshot.

    ``pods`` is a ``PodSpec`` whose fields carry a leading batch dim (B,).
    The batch axis is vmapped over the shared per-pod dispatch, so under
    ``jit`` the whole batch lowers to ONE device launch — this is the
    serving daemon's batched scoring pass (``sched.daemon``).
    """
    return jax.vmap(
        lambda p: score_afterstates(qparams, state, p, cfg, score_fn, fused,
                                    policy=policy)
    )(pods)


def make_sdqn_selector(qparams: dict, cfg: EnvConfig, epsilon: float = 0.0,
                       score_fn=None) -> Callable:
    def select(key, state, pod):
        ok = kenv.feasible(state, pod, cfg)
        q = score_afterstates(qparams, state, pod, cfg, score_fn)
        return masked_argmax(key, q, ok, epsilon)

    return select


# SDQN-n uses the same scoring machinery; consolidation comes from the reward
# the network was trained on (Table 5), not from a different selector.
make_sdqn_n_selector = make_sdqn_selector


def make_policy_selector(spec, params: dict, cfg: EnvConfig,
                         epsilon: float = 0.0):
    """Episode selector for any registered policy class.

    Returns ``(select, carry0)``:

      * stateless specs (``embed_dim == 0``, or ``spec is None`` = the
        default Table-4 net): ``select(key, state, pod) -> node`` and
        ``carry0 is None`` — drop-in for ``env.run_episode``;
      * sequence specs: ``select(key, state, pod, carry) -> (node, carry)``
        plus the initial carry — pass both to ``env.run_episode`` via
        ``select_carry`` so the history threads through the scanned episode.
    """
    if spec is None or spec.embed_dim == 0:

        def select(key, state, pod):
            ok = kenv.feasible(state, pod, cfg)
            q = score_afterstates(params, state, pod, cfg, policy=spec)
            return masked_argmax(key, q, ok, epsilon)

        return select, None

    from repro.core import policy as policy_mod

    def select(key, state, pod, carry):
        carry2, emb = spec.encode_step(
            params, carry, policy_mod.pod_workload_features(pod))
        ok = kenv.feasible(state, pod, cfg)
        q = score_afterstates(params, state, pod, cfg, policy=spec, embed=emb)
        return masked_argmax(key, q, ok, epsilon), carry2

    return select, spec.carry_init(params)


def make_neural_selector(params: dict, score_fn, cfg: EnvConfig) -> Callable:
    """LSTM / Transformer baselines: same afterstate scoring protocol."""

    def select(key, state, pod):
        ok = kenv.feasible(state, pod, cfg)
        scores = score_afterstates(params, state, pod, cfg, score_fn)
        return masked_argmax(key, scores, ok, 0.0)

    return select


def make_kube_selector(cfg: EnvConfig) -> Callable:
    from repro.core import baselines

    def select(key, state, pod):
        return baselines.kube_select(key, state, pod, cfg)

    return select
