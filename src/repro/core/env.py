"""Vectorized, jittable Kubernetes-cluster environment.

Reproduces the paper's experimental substrate (§4.3, §5): a cluster of slave
nodes receiving batches of compute-intensive no-op pods.  Everything is pure
JAX on static shapes so episodes can be ``lax.scan``-ed and whole populations
of clusters ``vmap``-ed / ``shard_map``-ed for fleet-scale policy training.

CPU accounting per node (millicores):

    used = base_cpu                               (pre-existing load)
         + active * node_active_overhead          (kubelet/runtime/monitoring)
         + pods_cpu                               (pod compute demand)
         + startup_cpu                            (decaying pull/start transients)
         + contention(used/capacity)              (super-linear above the knee)

Image pulls are cold only for the first experiment pod on a node
(`image_cached`), matching the paper's §4.3.2 image-caching/shared-I/O
explanation for why consolidation saves CPU.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ClusterState, EnvConfig, PodSpec

# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------


def _profile(key, profile: tuple, jitter: float, n: int) -> jnp.ndarray:
    """Tile `profile` to n entries, permute, jitter — stable totals, varied layout."""
    kp, kj = jax.random.split(key)
    reps = -(-n // len(profile))  # ceil
    vals = jnp.tile(jnp.asarray(profile, jnp.float32), reps)[:n]
    vals = jax.random.permutation(kp, vals)
    return vals + jax.random.uniform(kj, (n,), minval=-jitter, maxval=jitter)


def reset(key: jax.Array, cfg: EnvConfig) -> ClusterState:
    n = cfg.n_nodes
    k1, k2, k3, k4 = jax.random.split(key, 4)
    base = jnp.maximum(_profile(k1, cfg.base_cpu_profile, cfg.base_cpu_jitter, n), 0.0)
    uptime = jax.random.uniform(
        k2, (n,), minval=cfg.init_uptime_range_h[0], maxval=cfg.init_uptime_range_h[1]
    )
    healthy = jax.random.uniform(k3, (n,)) >= cfg.unhealthy_prob
    # pre-existing *requests* (control-plane bookings by other tenants) are
    # permuted independently of pre-existing *usage* — see EnvConfig docstring.
    requested0 = cfg.cpu_capacity * jnp.clip(
        _profile(k4, cfg.requested_frac_profile, cfg.requested_frac_jitter, n), 0.0, 0.95
    )
    z = jnp.zeros((n,), jnp.float32)

    # bookings come from tenant pods: a node with X millicores requested is
    # hosting ~X/pod_request pods of other tenants (visible to the Table-2
    # num_pods / pod-utilization features; their CPU usage is part of base).
    tenant_pods = (requested0 / cfg.pod_cpu_request).astype(jnp.int32)

    exp_pods0 = jnp.zeros((n,), jnp.int32)
    cached0 = jnp.zeros((n,), bool)
    startup0 = z
    if cfg.randomize_workload:
        # training-only domain randomization: nodes start mid-flight so the
        # Q-net sees (features -> reward) decorrelated from episode time.
        kr1, kr2, kr3, kr4 = jax.random.split(jax.random.fold_in(key, 7), 4)
        pods = jax.random.randint(kr1, (n,), 0, cfg.randomize_max_pods + 1)
        empty = jax.random.uniform(kr2, (n,)) < cfg.randomize_empty_prob
        exp_pods0 = jnp.where(empty, 0, pods).astype(jnp.int32)
        cached0 = (exp_pods0 > 0) | (jax.random.uniform(kr3, (n,)) < cfg.randomize_cached_prob)
        startup0 = jax.random.uniform(kr4, (n,), maxval=0.3 * cfg.image_pull_cost)

    fexp = exp_pods0.astype(jnp.float32)
    return ClusterState(
        cpu_capacity=jnp.full((n,), cfg.cpu_capacity),
        mem_capacity=jnp.full((n,), cfg.mem_capacity),
        max_pods=jnp.full((n,), cfg.max_pods, jnp.int32),
        healthy=healthy,
        uptime_hours=uptime,
        num_pods=tenant_pods + exp_pods0,
        exp_pods=exp_pods0,
        cpu_requested=jnp.minimum(requested0 + fexp * cfg.pod_cpu_request,
                                  0.98 * cfg.cpu_capacity),
        mem_requested=fexp * cfg.pod_mem_request,
        pods_cpu=fexp * cfg.pod_cpu_demand,
        mem_used=fexp * cfg.pod_mem_demand,
        base_cpu=base,
        startup_cpu=startup0,
        image_cached=cached0,
        time_s=jnp.float32(0.0),
    )


def default_pod(cfg: EnvConfig) -> PodSpec:
    return PodSpec(
        cpu_request=jnp.float32(cfg.pod_cpu_request),
        cpu_demand=jnp.float32(cfg.pod_cpu_demand),
        mem_request=jnp.float32(cfg.pod_mem_request),
        mem_demand=jnp.float32(cfg.pod_mem_demand),
    )


# ---------------------------------------------------------------------------
# observation (Table 2 features)
# ---------------------------------------------------------------------------


def cpu_used(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    """Actual per-node CPU usage in millicores, incl. contention inflation.

    Three super-linearities (all invisible to request-based scoring):
      * contention — CFS pressure once utilization passes the knee;
      * crowding — context-switch/cgroup cost once a node hosts many pods;
      * both stack on the base + overhead + pod-demand + startup transients.
    """
    active = state.exp_pods > 0
    crowd = jnp.maximum(state.num_pods.astype(jnp.float32) - cfg.crowd_knee, 0.0)
    raw = (
        state.base_cpu
        + jnp.where(active, cfg.node_active_overhead, 0.0)
        + state.pods_cpu
        + state.startup_cpu
        + cfg.crowd_coeff * crowd * crowd
    )
    util = raw / state.cpu_capacity
    over = jnp.maximum(util - cfg.contention_knee, 0.0)
    contention = cfg.contention_coeff * over * over * state.cpu_capacity
    return jnp.minimum(raw + contention, state.cpu_capacity)


def cpu_pct(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    return 100.0 * cpu_used(state, cfg) / state.cpu_capacity


def features(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    """The six Table-2 inputs, one row per node: (N, 6) float32."""
    return jnp.stack(
        [
            cpu_pct(state, cfg),
            100.0 * state.mem_used / state.mem_capacity,
            100.0 * state.num_pods / state.max_pods,   # utilization: ALL pods
            state.healthy.astype(jnp.float32),
            state.uptime_hours,
            state.exp_pods.astype(jnp.float32),        # count: OUR workload's pods
        ],
        axis=-1,
    )


FEATURE_SCALE = jnp.array([100.0, 100.0, 100.0, 1.0, 24.0, 32.0], jnp.float32)


def normalize_features(feats: jnp.ndarray) -> jnp.ndarray:
    """Scale raw Table-2 features to O(1) for the neural scorers."""
    return feats / FEATURE_SCALE


# ---------------------------------------------------------------------------
# scheduling predicates (k8s filtering phase)
# ---------------------------------------------------------------------------


def feasible(state: ClusterState, pod: PodSpec, cfg: EnvConfig) -> jnp.ndarray:
    """k8s predicates: Ready, CPU/mem requests fit, below max-pods. (N,) bool."""
    return (
        state.healthy
        & (state.cpu_requested + pod.cpu_request <= state.cpu_capacity)
        & (state.mem_requested + pod.mem_request <= state.mem_capacity)
        & (state.num_pods < state.max_pods)
    )


# ---------------------------------------------------------------------------
# transitions
# ---------------------------------------------------------------------------


def place(state: ClusterState, action: jnp.ndarray, pod: PodSpec, cfg: EnvConfig) -> ClusterState:
    """Bind one pod to node `action` (int32 scalar).

    Cold image pulls contend for registry/network bandwidth: each pull already
    in flight (startup transient still large) inflates a new pull's cost by
    ``pull_concurrency_coeff`` — spreading a burst of pods across many cold
    nodes at once (what the request-blind default scheduler does) is
    super-additively expensive, while warm reuse is cheap (paper §4.3.2).
    """
    onehot = jax.nn.one_hot(action, state.n_nodes, dtype=jnp.float32)
    onehot_i = onehot.astype(jnp.int32)
    cold = jnp.logical_not(state.image_cached)[action]
    in_flight = jnp.sum(state.startup_cpu > 0.25 * cfg.image_pull_cost).astype(jnp.float32)
    pull_cost = cfg.image_pull_cost * (1.0 + cfg.pull_concurrency_coeff * in_flight)
    start_cost = jnp.where(cold, pull_cost, cfg.warm_start_cost)
    return state._replace(
        num_pods=state.num_pods + onehot_i,
        exp_pods=state.exp_pods + onehot_i,
        cpu_requested=state.cpu_requested + onehot * pod.cpu_request,
        mem_requested=state.mem_requested + onehot * pod.mem_request,
        pods_cpu=state.pods_cpu + onehot * pod.cpu_demand,
        mem_used=state.mem_used + onehot * pod.mem_demand,
        startup_cpu=state.startup_cpu + onehot * start_cost,
        image_cached=state.image_cached | (onehot_i > 0),
    )


def hypothetical_place(state: ClusterState, pod: PodSpec, cfg: EnvConfig) -> jnp.ndarray:
    """Afterstate features for *every* candidate node: (N, 6).

    Row i = Table-2 features of node i as if the pod were placed there.
    This is the SDQN scoring input (Q is evaluated on afterstates).
    """
    n = state.n_nodes

    def one(i):
        return features(place(state, i, pod, cfg), cfg)[i]

    return jax.vmap(one)(jnp.arange(n))


def tick(state: ClusterState, cfg: EnvConfig, dt_s: float) -> ClusterState:
    """Advance wall-clock: decay startup transients, accrue uptime."""
    return state._replace(
        startup_cpu=state.startup_cpu * cfg.startup_decay,
        uptime_hours=state.uptime_hours + dt_s / 3600.0,
        time_s=state.time_s + dt_s,
    )


# ---------------------------------------------------------------------------
# the paper's evaluation metric (§4.3.2)
# ---------------------------------------------------------------------------


def average_cpu_utilization(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    """Cluster-wide average CPU% per node (idle nodes included)."""
    return jnp.mean(cpu_pct(state, cfg))


def run_episode(
    key: jax.Array,
    cfg: EnvConfig,
    select_action,  # (key, state, pod) -> int32 node index
    n_pods: int,
) -> Tuple[ClusterState, jnp.ndarray, jnp.ndarray]:
    """Schedule `n_pods` arrivals with `select_action`, then settle.

    Returns (final_state, pod_distribution (N,), metric = time-averaged
    cluster-average CPU% over the measurement window).
    """
    state = reset(key, cfg)
    pod = default_pod(cfg)

    def sched_step(carry, k):
        st, acc, cnt = carry
        a = select_action(k, st, pod)
        st = place(st, a, pod, cfg)
        st = tick(st, cfg, cfg.schedule_dt_s)
        m = average_cpu_utilization(st, cfg)
        return (st, acc + m, cnt + 1.0), a

    keys = jax.random.split(key, n_pods)
    (state, acc, cnt), actions = jax.lax.scan(sched_step, (state, 0.0, 0.0), keys)

    def settle_step(carry, _):
        st, acc, cnt = carry
        st = tick(st, cfg, cfg.schedule_dt_s)
        m = average_cpu_utilization(st, cfg)
        return (st, acc + m, cnt + 1.0), None

    (state, acc, cnt), _ = jax.lax.scan(
        settle_step, (state, acc, cnt), None, length=cfg.settle_steps
    )
    distribution = state.num_pods
    return state, distribution, acc / cnt
