"""Vectorized, jittable Kubernetes-cluster environment.

Reproduces the paper's experimental substrate (§4.3, §5): a cluster of slave
nodes receiving batches of compute-intensive no-op pods.  Everything is pure
JAX on static shapes so episodes can be ``lax.scan``-ed and whole populations
of clusters ``vmap``-ed / ``shard_map``-ed for fleet-scale policy training.

CPU accounting per node (millicores):

    used = base_cpu                               (pre-existing load)
         + active * node_active_overhead          (kubelet/runtime/monitoring)
         + pods_cpu                               (pod compute demand)
         + startup_cpu                            (decaying pull/start transients)
         + contention(used/capacity)              (super-linear above the knee)

Image pulls are cold only for the first experiment pod on a node
(`image_cached`), matching the paper's §4.3.2 image-caching/shared-I/O
explanation for why consolidation saves CPU.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import (FEATURE_DIM, NO_PLACEMENT, ClusterState,
                              EnvConfig, EpisodeResult, EpisodeStats,
                              FailureTrace, PodLedger, PodSpec, PodTable)

# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------


def _profile(key, profile: tuple, jitter: float, n: int) -> jnp.ndarray:
    """Tile `profile` to n entries, permute, jitter — stable totals, varied layout."""
    kp, kj = jax.random.split(key)
    reps = -(-n // len(profile))  # ceil
    vals = jnp.tile(jnp.asarray(profile, jnp.float32), reps)[:n]
    vals = jax.random.permutation(kp, vals)
    return vals + jax.random.uniform(kj, (n,), minval=-jitter, maxval=jitter)


def _scenario_pool(scn) -> dict:
    """Static per-node arrays for a heterogeneous pool (trace-time numpy)."""

    def col(get, dtype=np.float32):
        return np.concatenate(
            [np.full(c.count, get(c), dtype) for c in scn.node_classes]
        )

    return {
        "cpu_capacity": col(lambda c: c.cpu_capacity),
        "mem_capacity": col(lambda c: c.mem_capacity),
        "max_pods": col(lambda c: c.max_pods, np.int32),
        "unhealthy_prob": col(lambda c: c.unhealthy_prob),
        "cached_prob": col(lambda c: c.image_cached_prob),
        "base_lo": col(lambda c: c.base_cpu_frac[0]),
        "base_hi": col(lambda c: c.base_cpu_frac[1]),
        "req_lo": col(lambda c: c.requested_frac[0]),
        "req_hi": col(lambda c: c.requested_frac[1]),
        "idle_watts": col(lambda c: c.idle_watts),
        "peak_watts": col(lambda c: c.peak_watts),
        "mtbf": col(lambda c: c.mtbf_s),
        "mttr": col(lambda c: c.mttr_s),
    }


def node_watts(cfg: EnvConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Static per-node (idle_watts, peak_watts) arrays for the energy model."""
    if cfg.scenario is None:
        return (jnp.full((cfg.n_nodes,), cfg.idle_watts, jnp.float32),
                jnp.full((cfg.n_nodes,), cfg.peak_watts, jnp.float32))
    pool = _scenario_pool(cfg.scenario)
    return jnp.asarray(pool["idle_watts"]), jnp.asarray(pool["peak_watts"])


def reset(key: jax.Array, cfg: EnvConfig) -> ClusterState:
    n = cfg.n_nodes
    k1, k2, k3, k4 = jax.random.split(key, 4)
    uptime = jax.random.uniform(
        k2, (n,), minval=cfg.init_uptime_range_h[0], maxval=cfg.init_uptime_range_h[1]
    )
    if cfg.scenario is None:
        cap = jnp.full((n,), cfg.cpu_capacity)
        mem_cap = jnp.full((n,), cfg.mem_capacity)
        max_pods = jnp.full((n,), cfg.max_pods, jnp.int32)
        base = jnp.maximum(_profile(k1, cfg.base_cpu_profile, cfg.base_cpu_jitter, n), 0.0)
        healthy = jax.random.uniform(k3, (n,)) >= cfg.unhealthy_prob
        # pre-existing *requests* (control-plane bookings by other tenants) are
        # permuted independently of pre-existing *usage* — see EnvConfig docstring.
        requested0 = cfg.cpu_capacity * jnp.clip(
            _profile(k4, cfg.requested_frac_profile, cfg.requested_frac_jitter, n), 0.0, 0.95
        )
        cached_prob = jnp.zeros((n,), jnp.float32)
    else:
        pool = _scenario_pool(cfg.scenario)
        cap = jnp.asarray(pool["cpu_capacity"])
        mem_cap = jnp.asarray(pool["mem_capacity"])
        max_pods = jnp.asarray(pool["max_pods"])
        # base load and bookings scale with each class's own capacity, so a
        # 2-core edge node and a 16-core crunch node are proportionately busy.
        base = cap * jax.random.uniform(
            k1, (n,), minval=jnp.asarray(pool["base_lo"]), maxval=jnp.asarray(pool["base_hi"])
        )
        healthy = jax.random.uniform(k3, (n,)) >= jnp.asarray(pool["unhealthy_prob"])
        requested0 = cap * jnp.clip(
            jax.random.uniform(
                k4, (n,), minval=jnp.asarray(pool["req_lo"]), maxval=jnp.asarray(pool["req_hi"])
            ),
            0.0, 0.95,
        )
        cached_prob = jnp.asarray(pool["cached_prob"])
    z = jnp.zeros((n,), jnp.float32)
    pod0 = mean_pod(cfg)

    # bookings come from tenant pods: a node with X millicores requested is
    # hosting ~X/pod_request pods of other tenants (visible to the Table-2
    # num_pods / pod-utilization features; their CPU usage is part of base).
    tenant_pods = (requested0 / pod0.cpu_request).astype(jnp.int32)

    exp_pods0 = jnp.zeros((n,), jnp.int32)
    cached0 = jax.random.uniform(jax.random.fold_in(key, 11), (n,)) < cached_prob
    startup0 = z
    if cfg.randomize_workload:
        # training-only domain randomization: nodes start mid-flight so the
        # Q-net sees (features -> reward) decorrelated from episode time.
        kr1, kr2, kr3, kr4 = jax.random.split(jax.random.fold_in(key, 7), 4)
        pods = jax.random.randint(kr1, (n,), 0, cfg.randomize_max_pods + 1)
        # keep randomized starts physical on every node class: a node hosts
        # only what fits its own memory and pod slots (a small-edge node must
        # not wake up with a big node's worth of pods).
        mem_den = jnp.maximum(jnp.maximum(pod0.mem_request, pod0.mem_demand), 1e-6)
        mem_fit = jnp.floor(0.9 * mem_cap / mem_den).astype(jnp.int32)
        slot_fit = max_pods - tenant_pods
        pods = jnp.minimum(pods, jnp.maximum(jnp.minimum(mem_fit, slot_fit), 0))
        empty = jax.random.uniform(kr2, (n,)) < cfg.randomize_empty_prob
        exp_pods0 = jnp.where(empty, 0, pods).astype(jnp.int32)
        cached0 = cached0 | (exp_pods0 > 0) | (
            jax.random.uniform(kr3, (n,)) < cfg.randomize_cached_prob
        )
        startup0 = jax.random.uniform(kr4, (n,), maxval=0.3 * cfg.image_pull_cost)

    fexp = exp_pods0.astype(jnp.float32)
    return ClusterState(
        cpu_capacity=cap,
        mem_capacity=mem_cap,
        max_pods=max_pods,
        healthy=healthy,
        uptime_hours=uptime,
        num_pods=tenant_pods + exp_pods0,
        exp_pods=exp_pods0,
        cpu_requested=jnp.minimum(requested0 + fexp * pod0.cpu_request, 0.98 * cap),
        mem_requested=fexp * pod0.mem_request,
        pods_cpu=fexp * pod0.cpu_demand,
        mem_used=fexp * pod0.mem_demand,
        base_cpu=base,
        startup_cpu=startup0,
        image_cached=cached0,
        time_s=jnp.float32(0.0),
    )


def default_pod(cfg: EnvConfig) -> PodSpec:
    return PodSpec(
        cpu_request=jnp.float32(cfg.pod_cpu_request),
        cpu_demand=jnp.float32(cfg.pod_cpu_demand),
        mem_request=jnp.float32(cfg.pod_mem_request),
        mem_demand=jnp.float32(cfg.pod_mem_demand),
    )


def mean_pod(cfg: EnvConfig) -> PodSpec:
    """Mixture-weighted mean PodSpec of the scenario's catalog (falls back to
    the homogeneous default pod).  Used for pre-existing workload accounting
    at reset; the per-arrival specs come from the sampled pod table."""
    scn = cfg.scenario
    if scn is None:
        return default_pod(cfg)
    w = np.asarray([p.weight for p in scn.pod_types], np.float64)
    w = w / w.sum()

    def m(get):
        return jnp.float32(float(np.sum(w * np.asarray([get(p) for p in scn.pod_types]))))

    return PodSpec(
        cpu_request=m(lambda p: p.cpu_request),
        cpu_demand=m(lambda p: p.cpu_demand),
        mem_request=m(lambda p: p.mem_request),
        mem_demand=m(lambda p: p.mem_demand),
    )


# ---------------------------------------------------------------------------
# arrival stream (pre-sampled pod table; lax.scan consumes it row by row)
# ---------------------------------------------------------------------------


def _arrival_gaps(key: jax.Array, cfg: EnvConfig, n_pods: int) -> jnp.ndarray:
    """Inter-arrival times (n_pods,) for the scenario's arrival process."""
    arr = cfg.scenario.arrival if cfg.scenario is not None else None
    if arr is None or arr.kind == "burst":
        return jnp.full((n_pods,), cfg.schedule_dt_s, jnp.float32)
    e = jax.random.exponential(key, (n_pods,), jnp.float32)
    if arr.kind == "poisson":
        return e / arr.rate_per_s

    if arr.kind != "diurnal":
        raise ValueError(f"unknown arrival kind: {arr.kind!r}")

    # diurnal: Poisson stream with sinusoidally modulated rate.  The arrival
    # clock advances sequentially (each gap depends on the rate at the current
    # wall-clock), so thin through a tiny scan over the pre-sampled unit
    # exponentials — still one fused XLA loop.
    def step(t, e_i):
        rate = arr.rate_per_s * (1.0 + arr.depth * jnp.sin(2.0 * jnp.pi * t / arr.period_s))
        dt = e_i / jnp.maximum(rate, 1e-6)
        return t + dt, dt

    _, dts = jax.lax.scan(step, jnp.float32(0.0), e)
    return dts


def _sample_lifetimes(key: jax.Array, scn, type_idx: jnp.ndarray,
                      n_pods: int) -> jnp.ndarray:
    """Per-arrival running durations from each ``PodType``'s distribution.

    Lognormal with the type's mean and coefficient of variation (``cv=0``
    degenerates to the deterministic mean; ``mean=inf`` pods never finish).
    The lognormal's heavy tail is the empirically observed shape of container
    job durations (a few stragglers dominate the drain window).
    """
    if scn is None:
        return jnp.full((n_pods,), jnp.inf, jnp.float32)
    mean = jnp.asarray([p.lifetime_mean_s for p in scn.pod_types], jnp.float32)
    cv = jnp.asarray([p.lifetime_cv for p in scn.pod_types], jnp.float32)
    sigma2 = jnp.log1p(cv * cv)
    # mean = exp(mu + sigma^2/2)  =>  mu = log(mean) - sigma^2/2; inf means
    # propagate: log(inf) = inf, exp(inf) = inf — the pod runs forever.
    mu = jnp.log(mean) - 0.5 * sigma2
    z = jax.random.normal(key, (n_pods,), jnp.float32)
    return jnp.exp(mu[type_idx] + jnp.sqrt(sigma2)[type_idx] * z)


def sample_pod_table(key: jax.Array, cfg: EnvConfig, n_pods: int) -> PodTable:
    """Draw the episode's arrival stream from the scenario (jittable).

    Without a scenario this is the paper's homogeneous burst: `n_pods` copies
    of the default pod every `schedule_dt_s` seconds, all running forever.
    Lifetimes draw from a dedicated ``fold_in(key, 3)`` stream so the
    type/gap draws stay identical to the pre-lifecycle tables.
    """
    k_type, k_dt = jax.random.split(key)
    k_life = jax.random.fold_in(key, 3)
    scn = cfg.scenario
    if scn is None:
        specs = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_pods,)), default_pod(cfg)
        )
        return PodTable(specs=specs, dt_s=_arrival_gaps(k_dt, cfg, n_pods),
                        type_idx=jnp.zeros((n_pods,), jnp.int32),
                        lifetime_s=jnp.full((n_pods,), jnp.inf, jnp.float32))
    w = jnp.asarray([p.weight for p in scn.pod_types], jnp.float32)
    type_idx = jax.random.categorical(k_type, jnp.log(w), shape=(n_pods,))
    by_type = PodSpec(
        cpu_request=jnp.asarray([p.cpu_request for p in scn.pod_types], jnp.float32),
        cpu_demand=jnp.asarray([p.cpu_demand for p in scn.pod_types], jnp.float32),
        mem_request=jnp.asarray([p.mem_request for p in scn.pod_types], jnp.float32),
        mem_demand=jnp.asarray([p.mem_demand for p in scn.pod_types], jnp.float32),
    )
    specs = jax.tree.map(lambda col: col[type_idx], by_type)
    return PodTable(specs=specs, dt_s=_arrival_gaps(k_dt, cfg, n_pods),
                    type_idx=type_idx.astype(jnp.int32),
                    lifetime_s=_sample_lifetimes(k_life, scn, type_idx, n_pods))


# ---------------------------------------------------------------------------
# observation (Table 2 features)
# ---------------------------------------------------------------------------


def _node_cpu_used(base_cpu, active, pods_cpu, startup_cpu, num_pods,
                   cpu_capacity, cfg: EnvConfig) -> jnp.ndarray:
    """Elementwise per-node CPU model, shared by state scoring and the O(N)
    afterstate fast path (one definition, so they cannot diverge).

    Three super-linearities (all invisible to request-based scoring):
      * contention — CFS pressure once utilization passes the knee;
      * crowding — context-switch/cgroup cost once a node hosts many pods;
      * both stack on the base + overhead + pod-demand + startup transients.
    """
    crowd = jnp.maximum(num_pods.astype(jnp.float32) - cfg.crowd_knee, 0.0)
    raw = (
        base_cpu
        + jnp.where(active, cfg.node_active_overhead, 0.0)
        + pods_cpu
        + startup_cpu
        + cfg.crowd_coeff * crowd * crowd
    )
    util = raw / cpu_capacity
    over = jnp.maximum(util - cfg.contention_knee, 0.0)
    contention = cfg.contention_coeff * over * over * cpu_capacity
    return jnp.minimum(raw + contention, cpu_capacity)


def _feature_stack(used, mem_used, num_pods, max_pods, healthy, uptime_hours,
                   exp_pods, cpu_capacity, mem_capacity) -> jnp.ndarray:
    """The six Table-2 columns from elementwise node quantities: (..., 6)."""
    return jnp.stack(
        [
            100.0 * used / cpu_capacity,
            100.0 * mem_used / mem_capacity,
            100.0 * num_pods / max_pods,               # utilization: ALL pods
            healthy.astype(jnp.float32),
            uptime_hours,
            exp_pods.astype(jnp.float32),              # count: OUR workload's pods
        ],
        axis=-1,
    )


def cpu_used(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    """Actual per-node CPU usage in millicores, incl. contention inflation."""
    return _node_cpu_used(state.base_cpu, state.exp_pods > 0, state.pods_cpu,
                          state.startup_cpu, state.num_pods, state.cpu_capacity, cfg)


def cpu_pct(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    return 100.0 * cpu_used(state, cfg) / state.cpu_capacity


def features(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    """The six Table-2 inputs, one row per node: (N, 6) float32."""
    return _feature_stack(cpu_used(state, cfg), state.mem_used, state.num_pods,
                          state.max_pods, state.healthy, state.uptime_hours,
                          state.exp_pods, state.cpu_capacity, state.mem_capacity)


FEATURE_SCALE = jnp.array([100.0, 100.0, 100.0, 1.0, 24.0, 32.0], jnp.float32)
assert FEATURE_SCALE.shape == (FEATURE_DIM,), \
    "FEATURE_SCALE must cover exactly the canonical afterstate width"


def normalize_features(feats: jnp.ndarray) -> jnp.ndarray:
    """Scale raw Table-2 features to O(1) for the neural scorers."""
    return feats / FEATURE_SCALE


# ---------------------------------------------------------------------------
# scheduling predicates (k8s filtering phase)
# ---------------------------------------------------------------------------


def feasible(state: ClusterState, pod: PodSpec, cfg: EnvConfig) -> jnp.ndarray:
    """k8s predicates: Ready, CPU/mem requests fit, below max-pods. (N,) bool."""
    return (
        state.healthy
        & (state.cpu_requested + pod.cpu_request <= state.cpu_capacity)
        & (state.mem_requested + pod.mem_request <= state.mem_capacity)
        & (state.num_pods < state.max_pods)
    )


# ---------------------------------------------------------------------------
# transitions
# ---------------------------------------------------------------------------


def pull_cost_now(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    """Cost of starting a cold image pull *right now* (scalar).

    Cold image pulls contend for registry/network bandwidth: each pull already
    in flight (startup transient still large) inflates a new pull's cost by
    ``pull_concurrency_coeff`` — spreading a burst of pods across many cold
    nodes at once (what the request-blind default scheduler does) is
    super-additively expensive, while warm reuse is cheap (paper §4.3.2).
    """
    in_flight = jnp.sum(state.startup_cpu > 0.25 * cfg.image_pull_cost).astype(jnp.float32)
    return cfg.image_pull_cost * (1.0 + cfg.pull_concurrency_coeff * in_flight)


# sentinel action: no feasible node, the pod is dropped (no-op bind).  A
# re-export of the unified ``core.types.NO_PLACEMENT`` constant (the old
# per-module spelling, kept for callers that import it from here).
NO_NODE = NO_PLACEMENT


def place(state: ClusterState, action: jnp.ndarray, pod: PodSpec, cfg: EnvConfig) -> ClusterState:
    """Bind one pod to node `action` (int32 scalar).

    ``action == NO_NODE`` (-1) is the drop sentinel emitted by the selectors
    when the filtering phase leaves no candidate: the one-hot of -1 is all
    zeros, so the bind is a no-op and the cluster state passes through
    unchanged (no phantom pod on node 0 / a random node).
    """
    onehot = jax.nn.one_hot(action, state.n_nodes, dtype=jnp.float32)
    onehot_i = onehot.astype(jnp.int32)
    cold = jnp.logical_not(state.image_cached)[jnp.clip(action, 0, state.n_nodes - 1)]
    start_cost = jnp.where(cold, pull_cost_now(state, cfg), cfg.warm_start_cost)
    return state._replace(
        num_pods=state.num_pods + onehot_i,
        exp_pods=state.exp_pods + onehot_i,
        cpu_requested=state.cpu_requested + onehot * pod.cpu_request,
        mem_requested=state.mem_requested + onehot * pod.mem_request,
        pods_cpu=state.pods_cpu + onehot * pod.cpu_demand,
        mem_used=state.mem_used + onehot * pod.mem_demand,
        startup_cpu=state.startup_cpu + onehot * start_cost,
        image_cached=state.image_cached | (onehot_i > 0),
    )


def hypothetical_place(state: ClusterState, pod: PodSpec, cfg: EnvConfig,
                       pull_cost: jnp.ndarray | None = None) -> jnp.ndarray:
    """Afterstate features for *every* candidate node: (N, 6).

    Row i = Table-2 features of node i as if the pod were placed there.
    This is the SDQN scoring input (Q is evaluated on afterstates) and the
    hottest function in both training and serving-time placement.

    Row i of ``features(place(state, i, ...))`` depends only on node i's own
    columns, so instead of materializing N full placed cluster states
    (vmap-of-place: O(N^2) work and memory), apply the placement delta to
    every node at once and evaluate the feature formula elementwise — O(N).
    The ops mirror ``place``/``cpu_used``/``features`` exactly so the result
    is bit-identical to ``hypothetical_place_reference``.
    """
    # placement deltas (same arithmetic as `place` restricted to the chosen
    # row).  ``pull_cost`` overrides the in-flight pull-contention scalar:
    # it is a GLOBAL reduction over startup transients, so sharded scoring
    # (sched.shard) computes it ONCE from the full fleet and threads it into
    # every per-shard call — a per-shard recompute would silently diverge
    # from the unsharded program.
    pull = pull_cost_now(state, cfg) if pull_cost is None else pull_cost
    start_cost = jnp.where(jnp.logical_not(state.image_cached),
                           pull, cfg.warm_start_cost)
    num_pods = state.num_pods + 1
    exp_pods = state.exp_pods + 1
    pods_cpu = state.pods_cpu + 1.0 * pod.cpu_demand
    mem_used = state.mem_used + 1.0 * pod.mem_demand
    startup_cpu = state.startup_cpu + start_cost

    used = _node_cpu_used(state.base_cpu, exp_pods > 0, pods_cpu, startup_cpu,
                          num_pods, state.cpu_capacity, cfg)
    return _feature_stack(used, mem_used, num_pods, state.max_pods, state.healthy,
                          state.uptime_hours, exp_pods, state.cpu_capacity,
                          state.mem_capacity)


def hypothetical_place_one(state: ClusterState, pod: PodSpec, cfg: EnvConfig,
                           node: jnp.ndarray) -> jnp.ndarray:
    """Afterstate features of a single candidate node: one (6,) row.

    Row ``node`` of ``hypothetical_place`` without building the (N, 6)
    matrix — the training loop scores through the fused kernel dispatch and
    only ever *stores* the one afterstate it actually bound, so the full
    matrix is never needed on the replay path.  (Still O(N) *time*:
    ``pull_cost_now`` scans the in-flight startup transients; what this
    saves is the (N, 6) materialization and HBM round-trip.)  ``node`` must
    be a valid index (callers clamp the ``NO_NODE`` sentinel and zero-weight
    the sample).  Same elementwise arithmetic as ``hypothetical_place``,
    applied to the gathered columns, so the row matches bit-for-bit.
    """
    start_cost = jnp.where(jnp.logical_not(state.image_cached[node]),
                           pull_cost_now(state, cfg), cfg.warm_start_cost)
    num_pods = state.num_pods[node] + 1
    exp_pods = state.exp_pods[node] + 1
    pods_cpu = state.pods_cpu[node] + 1.0 * pod.cpu_demand
    mem_used = state.mem_used[node] + 1.0 * pod.mem_demand
    startup_cpu = state.startup_cpu[node] + start_cost

    used = _node_cpu_used(state.base_cpu[node], exp_pods > 0, pods_cpu,
                          startup_cpu, num_pods, state.cpu_capacity[node], cfg)
    return _feature_stack(used, mem_used, num_pods, state.max_pods[node],
                          state.healthy[node], state.uptime_hours[node],
                          exp_pods, state.cpu_capacity[node],
                          state.mem_capacity[node])


def hypothetical_place_reference(state: ClusterState, pod: PodSpec, cfg: EnvConfig) -> jnp.ndarray:
    """Reference afterstate scorer: vmap of the full transition (O(N^2)).

    Kept as the semantic ground truth the fast path is verified against
    (tests/test_scenarios.py) and as the baseline in benchmarks/sched_scale.py.
    """
    n = state.n_nodes

    def one(i):
        return features(place(state, i, pod, cfg), cfg)[i]

    return jax.vmap(one)(jnp.arange(n))


def remove_pod(state: ClusterState, node: jnp.ndarray, pod: PodSpec,
               count: jnp.ndarray | int = 1) -> ClusterState:
    """Unbind ``count`` pods of spec ``pod`` from ``node``: the exact inverse
    of ``place``'s resource accounting (startup transients and the cached
    image stay — pulling is not undone by a pod finishing or migrating)."""
    c = jnp.asarray(count, jnp.float32)
    onehot = jax.nn.one_hot(node, state.n_nodes, dtype=jnp.float32) * c
    onehot_i = onehot.astype(jnp.int32)
    return state._replace(
        num_pods=state.num_pods - onehot_i,
        exp_pods=state.exp_pods - onehot_i,
        cpu_requested=state.cpu_requested - onehot * pod.cpu_request,
        mem_requested=state.mem_requested - onehot * pod.mem_request,
        pods_cpu=state.pods_cpu - onehot * pod.cpu_demand,
        mem_used=state.mem_used - onehot * pod.mem_demand,
    )


# ---------------------------------------------------------------------------
# pod lifecycle: fixed-shape expiry ledger, retirement, energy accounting
# ---------------------------------------------------------------------------


def ledger_init(n_slots: int) -> PodLedger:
    """Empty expiry ledger with one slot per episode arrival (static shape)."""
    z = jnp.zeros((n_slots,), jnp.float32)
    return PodLedger(
        node=jnp.full((n_slots,), -1, jnp.int32),
        expiry_s=jnp.full((n_slots,), jnp.inf, jnp.float32),
        spec=PodSpec(cpu_request=z, cpu_demand=z, mem_request=z, mem_demand=z),
    )


def ledger_record(ledger: PodLedger, slot, action, expiry_s, pod: PodSpec) -> PodLedger:
    """Write arrival ``slot``: where the pod went and when it completes.

    Dropped arrivals (``action == NO_NODE``) record as empty slots, so they
    are never retired (no resources were ever acquired).
    """
    action = jnp.asarray(action, jnp.int32)
    return PodLedger(
        node=ledger.node.at[slot].set(action),
        expiry_s=ledger.expiry_s.at[slot].set(
            jnp.where(action >= 0, jnp.asarray(expiry_s, jnp.float32), jnp.inf)),
        spec=jax.tree.map(lambda col, v: col.at[slot].set(v), ledger.spec, pod),
    )


def retire_expired(state: ClusterState, ledger: PodLedger
                   ) -> Tuple[ClusterState, PodLedger, jnp.ndarray]:
    """Retire every ledger pod whose expiry has passed: release its CPU/mem
    requests, compute demand, and pod slots on its node, and free the slot.

    One fused scatter-add (``segment_sum`` over the ledger's node column) per
    resource column — O(K + N) with static shapes, so the scanned episode
    loop and the vmapped eval/train engines batch over lifecycle episodes
    unchanged.  With all-``inf`` lifetimes every mask is false and the state
    passes through bit-for-bit (the static-table parity case).
    """
    n = state.n_nodes
    done = (ledger.node >= 0) & (ledger.expiry_s <= state.time_s)
    seg = jnp.clip(ledger.node, 0, n - 1)
    w = done.astype(jnp.float32)

    def released(col):
        return jax.ops.segment_sum(w * col, seg, num_segments=n)

    cnt = jax.ops.segment_sum(done.astype(jnp.int32), seg, num_segments=n)
    state = state._replace(
        num_pods=state.num_pods - cnt,
        exp_pods=state.exp_pods - cnt,
        cpu_requested=state.cpu_requested - released(ledger.spec.cpu_request),
        mem_requested=state.mem_requested - released(ledger.spec.mem_request),
        pods_cpu=state.pods_cpu - released(ledger.spec.cpu_demand),
        mem_used=state.mem_used - released(ledger.spec.mem_demand),
    )
    ledger = ledger._replace(node=jnp.where(done, -1, ledger.node))
    return state, ledger, jnp.sum(done).astype(jnp.int32)


def has_lifecycle(cfg: EnvConfig) -> bool:
    """True when the scenario's catalog contains any finite-lifetime pod.

    A *static* (trace-time) property: scenarios are hashable jit statics, so
    episodes over purely-immortal workloads skip the ledger bookkeeping
    entirely — the hot training loop pays for retirement scatters only when
    pods can actually retire.
    """
    scn = cfg.scenario
    return scn is not None and any(
        np.isfinite(p.lifetime_mean_s) for p in scn.pod_types)


# ---------------------------------------------------------------------------
# chaos: mid-episode node failures (fixed-shape, jit/vmap-safe)
# ---------------------------------------------------------------------------


def has_chaos(cfg: EnvConfig) -> bool:
    """True when any node class can fail mid-episode (finite ``mtbf_s``).

    Like ``has_lifecycle`` this is a *static* (trace-time) property: the
    default all-``inf`` MTBF keeps every pre-chaos scenario's episode trace
    byte-identical — no eviction scatters, no reschedule ring in the carry.
    """
    scn = cfg.scenario
    return scn is not None and any(
        np.isfinite(c.mtbf_s) for c in scn.node_classes)


def empty_failure_trace(n_nodes: int, cycles: int = 1) -> FailureTrace:
    """A trace in which no node ever fails (all windows at ``inf``).

    Threading this through ``run_episode`` exercises the chaos code path with
    every mask false — the parity case the tests pin (≤1e-6 vs no trace).
    """
    full = jnp.full((cycles, n_nodes), jnp.inf, jnp.float32)
    return FailureTrace(fail_s=full, recover_s=full)


def sample_failure_trace(key: jax.Array, cfg: EnvConfig,
                         cycles: Optional[int] = None) -> FailureTrace:
    """Draw per-node fail/recover schedules from each class's MTBF/MTTR.

    An alternating-renewal (Poisson fail / Poisson repair) process: node
    ``n``'s ``c``-th outage starts ``Exp(mtbf)`` after its previous recovery
    and lasts ``Exp(mttr)``.  Cycles accumulate sequentially in a *static*
    python loop so ``mtbf = inf`` stays ``inf`` all the way down (a vectorized
    cumsum would hit ``inf - inf`` NaNs); the unit exponentials are clamped
    away from zero so ``inf * 0`` can never appear either.
    """
    cycles = cfg.chaos_cycles if cycles is None else cycles
    if cfg.scenario is None:
        mtbf = jnp.full((cfg.n_nodes,), jnp.inf, jnp.float32)
        mttr = jnp.full((cfg.n_nodes,), 60.0, jnp.float32)
    else:
        pool = _scenario_pool(cfg.scenario)
        mtbf = jnp.asarray(pool["mtbf"])
        mttr = jnp.asarray(pool["mttr"])
    n = mtbf.shape[0]
    prev = jnp.zeros((n,), jnp.float32)
    fails, recovers = [], []
    for c in range(cycles):
        ku = jax.random.fold_in(key, 2 * c)
        kd = jax.random.fold_in(key, 2 * c + 1)
        up = mtbf * jnp.maximum(jax.random.exponential(ku, (n,), jnp.float32), 1e-6)
        down = mttr * jnp.maximum(jax.random.exponential(kd, (n,), jnp.float32), 1e-6)
        f = prev + up
        r = f + down
        fails.append(f)
        recovers.append(r)
        prev = r
    return FailureTrace(fail_s=jnp.stack(fails), recover_s=jnp.stack(recovers))


def trace_down(trace: FailureTrace, t_s: jnp.ndarray) -> jnp.ndarray:
    """Per-node down mask at episode time ``t_s``: (N,) bool."""
    return jnp.any((trace.fail_s <= t_s) & (t_s < trace.recover_s), axis=0)


class RescheduleQueue(NamedTuple):
    """Fixed-capacity ring of evicted pods awaiting re-placement.

    Each entry points back at the pod's own pre-reserved ledger slot (its
    spec is still recorded there) plus the run time it had left when its node
    died — re-placement restarts the pod from scratch with that remaining
    duration (checkpoint/restart semantics).  ``head``/``count`` bound the
    live window; pushes past capacity are *lost* (counted, never silent).
    """

    slot: jnp.ndarray         # (R,) int32 ledger slot of each queued pod
    remaining_s: jnp.ndarray  # (R,) float32 run time left at eviction
    head: jnp.ndarray         # int32 index of the oldest entry
    count: jnp.ndarray        # int32 number of live entries


def reschedule_queue_init(cap: int) -> RescheduleQueue:
    return RescheduleQueue(
        slot=jnp.full((cap,), -1, jnp.int32),
        remaining_s=jnp.zeros((cap,), jnp.float32),
        head=jnp.int32(0),
        count=jnp.int32(0),
    )


def _queue_push(q: RescheduleQueue, mask: jnp.ndarray, values: jnp.ndarray,
                cap: int) -> Tuple[RescheduleQueue, jnp.ndarray]:
    """Push every masked ledger slot into the ring (oldest-first FIFO order).

    Rank-by-cumsum turns the boolean mask into contiguous ring positions;
    entries past the remaining space scatter to an out-of-range index and
    are dropped by ``mode="drop"`` — returned as the overflow (lost) count.
    """
    space = cap - q.count
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    ok = mask & (rank < space)
    pos = jnp.where(ok, (q.head + q.count + rank) % cap, cap)
    slot_ids = jnp.arange(mask.shape[0], dtype=jnp.int32)
    n_mask = jnp.sum(mask.astype(jnp.int32))
    n_push = jnp.minimum(n_mask, space)
    q = q._replace(
        slot=q.slot.at[pos].set(slot_ids, mode="drop"),
        remaining_s=q.remaining_s.at[pos].set(values, mode="drop"),
        count=q.count + n_push,
    )
    return q, n_mask - n_push


def evict_down_pods(state: ClusterState, ledger: PodLedger, q: RescheduleQueue,
                    healthy_base: jnp.ndarray, trace: FailureTrace, cap: int
                    ) -> Tuple[ClusterState, PodLedger, RescheduleQueue,
                               jnp.ndarray, jnp.ndarray]:
    """Apply the failure trace at the current episode time.

    Flips ``healthy`` to ``healthy_base & ~down(t)`` and evicts every ledger
    pod hosted on a down node through the same fused ``segment_sum`` release
    as ``retire_expired``, pushing each into the reschedule ring with its
    remaining run time.  Idempotent across steps: an evicted slot's node is
    ``-1``, so a node staying down evicts nothing new.  Returns
    ``(state, ledger, queue, n_evicted, n_overflow_lost)``.
    """
    n = state.n_nodes
    down = trace_down(trace, state.time_s)
    state = state._replace(healthy=healthy_base & jnp.logical_not(down))
    seg = jnp.clip(ledger.node, 0, n - 1)
    evict = (ledger.node >= 0) & down[seg]
    w = evict.astype(jnp.float32)

    def released(col):
        return jax.ops.segment_sum(w * col, seg, num_segments=n)

    cnt = jax.ops.segment_sum(evict.astype(jnp.int32), seg, num_segments=n)
    state = state._replace(
        num_pods=state.num_pods - cnt,
        exp_pods=state.exp_pods - cnt,
        cpu_requested=state.cpu_requested - released(ledger.spec.cpu_request),
        mem_requested=state.mem_requested - released(ledger.spec.mem_request),
        pods_cpu=state.pods_cpu - released(ledger.spec.cpu_demand),
        mem_used=state.mem_used - released(ledger.spec.mem_demand),
    )
    remaining = ledger.expiry_s - state.time_s
    ledger = ledger._replace(node=jnp.where(evict, -1, ledger.node))
    q, n_lost = _queue_push(q, evict, remaining, cap)
    return state, ledger, q, jnp.sum(evict).astype(jnp.int32), n_lost


def nodes_active(state: ClusterState) -> jnp.ndarray:
    """Nodes hosting >= 1 experiment pod — the nodes our workload keeps up."""
    return jnp.sum(state.exp_pods > 0).astype(jnp.int32)


def fleet_power_w(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    """Instantaneous power draw (watts) billed to the experiment workload.

    Each node hosting our pods draws ``idle + (peak - idle) * cpu_util``;
    nodes without experiment pods are releasable (a green autoscaler could
    power them down), so they bill nothing — consolidation savings show up
    directly in the integral of this quantity.
    """
    idle, peak = node_watts(cfg)
    util = cpu_used(state, cfg) / state.cpu_capacity
    return jnp.sum(jnp.where(state.exp_pods > 0,
                             idle + (peak - idle) * util, 0.0))


def tick(state: ClusterState, cfg: EnvConfig, dt_s) -> ClusterState:
    """Advance wall-clock: decay startup transients, accrue uptime.

    ``startup_decay`` is calibrated per ``schedule_dt_s`` step, so with
    variable arrival gaps (Poisson/diurnal scenarios) the transient decays
    by ``decay ** (dt / schedule_dt_s)`` — wall-clock time, not arrival
    count, governs how long an image pull saturates a node.
    """
    decay = cfg.startup_decay ** (dt_s / cfg.schedule_dt_s)
    return state._replace(
        startup_cpu=state.startup_cpu * decay,
        uptime_hours=state.uptime_hours + dt_s / 3600.0,
        time_s=state.time_s + dt_s,
    )


# ---------------------------------------------------------------------------
# the paper's evaluation metric (§4.3.2)
# ---------------------------------------------------------------------------


def average_cpu_utilization(state: ClusterState, cfg: EnvConfig) -> jnp.ndarray:
    """Cluster-wide average CPU% per node (idle nodes included)."""
    return jnp.mean(cpu_pct(state, cfg))


class _EpisodeAcc(NamedTuple):
    """Scan-carried accumulators of the dt-weighted episode integrals."""

    metric: jnp.ndarray        # sum of avg-CPU% * dt
    dt: jnp.ndarray            # total integrated wall-clock
    node_seconds: jnp.ndarray  # sum of nodes_active * dt
    energy_j: jnp.ndarray      # sum of fleet power * dt (joules)
    peak_active: jnp.ndarray   # max nodes_active seen
    retired: jnp.ndarray       # int32 pods completed + released
    evicted: jnp.ndarray       # int32 pods killed by node failures
    rescheduled: jnp.ndarray   # int32 evicted pods re-placed in-episode
    lost: jnp.ndarray          # int32 evicted pods dropped off the ring


def _acc_init() -> _EpisodeAcc:
    z = jnp.float32(0.0)
    zi = jnp.int32(0)
    return _EpisodeAcc(z, z, z, z, z, zi, zi, zi, zi)


def run_episode(
    key: jax.Array,
    cfg: EnvConfig,
    select_action,  # (key, state, pod) -> int32 node index
    n_pods: int,
    pod_table: Optional[PodTable] = None,
    consolidate: Optional[Callable] = None,
    select_carry=None,
    failure_trace: Optional[FailureTrace] = None,
) -> EpisodeResult:
    """Schedule `n_pods` arrivals with `select_action`, settle, retire.

    Arrivals come from `pod_table` when given, otherwise they are sampled
    from `cfg.scenario` (homogeneous fixed burst when no scenario is set).
    The reset / arrival-stream / per-step action keys are split up front so
    the initial cluster layout is independent of the exploration noise.

    The cluster is *dynamic*: every placement records its sampled lifetime in
    a fixed-shape ``PodLedger`` and ``retire_expired`` releases completed
    pods' CPU/mem/slots inside the scanned loop, so idle nodes appear over
    time and the SDQN-n consolidation/energy story becomes measurable.  With
    all-``inf`` lifetimes (the default pod, any catalog entry without a
    duration) retirement is the identity and episodes reproduce the
    pre-lifecycle static-table trajectories bit-for-bit.

    ``consolidate`` (see ``sched.elastic.make_consolidator``) runs every
    ``cfg.consolidate_every_s`` seconds of episode time: a jit-safe SDQN-n
    pass that migrates pods off nearly-idle nodes through the fused
    ``score_afterstates`` dispatch.

    ``select_carry`` (a pytree, e.g. ``PolicySpec.carry_init``'s state)
    switches ``select_action`` to the carrying protocol
    ``(key, state, pod, carry) -> (node, carry)``: sequence policy classes
    (Mamba arrival-history encoders) thread their recurrent state through
    the scanned arrivals.  ``None`` (the default) keeps the stateless
    three-argument selector protocol unchanged.

    ``failure_trace`` injects mid-episode node failures (see
    ``sample_failure_trace``): whenever a node's outage window opens, its
    ``healthy`` flips off, its ledger pods are evicted through the fused
    ``segment_sum`` release, and the evictees queue in a fixed-capacity
    reschedule ring — each subsequent arrival step attempts one re-placement
    back into the pod's own pre-reserved ledger slot with its remaining run
    time.  When ``None`` and the scenario has any finite-MTBF node class, a
    trace is auto-sampled from a dedicated ``fold_in(key, 13)`` stream (the
    reset/arrival/action streams are untouched).  With no trace and an
    all-``inf`` MTBF catalog the chaos path is skipped at trace time, and
    with an ``empty_failure_trace`` every chaos mask is false — both pin the
    pre-chaos trajectories (the parity the tests assert).

    Returns an ``EpisodeResult`` ``(state, placements, metric, dropped,
    stats)`` where ``metric`` is the dt-weighted cluster-average CPU% (the
    paper's objective), ``placements`` is the final (N,) pod distribution,
    ``dropped`` counts ``NO_NODE`` arrivals, and ``stats`` is an
    ``EpisodeStats`` of the time-resolved lifecycle metrics (active nodes,
    node-seconds, energy, retirements).  The field order matches the legacy
    positional 5-tuple, so old-style unpacking still works through the
    NamedTuple shim.
    """
    k_reset, k_pods, k_act = jax.random.split(key, 3)
    state = reset(k_reset, cfg)
    # ledger bookkeeping is skipped at trace time when nothing can ever
    # retire: the scenario's catalog is all-inf AND no caller-supplied table
    # (whose lifetimes are runtime values) or consolidation pass needs slots
    do_consolidate = consolidate is not None and cfg.consolidate_every_s > 0.0
    use_chaos = failure_trace is not None or has_chaos(cfg)
    use_ledger = (pod_table is not None or has_lifecycle(cfg) or do_consolidate
                  or use_chaos)
    if pod_table is None:
        pod_table = sample_pod_table(k_pods, cfg, n_pods)
    if use_chaos and failure_trace is None:
        failure_trace = sample_failure_trace(jax.random.fold_in(key, 13), cfg)
    healthy_base = state.healthy
    requeue_cap = cfg.chaos_requeue_cap if use_chaos else 1

    # the metric integrates cluster-average CPU% over wall-clock (dt-weighted),
    # so bursty arrival phases don't over-weight the average under Poisson /
    # diurnal streams; with constant gaps this reduces to the plain mean.
    def advance(st, ledger, q, dt, acc: _EpisodeAcc):
        """Shared post-placement body: tick, retire, evict, consolidate,
        integrate."""
        t_before = st.time_s
        st = tick(st, cfg, dt)
        if use_ledger:
            st, ledger, n_ret = retire_expired(st, ledger)
        else:
            n_ret = jnp.int32(0)
        if use_chaos:
            # retire-then-evict: a pod both expired and on a dead node
            # releases exactly once (retirement already freed its slot)
            st, ledger, q, n_ev, n_lost = evict_down_pods(
                st, ledger, q, healthy_base, failure_trace, requeue_cap)
        else:
            n_ev = n_lost = jnp.int32(0)
        if do_consolidate:
            period = cfg.consolidate_every_s
            crossed = jnp.floor(st.time_s / period) > jnp.floor(t_before / period)
            st, ledger = jax.lax.cond(
                crossed,
                lambda args: consolidate(args[0], args[1])[:2],
                lambda args: args,
                (st, ledger),
            )
        m = average_cpu_utilization(st, cfg)
        na = nodes_active(st).astype(jnp.float32)
        acc = acc._replace(
            metric=acc.metric + m * dt,
            dt=acc.dt + dt,
            node_seconds=acc.node_seconds + na * dt,
            energy_j=acc.energy_j + fleet_power_w(st, cfg) * dt,
            peak_active=jnp.maximum(acc.peak_active, na),
            retired=acc.retired + n_ret,
            evicted=acc.evicted + n_ev,
            lost=acc.lost + n_lost,
        )
        return st, ledger, q, acc

    def try_reschedule(k, st, ledger, q, acc, pc):
        """One re-placement attempt per arrival step (fixed shape).

        Pops the ring head, re-scores it through the same selector as the
        arrival stream (a dedicated ``fold_in`` of the step key, so the
        arrival draws are untouched), and re-records into the pod's original
        ledger slot with its remaining run time.  A failed attempt rotates
        the entry to the tail — no head-of-line blocking while its resources
        are still scarce.  Every branch is ``where``-masked, so with an
        empty ring the whole block is the identity.
        """
        n_slots = ledger.node.shape[0]
        has = q.count > 0
        slot = jnp.clip(q.slot[q.head], 0, n_slots - 1)
        remaining = q.remaining_s[q.head]
        rpod = jax.tree.map(lambda col: col[slot], ledger.spec)
        a, pc2 = _select(jax.random.fold_in(k, 17), st, rpod, pc)
        pc = jax.tree.map(lambda new, old: jnp.where(has, new, old), pc2, pc)
        placed = has & (a >= 0)
        a_eff = jnp.where(placed, a, NO_NODE)
        st = place(st, a_eff, rpod, cfg)
        led2 = ledger_record(ledger, slot, a_eff, st.time_s + remaining, rpod)
        ledger = jax.tree.map(
            lambda new, old: jnp.where(placed, new, old), led2, ledger)
        # ring update: success pops the head; failure rotates it to the tail
        # (writing at (head+count) mod cap then advancing head is a correct
        # rotation even when the ring is full)
        tail = (q.head + q.count) % requeue_cap
        rotated = has & jnp.logical_not(placed)
        q = q._replace(
            slot=jnp.where(rotated, q.slot.at[tail].set(q.slot[q.head]), q.slot),
            remaining_s=jnp.where(
                rotated, q.remaining_s.at[tail].set(remaining), q.remaining_s),
            head=jnp.where(has, (q.head + 1) % requeue_cap, q.head),
            count=jnp.where(placed, q.count - 1, q.count),
        )
        acc = acc._replace(
            rescheduled=acc.rescheduled + placed.astype(jnp.int32))
        return st, ledger, q, acc, pc

    # the selector's carry rides the scan as an (empty for stateless
    # selectors) pytree — the () case adds no arrays, so the trace of the
    # historical three-argument protocol is unchanged
    if select_carry is None:
        sel_carry0 = ()

        def _select(k, st, pod, pc):
            return select_action(k, st, pod), pc
    else:
        sel_carry0 = select_carry
        _select = select_action

    def sched_step(carry, xs):
        st, ledger, q, acc, pc = carry
        t, k, pod, dt, lifetime = xs
        a, pc = _select(k, st, pod, pc)
        st = place(st, a, pod, cfg)
        if use_ledger:
            ledger = ledger_record(ledger, t, a, st.time_s + lifetime, pod)
        if use_chaos:
            st, ledger, q, acc, pc = try_reschedule(k, st, ledger, q, acc, pc)
        st, ledger, q, acc = advance(st, ledger, q, dt, acc)
        return (st, ledger, q, acc, pc), a

    keys = jax.random.split(k_act, n_pods)
    (state, ledger, q, acc, _), actions = jax.lax.scan(
        sched_step, (state, ledger_init(n_pods if use_ledger else 1),
                     reschedule_queue_init(requeue_cap), _acc_init(),
                     sel_carry0),
        (jnp.arange(n_pods), keys, pod_table.specs, pod_table.dt_s,
         pod_table.lifetime_s),
    )

    def settle_step(carry, _):
        st, ledger, q, acc = carry
        st, ledger, q, acc = advance(st, ledger, q, cfg.schedule_dt_s, acc)
        return (st, ledger, q, acc), None

    (state, ledger, q, acc), _ = jax.lax.scan(
        settle_step, (state, ledger, q, acc), None, length=cfg.settle_steps
    )
    stats = EpisodeStats(
        nodes_active_mean=acc.node_seconds / acc.dt,
        nodes_active_final=nodes_active(state),
        nodes_active_peak=acc.peak_active.astype(jnp.int32),
        node_seconds=acc.node_seconds,
        energy_wh=acc.energy_j / 3600.0,
        retired=acc.retired,
        evicted=acc.evicted,
        rescheduled=acc.rescheduled,
        # still-queued evictees never re-entered before the episode ended
        lost=acc.lost + q.count,
    )
    return EpisodeResult(
        state=state,
        placements=state.num_pods,
        metric=acc.metric / acc.dt,
        dropped=jnp.sum(actions < 0).astype(jnp.int32),
        stats=stats,
    )
