"""Production placement daemon: continuously-serving, batched, optimistic.

The paper's SDQN scheduler is only useful in production if it can serve
placement decisions under load.  This daemon is that serving loop:

  * **Batched one-launch scoring.**  Pending pod requests accumulate into
    batches (cut by size OR by the oldest request's wait time) and the whole
    batch is scored through the shared fused dispatch
    (``schedulers.score_afterstates_batch`` / ``ops.sdqn_score_delta`` via
    ``repro.sched.api``) in ONE device launch — one jitted call per batch,
    padded to a static batch shape so every fill level reuses one
    compilation.
  * **Double-buffered fleet state.**  Admission (``submit`` + committed
    binds) writes the *live* buffer — a mutable host-side (numpy) mirror —
    while scoring reads a frozen device *snapshot* published at batch cut.
    Request intake is a queue append plus numpy writes and never blocks on a
    device launch; the snapshot publish is an O(columns) transfer.
  * **Optimistic concurrency.**  Scores are computed against the snapshot,
    but by bind time the live buffer may have moved (earlier binds in the
    same batch, external churn applied through ``substrate.live``).  Every
    bind re-validates feasibility against the live buffer; a conflicted
    request loses the race and is re-queued to be re-scored against fresh
    state (``conflict_policy="requeue"``, mirroring the real kube binding
    race where an optimistic bind fails admission and the pod returns to the
    scheduling queue) or falls through to its next-best snapshot candidate
    (``conflict_policy="next-best"``).

Two substrates plug into the same loop: ``ClusterSubstrate`` (the paper's
pod->node cluster, ``core.env`` physics) and ``FleetSubstrate`` (job->host
placement over ``sched.placement.FleetState``, used by the serving driver in
``launch/serve.py``).  Both keep their live buffer as numpy mirrors whose
bind/feasibility arithmetic is pinned against the jnp reference
(``env.place`` / ``env.feasible``) in tests/test_daemon.py.

    sub = ClusterSubstrate(kenv.reset(key, cfg), cfg)
    d = PlacementDaemon(sub, qparams, DaemonConfig(batch_size=32))
    d.submit(pod); ...; d.poll(); decisions = d.decisions

Offered load comes from the scenario engine's arrival streams
(``scenarios.arrivals.arrival_trace``) replayed through ``replay_trace`` —
see ``benchmarks/placement_serve.py`` for the sustained placements/sec and
p50/p99 decision-latency bench.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as kenv, schedulers
from repro.core.types import NO_PLACEMENT, ClusterState, EnvConfig, PodSpec
from repro.sched import placement as _pl
from repro.sched.api import DIVERGENCE_LIMIT as _DIVERGENCE_LIMIT

__all__ = [
    "ClusterSubstrate", "DaemonConfig", "DaemonMetrics", "DaemonStats",
    "Decision", "FleetSubstrate", "LatencyReservoir", "PlacementDaemon",
    "replay_trace",
]


@dataclasses.dataclass(frozen=True)
class DaemonConfig:
    """Serving-loop knobs.

    A batch is cut when ``batch_size`` requests are pending OR the oldest
    pending request has waited ``max_wait_s`` — the standard
    throughput/latency trade of a batching server.  ``max_retries`` bounds
    how many times a conflicted request re-queues before it is dropped;
    ``conflict_policy`` picks what happens when an optimistic bind loses the
    race (see module docstring).  ``fused`` threads through to the scoring
    dispatch (``repro.sched.api.score``).

    Robustness knobs (all default to the legacy fail-open behavior):

    * ``queue_cap`` — admission backpressure: with more than this many
      requests pending, each new ``submit`` sheds the OLDEST pending request
      (decided as ``shed``, counted in ``DaemonStats.shed``) rather than
      growing the queue without bound.  ``0`` = unbounded.
    * ``backoff_base_s`` — a request that loses its optimistic bind re-queues
      with exponential backoff: attempt ``k`` waits
      ``backoff_base_s * 2**(k-1)`` before it is eligible for another batch
      (``poll`` honors the hold; ``flush``/``drain`` force it through so
      shutdown always terminates).  ``0`` = immediate re-queue.
    * ``score_deadline_s`` — per-batch scoring deadline.  A Q-net launch
      exceeding it (or returning NaN/diverged scores — always checked)
      degrades the daemon: the breached batch is re-scored with the closed-
      form kube heuristic (``sched.api.heuristic_score`` arithmetic, numpy,
      no device launch) and the next ``degrade_batches`` batches skip the
      Q-net entirely before probing it again.  ``None`` = no deadline.
    * ``heuristic_only`` — serve every batch with the kube heuristic (the
      degraded mode pinned on; the chaos bench's kube arm).
    """

    batch_size: int = 32
    max_wait_s: float = 0.02
    max_retries: int = 4
    conflict_policy: str = "requeue"     # "requeue" | "next-best"
    fused: object = "auto"
    queue_cap: int = 0                   # 0 = unbounded admission queue
    backoff_base_s: float = 0.0          # 0 = immediate conflict re-queue
    score_deadline_s: Optional[float] = None
    degrade_batches: int = 8
    heuristic_only: bool = False

    def __post_init__(self):
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if self.conflict_policy not in ("requeue", "next-best"):
            raise ValueError(f"unknown conflict_policy "
                             f"{self.conflict_policy!r}")
        if self.queue_cap < 0:
            raise ValueError("queue_cap must be >= 0 (0 = unbounded)")
        if self.backoff_base_s < 0:
            raise ValueError("backoff_base_s must be >= 0")
        if self.degrade_batches < 0:
            raise ValueError("degrade_batches must be >= 0")


class Decision(NamedTuple):
    """One served placement decision (``node == NO_PLACEMENT`` = dropped)."""

    req_id: int
    node: int
    latency_s: float       # decision time - submission time
    attempts: int          # 1 + times the request lost an optimistic bind
    shed: bool = False     # evicted from the admission queue (backpressure)


class LatencyReservoir:
    """Fixed-memory uniform sample of the decision-latency stream.

    Algorithm R over a numpy buffer: every latency ever appended has equal
    probability of being in the sample, so p50/p99 stay unbiased while a
    days-long ``replay_trace`` run holds ``capacity`` floats instead of an
    unbounded python list.  Deterministically seeded — two daemons fed the
    same stream report the same percentiles.  Keeps the list surface the
    bench relies on (``append``, ``len``, iteration, ``np.asarray``).
    """

    __slots__ = ("_buf", "_filled", "_seen", "_rng")

    def __init__(self, capacity: int = 4096, seed: int = 0):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self._buf = np.zeros((capacity,), np.float64)
        self._filled = 0      # live entries in the buffer
        self._seen = 0        # total appends ever
        self._rng = np.random.default_rng(seed)

    def append(self, x: float) -> None:
        cap = self._buf.shape[0]
        if self._filled < cap:
            self._buf[self._filled] = x
            self._filled += 1
        else:
            j = int(self._rng.integers(0, self._seen + 1))
            if j < cap:
                self._buf[j] = x
        self._seen += 1

    @property
    def seen(self) -> int:
        """Total latencies observed (not just the retained sample)."""
        return self._seen

    def __len__(self) -> int:
        return self._filled

    def __iter__(self):
        return iter(self._buf[:self._filled])

    def __array__(self, dtype=None, copy=None):
        arr = self._buf[:self._filled]
        return arr.astype(dtype) if dtype is not None else arr.copy()

    def percentile(self, q: float) -> float:
        if self._filled == 0:
            return float("nan")
        return float(np.percentile(self._buf[:self._filled], q))

    def p50(self) -> float:
        return self.percentile(50.0)

    def p99(self) -> float:
        return self.percentile(99.0)


@dataclasses.dataclass
class DaemonMetrics:
    submitted: int = 0
    bound: int = 0
    dropped: int = 0
    shed: int = 0           # evicted from the admission queue (backpressure)
    conflicts: int = 0      # optimistic binds that failed live re-validation
    requeued: int = 0       # conflicted requests sent back to the queue
    evictions: int = 0      # bound pods auto-requeued off a failed node
    batches: int = 0
    device_launches: int = 0  # jitted scoring calls (degraded batches skip)
    fallback_batches: int = 0  # batches served by the kube heuristic
    # decision latency of SERVED requests (bound or dropped) — the p50/p99
    # the placement_serve gate measures.  Shed requests live in shed_wait_s:
    # mixing the two meant that under backpressure the p99 gate measured
    # time-to-shed, not decision latency.
    bind_latencies_s: LatencyReservoir = dataclasses.field(
        default_factory=LatencyReservoir)
    shed_wait_s: LatencyReservoir = dataclasses.field(
        default_factory=LatencyReservoir)

    @property
    def latencies_s(self) -> LatencyReservoir:
        """Deprecated alias of ``bind_latencies_s`` (the pre-split field
        mixed shed wait times into the decision-latency stream)."""
        import warnings

        warnings.warn("DaemonMetrics.latencies_s is deprecated: use "
                      "bind_latencies_s (served decisions) or shed_wait_s "
                      "(backpressure evictions)", DeprecationWarning,
                      stacklevel=2)
        return self.bind_latencies_s


# the public name the ops surface documents; the dataclass predates it
DaemonStats = DaemonMetrics


class _Request:
    __slots__ = ("req_id", "pod", "t_submit", "attempts", "not_before")

    def __init__(self, req_id, pod, t_submit):
        self.req_id = req_id
        self.pod = pod
        self.t_submit = t_submit
        self.attempts = 0
        self.not_before = t_submit   # conflict-backoff hold (poll honors it)


# ---------------------------------------------------------------------------
# substrates: live-buffer mirror + batched snapshot scorer
# ---------------------------------------------------------------------------


class ClusterSubstrate:
    """The paper's pod->node cluster as a daemon substrate.

    ``live`` is a ``ClusterState`` of *mutable numpy* arrays — the admission
    buffer.  ``snapshot`` publishes it as device arrays for the scoring
    launch.  ``bind``/``feasible_one`` mirror ``env.place``/``env.feasible``
    restricted to the touched row (parity pinned in tests/test_daemon.py).
    """

    def __init__(self, state: ClusterState, cfg: EnvConfig,
                 score_fn: Optional[Callable] = None, policy=None,
                 layout=None, topk: int = 8):
        if score_fn is not None and policy is not None:
            raise ValueError("pass either score_fn or policy, not both")
        self.cfg = cfg
        self.score_fn = score_fn
        self.policy = policy
        # a launch.mesh.FleetLayout switches the substrate to two-stage
        # sharded scoring (sched.shard): the snapshot is published PRE-SHARDED
        # — (shards, shard_size) columns, device-distributed when the layout
        # carries a mesh — and stays that way between batches; the scorer
        # returns per-request candidate lists (topk per shard, merged)
        # instead of full (B, N) score rows
        self.layout = layout
        self.topk = topk
        self.live = jax.tree.map(lambda x: np.array(x), state)

    def snapshot(self) -> ClusterState:
        snap = jax.tree.map(jnp.asarray, self.live)
        if self.layout is not None:
            from repro.sched import shard as _shard

            snap = _shard.shard_cluster(snap, self.layout)
        return snap

    def init_carry(self, params: dict):
        """The daemon-lifetime arrival-history carry: the policy's encoder
        state over the submitted request stream (() for stateless specs)."""
        if self.policy is not None and self.policy.embed_dim > 0:
            return self.policy.carry_init(params)
        return ()

    def pack(self, pods: Sequence[PodSpec], size: int) -> PodSpec:
        """Stack + pad a request batch to the static (size,) scoring shape."""
        pad = size - len(pods)
        pods = list(pods) + [pods[-1]] * pad

        def col(get):
            return jnp.asarray([float(get(p)) for p in pods], jnp.float32)

        return PodSpec(cpu_request=col(lambda p: p.cpu_request),
                       cpu_demand=col(lambda p: p.cpu_demand),
                       mem_request=col(lambda p: p.mem_request),
                       mem_demand=col(lambda p: p.mem_demand))

    def make_scorer(self, fused) -> Callable:
        """Jitted ``(params, snapshot, pod_batch, carry, n_real) ->
        (scores, feasible, carry)``, scores/feasible (B, N): the whole batch
        in ONE device launch.

        The signature is uniform across policy classes so the daemon loop
        never branches: stateless specs thread ``carry = ()`` untouched,
        sequence specs advance their encoder carry *inside* the launch via a
        ``lax.scan`` over the batch (requests encode in submission order).
        ``n_real`` is a traced scalar — the ``< n_real`` pad mask means pad
        rows are scored (static shape, one compilation at every fill level)
        but never advance the history.  A conflicted request that re-queues
        re-encodes on its next batch — the history sees it twice, which is
        faithful to a kube scheduling queue (the pod really does arrive at
        the scheduler again).

        With a ``layout`` the contract becomes ``(params, snap, pods, carry,
        n_real) -> (cand_vals, cand_idx, carry)``, both (B, C) with
        ``C = shards * topk``: each request's two-stage candidate merge
        (sorted descending, ``-inf``/``-1`` past the feasible set) — the full
        (B, N) score matrix is never materialized on one device.  The
        ``pull_cost_now`` global reduction is computed once per batch from
        the sharded snapshot and threaded into every per-shard call.
        """
        cfg, score_fn, policy = self.cfg, self.score_fn, self.policy

        if self.layout is not None:
            from repro.core import policy as policy_mod
            from repro.sched import shard as _shard

            layout, k = self.layout, self.topk

            if policy is None or policy.embed_dim == 0:

                @jax.jit
                def score(params, snap, pods, carry, n_real):
                    pull = kenv.pull_cost_now(snap, cfg)
                    cv, ci = jax.vmap(
                        lambda p: _shard.cluster_topk(
                            params, snap, p, cfg, layout, k=k, fused=fused,
                            score_fn=score_fn, policy=policy,
                            pull_cost=pull))(pods)
                    return cv, ci, carry

                return score

            @jax.jit
            def score(params, snap, pods, carry, n_real):
                pull = kenv.pull_cost_now(snap, cfg)

                def step(c, xs):
                    pod, is_real = xs
                    c2, emb = policy.encode_step(
                        params, c, policy_mod.pod_workload_features(pod))
                    c2 = jax.tree.map(lambda a, b: jnp.where(is_real, a, b),
                                      c2, c)
                    cv, ci = _shard.cluster_topk(
                        params, snap, pod, cfg, layout, k=k, fused=fused,
                        policy=policy, embed=emb, pull_cost=pull)
                    return c2, (cv, ci)

                n_b = jax.tree.leaves(pods)[0].shape[0]
                is_real = jnp.arange(n_b) < n_real
                carry2, (cv, ci) = jax.lax.scan(step, carry, (pods, is_real))
                return cv, ci, carry2

            return score

        if policy is None or policy.embed_dim == 0:

            @jax.jit
            def score(params, snap, pods, carry, n_real):
                q = schedulers.score_afterstates_batch(params, snap, pods,
                                                       cfg, score_fn, fused,
                                                       policy=policy)
                ok = jax.vmap(lambda p: kenv.feasible(snap, p, cfg))(pods)
                return q, ok, carry

            return score

        from repro.core import policy as policy_mod

        @jax.jit
        def score(params, snap, pods, carry, n_real):
            def step(c, xs):
                pod, is_real = xs
                c2, emb = policy.encode_step(
                    params, c, policy_mod.pod_workload_features(pod))
                c2 = jax.tree.map(lambda a, b: jnp.where(is_real, a, b),
                                  c2, c)
                q = schedulers.score_afterstates(params, snap, pod, cfg,
                                                 fused=fused, policy=policy,
                                                 embed=emb)
                return c2, (q, kenv.feasible(snap, pod, cfg))

            n_b = jax.tree.leaves(pods)[0].shape[0]
            is_real = jnp.arange(n_b) < n_real
            carry2, (q, ok) = jax.lax.scan(step, carry, (pods, is_real))
            return q, ok, carry2

        return score

    def feasible_one(self, node: int, pod: PodSpec) -> bool:
        """``env.feasible`` row ``node`` against the LIVE buffer (bind-time
        re-validation)."""
        lv = self.live
        return bool(
            lv.healthy[node]
            and lv.cpu_requested[node] + float(pod.cpu_request)
            <= lv.cpu_capacity[node]
            and lv.mem_requested[node] + float(pod.mem_request)
            <= lv.mem_capacity[node]
            and lv.num_pods[node] < lv.max_pods[node]
        )

    def bind(self, node: int, pod: PodSpec) -> None:
        """Commit one bind to the live buffer: ``env.place`` restricted to
        the chosen row, in numpy (no device op on the serving hot path)."""
        lv, cfg = self.live, self.cfg
        in_flight = float(np.sum(lv.startup_cpu > 0.25 * cfg.image_pull_cost))
        pull = cfg.image_pull_cost * (1.0 + cfg.pull_concurrency_coeff
                                      * in_flight)
        start = cfg.warm_start_cost if lv.image_cached[node] else pull
        lv.num_pods[node] += 1
        lv.exp_pods[node] += 1
        lv.cpu_requested[node] += float(pod.cpu_request)
        lv.mem_requested[node] += float(pod.mem_request)
        lv.pods_cpu[node] += float(pod.cpu_demand)
        lv.mem_used[node] += float(pod.mem_demand)
        lv.startup_cpu[node] += start
        lv.image_cached[node] = True

    def unbind(self, node: int, pod: PodSpec) -> None:
        """Release one bound pod from the live buffer: ``env.remove_pod``
        restricted to the touched row (startup transients and the cached
        image stay, exactly like the env's arithmetic)."""
        lv = self.live
        lv.num_pods[node] -= 1
        lv.exp_pods[node] -= 1
        lv.cpu_requested[node] -= float(pod.cpu_request)
        lv.mem_requested[node] -= float(pod.mem_request)
        lv.pods_cpu[node] -= float(pod.cpu_demand)
        lv.mem_used[node] -= float(pod.mem_demand)

    def set_health(self, node: int, healthy: bool) -> None:
        """Flip one node's Ready condition in the live buffer (the health
        watchdog's write; ``feasible_one`` and the next snapshot see it)."""
        self.live.healthy[node] = bool(healthy)

    def heuristic_batch(self, pods: Sequence[PodSpec]):
        """(B, N) kube LeastRequested+Balanced scores + feasibility against
        the LIVE buffer, pure numpy — the degraded-mode scorer (same formula
        as ``sched.api.heuristic_score``, no device launch)."""
        lv = self.live
        creq = np.asarray([float(p.cpu_request) for p in pods])[:, None]
        mreq = np.asarray([float(p.mem_request) for p in pods])[:, None]
        cpu_free = (lv.cpu_capacity[None, :] - lv.cpu_requested[None, :]
                    - creq) / lv.cpu_capacity[None, :]
        mem_free = (lv.mem_capacity[None, :] - lv.mem_requested[None, :]
                    - mreq) / lv.mem_capacity[None, :]
        q = 10.0 * (cpu_free + mem_free) / 2.0 \
            + 10.0 * (1.0 - np.abs(cpu_free - mem_free))
        ok = (lv.healthy[None, :]
              & (lv.cpu_requested[None, :] + creq <= lv.cpu_capacity[None, :])
              & (lv.mem_requested[None, :] + mreq <= lv.mem_capacity[None, :])
              & (lv.num_pods[None, :] < lv.max_pods[None, :]))
        return q, ok


class FleetSubstrate:
    """Job->host placement (``sched.placement``) as a daemon substrate.

    Jobs are packed as (B, 6) afterstate-delta rows (``placement.job_delta``)
    and scored through the fused column kernel — the same dispatch
    ``PlacementEngine.select`` uses, batched.
    """

    def __init__(self, fleet: _pl.FleetState,
                 max_host_cpu_pct: float = 88.0, policy=None,
                 layout=None, topk: int = 8):
        self.live = jax.tree.map(lambda x: np.array(x, np.float64), fleet)
        self.max_host_cpu_pct = max_host_cpu_pct
        self.policy = policy
        # same sharded-substrate switch as ClusterSubstrate: pre-sharded
        # snapshot, candidate-list scorer contract (see there)
        self.layout = layout
        self.topk = topk

    def snapshot(self) -> _pl.FleetState:
        snap = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), self.live)
        if self.layout is not None:
            from repro.sched import shard as _shard

            snap = _shard.shard_fleet(snap, self.layout)
        return snap

    def pack(self, jobs: Sequence[_pl.JobSpec], size: int) -> jnp.ndarray:
        jobs = list(jobs) + [jobs[-1]] * (size - len(jobs))
        return jnp.stack([_pl.job_delta(j) for j in jobs])

    def init_carry(self, params: dict):
        if self.policy is not None and self.policy.embed_dim > 0:
            return self.policy.carry_init(params)
        return ()

    def make_scorer(self, fused) -> Callable:
        """Same uniform ``(params, snap, deltas, carry, n_real) ->
        (q, ok, carry)`` contract as ``ClusterSubstrate.make_scorer``.

        Fused-capable specs (and the default ``policy=None``) keep the fused
        column kernel; other policy classes score the assembled (N, 6) rows
        through ``PolicySpec.score_set``.  Sequence specs feed their encoder
        the job's normalized demand delta (the first ``ENCODER_IN`` entries
        of ``delta / FEATURE_SCALE`` — the job-stream analogue of
        ``pod_workload_features``).
        """
        max_cpu = self.max_host_cpu_pct
        policy = self.policy
        if policy is not None and policy.fused_kernel:
            policy = None          # "mlp": the column kernel IS its score_set

        from repro.kernels import ops
        from repro.sched.api import _fleet_mode

        mode = _fleet_mode(fused)

        if self.layout is not None:
            from repro.core.policy import ENCODER_IN
            from repro.sched import shard as _shard

            layout, k = self.layout, self.topk

            def shard_topk(params, snap, d, emb=None):
                return _shard.fleet_topk(params, snap, None, layout, k=k,
                                         fused=fused, policy=policy,
                                         embed=emb, delta=d,
                                         max_host_cpu_pct=max_cpu)

            if policy is None or policy.embed_dim == 0:

                @jax.jit
                def score(params, snap, deltas, carry, n_real):
                    cv, ci = jax.vmap(
                        lambda d: shard_topk(params, snap, d))(deltas)
                    return cv, ci, carry

                return score

            @jax.jit
            def score(params, snap, deltas, carry, n_real):
                def step(c, xs):
                    d, is_real = xs
                    wf = (d / kenv.FEATURE_SCALE)[:ENCODER_IN]
                    c2, emb = policy.encode_step(params, c, wf)
                    c2 = jax.tree.map(lambda a, b: jnp.where(is_real, a, b),
                                      c2, c)
                    return c2, shard_topk(params, snap, d, emb)

                is_real = jnp.arange(deltas.shape[0]) < n_real
                carry2, (cv, ci) = jax.lax.scan(step, carry, (deltas, is_real))
                return cv, ci, carry2

            return score

        def feasible(snap, deltas):
            return (
                (snap.healthy > 0.5)[None, :]
                & (snap.cpu_pct[None, :] + deltas[:, 0:1] <= max_cpu)
                & (snap.mem_pct[None, :] + deltas[:, 1:2] <= 95.0)
                & (snap.job_util_pct[None, :] + deltas[:, 2:3]
                   <= 100.0 + 1e-6)
            )

        def afterstate_rows(snap, delta, embed=None):
            feats = (jnp.stack(_pl.fleet_cols(snap), axis=-1)
                     + delta[None, :]) / kenv.FEATURE_SCALE
            if embed is not None:
                feats = jnp.concatenate(
                    [feats,
                     jnp.broadcast_to(embed, feats.shape[:-1] + embed.shape)],
                    axis=-1)
            return feats

        if policy is None:

            @jax.jit
            def score(params, snap, deltas, carry, n_real):
                cols = _pl.fleet_cols(snap)
                q = jax.vmap(lambda d: ops.sdqn_score_delta(
                    cols, d, params, mode=mode))(deltas)
                return q, feasible(snap, deltas), carry

            return score

        if policy.embed_dim == 0:

            @jax.jit
            def score(params, snap, deltas, carry, n_real):
                q = jax.vmap(lambda d: policy.score_set(
                    params, afterstate_rows(snap, d)))(deltas)
                return q, feasible(snap, deltas), carry

            return score

        from repro.core.policy import ENCODER_IN

        @jax.jit
        def score(params, snap, deltas, carry, n_real):
            def step(c, xs):
                d, is_real = xs
                wf = (d / kenv.FEATURE_SCALE)[:ENCODER_IN]
                c2, emb = policy.encode_step(params, c, wf)
                c2 = jax.tree.map(lambda a, b: jnp.where(is_real, a, b),
                                  c2, c)
                return c2, policy.score_set(
                    params, afterstate_rows(snap, d, embed=emb))

            is_real = jnp.arange(deltas.shape[0]) < n_real
            carry2, q = jax.lax.scan(step, carry, (deltas, is_real))
            return q, feasible(snap, deltas), carry2

        return score

    def feasible_one(self, node: int, job: _pl.JobSpec) -> bool:
        lv = self.live
        return bool(
            lv.healthy[node] > 0.5
            and lv.cpu_pct[node] + job.cpu_pct_demand <= self.max_host_cpu_pct
            and lv.mem_pct[node] + job.mem_pct_demand <= 95.0
            and lv.job_util_pct[node] + _pl.JOB_UTIL_DELTA_PCT
            <= 100.0 + 1e-6
        )

    def bind(self, node: int, job: _pl.JobSpec) -> None:
        lv = self.live
        lv.cpu_pct[node] += job.cpu_pct_demand
        lv.mem_pct[node] += job.mem_pct_demand
        lv.job_util_pct[node] += _pl.JOB_UTIL_DELTA_PCT
        lv.num_jobs[node] += 1

    def unbind(self, node: int, job: _pl.JobSpec) -> None:
        lv = self.live
        lv.cpu_pct[node] -= job.cpu_pct_demand
        lv.mem_pct[node] -= job.mem_pct_demand
        lv.job_util_pct[node] -= _pl.JOB_UTIL_DELTA_PCT
        lv.num_jobs[node] -= 1

    def set_health(self, node: int, healthy: bool) -> None:
        self.live.healthy[node] = 1.0 if healthy else 0.0

    def heuristic_batch(self, jobs: Sequence[_pl.JobSpec]):
        """(B, N) percent-utilization LeastRequested+Balanced scores against
        the LIVE buffer (``sched.api.heuristic_score``'s FleetState arm)."""
        lv = self.live
        dc = np.asarray([j.cpu_pct_demand for j in jobs])[:, None]
        dm = np.asarray([j.mem_pct_demand for j in jobs])[:, None]
        cpu_free = (100.0 - lv.cpu_pct[None, :] - dc) / 100.0
        mem_free = (100.0 - lv.mem_pct[None, :] - dm) / 100.0
        q = 10.0 * (cpu_free + mem_free) / 2.0 \
            + 10.0 * (1.0 - np.abs(cpu_free - mem_free))
        ok = ((lv.healthy[None, :] > 0.5)
              & (lv.cpu_pct[None, :] + dc <= self.max_host_cpu_pct)
              & (lv.mem_pct[None, :] + dm <= 95.0)
              & (lv.job_util_pct[None, :] + _pl.JOB_UTIL_DELTA_PCT
                 <= 100.0 + 1e-6))
        return q, ok


# ---------------------------------------------------------------------------
# the daemon
# ---------------------------------------------------------------------------


class PlacementDaemon:
    """Continuously-serving placement loop over a substrate.

    ``submit`` is admission: O(1) queue append, never blocks on the device.
    ``poll`` cuts at most one batch when ready (size or max-wait), publishes
    the live buffer as the scoring snapshot, scores the whole batch in one
    jitted launch, and commits binds with bind-time re-validation.
    ``flush``/``drain`` force remaining work through.  ``clock`` is
    injectable for deterministic tests (defaults to ``time.monotonic``).
    """

    def __init__(self, substrate, params: dict,
                 config: DaemonConfig = DaemonConfig(),
                 clock: Callable[[], float] = time.monotonic,
                 timer: Callable[[], float] = time.monotonic,
                 decision_hook: Optional[Callable] = None):
        self._sub = substrate
        # ``decision_hook(pod, node)`` observes every SERVED decision (bound
        # or dropped; shed requests are never scored, so they produce no
        # transition) — the online-learning recorder attaches here
        self.decision_hook = decision_hook
        self._params = params
        self.config = config
        self._clock = clock
        # the deadline stopwatch: separate from ``clock`` so tests can pin
        # the logical clock while still faking launch durations
        self._timer = timer
        self._pending: collections.deque = collections.deque()
        self._scorer = substrate.make_scorer(config.fused)
        # sharded substrates score to (B, C) candidate lists (two-stage
        # top-k merge) instead of full (B, N) rows — the commit path reads
        # candidates in merged order and never sees a fleet-length vector
        self._cand_mode = getattr(substrate, "layout", None) is not None
        # sequence policy classes carry their arrival-history encoder state
        # across batches; stateless substrates (incl. ones predating
        # init_carry) thread an empty pytree
        self._carry = getattr(substrate, "init_carry", lambda p: ())(params)
        self._next_id = 0
        # req_id -> (node, pod) of every currently-bound placement: the
        # health watchdog's index for evicting pods off a failed node
        self._bound: dict = {}
        # > 0: this many upcoming batches skip the Q-net launch and serve
        # from the kube heuristic (set on a deadline breach / NaN scores)
        self._degraded = 0
        self.metrics = DaemonMetrics()
        self.decisions: List[Decision] = []

    # -- admission (writes the live buffer side only) -----------------------

    def submit(self, pod, now: Optional[float] = None) -> int:
        """Enqueue one placement request; returns its request id.

        With ``queue_cap`` set, admission applies backpressure: a full queue
        sheds its OLDEST pending request (decided as ``shed=True``, node
        ``NO_PLACEMENT``) to make room — the newest work is the most likely
        to still matter, and the shed count is the overload signal.
        """
        now = self._clock() if now is None else now
        cap = self.config.queue_cap
        if cap > 0:
            while len(self._pending) >= cap:
                old = self._pending.popleft()
                lat = max(now - old.t_submit, 0.0)
                self.decisions.append(Decision(old.req_id, NO_PLACEMENT, lat,
                                               old.attempts, shed=True))
                self.metrics.shed_wait_s.append(lat)
                self.metrics.shed += 1
        req = _Request(self._next_id, pod, now)
        self._next_id += 1
        self._pending.append(req)
        self.metrics.submitted += 1
        return req.req_id

    # -- health watchdog (fail/recover events from the node controller) -----

    def fail_node(self, node: int, now: Optional[float] = None) -> int:
        """Mark ``node`` NotReady and auto-requeue every pod bound there.

        The self-healing path: each evicted pod re-enters the admission
        queue as a NEW submission (fresh request id, so the
        bound+dropped+shed == submitted accounting stays exact per request)
        and will be re-scored against the updated fleet — never against the
        dead node, whose ``healthy`` is now false in both the live buffer
        and the next snapshot.  Returns the number of evicted pods.
        """
        now = self._clock() if now is None else now
        self._sub.set_health(node, False)
        evicted = [(rid, pod) for rid, (n, pod) in self._bound.items()
                   if n == node]
        for rid, pod in evicted:
            del self._bound[rid]
            self._sub.unbind(node, pod)
            self.metrics.evictions += 1
            self.submit(pod, now=now)
        return len(evicted)

    def recover_node(self, node: int) -> None:
        """Mark ``node`` Ready again — it rejoins the feasible set at the
        next snapshot/bind re-validation."""
        self._sub.set_health(node, True)

    def set_params(self, params: dict) -> None:
        """Hot-swap policy params (same pytree structure: no recompile) —
        the online-learning refresh hook."""
        self._params = params

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- serving loop -------------------------------------------------------

    def _cut_ready(self, now: float) -> bool:
        if not self._pending:
            return False
        if len(self._pending) >= self.config.batch_size:
            return True
        return now - self._pending[0].t_submit >= self.config.max_wait_s

    def poll(self, now: Optional[float] = None) -> int:
        """Process at most one batch if the cut condition holds.  Returns
        the number of requests decided (bound or dropped) this call."""
        now = self._clock() if now is None else now
        if not self._cut_ready(now):
            return 0
        return self._process_batch(now)

    def flush(self, now: Optional[float] = None) -> int:
        """Process one batch regardless of the cut condition (0 if idle).
        Backoff holds are overridden — flush means *now*."""
        now = self._clock() if now is None else now
        if not self._pending:
            return 0
        return self._process_batch(now, force=True)

    def drain(self, now: Optional[float] = None) -> int:
        """Flush until the queue is empty (conflict re-queues included)."""
        done = 0
        while self._pending:
            done += self.flush(now)
        return done

    def warmup(self) -> None:
        """Prime the scoring compilation outside any timing window.

        ``n_real = 0``: every warmup row is a pad row, so a sequence
        policy's history carry is untouched by warming up.
        """
        snap = self._sub.snapshot()
        pods = self._sub.pack([self._dummy_pod()], self.config.batch_size)
        jax.block_until_ready(
            self._scorer(self._params, snap, pods, self._carry, 0))

    def scorer_cache_size(self) -> int:
        """Compilations of the batched scorer (1 == every batch, at every
        fill level, reused one executable)."""
        return self._scorer._cache_size()

    # -- internals ----------------------------------------------------------

    def _dummy_pod(self):
        if isinstance(self._sub, ClusterSubstrate):
            return kenv.default_pod(self._sub.cfg)
        return _pl.JobSpec()

    def _take_batch(self, now: float, force: bool) -> List[_Request]:
        """Pop up to one batch of eligible requests (backoff holds honored
        unless forced; held requests keep their queue order)."""
        b = self.config.batch_size
        take: List[_Request] = []
        held: List[_Request] = []
        while self._pending and len(take) < b:
            req = self._pending.popleft()
            if force or req.not_before <= now:
                take.append(req)
            else:
                held.append(req)
        for req in reversed(held):
            self._pending.appendleft(req)
        return take

    def _process_batch(self, now: float, force: bool = False) -> int:
        reqs = self._take_batch(now, force)
        if not reqs:
            return 0
        scores = ok = cand_idx = None
        degraded = self.config.heuristic_only or self._degraded > 0
        if not degraded:
            # publish the admission buffer as the read (scoring) snapshot;
            # the live buffer keeps taking writes from here on
            snap = self._sub.snapshot()
            pods = self._sub.pack([r.pod for r in reqs],
                                  self.config.batch_size)
            t0 = self._timer()
            q, okq, carry2 = self._scorer(
                self._params, snap, pods, self._carry, len(reqs))  # 1 launch
            q = np.asarray(q)
            elapsed = self._timer() - t0
            self.metrics.device_launches += 1
            deadline = self.config.score_deadline_s
            real = q[:len(reqs)]
            if self._cand_mode:
                # candidate lists legitimately carry -inf (infeasible /
                # exhausted slots) — divergence means NaN, or a FINITE
                # candidate outside the limit
                finite = np.isfinite(real)
                bad = bool(np.isnan(real).any()
                           or (np.where(finite, np.abs(real), 0.0)
                               > _DIVERGENCE_LIMIT).any())
            else:
                bad = (not np.all(np.isfinite(real))
                       or float(np.max(np.abs(real))) > _DIVERGENCE_LIMIT)
            if bad or (deadline is not None and elapsed > deadline):
                # degrade: discard the launch (scores AND its history-carry
                # advance) and serve this + the next degrade_batches batches
                # from the closed-form heuristic, no device round-trips
                self._degraded = self.config.degrade_batches
                degraded = True
            else:
                self._carry = carry2
                if self._cand_mode:
                    scores, cand_idx = q, np.asarray(okq)
                else:
                    scores, ok = q, np.asarray(okq)
        if degraded:
            if not self.config.heuristic_only and self._degraded > 0:
                self._degraded -= 1
            self.metrics.fallback_batches += 1
            scores, ok = self._sub.heuristic_batch([r.pod for r in reqs])
            if self._cand_mode:
                # degraded mode is host-side numpy by design (no device
                # launches while degraded), so the full-N heuristic rows are
                # sorted here into the same candidate contract; the stable
                # sort keeps the lowest-index-first tie rule of the merge
                masked = np.where(ok, scores, -np.inf)
                cand_idx = np.argsort(-masked, axis=1, kind="stable")
                scores = np.take_along_axis(masked, cand_idx, axis=1)
        self.metrics.batches += 1
        decided = 0
        for i, req in enumerate(reqs):
            if self._cand_mode:
                decided += self._commit_candidates(req, scores[i],
                                                   cand_idx[i], now)
            else:
                decided += self._commit(req, scores[i], ok[i], now)
        return decided

    def _decide(self, req: _Request, node: int) -> None:
        lat = max(self._clock() - req.t_submit, 0.0)
        self.decisions.append(Decision(req.req_id, node, lat, req.attempts))
        self.metrics.bind_latencies_s.append(lat)
        if node == NO_PLACEMENT:
            self.metrics.dropped += 1
        else:
            self.metrics.bound += 1
            self._bound[req.req_id] = (node, req.pod)
        if self.decision_hook is not None:
            # O(1) host-side append inside the hook (sched.online's
            # TransitionRecorder): no device work on the serving hot path,
            # so enabling online learning adds zero scoring launches
            self.decision_hook(req.pod, node)

    def _commit(self, req: _Request, row: np.ndarray, ok: np.ndarray,
                now: float) -> int:
        """Optimistic bind of one scored request; returns 1 if decided."""
        req.attempts += 1
        masked = np.where(ok, row, -np.inf)
        if not ok.any():
            # the snapshot offered no feasible node at all: a genuine drop,
            # exactly env.run_episode's NO_NODE accounting
            self._decide(req, NO_PLACEMENT)
            return 1
        choice = int(np.argmax(masked))
        if self._sub.feasible_one(choice, req.pod):
            self._sub.bind(choice, req.pod)
            self._decide(req, choice)
            return 1
        # optimistic bind lost the race: the snapshot's winner was taken by
        # an earlier bind (or external churn) before this request's turn
        self.metrics.conflicts += 1
        if self.config.conflict_policy == "next-best":
            for cand in np.argsort(-masked)[1:]:
                if not np.isfinite(masked[cand]):
                    break
                if self._sub.feasible_one(int(cand), req.pod):
                    self._sub.bind(int(cand), req.pod)
                    self._decide(req, int(cand))
                    return 1
        return self._requeue_or_drop(req, now)

    def _commit_candidates(self, req: _Request, vals: np.ndarray,
                           idx: np.ndarray, now: float) -> int:
        """Optimistic bind from a merged candidate list (sharded substrates).

        ``vals``/``idx`` are the two-stage merge output: descending scores
        with global node indices, ``-inf`` past the feasible set.  Same
        semantics as ``_commit`` — element 0 is exactly the full argmax
        winner; ``next-best`` walks the remaining candidates (depth
        ``shards * topk`` instead of N, the price of never materializing the
        fleet)."""
        req.attempts += 1
        if not np.isfinite(vals[0]):
            self._decide(req, NO_PLACEMENT)
            return 1
        choice = int(idx[0])
        if self._sub.feasible_one(choice, req.pod):
            self._sub.bind(choice, req.pod)
            self._decide(req, choice)
            return 1
        self.metrics.conflicts += 1
        if self.config.conflict_policy == "next-best":
            for v, cand in zip(vals[1:], idx[1:]):
                if not np.isfinite(v):
                    break
                if self._sub.feasible_one(int(cand), req.pod):
                    self._sub.bind(int(cand), req.pod)
                    self._decide(req, int(cand))
                    return 1
        return self._requeue_or_drop(req, now)

    def _requeue_or_drop(self, req: _Request, now: float) -> int:
        if req.attempts > self.config.max_retries:
            self._decide(req, NO_PLACEMENT)
            return 1
        # back to the queue head (with exponential backoff when configured):
        # re-scored against fresh state next eligible batch
        self.metrics.requeued += 1
        if self.config.backoff_base_s > 0:
            req.not_before = now + (self.config.backoff_base_s
                                    * 2.0 ** (req.attempts - 1))
        self._pending.appendleft(req)
        return 0


def replay_trace(daemon: PlacementDaemon, t_s: Sequence[float],
                 pods: Sequence, speed: float = 1.0,
                 events: Optional[Sequence] = None) -> float:
    """Replay an arrival trace in real time through the daemon.

    ``t_s`` are arrival offsets (seconds) from the replay start, ``pods``
    the matching workload specs (see ``scenarios.arrivals.arrival_trace``).
    Each request's submission time is its *scheduled* arrival, so when the
    daemon cannot keep up, queueing delay shows up in decision latency —
    the offered-load curve the placement_serve bench measures.  ``speed``
    compresses the trace (2.0 = twice the offered rate).  Polls between
    arrivals, drains at the end; returns the wall-clock serving duration.

    ``events`` injects node chaos into the replay: an optional sequence of
    ``(t_off, kind, node)`` tuples (``kind`` in ``{"fail", "recover"}``,
    offsets on the same clock as ``t_s``), applied in order as the replay
    clock passes each offset — ``fail`` evicts and auto-requeues the node's
    bound pods through the health watchdog.  Events left over when the
    arrivals end are applied before the final drain.
    """
    clock = daemon._clock
    ev = sorted(events or [], key=lambda e: e[0])
    ev_i = 0

    def apply_events(up_to: float):
        nonlocal ev_i
        while ev_i < len(ev) and ev[ev_i][0] / speed <= up_to:
            _, kind, node = ev[ev_i]
            if kind == "fail":
                daemon.fail_node(int(node))
            elif kind == "recover":
                daemon.recover_node(int(node))
            else:
                raise ValueError(f"unknown chaos event kind {kind!r}")
            ev_i += 1

    t0 = clock()
    for t_off, pod in zip(t_s, pods):
        due = t0 + t_off / speed
        apply_events(due - t0)
        while clock() < due:
            if not daemon.poll():
                time.sleep(0)        # yield; arrival gaps are sub-ms anyway
        daemon.submit(pod, now=due)
        daemon.poll()
    apply_events(float("inf"))
    daemon.drain()
    return clock() - t0
