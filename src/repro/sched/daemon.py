"""Production placement daemon: continuously-serving, batched, optimistic.

The paper's SDQN scheduler is only useful in production if it can serve
placement decisions under load.  This daemon is that serving loop:

  * **Batched one-launch scoring.**  Pending pod requests accumulate into
    batches (cut by size OR by the oldest request's wait time) and the whole
    batch is scored through the shared fused dispatch
    (``schedulers.score_afterstates_batch`` / ``ops.sdqn_score_delta`` via
    ``repro.sched.api``) in ONE device launch — one jitted call per batch,
    padded to a static batch shape so every fill level reuses one
    compilation.
  * **Double-buffered fleet state.**  Admission (``submit`` + committed
    binds) writes the *live* buffer — a mutable host-side (numpy) mirror —
    while scoring reads a frozen device *snapshot* published at batch cut.
    Request intake is a queue append plus numpy writes and never blocks on a
    device launch; the snapshot publish is an O(columns) transfer.
  * **Optimistic concurrency.**  Scores are computed against the snapshot,
    but by bind time the live buffer may have moved (earlier binds in the
    same batch, external churn applied through ``substrate.live``).  Every
    bind re-validates feasibility against the live buffer; a conflicted
    request loses the race and is re-queued to be re-scored against fresh
    state (``conflict_policy="requeue"``, mirroring the real kube binding
    race where an optimistic bind fails admission and the pod returns to the
    scheduling queue) or falls through to its next-best snapshot candidate
    (``conflict_policy="next-best"``).

Two substrates plug into the same loop: ``ClusterSubstrate`` (the paper's
pod->node cluster, ``core.env`` physics) and ``FleetSubstrate`` (job->host
placement over ``sched.placement.FleetState``, used by the serving driver in
``launch/serve.py``).  Both keep their live buffer as numpy mirrors whose
bind/feasibility arithmetic is pinned against the jnp reference
(``env.place`` / ``env.feasible``) in tests/test_daemon.py.

    sub = ClusterSubstrate(kenv.reset(key, cfg), cfg)
    d = PlacementDaemon(sub, qparams, DaemonConfig(batch_size=32))
    d.submit(pod); ...; d.poll(); decisions = d.decisions

Offered load comes from the scenario engine's arrival streams
(``scenarios.arrivals.arrival_trace``) replayed through ``replay_trace`` —
see ``benchmarks/placement_serve.py`` for the sustained placements/sec and
p50/p99 decision-latency bench.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as kenv, schedulers
from repro.core.types import NO_PLACEMENT, ClusterState, EnvConfig, PodSpec
from repro.sched import placement as _pl

__all__ = [
    "ClusterSubstrate", "DaemonConfig", "DaemonMetrics", "Decision",
    "FleetSubstrate", "PlacementDaemon", "replay_trace",
]


@dataclasses.dataclass(frozen=True)
class DaemonConfig:
    """Serving-loop knobs.

    A batch is cut when ``batch_size`` requests are pending OR the oldest
    pending request has waited ``max_wait_s`` — the standard
    throughput/latency trade of a batching server.  ``max_retries`` bounds
    how many times a conflicted request re-queues before it is dropped;
    ``conflict_policy`` picks what happens when an optimistic bind loses the
    race (see module docstring).  ``fused`` threads through to the scoring
    dispatch (``repro.sched.api.score``).
    """

    batch_size: int = 32
    max_wait_s: float = 0.02
    max_retries: int = 4
    conflict_policy: str = "requeue"     # "requeue" | "next-best"
    fused: object = "auto"

    def __post_init__(self):
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if self.conflict_policy not in ("requeue", "next-best"):
            raise ValueError(f"unknown conflict_policy "
                             f"{self.conflict_policy!r}")


class Decision(NamedTuple):
    """One served placement decision (``node == NO_PLACEMENT`` = dropped)."""

    req_id: int
    node: int
    latency_s: float       # decision time - submission time
    attempts: int          # 1 + times the request lost an optimistic bind


@dataclasses.dataclass
class DaemonMetrics:
    submitted: int = 0
    bound: int = 0
    dropped: int = 0
    conflicts: int = 0      # optimistic binds that failed live re-validation
    requeued: int = 0       # conflicted requests sent back to the queue
    batches: int = 0
    device_launches: int = 0  # jitted scoring calls; == batches by design
    latencies_s: List[float] = dataclasses.field(default_factory=list)


class _Request:
    __slots__ = ("req_id", "pod", "t_submit", "attempts")

    def __init__(self, req_id, pod, t_submit):
        self.req_id = req_id
        self.pod = pod
        self.t_submit = t_submit
        self.attempts = 0


# ---------------------------------------------------------------------------
# substrates: live-buffer mirror + batched snapshot scorer
# ---------------------------------------------------------------------------


class ClusterSubstrate:
    """The paper's pod->node cluster as a daemon substrate.

    ``live`` is a ``ClusterState`` of *mutable numpy* arrays — the admission
    buffer.  ``snapshot`` publishes it as device arrays for the scoring
    launch.  ``bind``/``feasible_one`` mirror ``env.place``/``env.feasible``
    restricted to the touched row (parity pinned in tests/test_daemon.py).
    """

    def __init__(self, state: ClusterState, cfg: EnvConfig,
                 score_fn: Optional[Callable] = None, policy=None):
        if score_fn is not None and policy is not None:
            raise ValueError("pass either score_fn or policy, not both")
        self.cfg = cfg
        self.score_fn = score_fn
        self.policy = policy
        self.live = jax.tree.map(lambda x: np.array(x), state)

    def snapshot(self) -> ClusterState:
        return jax.tree.map(jnp.asarray, self.live)

    def init_carry(self, params: dict):
        """The daemon-lifetime arrival-history carry: the policy's encoder
        state over the submitted request stream (() for stateless specs)."""
        if self.policy is not None and self.policy.embed_dim > 0:
            return self.policy.carry_init(params)
        return ()

    def pack(self, pods: Sequence[PodSpec], size: int) -> PodSpec:
        """Stack + pad a request batch to the static (size,) scoring shape."""
        pad = size - len(pods)
        pods = list(pods) + [pods[-1]] * pad

        def col(get):
            return jnp.asarray([float(get(p)) for p in pods], jnp.float32)

        return PodSpec(cpu_request=col(lambda p: p.cpu_request),
                       cpu_demand=col(lambda p: p.cpu_demand),
                       mem_request=col(lambda p: p.mem_request),
                       mem_demand=col(lambda p: p.mem_demand))

    def make_scorer(self, fused) -> Callable:
        """Jitted ``(params, snapshot, pod_batch, carry, n_real) ->
        (scores, feasible, carry)``, scores/feasible (B, N): the whole batch
        in ONE device launch.

        The signature is uniform across policy classes so the daemon loop
        never branches: stateless specs thread ``carry = ()`` untouched,
        sequence specs advance their encoder carry *inside* the launch via a
        ``lax.scan`` over the batch (requests encode in submission order).
        ``n_real`` is a traced scalar — the ``< n_real`` pad mask means pad
        rows are scored (static shape, one compilation at every fill level)
        but never advance the history.  A conflicted request that re-queues
        re-encodes on its next batch — the history sees it twice, which is
        faithful to a kube scheduling queue (the pod really does arrive at
        the scheduler again).
        """
        cfg, score_fn, policy = self.cfg, self.score_fn, self.policy

        if policy is None or policy.embed_dim == 0:

            @jax.jit
            def score(params, snap, pods, carry, n_real):
                q = schedulers.score_afterstates_batch(params, snap, pods,
                                                       cfg, score_fn, fused,
                                                       policy=policy)
                ok = jax.vmap(lambda p: kenv.feasible(snap, p, cfg))(pods)
                return q, ok, carry

            return score

        from repro.core import policy as policy_mod

        @jax.jit
        def score(params, snap, pods, carry, n_real):
            def step(c, xs):
                pod, is_real = xs
                c2, emb = policy.encode_step(
                    params, c, policy_mod.pod_workload_features(pod))
                c2 = jax.tree.map(lambda a, b: jnp.where(is_real, a, b),
                                  c2, c)
                q = schedulers.score_afterstates(params, snap, pod, cfg,
                                                 fused=fused, policy=policy,
                                                 embed=emb)
                return c2, (q, kenv.feasible(snap, pod, cfg))

            n_b = jax.tree.leaves(pods)[0].shape[0]
            is_real = jnp.arange(n_b) < n_real
            carry2, (q, ok) = jax.lax.scan(step, carry, (pods, is_real))
            return q, ok, carry2

        return score

    def feasible_one(self, node: int, pod: PodSpec) -> bool:
        """``env.feasible`` row ``node`` against the LIVE buffer (bind-time
        re-validation)."""
        lv = self.live
        return bool(
            lv.healthy[node]
            and lv.cpu_requested[node] + float(pod.cpu_request)
            <= lv.cpu_capacity[node]
            and lv.mem_requested[node] + float(pod.mem_request)
            <= lv.mem_capacity[node]
            and lv.num_pods[node] < lv.max_pods[node]
        )

    def bind(self, node: int, pod: PodSpec) -> None:
        """Commit one bind to the live buffer: ``env.place`` restricted to
        the chosen row, in numpy (no device op on the serving hot path)."""
        lv, cfg = self.live, self.cfg
        in_flight = float(np.sum(lv.startup_cpu > 0.25 * cfg.image_pull_cost))
        pull = cfg.image_pull_cost * (1.0 + cfg.pull_concurrency_coeff
                                      * in_flight)
        start = cfg.warm_start_cost if lv.image_cached[node] else pull
        lv.num_pods[node] += 1
        lv.exp_pods[node] += 1
        lv.cpu_requested[node] += float(pod.cpu_request)
        lv.mem_requested[node] += float(pod.mem_request)
        lv.pods_cpu[node] += float(pod.cpu_demand)
        lv.mem_used[node] += float(pod.mem_demand)
        lv.startup_cpu[node] += start
        lv.image_cached[node] = True


class FleetSubstrate:
    """Job->host placement (``sched.placement``) as a daemon substrate.

    Jobs are packed as (B, 6) afterstate-delta rows (``placement.job_delta``)
    and scored through the fused column kernel — the same dispatch
    ``PlacementEngine.select`` uses, batched.
    """

    def __init__(self, fleet: _pl.FleetState,
                 max_host_cpu_pct: float = 88.0, policy=None):
        self.live = jax.tree.map(lambda x: np.array(x, np.float64), fleet)
        self.max_host_cpu_pct = max_host_cpu_pct
        self.policy = policy

    def snapshot(self) -> _pl.FleetState:
        return jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), self.live)

    def pack(self, jobs: Sequence[_pl.JobSpec], size: int) -> jnp.ndarray:
        jobs = list(jobs) + [jobs[-1]] * (size - len(jobs))
        return jnp.stack([_pl.job_delta(j) for j in jobs])

    def init_carry(self, params: dict):
        if self.policy is not None and self.policy.embed_dim > 0:
            return self.policy.carry_init(params)
        return ()

    def make_scorer(self, fused) -> Callable:
        """Same uniform ``(params, snap, deltas, carry, n_real) ->
        (q, ok, carry)`` contract as ``ClusterSubstrate.make_scorer``.

        Fused-capable specs (and the default ``policy=None``) keep the fused
        column kernel; other policy classes score the assembled (N, 6) rows
        through ``PolicySpec.score_set``.  Sequence specs feed their encoder
        the job's normalized demand delta (the first ``ENCODER_IN`` entries
        of ``delta / FEATURE_SCALE`` — the job-stream analogue of
        ``pod_workload_features``).
        """
        max_cpu = self.max_host_cpu_pct
        policy = self.policy
        if policy is not None and policy.fused_kernel:
            policy = None          # "mlp": the column kernel IS its score_set

        from repro.kernels import ops
        from repro.sched.api import _fleet_mode

        mode = _fleet_mode(fused)

        def feasible(snap, deltas):
            return (
                (snap.healthy > 0.5)[None, :]
                & (snap.cpu_pct[None, :] + deltas[:, 0:1] <= max_cpu)
                & (snap.mem_pct[None, :] + deltas[:, 1:2] <= 95.0)
                & (snap.job_util_pct[None, :] + deltas[:, 2:3]
                   <= 100.0 + 1e-6)
            )

        def afterstate_rows(snap, delta, embed=None):
            feats = (jnp.stack(_pl.fleet_cols(snap), axis=-1)
                     + delta[None, :]) / kenv.FEATURE_SCALE
            if embed is not None:
                feats = jnp.concatenate(
                    [feats,
                     jnp.broadcast_to(embed, feats.shape[:-1] + embed.shape)],
                    axis=-1)
            return feats

        if policy is None:

            @jax.jit
            def score(params, snap, deltas, carry, n_real):
                cols = _pl.fleet_cols(snap)
                q = jax.vmap(lambda d: ops.sdqn_score_delta(
                    cols, d, params, mode=mode))(deltas)
                return q, feasible(snap, deltas), carry

            return score

        if policy.embed_dim == 0:

            @jax.jit
            def score(params, snap, deltas, carry, n_real):
                q = jax.vmap(lambda d: policy.score_set(
                    params, afterstate_rows(snap, d)))(deltas)
                return q, feasible(snap, deltas), carry

            return score

        from repro.core.policy import ENCODER_IN

        @jax.jit
        def score(params, snap, deltas, carry, n_real):
            def step(c, xs):
                d, is_real = xs
                wf = (d / kenv.FEATURE_SCALE)[:ENCODER_IN]
                c2, emb = policy.encode_step(params, c, wf)
                c2 = jax.tree.map(lambda a, b: jnp.where(is_real, a, b),
                                  c2, c)
                return c2, policy.score_set(
                    params, afterstate_rows(snap, d, embed=emb))

            is_real = jnp.arange(deltas.shape[0]) < n_real
            carry2, q = jax.lax.scan(step, carry, (deltas, is_real))
            return q, feasible(snap, deltas), carry2

        return score

    def feasible_one(self, node: int, job: _pl.JobSpec) -> bool:
        lv = self.live
        return bool(
            lv.healthy[node] > 0.5
            and lv.cpu_pct[node] + job.cpu_pct_demand <= self.max_host_cpu_pct
            and lv.mem_pct[node] + job.mem_pct_demand <= 95.0
            and lv.job_util_pct[node] + _pl.JOB_UTIL_DELTA_PCT
            <= 100.0 + 1e-6
        )

    def bind(self, node: int, job: _pl.JobSpec) -> None:
        lv = self.live
        lv.cpu_pct[node] += job.cpu_pct_demand
        lv.mem_pct[node] += job.mem_pct_demand
        lv.job_util_pct[node] += _pl.JOB_UTIL_DELTA_PCT
        lv.num_jobs[node] += 1


# ---------------------------------------------------------------------------
# the daemon
# ---------------------------------------------------------------------------


class PlacementDaemon:
    """Continuously-serving placement loop over a substrate.

    ``submit`` is admission: O(1) queue append, never blocks on the device.
    ``poll`` cuts at most one batch when ready (size or max-wait), publishes
    the live buffer as the scoring snapshot, scores the whole batch in one
    jitted launch, and commits binds with bind-time re-validation.
    ``flush``/``drain`` force remaining work through.  ``clock`` is
    injectable for deterministic tests (defaults to ``time.monotonic``).
    """

    def __init__(self, substrate, params: dict,
                 config: DaemonConfig = DaemonConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self._sub = substrate
        self._params = params
        self.config = config
        self._clock = clock
        self._pending: collections.deque = collections.deque()
        self._scorer = substrate.make_scorer(config.fused)
        # sequence policy classes carry their arrival-history encoder state
        # across batches; stateless substrates (incl. ones predating
        # init_carry) thread an empty pytree
        self._carry = getattr(substrate, "init_carry", lambda p: ())(params)
        self._next_id = 0
        self.metrics = DaemonMetrics()
        self.decisions: List[Decision] = []

    # -- admission (writes the live buffer side only) -----------------------

    def submit(self, pod, now: Optional[float] = None) -> int:
        """Enqueue one placement request; returns its request id."""
        now = self._clock() if now is None else now
        req = _Request(self._next_id, pod, now)
        self._next_id += 1
        self._pending.append(req)
        self.metrics.submitted += 1
        return req.req_id

    def set_params(self, params: dict) -> None:
        """Hot-swap policy params (same pytree structure: no recompile) —
        the online-learning refresh hook."""
        self._params = params

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- serving loop -------------------------------------------------------

    def _cut_ready(self, now: float) -> bool:
        if not self._pending:
            return False
        if len(self._pending) >= self.config.batch_size:
            return True
        return now - self._pending[0].t_submit >= self.config.max_wait_s

    def poll(self, now: Optional[float] = None) -> int:
        """Process at most one batch if the cut condition holds.  Returns
        the number of requests decided (bound or dropped) this call."""
        now = self._clock() if now is None else now
        if not self._cut_ready(now):
            return 0
        return self._process_batch(now)

    def flush(self, now: Optional[float] = None) -> int:
        """Process one batch regardless of the cut condition (0 if idle)."""
        now = self._clock() if now is None else now
        if not self._pending:
            return 0
        return self._process_batch(now)

    def drain(self, now: Optional[float] = None) -> int:
        """Flush until the queue is empty (conflict re-queues included)."""
        done = 0
        while self._pending:
            done += self.flush(now)
        return done

    def warmup(self) -> None:
        """Prime the scoring compilation outside any timing window.

        ``n_real = 0``: every warmup row is a pad row, so a sequence
        policy's history carry is untouched by warming up.
        """
        snap = self._sub.snapshot()
        pods = self._sub.pack([self._dummy_pod()], self.config.batch_size)
        jax.block_until_ready(
            self._scorer(self._params, snap, pods, self._carry, 0))

    def scorer_cache_size(self) -> int:
        """Compilations of the batched scorer (1 == every batch, at every
        fill level, reused one executable)."""
        return self._scorer._cache_size()

    # -- internals ----------------------------------------------------------

    def _dummy_pod(self):
        if isinstance(self._sub, ClusterSubstrate):
            return kenv.default_pod(self._sub.cfg)
        return _pl.JobSpec()

    def _process_batch(self, now: float) -> int:
        b = self.config.batch_size
        reqs = [self._pending.popleft()
                for _ in range(min(len(self._pending), b))]
        # publish the admission buffer as the read (scoring) snapshot; the
        # live buffer keeps taking writes from here on
        snap = self._sub.snapshot()
        pods = self._sub.pack([r.pod for r in reqs], b)
        scores, ok, self._carry = self._scorer(
            self._params, snap, pods, self._carry, len(reqs))  # ONE launch
        self.metrics.device_launches += 1
        self.metrics.batches += 1
        scores = np.asarray(scores)
        ok = np.asarray(ok)
        decided = 0
        for i, req in enumerate(reqs):
            decided += self._commit(req, scores[i], ok[i])
        return decided

    def _decide(self, req: _Request, node: int) -> None:
        lat = max(self._clock() - req.t_submit, 0.0)
        self.decisions.append(Decision(req.req_id, node, lat, req.attempts))
        self.metrics.latencies_s.append(lat)
        if node == NO_PLACEMENT:
            self.metrics.dropped += 1
        else:
            self.metrics.bound += 1

    def _commit(self, req: _Request, row: np.ndarray, ok: np.ndarray) -> int:
        """Optimistic bind of one scored request; returns 1 if decided."""
        req.attempts += 1
        masked = np.where(ok, row, -np.inf)
        if not ok.any():
            # the snapshot offered no feasible node at all: a genuine drop,
            # exactly env.run_episode's NO_NODE accounting
            self._decide(req, NO_PLACEMENT)
            return 1
        choice = int(np.argmax(masked))
        if self._sub.feasible_one(choice, req.pod):
            self._sub.bind(choice, req.pod)
            self._decide(req, choice)
            return 1
        # optimistic bind lost the race: the snapshot's winner was taken by
        # an earlier bind (or external churn) before this request's turn
        self.metrics.conflicts += 1
        if self.config.conflict_policy == "next-best":
            for cand in np.argsort(-masked)[1:]:
                if not np.isfinite(masked[cand]):
                    break
                if self._sub.feasible_one(int(cand), req.pod):
                    self._sub.bind(int(cand), req.pod)
                    self._decide(req, int(cand))
                    return 1
        if req.attempts > self.config.max_retries:
            self._decide(req, NO_PLACEMENT)
            return 1
        # back to the queue head: re-scored against fresh state next batch
        self.metrics.requeued += 1
        self._pending.appendleft(req)
        return 0


def replay_trace(daemon: PlacementDaemon, t_s: Sequence[float],
                 pods: Sequence, speed: float = 1.0) -> float:
    """Replay an arrival trace in real time through the daemon.

    ``t_s`` are arrival offsets (seconds) from the replay start, ``pods``
    the matching workload specs (see ``scenarios.arrivals.arrival_trace``).
    Each request's submission time is its *scheduled* arrival, so when the
    daemon cannot keep up, queueing delay shows up in decision latency —
    the offered-load curve the placement_serve bench measures.  ``speed``
    compresses the trace (2.0 = twice the offered rate).  Polls between
    arrivals, drains at the end; returns the wall-clock serving duration.
    """
    clock = daemon._clock
    t0 = clock()
    for t_off, pod in zip(t_s, pods):
        due = t0 + t_off / speed
        while clock() < due:
            if not daemon.poll():
                time.sleep(0)        # yield; arrival gaps are sub-ms anyway
        daemon.submit(pod, now=due)
        daemon.poll()
    daemon.drain()
    return clock() - t0
