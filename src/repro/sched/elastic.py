"""Elastic consolidation: SDQN-n-style packing → green scale-down proposals.

The paper's headline SDQN-n result is that consolidating compute-intensive
pods onto fewer nodes lets idle nodes be decommissioned (§1 contribution 2,
§6).  At fleet scale this module turns the learned consolidation policy into
actionable plans: which hosts can be drained and powered down, and what the
projected fleet-average utilization becomes.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.sched.placement import (JOB_UTIL_DELTA_PCT, FleetState, JobSpec,
                                   PlacementEngine)


@dataclasses.dataclass
class ConsolidationPlan:
    drain_hosts: List[int]                # hosts whose jobs should migrate
    target_hosts: List[int]               # where they go
    migrations: List[tuple]               # (job_host_before, job_host_after)
    projected_avg_cpu_before: float
    projected_avg_cpu_after: float
    hosts_freed: int


def consolidation_plan(engine: PlacementEngine, fleet: FleetState,
                       job: JobSpec, idle_threshold_jobs: int = 3) -> ConsolidationPlan:
    """Propose migrating jobs off nearly-idle hosts using the SDQN-n policy.

    Hosts with <= `idle_threshold_jobs` jobs are drain candidates; each of
    their jobs is re-placed with the consolidating engine (which refuses
    placements violating the CPU ceiling).  A host is freed only if *all*
    its jobs found a new home.
    """
    before = float(jnp.mean(fleet.cpu_pct))
    num_jobs = np.asarray(fleet.num_jobs)
    drain = [int(i) for i in np.nonzero((num_jobs > 0) & (num_jobs <= idle_threshold_jobs))[0]]

    migrations = []
    freed = []
    cur = fleet
    for host in drain:
        jobs_here = int(num_jobs[host])
        moved = []
        trial = cur._replace(
            healthy=cur.healthy.at[host].set(0.0)  # exclude self as target
        )
        ok_all = True
        for _ in range(jobs_here):
            tgt, scores = engine.select(trial, job)
            if not bool(jnp.isfinite(scores[tgt])):
                ok_all = False
                break
            trial = engine.place(trial, tgt, job)
            moved.append((host, tgt))
        if ok_all and moved:
            # commit: remove jobs from the drained host
            n = cur.cpu_pct.shape[0]
            onehot = (jnp.arange(n) == host).astype(jnp.float32)
            trial = trial._replace(
                cpu_pct=trial.cpu_pct - onehot * job.cpu_pct_demand * jobs_here,
                mem_pct=trial.mem_pct - onehot * job.mem_pct_demand * jobs_here,
                job_util_pct=trial.job_util_pct - onehot * JOB_UTIL_DELTA_PCT * jobs_here,
                num_jobs=trial.num_jobs - (onehot * jobs_here).astype(jnp.int32),
                healthy=cur.healthy,  # restore health flag
            )
            cur = trial
            migrations.extend(moved)
            freed.append(host)

    after = float(jnp.mean(cur.cpu_pct))
    return ConsolidationPlan(
        drain_hosts=freed,
        target_hosts=sorted({t for _, t in migrations}),
        migrations=migrations,
        projected_avg_cpu_before=before,
        projected_avg_cpu_after=after,
        hosts_freed=len(freed),
    )
