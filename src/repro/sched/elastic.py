"""Elastic consolidation: SDQN-n-style packing → green scale-down proposals.

The paper's headline SDQN-n result is that consolidating compute-intensive
pods onto fewer nodes lets idle nodes be decommissioned (§1 contribution 2,
§6).  At fleet scale this module turns the learned consolidation policy into
actionable plans: which hosts can be drained and powered down, and what the
projected fleet-average utilization becomes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as kenv, schedulers
from repro.core.types import ClusterState, EnvConfig, PodLedger
from repro.sched.placement import (JOB_UTIL_DELTA_PCT, FleetState, JobSpec,
                                   PlacementEngine)


@dataclasses.dataclass
class ConsolidationPlan:
    drain_hosts: List[int]                # hosts whose jobs should migrate
    target_hosts: List[int]               # where they go
    migrations: List[tuple]               # (job_host_before, job_host_after)
    projected_avg_cpu_before: float
    projected_avg_cpu_after: float
    hosts_freed: int


def make_consolidator(
    qparams: dict,
    cfg: EnvConfig,
    max_migrations: int = 4,
    idle_threshold: int = 2,
    score_fn: Callable = None,
) -> Callable:
    """Jit-safe in-episode consolidation: the SDQN-n green pass, on-device.

    ``consolidation_plan`` above proposes drains from Python; this is the
    same policy as a fixed-shape kernel ``(state, ledger) -> (state, ledger,
    moved)`` that ``env.run_episode`` invokes every
    ``cfg.consolidate_every_s`` seconds inside the scanned loop.  Each of the
    ``max_migrations`` sub-steps:

      1. picks the drain source — the node with the fewest (but > 0)
         experiment pods, at most ``idle_threshold`` of them;
      2. picks the longest-remaining pod on it from the expiry ledger
         (migrating a pod about to finish anyway buys nothing);
      3. scores every candidate target through the shared fused
         ``schedulers.score_afterstates`` dispatch and migrates to the
         argmax-Q node among feasible nodes that are at least as loaded as
         the source (packing is monotone, so the pass cannot ping-pong);
      4. re-binds the pod (warm/cold start costs apply on the target) and
         rewrites its ledger row, keeping its expiry — migration does not
         restart the job's clock.

    A sub-step with no valid source, pod, or target is the identity, so the
    pass is a no-op on already-consolidated or saturated clusters.  All
    shapes are static: the pass scans under jit/vmap in both the eval and
    seed-parallel train engines unchanged.
    """

    def migrate_once(carry, _):
        st, led, moved = carry
        exp = st.exp_pods
        n = st.n_nodes
        drainable = st.healthy & (exp > 0) & (exp <= idle_threshold)
        src = jnp.argmin(jnp.where(drainable, exp, jnp.iinfo(jnp.int32).max))
        src = src.astype(jnp.int32)
        # the live ledger pod on src with the most remaining runtime
        on_src = led.node == src
        row = jnp.argmax(jnp.where(on_src, led.expiry_s, -jnp.inf)).astype(jnp.int32)
        pod = jax.tree.map(lambda c: c[row], led.spec)

        st_rm = kenv.remove_pod(st, src, pod)
        ok = kenv.feasible(st_rm, pod, cfg)
        ok = ok & (jnp.arange(n) != src)
        # consolidate monotonically: only onto nodes at least as loaded as
        # the source was BEFORE the pod came off it — the busiest node count
        # strictly grows (or the source empties), so the pass terminates,
        # never ping-pongs, and a lone pod on an otherwise-idle cluster
        # (already maximally packed) stays put instead of hopping between
        # empty nodes paying pull costs
        ok = ok & (st_rm.exp_pods >= st.exp_pods[src])
        q = schedulers.score_afterstates(qparams, st_rm, pod, cfg, score_fn)
        tgt = jnp.argmax(jnp.where(ok, q, -jnp.inf)).astype(jnp.int32)

        do = jnp.any(drainable) & jnp.any(on_src) & jnp.any(ok)
        st_new = kenv.place(st_rm, tgt, pod, cfg)
        st = jax.tree.map(lambda a, b: jnp.where(do, b, a), st, st_new)
        led = led._replace(node=led.node.at[row].set(jnp.where(do, tgt, led.node[row])))
        return (st, led, moved + do.astype(jnp.int32)), None

    def consolidate(state: ClusterState, ledger: PodLedger):
        (state, ledger, moved), _ = jax.lax.scan(
            migrate_once, (state, ledger, jnp.int32(0)), None,
            length=max_migrations)
        return state, ledger, moved

    return consolidate


def consolidation_plan(engine: PlacementEngine, fleet: FleetState,
                       job: JobSpec, idle_threshold_jobs: int = 3) -> ConsolidationPlan:
    """Propose migrating jobs off nearly-idle hosts using the SDQN-n policy.

    Hosts with <= `idle_threshold_jobs` jobs are drain candidates; each of
    their jobs is re-placed with the consolidating engine (which refuses
    placements violating the CPU ceiling).  A host is freed only if *all*
    its jobs found a new home.
    """
    before = float(jnp.mean(fleet.cpu_pct))
    num_jobs = np.asarray(fleet.num_jobs)
    drain = [int(i) for i in np.nonzero((num_jobs > 0) & (num_jobs <= idle_threshold_jobs))[0]]

    migrations = []
    freed = []
    cur = fleet
    for host in drain:
        jobs_here = int(num_jobs[host])
        moved = []
        trial = cur._replace(
            healthy=cur.healthy.at[host].set(0.0)  # exclude self as target
        )
        ok_all = True
        for _ in range(jobs_here):
            # this planner is a host-side loop: syncing the 0-d choice here
            # IS the API boundary select defers to
            tgt, scores = engine.select(trial, job)
            tgt = int(tgt)
            if not bool(jnp.isfinite(scores[tgt])):
                ok_all = False
                break
            trial = engine.place(trial, tgt, job)
            moved.append((host, tgt))
        if ok_all and moved:
            # commit: remove jobs from the drained host
            n = cur.cpu_pct.shape[0]
            onehot = (jnp.arange(n) == host).astype(jnp.float32)
            trial = trial._replace(
                cpu_pct=trial.cpu_pct - onehot * job.cpu_pct_demand * jobs_here,
                mem_pct=trial.mem_pct - onehot * job.mem_pct_demand * jobs_here,
                job_util_pct=trial.job_util_pct - onehot * JOB_UTIL_DELTA_PCT * jobs_here,
                num_jobs=trial.num_jobs - (onehot * jobs_here).astype(jnp.int32),
                healthy=cur.healthy,  # restore health flag
            )
            cur = trial
            migrations.extend(moved)
            freed.append(host)

    after = float(jnp.mean(cur.cpu_pct))
    return ConsolidationPlan(
        drain_hosts=freed,
        target_hosts=sorted({t for _, t in migrations}),
        migrations=migrations,
        projected_avg_cpu_before=before,
        projected_avg_cpu_after=after,
        hosts_freed=len(freed),
    )
