"""Straggler detection, evacuation, and recovery for the distributed runtime.

A host whose recent step times drift beyond ``threshold``× the fleet median
(or whose health flag drops) is declared a straggler; its jobs are re-placed
through the unified ``sched.api.select`` dispatch — the Table-3 health term
(−100) guarantees the Q-scores of unhealthy hosts are never selected, so
evacuation and avoidance share one mechanism.  Evacuated hosts are tracked,
and ``recover`` marks them healthy again once their fresh step times come
back under the straggler line (the daemon's fail/recover cycle, host-side).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.types import NO_PLACEMENT
from repro.sched import api
from repro.sched.placement import (JOB_UTIL_DELTA_PCT, FleetState, JobSpec,
                                   PlacementEngine)


class StragglerMonitor:
    def __init__(self, window: int = 16, threshold: float = 1.8):
        self.window = window
        self.threshold = threshold
        self._times: Dict[int, collections.deque] = {}
        self._evacuated: Set[int] = set()

    def record(self, host: int, step_time_s: float):
        self._times.setdefault(host, collections.deque(maxlen=self.window)).append(step_time_s)

    def _medians(self) -> Dict[int, float]:
        return {h: float(np.median(t)) for h, t in self._times.items()
                if len(t) >= 4}

    def stragglers(self) -> List[int]:
        if not self._times:
            return []
        medians = self._medians()
        if len(medians) < 2:
            return []
        fleet_median = float(np.median(list(medians.values())))
        return [h for h, m in medians.items() if m > self.threshold * fleet_median]

    @property
    def evacuated(self) -> List[int]:
        """Hosts currently marked unhealthy by an ``evacuate`` call."""
        return sorted(self._evacuated)

    def evacuate(self, engine: PlacementEngine, fleet: FleetState, job: JobSpec,
                 hosts: Optional[List[int]] = None) -> tuple:
        """Mark stragglers unhealthy and re-place their jobs.  Returns
        (new_fleet, migrations).

        Re-placement routes through ``sched.api.select`` — the same dispatch
        (and the same ``NO_PLACEMENT`` no-feasible-host sentinel) every other
        serving path uses.  Jobs that find no feasible host simply drain off
        with their dead host (no migration recorded); the host's stale step
        samples are cleared so ``recover`` judges it on fresh times only.
        """
        hosts = self.stragglers() if hosts is None else hosts
        migrations = []
        for host in hosts:
            n_jobs = int(fleet.num_jobs[host])
            fleet = fleet._replace(healthy=fleet.healthy.at[host].set(0.0))
            self._evacuated.add(int(host))
            self._times.pop(int(host), None)
            for _ in range(n_jobs):
                tgt = int(api.select(fleet, job, params=engine.qparams,
                                     guard=True))
                if tgt == NO_PLACEMENT:
                    break
                fleet = engine.place(fleet, tgt, job)
                migrations.append((host, tgt))
            onehot = (np.arange(fleet.cpu_pct.shape[0]) == host)
            fleet = fleet._replace(
                cpu_pct=fleet.cpu_pct - onehot * job.cpu_pct_demand * n_jobs,
                mem_pct=fleet.mem_pct - onehot * job.mem_pct_demand * n_jobs,
                job_util_pct=fleet.job_util_pct - onehot * JOB_UTIL_DELTA_PCT * n_jobs,
                num_jobs=fleet.num_jobs - (onehot * n_jobs).astype(np.int32),
            )
        return fleet, migrations

    def recover(self, fleet: FleetState,
                hosts: Optional[List[int]] = None) -> tuple:
        """Mark recovered hosts healthy again.  Returns (new_fleet, healed).

        With ``hosts=None``, heals every evacuated host that has reported
        ≥ 4 FRESH step samples (its history was cleared at evacuation) whose
        median is back under the straggler line — a flapping host that is
        still slow stays out of the fleet.  Explicit ``hosts`` force-heal.
        """
        if hosts is None:
            bad = set(self.stragglers())
            hosts = [h for h in sorted(self._evacuated)
                     if h in self._medians() and h not in bad]
        healed = []
        for host in hosts:
            fleet = fleet._replace(healthy=fleet.healthy.at[host].set(1.0))
            self._evacuated.discard(int(host))
            healed.append(int(host))
        return fleet, healed
