"""Straggler detection and evacuation for the distributed runtime.

A host whose recent step times drift beyond ``threshold``× the fleet median
(or whose health flag drops) is declared a straggler; its jobs are re-placed
through the SDQN engine — the Table-3 health term (−100) guarantees the
Q-scores of unhealthy hosts are never selected, so evacuation and avoidance
share one mechanism.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional

import numpy as np

from repro.sched.placement import (JOB_UTIL_DELTA_PCT, FleetState, JobSpec,
                                   PlacementEngine)


class StragglerMonitor:
    def __init__(self, window: int = 16, threshold: float = 1.8):
        self.window = window
        self.threshold = threshold
        self._times: Dict[int, collections.deque] = {}

    def record(self, host: int, step_time_s: float):
        self._times.setdefault(host, collections.deque(maxlen=self.window)).append(step_time_s)

    def stragglers(self) -> List[int]:
        if not self._times:
            return []
        medians = {h: float(np.median(t)) for h, t in self._times.items() if len(t) >= 4}
        if len(medians) < 2:
            return []
        fleet_median = float(np.median(list(medians.values())))
        return [h for h, m in medians.items() if m > self.threshold * fleet_median]

    def evacuate(self, engine: PlacementEngine, fleet: FleetState, job: JobSpec,
                 hosts: Optional[List[int]] = None) -> tuple:
        """Mark stragglers unhealthy and re-place their jobs. Returns
        (new_fleet, migrations)."""
        hosts = self.stragglers() if hosts is None else hosts
        migrations = []
        for host in hosts:
            n_jobs = int(fleet.num_jobs[host])
            fleet = fleet._replace(healthy=fleet.healthy.at[host].set(0.0))
            for _ in range(n_jobs):
                tgt, scores = engine.select(fleet, job)
                if not bool(np.isfinite(np.asarray(scores)[tgt])):
                    break
                fleet = engine.place(fleet, tgt, job)
                migrations.append((host, tgt))
            onehot = (np.arange(fleet.cpu_pct.shape[0]) == host)
            fleet = fleet._replace(
                cpu_pct=fleet.cpu_pct - onehot * job.cpu_pct_demand * n_jobs,
                mem_pct=fleet.mem_pct - onehot * job.mem_pct_demand * n_jobs,
                job_util_pct=fleet.job_util_pct - onehot * JOB_UTIL_DELTA_PCT * n_jobs,
                num_jobs=fleet.num_jobs - (onehot * n_jobs).astype(np.int32),
            )
        return fleet, migrations
