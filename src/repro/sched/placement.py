"""SDQN-driven job→host placement for the training/serving runtime.

This is the framework-integration of the paper's technique: the same
Q-network that schedules pods in the reproduction schedules *jobs* (training
replicas, serving replicas, data workers) onto fleet hosts.  Host state maps
onto the six Table-2 features 1:1; scoring runs through the fused Pallas
kernel (``repro.kernels.ops.sdqn_score``) so a 10^5-host fleet is scored in
one kernel launch.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as kenv
from repro.core.types import NO_PLACEMENT
from repro.kernels import ops

# Job-slot ceiling per host: the Table-2 "pod utilization" analogue for the
# fleet.  ``job_util_pct`` advances by JOB_UTIL_DELTA_PCT per bound job, and
# ``select`` assumes the same delta when scoring afterstates, so the third
# feature stays consistent with ``num_jobs`` across a placement session.
MAX_JOBS_PER_HOST = 25.0
JOB_UTIL_DELTA_PCT = 100.0 / MAX_JOBS_PER_HOST

# select() sentinel: no feasible host, the job is not bound.  Re-export of
# the unified ``core.types.NO_PLACEMENT`` constant (old spelling kept).
NO_HOST = NO_PLACEMENT


class FleetState(NamedTuple):
    """Host fleet, vectorized (same layout as the cluster env)."""

    cpu_pct: jnp.ndarray       # (N,) current host utilization %
    mem_pct: jnp.ndarray       # (N,)
    job_util_pct: jnp.ndarray  # (N,) jobs / max_jobs * 100
    healthy: jnp.ndarray       # (N,) {0, 1}
    uptime_hours: jnp.ndarray  # (N,)
    num_jobs: jnp.ndarray      # (N,)

    def features(self) -> jnp.ndarray:
        return jnp.stack(
            [self.cpu_pct, self.mem_pct, self.job_util_pct,
             self.healthy.astype(jnp.float32), self.uptime_hours,
             self.num_jobs.astype(jnp.float32)], axis=-1)


@dataclasses.dataclass(frozen=True)
class JobSpec:
    cpu_pct_demand: float = 5.0     # host-% one job replica adds
    mem_pct_demand: float = 2.0
    kind: str = "train"             # train | serve | data


def fleet_cols(fleet: FleetState) -> tuple:
    """The six raw Table-2 feature columns of a fleet, for the column kernel."""
    return (fleet.cpu_pct, fleet.mem_pct, fleet.job_util_pct,
            fleet.healthy.astype(jnp.float32), fleet.uptime_hours,
            fleet.num_jobs.astype(jnp.float32))


def job_delta(job: JobSpec) -> jnp.ndarray:
    """The afterstate delta one job adds to the six columns (matches ``place``
    exactly — including the JOB_UTIL_DELTA_PCT advance of the third feature)."""
    return jnp.array([job.cpu_pct_demand, job.mem_pct_demand,
                      JOB_UTIL_DELTA_PCT, 0.0, 0.0, 1.0])


class PlacementEngine:
    """Scores afterstates with a trained SDQN and binds jobs to hosts.

    ``consolidate=True`` uses an SDQN-n-trained network: placements pack
    onto the busiest feasible hosts, which feeds ``elastic.consolidation_plan``
    with shut-down candidates (the paper's green-datacenter §6 narrative).
    """

    def __init__(self, qparams: dict, consolidate: bool = False,
                 max_host_cpu_pct: float = 88.0, use_kernel: Optional[bool] = None):
        self.qparams = qparams
        self.consolidate = consolidate
        self.max_host_cpu_pct = max_host_cpu_pct
        self.use_kernel = use_kernel

    def _score(self, feats: jnp.ndarray) -> jnp.ndarray:
        mode = None if self.use_kernel is None else ("interpret" if self.use_kernel else "ref")
        return ops.sdqn_score(kenv.normalize_features(feats), self.qparams, mode=mode)

    def feasible(self, fleet: FleetState, job: JobSpec) -> jnp.ndarray:
        return (
            (fleet.healthy > 0.5)
            & (fleet.cpu_pct + job.cpu_pct_demand <= self.max_host_cpu_pct)
            & (fleet.mem_pct + job.mem_pct_demand <= 95.0)
            # job-slot ceiling: keeps job_util_pct <= 100 (the k8s max-pods
            # analogue), so the third feature stays in the trained range
            & (fleet.job_util_pct + JOB_UTIL_DELTA_PCT <= 100.0 + 1e-6)
        )

    def select(self, fleet: FleetState, job: JobSpec) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Pick the host for one job. Returns (host index, scores).

        Afterstate scoring streams the six fleet columns through the fused
        column kernel (``ops.sdqn_score_delta``, via the unified
        ``repro.sched.api.score`` entry point): features + job delta +
        normalization + Q-net in one pass, never materializing the (N, 6)
        feature matrix in HBM.  The delta matches ``place`` exactly —
        including the ``job_util_pct`` advance of JOB_UTIL_DELTA_PCT, which
        previously stayed stale at its reset value.

        The host index comes back as a 0-d int32 device array, not a Python
        int: ``int(argmax)``/``bool(any)`` here forced a device sync on
        EVERY decision, serializing ``place_batch`` on dispatch latency.
        ``place`` consumes the device scalar as-is; callers that need a
        Python int sync once at their own API boundary.
        """
        from repro.sched import api  # lazy: api imports this module

        fused = ("auto" if self.use_kernel is None
                 else ("interpret" if self.use_kernel else False))
        scores = api.score(fleet, job, params=self.qparams, fused=fused)
        ok = self.feasible(fleet, job)
        scores = jnp.where(ok, scores, -jnp.inf)
        # all-infeasible fleet: argmax over all -inf would bind host 0 —
        # return the NO_HOST sentinel instead (place() ignores it)
        choice = jnp.where(jnp.any(ok), jnp.argmax(scores),
                           NO_HOST).astype(jnp.int32)
        return choice, scores

    def place(self, fleet: FleetState, host: int, job: JobSpec) -> FleetState:
        onehot = (jnp.arange(fleet.cpu_pct.shape[0]) == host)
        return fleet._replace(
            cpu_pct=fleet.cpu_pct + onehot * job.cpu_pct_demand,
            mem_pct=fleet.mem_pct + onehot * job.mem_pct_demand,
            # keep the third Table-2 feature live: without this the serving
            # path scores every post-first-binding afterstate on stale data
            job_util_pct=fleet.job_util_pct + onehot * JOB_UTIL_DELTA_PCT,
            num_jobs=fleet.num_jobs + onehot.astype(jnp.int32),
        )

    def place_batch(self, fleet: FleetState, jobs: int, job: JobSpec) -> Tuple[FleetState, np.ndarray]:
        hosts = []
        for _ in range(jobs):
            h, _ = self.select(fleet, job)
            fleet = self.place(fleet, h, job)
            hosts.append(h)
        return fleet, np.asarray(hosts)


def fresh_fleet(n_hosts: int, key: Optional[jax.Array] = None) -> FleetState:
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    return FleetState(
        cpu_pct=2.0 + 8.0 * jax.random.uniform(k1, (n_hosts,)),
        mem_pct=jnp.full((n_hosts,), 5.0),
        job_util_pct=jnp.zeros((n_hosts,)),
        healthy=jnp.ones((n_hosts,)),
        uptime_hours=5.0 + 100.0 * jax.random.uniform(k2, (n_hosts,)),
        num_jobs=jnp.zeros((n_hosts,), jnp.int32),
    )
