"""The unified public scheduling API.

Five PRs grew three overlapping scoring entry points — the trainer called
``schedulers.score_afterstates``, the job-placement engine called
``ops.sdqn_score_delta``, and the serving path stitched the two together.
This module is the ONE documented surface that wraps that shared dispatch;
the placement daemon (``sched.daemon``), the trainer, and the
``PlacementEngine`` all route through it (directly or via the same
underlying ``schedulers.score_afterstates`` dispatch).

    from repro.sched import api

    q = api.score(cluster_state, pod, params=qparams, cfg=env_cfg)   # (N,)
    q = api.score(fleet_state, job, params=qparams)                  # (N,)
    qb = api.score_batch(cluster_state, pods, params=qparams, cfg=env_cfg)

``score`` dispatches on the fleet's type:

  * ``core.types.ClusterState`` + ``core.types.PodSpec`` — the paper's pod
    scheduler: Q(afterstate) per candidate node through
    ``schedulers.score_afterstates`` (fused Pallas kernel on TPU at fleet
    scale, fused XLA twin elsewhere, plain O(N) jnp below the threshold).
    ``cfg`` (the ``EnvConfig``) is required.
  * ``sched.placement.FleetState`` + ``sched.placement.JobSpec`` — job→host
    placement: the six raw fleet columns + the job's afterstate delta
    through the fused column kernel (``ops.sdqn_score_delta``).

``fused`` selects the backend uniformly across both substrates:
``"auto"`` (default heuristics), ``True`` (force the fused path),
``"interpret"`` (Pallas kernel body in interpret mode, for CPU kernel
sweeps), ``False`` (force the unfused reference path).

``NO_PLACEMENT`` (== ``env.NO_NODE`` == ``placement.NO_HOST``) is the
sentinel every selector in the repo returns when the filtering phase leaves
no feasible target.

``shard`` mirrors ``fused`` as the *fleet-axis* knob: ``"auto"`` (default)
shards node columns across the visible devices' ``data`` axis when there is
more than one device and runs two-stage hierarchical scoring
(``sched.shard``: per-shard in-kernel top-k, then a tiny global merge — no
full N-length score vector on one device); on a single device it resolves
to the unsharded program, bit-identically.  ``False`` disables sharding; an
int forces that shard count (single-device two-stage execution, for tests
and benchmarks); a ``launch.mesh.FleetLayout`` pins an explicit layout.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import schedulers
from repro.core.types import NO_PLACEMENT, ClusterState, EnvConfig, PodSpec
from repro.sched import placement as _placement
from repro.sched.placement import FleetState, JobSpec

__all__ = ["DIVERGENCE_LIMIT", "NO_PLACEMENT", "heuristic_score", "score",
           "score_batch", "scores_valid", "select", "topk", "topsis_score"]

Fleet = Union[ClusterState, FleetState]
Workload = Union[PodSpec, JobSpec]

# |Q| beyond this is treated as a diverged net (a blown-up training run or a
# corrupted checkpoint), not a preference — the guard swaps in the heuristic
DIVERGENCE_LIMIT = 1e6


def heuristic_score(fleet: Fleet, pod: Workload, *,
                    cfg: Optional[EnvConfig] = None) -> jnp.ndarray:
    """(N,) kube-style LeastRequested+Balanced scores — no Q-net involved.

    The graceful-degradation fallback: when a policy class's scores miss a
    serving deadline, go NaN, or diverge, every dispatcher in the repo can
    fall back to this closed-form scorer and keep placing pods.  On a
    ``ClusterState`` it IS ``baselines.kube_scores``; on a ``FleetState`` it
    is the same formula over the fleet's percent-utilization columns.
    """
    if isinstance(fleet, ClusterState):
        if cfg is None:
            raise ValueError("cfg (EnvConfig) is required to score a "
                             "ClusterState fleet")
        from repro.core import baselines

        return baselines.kube_scores(fleet, pod, cfg)
    if isinstance(fleet, FleetState):
        delta = _placement.job_delta(pod)
        cpu_free = (100.0 - fleet.cpu_pct - delta[0]) / 100.0
        mem_free = (100.0 - fleet.mem_pct - delta[1]) / 100.0
        least_requested = 10.0 * (cpu_free + mem_free) / 2.0
        balanced = 10.0 * (1.0 - jnp.abs(cpu_free - mem_free))
        return least_requested + balanced
    raise TypeError(f"unsupported fleet type: {type(fleet).__name__}")


def topsis_score(fleet: Fleet, pod: Workload, *,
                 cfg: Optional[EnvConfig] = None,
                 weights=None) -> jnp.ndarray:
    """(N,) TOPSIS closeness coefficients — the multi-objective non-RL
    baseline (``sched.topsis``, GreenPod-shaped: CPU / memory / wake-energy
    / imbalance cost columns, distance-to-ideal ranking).  Same substrate
    dispatch as ``heuristic_score``; higher = better, mask feasibility at
    the caller like every other scorer."""
    from repro.sched import topsis as _topsis

    weights = _topsis.DEFAULT_WEIGHTS if weights is None else weights
    return _topsis.topsis_scores(fleet, pod, cfg=cfg, weights=weights)


def scores_valid(q: jnp.ndarray) -> jnp.ndarray:
    """Scalar bool: all scores finite and inside ``DIVERGENCE_LIMIT``."""
    return jnp.all(jnp.isfinite(q) & (jnp.abs(q) <= DIVERGENCE_LIMIT))


def _fleet_mode(fused) -> Optional[str]:
    """Map the uniform ``fused`` knob onto ``ops.sdqn_score_delta`` modes."""
    if fused == "auto":
        return None          # backend default: Pallas on TPU, fused XLA twin
    if fused is True:
        return None
    if fused == "interpret":
        return "interpret"
    if fused is False:
        return "ref"
    raise ValueError(f"fused must be 'auto', True, False or 'interpret'; "
                     f"got {fused!r}")


def _fleet_policy_score(fleet: FleetState, delta: jnp.ndarray, params: dict,
                        policy, embed=None) -> jnp.ndarray:
    """FleetState scoring through a non-fusable policy class: assemble the
    (N, 6) afterstate rows the column kernel would have built in-kernel,
    append ``embed`` when the spec carries one, and hand the whole set to
    ``policy.score_set``."""
    from repro.core import env as kenv

    feats = (jnp.stack(_placement.fleet_cols(fleet), axis=-1)
             + delta[None, :]) / kenv.FEATURE_SCALE
    if embed is not None:
        feats = jnp.concatenate(
            [feats, jnp.broadcast_to(embed, feats.shape[:-1] + embed.shape)],
            axis=-1)
    return policy.score_set(params, feats)


def _fleet_size(fleet: Fleet) -> int:
    return (fleet.n_nodes if isinstance(fleet, ClusterState)
            else fleet.cpu_pct.shape[0])


def score(fleet: Fleet, pod: Workload, *, params: dict,
          cfg: Optional[EnvConfig] = None, fused="auto", shard="auto",
          score_fn=None, policy=None, embed=None,
          guard: bool = False) -> jnp.ndarray:
    """(N,) Q-scores of placing ``pod`` on each target in ``fleet``.

    See the module docstring for the dispatch rules.  ``score_fn`` swaps the
    Table-4 Q-net for a custom scorer (LSTM/Transformer baselines;
    ClusterState substrate only, always the unfused path).  ``policy`` (a
    ``core.policy.PolicySpec``) swaps in a registered policy class on either
    substrate; ``embed`` is its history embedding for sequence specs.

    ``shard`` (module docstring) distributes the fleet axis: with a
    resolved layout the vector is computed shard-by-shard and stays
    device-sharded along ``data`` — logically (N,), physically never
    gathered until the caller syncs it.  Selection-only callers should
    prefer ``topk``/``select``, which never build the vector at all.

    ``guard=True`` validates the scores at this dispatch — NaN/inf or
    ``|Q| > DIVERGENCE_LIMIT`` anywhere in the vector swaps the WHOLE vector
    for ``heuristic_score`` (jit-safe ``where``, so it composes with every
    policy class and both substrates).  Serving paths set it; the training
    loop keeps the unguarded hot path.
    """
    from repro.sched import shard as _shard

    layout = _shard.resolve_layout(shard, _fleet_size(fleet))
    if layout is None:
        q = _score_raw(fleet, pod, params=params, cfg=cfg, fused=fused,
                       score_fn=score_fn, policy=policy, embed=embed)
    else:
        q = _shard.sharded_scores(fleet, pod, params=params, cfg=cfg,
                                  layout=layout, fused=fused,
                                  score_fn=score_fn, policy=policy,
                                  embed=embed)
    if not guard:
        return q
    return jnp.where(scores_valid(q), q, heuristic_score(fleet, pod, cfg=cfg))


def _score_raw(fleet: Fleet, pod: Workload, *, params: dict,
               cfg: Optional[EnvConfig] = None, fused="auto",
               score_fn=None, policy=None, embed=None) -> jnp.ndarray:
    if isinstance(fleet, ClusterState):
        if cfg is None:
            raise ValueError("cfg (EnvConfig) is required to score a "
                             "ClusterState fleet")
        return schedulers.score_afterstates(params, fleet, pod, cfg,
                                            score_fn=score_fn, fused=fused,
                                            policy=policy, embed=embed)
    if isinstance(fleet, FleetState):
        if score_fn is not None:
            raise ValueError("score_fn is not supported on the FleetState "
                             "column-kernel path")
        if policy is not None and not policy.fused_kernel:
            return _fleet_policy_score(fleet, _placement.job_delta(pod),
                                       params, policy, embed=embed)
        from repro.kernels import ops

        return ops.sdqn_score_delta(
            _placement.fleet_cols(fleet), _placement.job_delta(pod), params,
            mode=_fleet_mode(fused))
    raise TypeError(f"unsupported fleet type: {type(fleet).__name__}")


def score_batch(fleet: Fleet, pods: Workload, *, params: dict,
                cfg: Optional[EnvConfig] = None, fused="auto",
                score_fn=None, policy=None) -> jnp.ndarray:
    """(B, N) Q-scores for a batch of workloads against ONE fleet snapshot.

    ``pods``: a ``PodSpec`` with a leading (B,) batch dim on every field
    (ClusterState substrate), or a sequence of B ``JobSpec``s (FleetState
    substrate).  Under ``jit`` the whole batch lowers to one device launch —
    this is the serving daemon's batched scoring pass.
    """
    if isinstance(fleet, ClusterState):
        if cfg is None:
            raise ValueError("cfg (EnvConfig) is required to score a "
                             "ClusterState fleet")
        return schedulers.score_afterstates_batch(params, fleet, pods, cfg,
                                                  score_fn=score_fn,
                                                  fused=fused, policy=policy)
    if isinstance(fleet, FleetState):
        deltas = jnp.stack([_placement.job_delta(j) for j in pods])
        if policy is not None and not policy.fused_kernel:
            return jnp.stack([_fleet_policy_score(fleet, d, params, policy)
                              for d in deltas])
        from repro.kernels import ops

        cols = _placement.fleet_cols(fleet)
        mode = _fleet_mode(fused)
        return jnp.stack([ops.sdqn_score_delta(cols, d, params, mode=mode)
                          for d in deltas])
    raise TypeError(f"unsupported fleet type: {type(fleet).__name__}")


def topk(fleet: Fleet, pod: Workload, *, params: dict,
         cfg: Optional[EnvConfig] = None, k: int = 4, fused="auto",
         shard="auto", score_fn=None, policy=None, embed=None):
    """The ``k`` best feasible targets: ``(values, indices)`` sorted
    descending, ties by ascending index.  Infeasible slots carry ``-inf`` /
    index ``-1``; element 0 matches ``select`` exactly (modulo the sentinel).

    With a resolved shard layout this is the two-stage hierarchical path —
    per-shard in-kernel top-k, global merge over ``shards × k`` candidates —
    and the result may hold up to ``shards * k`` entries (all candidates
    that survived stage 1, the daemon's conflict-fallback depth).  Unsharded
    it is a plain masked ``lax.top_k``.
    """
    from repro.sched import shard as _shard

    n = _fleet_size(fleet)
    layout = _shard.resolve_layout(shard, n)
    if layout is not None:
        return _shard.topk(fleet, pod, params=params, cfg=cfg, layout=layout,
                           k=k, fused=fused, score_fn=score_fn,
                           policy=policy, embed=embed)
    q = _score_raw(fleet, pod, params=params, cfg=cfg, fused=fused,
                   score_fn=score_fn, policy=policy, embed=embed)
    ok = _feasible(fleet, pod, cfg, params)
    vals, idx = jax.lax.top_k(jnp.where(ok, q, -jnp.inf), max(1, min(k, n)))
    return vals, jnp.where(jnp.isfinite(vals), idx, -1)


def _feasible(fleet: Fleet, pod: Workload, cfg, params: dict) -> jnp.ndarray:
    if isinstance(fleet, ClusterState):
        from repro.core import env as kenv

        return kenv.feasible(fleet, pod, cfg)
    return _placement.PlacementEngine(params).feasible(fleet, pod)


def select(fleet: Fleet, pod: Workload, *, params: dict,
           cfg: Optional[EnvConfig] = None, fused="auto", shard="auto",
           score_fn=None, policy=None, guard: bool = False) -> jnp.ndarray:
    """Greedy feasible argmax over ``score``; ``NO_PLACEMENT`` if none fit.

    The one-shot convenience wrapper (scores + k8s filtering phase in one
    call).  For continuous serving use ``sched.daemon.PlacementDaemon``,
    which batches requests and binds with optimistic concurrency.
    ``guard=True`` falls back to the kube heuristic on NaN/diverged scores
    (see ``score``) — invalid Q values degrade the placement, never wedge it.

    With a resolved ``shard`` layout (module docstring) selection goes
    through the two-stage candidate merge and the full score vector is
    never materialized on one device; the winner is identical to the flat
    masked argmax (ties break to the lowest index at every merge stage).
    """
    from repro.sched import shard as _shard

    layout = _shard.resolve_layout(shard, _fleet_size(fleet))
    if layout is not None:
        return _shard.select_candidates(fleet, pod, params=params, cfg=cfg,
                                        layout=layout, fused=fused,
                                        score_fn=score_fn, policy=policy,
                                        guard=guard)
    q = score(fleet, pod, params=params, cfg=cfg, fused=fused, shard=False,
              score_fn=score_fn, policy=policy, guard=guard)
    ok = _feasible(fleet, pod, cfg, params)
    masked = jnp.where(ok, q, -jnp.inf)
    choice = jnp.argmax(masked).astype(jnp.int32)
    return jnp.where(jnp.any(ok), choice, jnp.int32(NO_PLACEMENT))
