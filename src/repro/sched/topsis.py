"""TOPSIS multi-objective placement scorer — the GreenPod-shaped non-RL
baseline for the green Pareto frontier.

GreenPod (PAPERS.md, arXiv 2506.04902) ranks candidate nodes by the classic
TOPSIS procedure over a normalized criteria matrix; this module is that
scorer over the repo's substrates.  Each candidate node's row is its
*afterstate* under the arriving workload, reduced to four cost criteria:

  * ``cpu``      — the node's CPU% after placement (the paper's objective:
                   minimize average CPU; GreenPod's utilization column)
  * ``mem``      — memory% after placement
  * ``energy``   — wake indicator: 1 when the node currently runs none of
                   the experiment's pods, so placing there activates an
                   idle node (the node-count quantity
                   ``rewards.energy_term`` / ``EpisodeStats.energy_wh``
                   integrate; GreenPod's power-draw column)
  * ``balance``  — |cpu% - mem%| after placement: resource imbalance, the
                   closed-form overload/drop-risk proxy (GreenPod's
                   drop-rate column)

The procedure is the textbook one: vector (L2) column normalization,
weighting, ideal/anti-ideal reference points (all criteria are costs, so
the ideal is the column-wise minimum), Euclidean distances, and the
closeness coefficient ``d- / (d+ + d-)`` — higher is better, so the scores
drop into ``masked_argmax``/``api.select`` exactly like Q-scores.

Deliberately NOT a ``core.policy`` registry entry: the registry contract is
trainable parametric policies (init/qvalues/train_step); TOPSIS has no
params and no learner.  It plugs in as a *selector* (``make_topsis_selector``
for episodes, ``topsis_scores`` wherever a score vector is wanted) and as
the ``topsis`` arm of the lifecycle/Pareto benchmarks.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core import env as kenv, schedulers
from repro.core.types import ClusterState, EnvConfig, PodSpec
from repro.sched import placement as _pl
from repro.sched.placement import FleetState, JobSpec

__all__ = ["DEFAULT_WEIGHTS", "closeness", "make_topsis_selector",
           "topsis_scores"]

# (cpu, mem, energy, balance) criterion weights.  CPU leads (it is the
# paper's stated objective), the wake indicator carries the green story,
# memory and imbalance temper pathological packings.  Renormalized inside
# `closeness`, so callers may pass any positive mix — the Pareto sweep
# scales the energy entry.
DEFAULT_WEIGHTS = (0.40, 0.20, 0.30, 0.10)

_EPS = 1e-9


def closeness(criteria: jnp.ndarray,
              weights: Sequence[float] = DEFAULT_WEIGHTS) -> jnp.ndarray:
    """TOPSIS closeness coefficients of an all-cost criteria matrix.

    ``criteria``: (N, C) raw cost columns (lower = better).  Returns (N,)
    in [0, 1], higher = better.  Degenerate columns (all candidates equal)
    contribute zero distance either way and drop out, as they should.
    """
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), _EPS)
    # vector normalization: each column scaled by its L2 norm
    norm = criteria / (jnp.linalg.norm(criteria, axis=0, keepdims=True) + _EPS)
    v = norm * w
    ideal = jnp.min(v, axis=0)       # all-cost: best is the minimum
    anti = jnp.max(v, axis=0)
    d_pos = jnp.linalg.norm(v - ideal, axis=1)
    d_neg = jnp.linalg.norm(v - anti, axis=1)
    return d_neg / (d_pos + d_neg + _EPS)


def _cluster_criteria(state: ClusterState, pod: PodSpec,
                      cfg: EnvConfig) -> jnp.ndarray:
    """(N, 4) cost criteria of every candidate afterstate (ClusterState)."""
    n = state.cpu_capacity.shape[0]
    # each candidate's own afterstate row — the same single-row arithmetic
    # the replay stores, vmapped over candidates (N rows, never (N, N, 6))
    rows = jax.vmap(
        lambda a: kenv.hypothetical_place_one(state, pod, cfg, a)
    )(jnp.arange(n))
    cpu, mem = rows[:, 0], rows[:, 1]
    wake = (state.exp_pods == 0).astype(jnp.float32)
    return jnp.stack([cpu, mem, wake, jnp.abs(cpu - mem)], axis=1)


def _fleet_criteria(fleet: FleetState, job: JobSpec) -> jnp.ndarray:
    """(N, 4) cost criteria of every candidate afterstate (FleetState)."""
    delta = _pl.job_delta(job)
    cpu = fleet.cpu_pct + delta[0]
    mem = fleet.mem_pct + delta[1]
    wake = (fleet.num_jobs == 0).astype(jnp.float32)
    return jnp.stack([cpu, mem, wake, jnp.abs(cpu - mem)], axis=1)


def topsis_scores(fleet: Union[ClusterState, FleetState],
                  pod: Union[PodSpec, JobSpec], *,
                  cfg: Optional[EnvConfig] = None,
                  weights: Sequence[float] = DEFAULT_WEIGHTS) -> jnp.ndarray:
    """(N,) TOPSIS closeness of placing ``pod`` on each target (higher =
    better).  Mirrors ``sched.api.heuristic_score``'s substrate dispatch;
    feasibility masking stays with the caller, as for every scorer."""
    if isinstance(fleet, ClusterState):
        if cfg is None:
            raise ValueError("cfg (EnvConfig) is required to score a "
                             "ClusterState fleet")
        return closeness(_cluster_criteria(fleet, pod, cfg), weights)
    if isinstance(fleet, FleetState):
        return closeness(_fleet_criteria(fleet, pod), weights)
    raise TypeError(f"unsupported fleet type: {type(fleet).__name__}")


def make_topsis_selector(cfg: EnvConfig,
                         weights: Sequence[float] = DEFAULT_WEIGHTS
                         ) -> Callable:
    """Episode selector ``(key, state, pod) -> node`` — drop-in for
    ``env.run_episode``/``eval_engine``, like ``make_kube_selector``."""

    def select(key, state, pod):
        ok = kenv.feasible(state, pod, cfg)
        q = topsis_scores(state, pod, cfg=cfg, weights=weights)
        return schedulers.masked_argmax(key, q, ok)

    return select
