"""Online learning in the serving path: realized transitions -> replay ring
-> background policy refresh with double-buffered params.

The serving daemon (``sched.daemon.PlacementDaemon``) runs a frozen policy;
this module closes the loop so the deployed policy adapts to the traffic it
actually serves:

  * **TransitionRecorder** observes every SERVED decision through the
    daemon's ``decision_hook`` — an O(1) host-side deque append, zero device
    work on the serving hot path, so attaching a recorder adds **zero
    scoring launches** and leaves decision latency untouched.  ``drain()``
    converts the recorded ``(pod, action)`` stream into replay rows with the
    EXACT offline arithmetic: a jnp shadow state advanced by ``env.place``
    through ``core.train_rl.realized_transition`` (afterstate features,
    realized Table-3/5 reward from the state delta, ``REWARD_SCALE``
    targets, weight-0 drops), written into the fused PR-5 ring via one
    jitted fixed-chunk scan per drain (``replay_add(..., n_valid=...)``).
    The stream a recorder produces is bit-identical to feeding the same
    ``(pod, action)`` trace through the offline transition body — pinned in
    tests/test_online.py.

  * **OnlineRefresher** runs ``policy.make_train_step`` batches off that
    ring against a **back** parameter buffer while the daemon keeps scoring
    from its **front** buffer.  Params are immutable jax pytrees, so the
    double-buffer is two *references*: the refresher's gradient step builds
    a new back pytree off-path, then publishes it with one atomic reference
    assignment (``daemon.set_params``).  The daemon reads its front pointer
    ONCE per batch (at batch cut), so a batch's scores never mix old and new
    params — stale reads are allowed (a batch cut just before a publish
    scores on the previous params), serving never blocks on a gradient
    step.  Targets are the realized rewards (bandit semantics, the literal
    Table-4 update): the online stream has no epsilon exploration, so
    bootstrapped max-Q targets would feed back the net's own optimism on
    exactly the states it already prefers.

Staleness model: scoring params lag the learner by at most one published
step plus whatever is in-flight; transitions lag the live cluster by the
un-drained tail of the deque.  External churn the decision stream does not
carry (``fail_node`` evictions, manual ``unbind``) desyncs the shadow state
— call ``resync(substrate.live)`` after such events (`serve.py --online`
does; a pure submit/bind/drop trace needs none).

    rec = TransitionRecorder(state, cfg)
    daemon = PlacementDaemon(sub, params, decision_hook=rec.record)
    ref = OnlineRefresher(daemon, rec)
    ... replay_trace(daemon, t_s, pods) ...   # serving thread
    ref.step()                                # or ref.start()/stop()
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import env as kenv, policy as policy_mod, rewards, train_rl
from repro.core.replay import Replay, replay_add, replay_init, replay_sample
from repro.core.types import FEATURE_DIM, EnvConfig, PodSpec
from repro.sched import placement as _pl

__all__ = [
    "FleetTransitionRecorder", "OnlineRefresher", "TransitionRecorder",
]

# transitions converted per jitted drain call: one executable serves every
# fill level (the last chunk pads with no-op rows masked out of the ring)
DRAIN_CHUNK = 64


def _pack_pods(pods: Sequence[PodSpec], size: int) -> PodSpec:
    """Stack + pad a pod list to the static (size,) drain-chunk shape."""
    pods = list(pods) + [pods[-1]] * (size - len(pods))

    def col(get):
        return jnp.asarray([float(get(p)) for p in pods], jnp.float32)

    return PodSpec(cpu_request=col(lambda p: p.cpu_request),
                   cpu_demand=col(lambda p: p.cpu_demand),
                   mem_request=col(lambda p: p.mem_request),
                   mem_demand=col(lambda p: p.mem_demand))


class TransitionRecorder:
    """Daemon decisions -> fused replay ring, with the offline arithmetic.

    ``state``/``cfg`` are the substrate's initial ``ClusterState``/
    ``EnvConfig``; the recorder keeps its own jnp *shadow* of the cluster,
    advanced by ``env.place`` with the realized actions at drain time, so
    rewards and stored afterstates are computed by exactly the code the
    offline trainer scans (``train_rl.realized_transition``).  ``record`` is
    the hot-path half (attach it as the daemon's ``decision_hook``): one
    deque append, no device work.
    """

    def __init__(self, state, cfg: EnvConfig, capacity: int = 4096,
                 reward_fn: Optional[Callable] = None,
                 chunk: int = DRAIN_CHUNK):
        self.cfg = cfg
        self.buffer: Replay = replay_init(capacity, n_features=FEATURE_DIM,
                                          lane=1)
        self._shadow = jax.tree.map(jnp.asarray, state)
        self._pending: collections.deque = collections.deque()
        self._chunk = chunk
        self.recorded = 0
        self.drained = 0
        reward_fn = reward_fn if reward_fn is not None \
            else rewards.make_reward_fn()

        @jax.jit
        def drain_chunk(shadow, buf, pods, actions, n_valid):
            def step(st, xs):
                pod, action = xs
                st2, stored, r = train_rl.realized_transition(
                    st, pod, action, cfg, reward_fn)
                # drops store with weight 0, exactly like the trainer: their
                # afterstate is fabricated (clamped gather) and must not
                # train the net
                return st2, (stored, r, (action >= 0).astype(jnp.float32))

            shadow2, (feats, targets, weights) = jax.lax.scan(
                step, shadow, (pods, actions))
            return shadow2, replay_add(buf, feats, targets, weights,
                                       n_valid=n_valid)

        self._drain_chunk = drain_chunk

    def record(self, pod, action: int) -> None:
        """The daemon's ``decision_hook``: O(1), no device work."""
        self._pending.append((pod, int(action)))
        self.recorded += 1

    @property
    def pending(self) -> int:
        return len(self._pending)

    def warmup(self) -> None:
        """Compile the drain executable before traffic arrives.

        Pushes one all-pad chunk through the jitted drain: every action is
        NO_NODE (``place`` is a one-hot no-op) and ``n_valid=0`` writes
        nothing to the ring and advances no pointer, so the shadow and
        buffer are bit-identical afterwards — only the compile cache warms.
        """
        zero = PodSpec(cpu_request=0.0, cpu_demand=0.0,
                       mem_request=0.0, mem_demand=0.0)
        pods = _pack_pods([zero], self._chunk)
        actions = jnp.full((self._chunk,), -1, jnp.int32)
        self._shadow, self.buffer = self._drain_chunk(
            self._shadow, self.buffer, pods, actions, 0)

    def drain(self, max_chunks: Optional[int] = None) -> int:
        """Convert recorded decisions into ring rows (jitted chunks).
        Returns the number of transitions written.

        ``max_chunks`` bounds the device work of one call (a background
        refresh cycle must have bounded stall potential on a shared
        device); the remainder stays pending for the next cycle."""
        n_total = 0
        n_chunks = 0
        while self._pending and (max_chunks is None or n_chunks < max_chunks):
            n_chunks += 1
            take = [self._pending.popleft()
                    for _ in range(min(len(self._pending), self._chunk))]
            pods = _pack_pods([p for p, _ in take], self._chunk)
            # pad actions are NO_NODE: `place` is a one-hot no-op, so the
            # shadow only advances through the real prefix; n_valid keeps
            # the pad rows out of the ring entirely
            actions = jnp.asarray(
                [a for _, a in take] + [-1] * (self._chunk - len(take)),
                jnp.int32)
            self._shadow, self.buffer = self._drain_chunk(
                self._shadow, self.buffer, pods, actions, len(take))
            n_total += len(take)
        self.drained += n_total
        return n_total

    def resync(self, live) -> None:
        """Rebase the shadow on the daemon's live buffer after external
        churn the decision stream does not carry (``fail_node`` evictions,
        manual ``unbind``).  Drains first, so already-recorded decisions are
        charged against the pre-churn state they were served under."""
        self.drain()
        self._shadow = jax.tree.map(jnp.asarray, live)


class FleetTransitionRecorder:
    """The job->host analogue of ``TransitionRecorder`` (FleetSubstrate).

    The shadow is a ``FleetState``; a bind adds the job's six-column
    afterstate delta (``placement.job_delta``) to the chosen host, and the
    reward is the literal Table-3 ``rewards.sdqn_reward`` over the raw
    fleet feature rows (feature 5 = running jobs plays the pod-distribution
    role, exactly as ``sched.api.score`` treats it when scoring a fleet).
    """

    def __init__(self, fleet: _pl.FleetState, capacity: int = 4096,
                 efficiency_weight: float = 5.0, chunk: int = DRAIN_CHUNK):
        self.buffer: Replay = replay_init(capacity, n_features=FEATURE_DIM,
                                          lane=1)
        self._shadow = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                                    fleet)
        self._pending: collections.deque = collections.deque()
        self._chunk = chunk
        self.recorded = 0
        self.drained = 0

        @jax.jit
        def drain_chunk(shadow, buf, deltas, actions, n_valid):
            def step(fl, xs):
                delta, action = xs
                onehot = (jnp.arange(fl.cpu_pct.shape[0]) == action
                          ).astype(jnp.float32)   # action < 0 -> all-zero
                fl2 = fl._replace(
                    cpu_pct=fl.cpu_pct + onehot * delta[0],
                    mem_pct=fl.mem_pct + onehot * delta[1],
                    job_util_pct=fl.job_util_pct + onehot * delta[2],
                    num_jobs=fl.num_jobs + onehot * delta[5],
                )
                before, after = fl.features(), fl2.features()
                a = jnp.maximum(action, 0)
                r = rewards.sdqn_reward(after, a,
                                        efficiency_weight=efficiency_weight,
                                        before_feats=before)
                stored = kenv.normalize_features(after[a])
                w = (action >= 0).astype(jnp.float32)
                return fl2, (stored, r * train_rl.REWARD_SCALE, w)

            shadow2, (feats, targets, weights) = jax.lax.scan(
                step, shadow, (deltas, actions))
            return shadow2, replay_add(buf, feats, targets, weights,
                                       n_valid=n_valid)

        self._drain_chunk = drain_chunk

    def record(self, job, action: int) -> None:
        self._pending.append((job, int(action)))
        self.recorded += 1

    @property
    def pending(self) -> int:
        return len(self._pending)

    def warmup(self) -> None:
        """Compile the drain executable (all-pad no-op chunk; see
        ``TransitionRecorder.warmup``)."""
        deltas = jnp.zeros((self._chunk, 6))
        actions = jnp.full((self._chunk,), -1, jnp.int32)
        self._shadow, self.buffer = self._drain_chunk(
            self._shadow, self.buffer, deltas, actions, 0)

    def drain(self, max_chunks: Optional[int] = None) -> int:
        n_total = 0
        n_chunks = 0
        while self._pending and (max_chunks is None or n_chunks < max_chunks):
            n_chunks += 1
            take = [self._pending.popleft()
                    for _ in range(min(len(self._pending), self._chunk))]
            deltas = jnp.stack(
                [_pl.job_delta(j) for j, _ in take]
                + [jnp.zeros((6,))] * (self._chunk - len(take)))
            actions = jnp.asarray(
                [a for _, a in take] + [-1] * (self._chunk - len(take)),
                jnp.int32)
            self._shadow, self.buffer = self._drain_chunk(
                self._shadow, self.buffer, deltas, actions, len(take))
            n_total += len(take)
        self.drained += n_total
        return n_total

    def resync(self, live) -> None:
        self.drain()
        self._shadow = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                                    live)


class OnlineRefresher:
    """Background policy refresh off a recorder's ring, double-buffered.

    ``step()`` is one refresh cycle — drain the recorder, sample a batch,
    run ``policy.make_train_step`` on the BACK params, publish the result to
    the daemon's front pointer (``set_params``; one atomic reference
    assignment at a batch-cut boundary — the daemon reads params once per
    batch, so mid-batch scores never mix buffers).  Call it inline for
    deterministic tests/benches, or ``start()`` a daemon thread that cycles
    with ``min_interval_s`` throttling (CPython reference assignment is
    atomic under the GIL; ``deque`` append/popleft are thread-safe, so the
    serving thread never takes a lock either).

    Adam moments warm-start from the served params (``policy.make_opt_state``)
    and persist across cycles — this is fine-tuning the deployed policy, not
    retraining it.
    """

    def __init__(self, daemon, recorder, spec=None, batch_size: int = 128,
                 min_interval_s: float = 0.0, seed: int = 0,
                 drain_chunks_per_step: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.daemon = daemon
        self.recorder = recorder
        spec = spec if spec is not None else policy_mod.get("mlp")
        self._step_fn = policy_mod.make_train_step(spec)
        self._back = daemon._params          # back buffer starts == front
        self._opt = policy_mod.make_opt_state(self._back)
        self._key = jax.random.PRNGKey(seed)
        self.batch_size = batch_size
        self.min_interval_s = min_interval_s
        # on a shared device, refresher launches queue ahead of scoring
        # launches — bounding the per-cycle drain bounds how long one cycle
        # can stall a serving batch (the tail stays pending for next cycle)
        self.drain_chunks_per_step = drain_chunks_per_step
        self._clock = clock
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.steps = 0
        self.swaps = 0
        self.last_loss: Optional[float] = None

    @property
    def params(self) -> dict:
        """The back buffer (the freshest learned params)."""
        return self._back

    def warmup(self) -> None:
        """Compile the drain AND train executables off the serving clock.

        The recorder warms with an all-pad no-op chunk; the sample + train
        path runs on the (possibly empty) ring with a throwaway key —
        ``replay_sample`` clamps an empty ring to index 0 with zero weights
        — and its outputs are DISCARDED: nothing is published, the back
        buffer, opt state and RNG stream are untouched.  Call before
        ``start()`` so the first real cycle costs a warm step (~tens of
        ms), not a trace-blocking compile."""
        self.recorder.warmup()
        k = jax.random.split(jax.random.PRNGKey(0))[0]
        feats, targets, w = replay_sample(self.recorder.buffer, k,
                                          self.batch_size)
        self._step_fn(self._back, self._opt, feats, targets, w)

    def step(self) -> Optional[float]:
        """One drain/train/publish cycle; returns the batch loss, or None
        when the ring is still empty (nothing to learn from yet)."""
        self.recorder.drain(max_chunks=self.drain_chunks_per_step)
        buf = self.recorder.buffer
        if int(buf.size) == 0:
            return None
        self._key, k = jax.random.split(self._key)
        feats, targets, w = replay_sample(buf, k, self.batch_size)
        # the gradient step runs entirely against the back buffer; the
        # serving path keeps scoring from whatever front pointer it last
        # read — no lock, no stall
        self._back, self._opt, loss, _ = self._step_fn(
            self._back, self._opt, feats, targets, w)
        self.daemon.set_params(self._back)   # the atomic pointer flip
        self.steps += 1
        self.swaps += 1
        self.last_loss = float(loss)
        return self.last_loss

    def start(self) -> None:
        """Spawn the background refresh thread (idempotent)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                t0 = self._clock()
                self.step()
                lag = self.min_interval_s - (self._clock() - t0)
                if lag > 0:
                    self._stop.wait(lag)
                else:
                    time.sleep(0)            # yield to the serving thread

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="online-refresher")
        self._thread.start()

    def stop(self) -> None:
        """Stop and join the refresh thread (no-op when not running)."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
