"""Two-stage hierarchical sharded fleet scoring.

The paper's schedulers score every candidate node per decision; a single
device caps that at a few thousand nodes.  This module scales the *fleet*
axis the way the training engine scaled seed×env (``launch/mesh.py``):

  1. **Shard** — the fleet's node columns split into ``layout.shards``
     contiguous slices of ``layout.shard_size`` (``launch.mesh.FleetLayout``,
     planned by ``plan_fleet_layout``), optionally pinned to a 1-D
     ``("data",)`` device mesh with sharding constraints so each device holds
     only its own slice.
  2. **Per-shard top-k, in-kernel** — each shard runs the fused scoring
     dispatch with the k8s filtering phase *and* a top-k reduction inside the
     kernel (``ops.sdqn_topk_afterstate`` / ``ops.sdqn_topk_delta``), so only
     ``k`` (score, global-index) candidates per shard ever leave it.
     Non-fusable policy classes reduce their shard-local ``score_set``
     output with ``lax.top_k`` instead — same candidate contract.
  3. **Global merge** — one tiny top-k over the ``shards × k`` candidates.
     Ties break to the lowest global index at every stage (the
     first-occurrence ``jnp.argmax`` rule), so the merged winner is exactly
     the flat masked argmax.

No full N-length score vector ever materializes on one device.  Padding to
``shards * shard_size`` uses infeasible filler (``healthy=False``, unit
capacities), so padded lanes score ``-inf`` and can never win.

Two semantics caveats, both pinned in tests/test_fleet_shard.py:

  * ``env.pull_cost_now`` is a GLOBAL reduction over in-flight startup
    transients — it is computed once from the full fleet here and threaded
    into every per-shard call as a scalar, keeping shard-local scores
    identical to the unsharded program.
  * the "attention" policy class mixes context over the node *set*, so under
    sharding it becomes block-local attention over each shard's nodes — an
    approximation by construction.  Pointwise classes ("mlp", "mamba") and
    the default Table-4 net are exact.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, env as kenv, schedulers
from repro.core.types import NO_PLACEMENT, ClusterState
from repro.kernels import ops
from repro.launch.mesh import FleetLayout, plan_fleet_layout
from repro.sched import placement as _pl

__all__ = [
    "FleetLayout", "cluster_topk", "fleet_topk", "plan_fleet_layout",
    "resolve_layout", "select_candidates", "shard_cluster", "shard_fleet",
    "sharded_scores",
]

# per-column pad fill for ClusterState: unit capacities keep padded lanes'
# arithmetic finite; healthy defaults to 0 (False) which makes them
# infeasible, so they mask to -inf before any reduction sees them
_CLUSTER_PAD = {"cpu_capacity": 1, "mem_capacity": 1, "max_pods": 1}

# |Q| beyond this is a diverged net, not a preference (sched.api's limit;
# re-declared here to keep this module importable without the api surface)
_DIVERGENCE_LIMIT = 1e6


def resolve_layout(shard, n_nodes: int, mesh=None) -> Optional[FleetLayout]:
    """Map the public ``shard=`` knob onto a :class:`FleetLayout`.

    ``"auto"`` plans one shard per visible device (``None`` on a single
    device — the bit-identical fallback); ``False``/``None`` disables
    sharding; an ``int`` forces that shard count on the current device set
    (single-device two-stage execution: same reduction tree, one device —
    the benchmarking/test path); a ``FleetLayout`` passes through.
    """
    if shard is None or shard is False:
        return None
    if isinstance(shard, FleetLayout):
        return shard if shard.shards > 1 else None
    if shard == "auto":
        if mesh is None:
            devs = jax.devices()
            if len(devs) <= 1:
                return None
            mesh = jax.sharding.Mesh(np.array(devs), ("data",))
        return plan_fleet_layout(n_nodes, mesh)
    if isinstance(shard, int) and not isinstance(shard, bool):
        return plan_fleet_layout(n_nodes, mesh, shards=shard)
    raise ValueError(f"shard must be 'auto', False, an int shard count or a "
                     f"FleetLayout; got {shard!r}")


def _pad_reshape(col, layout: FleetLayout, fill=0):
    pad = layout.padded - col.shape[0]
    if pad:
        col = jnp.pad(col, (0, pad), constant_values=fill)
    col = col.reshape(layout.shards, layout.shard_size)
    if layout.mesh is not None:
        col = jax.lax.with_sharding_constraint(
            col, jax.sharding.NamedSharding(
                layout.mesh, jax.sharding.PartitionSpec("data", None)))
    return col


def shard_cluster(state: ClusterState, layout: FleetLayout) -> ClusterState:
    """Pad each (N,) column with infeasible filler and view it as
    (shards, shard_size); scalar fields (``time_s``) pass through.  Accepts
    already-padded columns (the daemon's sharded snapshot) unchanged."""
    return type(state)(*[
        _pad_reshape(c, layout, _CLUSTER_PAD.get(name, 0))
        if getattr(c, "ndim", 0) == 1 else c
        for name, c in zip(state._fields, state)])


def shard_fleet(fleet: _pl.FleetState, layout: FleetLayout) -> _pl.FleetState:
    """FleetState analogue of :func:`shard_cluster` (all-zero filler:
    ``healthy == 0`` makes padded hosts infeasible)."""
    return type(fleet)(*[_pad_reshape(c, layout)
                         if getattr(c, "ndim", 0) == 1 else c
                         for c in fleet])


def _shard_axes(tree):
    """vmap ``in_axes`` over the shard axis: 0 for sharded columns, None for
    scalar fields."""
    return type(tree)(*[0 if getattr(c, "ndim", 0) >= 2 else None
                        for c in tree])


def _global_index(vals, local_idx, layout: FleetLayout):
    """(S, k) shard-local indices -> global node indices (−1 on dead slots)."""
    offs = (jnp.arange(layout.shards, dtype=jnp.int32)
            * layout.shard_size)[:, None]
    return jnp.where(jnp.isfinite(vals), local_idx + offs, -1)


def _merge(vals, gidx):
    """Merge the (S, k) candidate sets: full descending sort of the tiny
    flattened list.  ``lax.top_k`` keeps ties in ascending flat position ==
    ascending global index (shards cover ascending index ranges, per-shard
    candidates are emitted lowest-index-first), preserving first-occurrence
    argmax semantics end to end."""
    flat_v, flat_i = vals.reshape(-1), gidx.reshape(-1)
    top_v, pos = jax.lax.top_k(flat_v, flat_v.shape[0])
    return top_v, flat_i[pos]


def cluster_topk(params: dict, state: ClusterState, pod, cfg, layout: FleetLayout,
                 *, k: int = 4, fused="auto", score_fn=None, policy=None,
                 embed=None, heuristic: bool = False, pull_cost=None):
    """Two-stage feasible top-k over a ClusterState fleet.

    Returns ``(values, indices)`` of length ``shards * k``, sorted
    descending (ties by ascending node index): element 0 is exactly
    ``masked_argmax`` of the flat program.  Infeasible/exhausted slots carry
    ``-inf`` / index ``-1``.  ``heuristic=True`` scores with the closed-form
    kube formula instead of the Q-net (the degraded-mode arm — same
    two-stage shape, so the fallback also never gathers the fleet).
    """
    k = max(1, min(k, layout.shard_size))
    if pull_cost is None:
        pull_cost = kenv.pull_cost_now(state, cfg)
    st = shard_cluster(state, layout)
    fusable = score_fn is None and (policy is None or policy.fused_kernel)
    use_fused = not heuristic and fusable and (
        fused in (True, "interpret")
        or (fused == "auto"
            and layout.shard_size >= schedulers.FUSED_SCORE_MIN_NODES))

    def one_shard(sub):
        if heuristic:
            q = baselines.kube_scores(sub, pod, cfg)
        elif use_fused:
            mode = "interpret" if fused == "interpret" else None
            return ops.sdqn_topk_afterstate(sub, pod, cfg, params, k=k,
                                            mode=mode, pull_cost=pull_cost)
        else:
            q = schedulers.score_afterstates(params, sub, pod, cfg,
                                             score_fn=score_fn, fused=fused,
                                             policy=policy, embed=embed,
                                             pull_cost=pull_cost)
        ok = kenv.feasible(sub, pod, cfg)
        return jax.lax.top_k(jnp.where(ok, q, -jnp.inf), k)

    vals, lidx = jax.vmap(one_shard, in_axes=(_shard_axes(st),))(st)
    return _merge(vals, _global_index(vals, lidx, layout))


def fleet_topk(params: dict, fleet: _pl.FleetState, job, layout: FleetLayout,
               *, k: int = 4, fused="auto", policy=None, embed=None,
               heuristic: bool = False, max_host_cpu_pct: float = 88.0,
               delta=None):
    """Two-stage feasible top-k over a FleetState fleet (job→host placement).

    Same contract as :func:`cluster_topk`; feasibility is
    ``PlacementEngine.feasible`` (healthy + post-delta cpu/mem/job-util
    ceilings), run in-kernel on the fused path.  ``delta`` overrides
    ``job_delta(job)`` with a pre-packed (6,) afterstate delta row (the
    daemon's batched path, where ``job`` may be a tracer-free placeholder).
    """
    from repro.sched.api import _fleet_mode, heuristic_score

    k = max(1, min(k, layout.shard_size))
    if delta is None:
        delta = _pl.job_delta(job)
    ceilings = (max_host_cpu_pct, 95.0, 100.0 + 1e-6)
    ft = shard_fleet(fleet, layout)
    fused_path = not heuristic and (policy is None or policy.fused_kernel)

    def feasible(sub):
        return ((sub.healthy > 0.5)
                & (sub.cpu_pct + delta[0] <= ceilings[0])
                & (sub.mem_pct + delta[1] <= ceilings[1])
                & (sub.job_util_pct + delta[2] <= ceilings[2]))

    def one_shard(sub):
        if fused_path:
            return ops.sdqn_topk_delta(_pl.fleet_cols(sub), delta, params,
                                       k=k, mode=_fleet_mode(fused),
                                       ceilings=ceilings)
        if heuristic:
            q = heuristic_score(sub, job)
        else:
            feats = (jnp.stack(_pl.fleet_cols(sub), axis=-1)
                     + delta[None, :]) / kenv.FEATURE_SCALE
            if embed is not None:
                feats = jnp.concatenate(
                    [feats,
                     jnp.broadcast_to(embed, feats.shape[:-1] + embed.shape)],
                    axis=-1)
            q = policy.score_set(params, feats)
        return jax.lax.top_k(jnp.where(feasible(sub), q, -jnp.inf), k)

    vals, lidx = jax.vmap(one_shard)(ft)
    return _merge(vals, _global_index(vals, lidx, layout))


def topk(fleet, pod, *, params: dict, cfg=None, layout: FleetLayout,
         k: int = 4, fused="auto", score_fn=None, policy=None, embed=None,
         heuristic: bool = False):
    """Substrate-dispatching wrapper (mirrors ``sched.api.score``'s rules)."""
    if isinstance(fleet, ClusterState):
        if cfg is None:
            raise ValueError("cfg (EnvConfig) is required to score a "
                             "ClusterState fleet")
        return cluster_topk(params, fleet, pod, cfg, layout, k=k, fused=fused,
                            score_fn=score_fn, policy=policy, embed=embed,
                            heuristic=heuristic)
    if isinstance(fleet, _pl.FleetState):
        if score_fn is not None:
            raise ValueError("score_fn is not supported on the FleetState "
                             "column-kernel path")
        return fleet_topk(params, fleet, pod, layout, k=k, fused=fused,
                          policy=policy, embed=embed, heuristic=heuristic)
    raise TypeError(f"unsupported fleet type: {type(fleet).__name__}")


def candidates_valid(vals: jnp.ndarray) -> jnp.ndarray:
    """Scalar bool: no NaN and every *finite* candidate inside the
    divergence limit.  ``-inf`` marks infeasible slots — legitimate here,
    unlike in ``api.scores_valid`` which sees unmasked scores."""
    finite = jnp.isfinite(vals)
    bounded = jnp.where(finite, jnp.abs(vals), 0.0) <= _DIVERGENCE_LIMIT
    return jnp.all(bounded) & ~jnp.any(jnp.isnan(vals))


def select_candidates(fleet, pod, *, params: dict, cfg=None,
                      layout: FleetLayout, k: int = 4, fused="auto",
                      score_fn=None, policy=None, embed=None,
                      guard: bool = False):
    """Greedy selection via the two-stage path: the merged candidate winner,
    or ``NO_PLACEMENT`` when every candidate is infeasible.

    ``guard=True`` mirrors ``api.select``'s degraded mode: NaN/diverged
    candidates swap the WHOLE candidate list for the kube-heuristic list
    (computed through the same two-stage shape — still no fleet gather).
    """
    vals, idx = topk(fleet, pod, params=params, cfg=cfg, layout=layout, k=k,
                     fused=fused, score_fn=score_fn, policy=policy,
                     embed=embed)
    if guard:
        hvals, hidx = topk(fleet, pod, params=params, cfg=cfg, layout=layout,
                           k=k, fused=fused, score_fn=None, policy=None,
                           heuristic=True)
        valid = candidates_valid(vals)
        vals = jnp.where(valid, vals, hvals)
        idx = jnp.where(valid, idx, hidx)
    choice = jnp.where(jnp.isfinite(vals[0]), idx[0], NO_PLACEMENT)
    return choice.astype(jnp.int32)


def sharded_scores(fleet, pod, *, params: dict, cfg=None,
                   layout: FleetLayout, fused="auto", score_fn=None,
                   policy=None, embed=None) -> jnp.ndarray:
    """The (N,) score vector, computed shard-by-shard.

    The vector is *logically* full-length (``api.score``'s contract) but
    physically distributed when the layout carries a mesh: each device
    computes and holds only its own ``shard_size`` slice.  On a single
    device this is plain chunked evaluation — bit-identical to the flat
    program for pointwise scorers.
    """
    if isinstance(fleet, ClusterState):
        if cfg is None:
            raise ValueError("cfg (EnvConfig) is required to score a "
                             "ClusterState fleet")
        pull = kenv.pull_cost_now(fleet, cfg)
        st = shard_cluster(fleet, layout)
        q = jax.vmap(
            lambda sub: schedulers.score_afterstates(
                params, sub, pod, cfg, score_fn=score_fn, fused=fused,
                policy=policy, embed=embed, pull_cost=pull),
            in_axes=(_shard_axes(st),))(st)
        n = fleet.n_nodes
    elif isinstance(fleet, _pl.FleetState):
        from repro.sched import api as _api

        ft = shard_fleet(fleet, layout)
        q = jax.vmap(lambda sub: _api._score_raw(sub, pod, params=params,
                                                 fused=fused, policy=policy,
                                                 embed=embed))(ft)
        n = fleet.cpu_pct.shape[0]
    else:
        raise TypeError(f"unsupported fleet type: {type(fleet).__name__}")
    if layout.mesh is not None:
        q = jax.lax.with_sharding_constraint(
            q, jax.sharding.NamedSharding(
                layout.mesh, jax.sharding.PartitionSpec("data", None)))
    return q.reshape(-1)[:n]
