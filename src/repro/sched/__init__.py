from repro.sched.placement import FleetState, PlacementEngine, JobSpec  # noqa: F401
from repro.sched.elastic import consolidation_plan  # noqa: F401
from repro.sched.straggler import StragglerMonitor  # noqa: F401
