from repro.sched.placement import FleetState, PlacementEngine, JobSpec, NO_HOST  # noqa: F401
from repro.sched.elastic import consolidation_plan  # noqa: F401
from repro.sched.straggler import StragglerMonitor  # noqa: F401
from repro.sched import api  # noqa: F401  (the unified public scheduling API)
from repro.sched.api import NO_PLACEMENT  # noqa: F401
from repro.sched.daemon import (  # noqa: F401
    ClusterSubstrate,
    DaemonConfig,
    FleetSubstrate,
    PlacementDaemon,
    replay_trace,
)
from repro.sched.online import (  # noqa: F401
    FleetTransitionRecorder,
    OnlineRefresher,
    TransitionRecorder,
)
from repro.sched.topsis import make_topsis_selector, topsis_scores  # noqa: F401
