"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.

GQA, 128k vocab, RoPE theta 500000. [arXiv:2407.21783; unverified]
"""
from repro.configs.base import ModelConfig, register, smoke_reduce


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        head_dim=128,
        rope_theta=500000.0,
    )


def smoke() -> ModelConfig:
    return smoke_reduce(full(), num_kv_heads=2)


register("llama3-405b", full, smoke)
