"""olmo-1b [dense] — 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.

Non-parametric LayerNorm, SwiGLU, no biases, tied embeddings.
[arXiv:2402.00838; hf]
"""
from repro.configs.base import ModelConfig, register, smoke_reduce


def full() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        norm="layernorm_np",
        act="silu",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return smoke_reduce(full())


register("olmo-1b", full, smoke)
