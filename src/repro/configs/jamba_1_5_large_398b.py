"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576,
vocab=65536, MoE 16 experts top-2, Mamba:attention 7:1 interleave
(one attention layer per period of 8, MoE every other layer).

[arXiv:2403.19887; hf]
"""
from repro.configs.base import ModelConfig, register, smoke_reduce


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        head_dim=128,
        moe_num_experts=16,
        moe_top_k=2,
        moe_d_ff=24576,
        moe_every=2,
        attn_period=8,
        attn_offset=4,       # attention sits mid-period (jamba places it at layer 4 of 8)
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
    )


def smoke() -> ModelConfig:
    return smoke_reduce(full(), num_kv_heads=2)


register("jamba-1.5-large-398b", full, smoke)
