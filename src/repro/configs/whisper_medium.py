"""whisper-medium [audio] — enc-dec, 24L encoder + 24L decoder, d_model=1024,
16H, d_ff=4096, vocab=51865 (padded to 51968 for TP divisibility).

Conv audio frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (1500 frames) to the encoder.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig, register, smoke_reduce


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        head_dim=64,
        is_encoder_decoder=True,
        enc_layers=24,
        enc_seq=1500,
        norm="layernorm",
        act="gelu",
        use_bias=True,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return smoke_reduce(full())


register("whisper-medium", full, smoke)
