"""falcon-mamba-7b [ssm] — 64L d_model=4096 attention-free, vocab=65024,
ssm_state=16, mamba-1 blocks (expand=2 -> d_inner=8192, conv=4, dt_rank=256).

[arXiv:2410.05355; unverified]
"""
from repro.configs.base import ModelConfig, register, smoke_reduce


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=65024,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return smoke_reduce(full(), num_heads=0, num_kv_heads=0, d_ff=0)


register("falcon-mamba-7b", full, smoke)
