"""Architecture + shape configuration system.

Every assigned architecture registers a ``ModelConfig`` here (exact published
hyper-parameters) plus a reduced ``smoke`` variant used by CPU tests.  Configs
are selected by id via ``get_config("--arch" id)``; shapes via ``SHAPES``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Unified LM-family model configuration.

    Families: dense | moe | ssm | hybrid | vlm | audio (enc-dec).
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int            # query heads (0 => attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 => d_model // num_heads

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0         # per-expert FFN width (0 => d_ff)
    moe_shared_d_ff: int = 0  # shared-expert FFN width (qwen2-moe)
    moe_every: int = 1        # apply MoE every k-th layer (jamba: 2)
    moe_capacity_factor: float = 1.25

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0      # 0 => ceil(d_model / 16)

    # --- hybrid (jamba): one attention layer per `attn_period`, rest mamba ---
    attn_period: int = 0      # 0 => pure family; jamba: 8
    attn_offset: int = 0      # index of the attention layer within a period

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500       # whisper audio frames after conv frontend (stub)

    # --- VLM (internvl): vision patch embeddings spliced into the prefix ---
    num_vision_tokens: int = 0

    # --- misc architecture knobs ---
    norm: str = "rmsnorm"     # rmsnorm | layernorm | layernorm_np (non-parametric)
    act: str = "silu"         # silu (SwiGLU) | gelu (plain MLP)
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    vocab_pad_to: int = 128   # pad vocab for TP divisibility

    # --- performance knobs (§Perf hillclimbing) ---
    causal_buckets: int = 1     # >1: bucketed lower-triangle attention
    moe_dispatch: str = "batched"  # "batched" (per-row, shard-local) | "global"
    cache_dtype: str = "bfloat16"  # KV-cache storage ("float8_e4m3fn" halves traffic)

    # --- numerics / memory policy ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: str = "full"       # none | dots | full
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        if self.ssm_dt_rank:
            return self.ssm_dt_rank
        return max(1, (self.d_model + 15) // 16)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can decode at 500k context (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        d, v = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        total = v * d  # embed
        if not self.tie_embeddings:
            total += d * v

        def attn_params() -> int:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o

        def mlp_params(ff: int) -> int:
            if self.act == "silu":
                return 3 * d * ff
            return 2 * d * ff

        def moe_params() -> int:
            e_ff = self.moe_d_ff or self.d_ff
            p = d * self.moe_num_experts  # router
            p += self.moe_num_experts * mlp_params(e_ff)
            if self.moe_shared_d_ff:
                p += mlp_params(self.moe_shared_d_ff) + d  # + shared gate
            return p

        def mamba_params() -> int:
            di, n, r = self.d_inner, self.ssm_state, self.dt_rank
            p = d * 2 * di              # in_proj
            p += di * self.ssm_conv     # depthwise conv
            p += di * (r + 2 * n)       # x_proj -> dt, B, C
            p += r * di + di            # dt_proj
            p += di * n + di            # A_log, D
            p += di * d                 # out_proj
            return p

        for layer in range(self.num_layers):
            total += 2 * d  # norms (approximate; np-norm contributes 0 but keep simple)
            if self.family == "ssm":
                total += mamba_params()
                continue
            is_attn = True
            if self.attn_period:
                is_attn = layer % self.attn_period == self.attn_offset
            total += attn_params() if is_attn else mamba_params()
            use_moe = self.moe_num_experts and (layer % self.moe_every == self.moe_every - 1)
            total += moe_params() if use_moe else mlp_params(self.d_ff)

        if self.is_encoder_decoder:
            for _ in range(self.enc_layers):
                total += attn_params() + mlp_params(self.d_ff) + 2 * d
            total += self.num_layers * (attn_params() + d)  # cross-attn + its norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.moe_num_experts:
            return self.param_count()
        full = self.param_count()
        e_ff = self.moe_d_ff or self.d_ff
        d = self.d_model
        per_expert = (3 if self.act == "silu" else 2) * d * e_ff
        n_moe_layers = sum(
            1
            for layer in range(self.num_layers)
            if layer % self.moe_every == self.moe_every - 1
        )
        inactive = n_moe_layers * (self.moe_num_experts - self.moe_top_k) * per_expert
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned (workload) input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[arch_id] = full
    _SMOKE_REGISTRY[arch_id] = smoke


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    reg = _SMOKE_REGISTRY if smoke else _REGISTRY
    if arch_id not in reg:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(reg)}")
    return reg[arch_id]()


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # Import every config module so registration side effects run.
    from repro.configs import (  # noqa: F401
        olmo_1b,
        llama3_405b,
        command_r_plus_104b,
        granite_8b,
        qwen2_moe_a2_7b,
        dbrx_132b,
        falcon_mamba_7b,
        internvl2_76b,
        jamba_1_5_large_398b,
        whisper_medium,
    )


def smoke_reduce(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Produce a tiny same-family variant for CPU smoke tests."""
    base = dict(
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        enc_layers=2 if cfg.is_encoder_decoder else 0,
        enc_seq=16 if cfg.is_encoder_decoder else cfg.enc_seq,
        num_vision_tokens=4 if cfg.num_vision_tokens else 0,
        remat="none",
    )
    if cfg.num_heads:
        base["num_heads"] = 4
        base["num_kv_heads"] = min(4, max(1, cfg.num_kv_heads * 4 // max(cfg.num_heads, 1)))
    if cfg.moe_num_experts:
        base["moe_num_experts"] = 4
        base["moe_top_k"] = min(2, cfg.moe_top_k)
        base["moe_d_ff"] = 32
        base["moe_shared_d_ff"] = 64 if cfg.moe_shared_d_ff else 0
        base["moe_every"] = min(cfg.moe_every, 2)
    if cfg.family in ("ssm", "hybrid"):
        base["ssm_state"] = min(cfg.ssm_state, 8) or 8
        base["ssm_dt_rank"] = 8
    if cfg.attn_period:
        base["attn_period"] = 2
        base["attn_offset"] = 1
        base["num_layers"] = 4
    base.update(overrides)
    return replace(cfg, **base)
