"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408/expert
vocab=151936, MoE 60 experts top-4 + 4 shared experts (shared width 4x1408=5632).

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import ModelConfig, register, smoke_reduce


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,           # routed-expert FFN width
        vocab_size=151936,
        moe_num_experts=60,
        moe_top_k=4,
        moe_d_ff=1408,
        moe_shared_d_ff=5632,  # 4 shared experts fused into one wide MLP
        use_bias=True,          # qwen uses attention QKV biases
    )


def smoke() -> ModelConfig:
    return smoke_reduce(full())


register("qwen2-moe-a2.7b", full, smoke)
