"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.

GQA, no biases, tied embeddings (Cohere uses tied input/output embeddings).
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig, register, smoke_reduce


def full() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        num_layers=64,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=33792,
        vocab_size=256000,
        head_dim=128,
        use_bias=False,
        tie_embeddings=True,
        norm="layernorm",
    )


def smoke() -> ModelConfig:
    return smoke_reduce(full(), num_kv_heads=2)


register("command-r-plus-104b", full, smoke)
