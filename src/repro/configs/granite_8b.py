"""granite-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.

Llama-style code model. [arXiv:2405.04324; hf]
"""
from repro.configs.base import ModelConfig, register, smoke_reduce


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        head_dim=128,
    )


def smoke() -> ModelConfig:
    return smoke_reduce(full(), num_kv_heads=1)


register("granite-8b", full, smoke)
