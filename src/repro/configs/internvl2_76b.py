"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

InternViT frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed patch embeddings (256 vision tokens) spliced into the prefix of
the token stream; the backbone is the (Llama-3-70B-style) language model.
[arXiv:2404.16821; unverified]
"""
from repro.configs.base import ModelConfig, register, smoke_reduce


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        head_dim=128,
        num_vision_tokens=256,
        rope_theta=500000.0,
    )


def smoke() -> ModelConfig:
    return smoke_reduce(full(), num_kv_heads=2)


register("internvl2-76b", full, smoke)
