from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_archs,
    shape_applicable,
)
