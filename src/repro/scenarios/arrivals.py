"""Arrival-stream adapter: scenario pod tables -> daemon request traces.

The scenario engine's arrival processes (burst / Poisson / diurnal, sampled
by ``env.sample_pod_table``) drive episode *simulations*.  The placement
daemon (``sched.daemon``) serves the same streams in *wall-clock* time: this
module converts a sampled ``PodTable`` into an ``ArrivalTrace`` — absolute
arrival offsets plus per-request ``PodSpec``s — optionally rescaled to a
target offered rate, ready for ``daemon.replay_trace`` and the
``placement_serve`` benchmark.

    trace = arrival_trace(key, cfg, n_pods=500, rate_per_s=2000.0)
    replay_trace(daemon, trace.t_s, trace.pods)

Traces are reproducible: same key + config + n_pods = same trace (the pod
table sampling is the exact episode-stream code path).
"""
from __future__ import annotations

from typing import List, NamedTuple

import jax
import numpy as np

from repro.core import env as kenv
from repro.core.types import EnvConfig, PodSpec

__all__ = ["ArrivalTrace", "arrival_trace", "trace_from_table"]


class ArrivalTrace(NamedTuple):
    """A serving request trace: request i arrives ``t_s[i]`` seconds after
    the trace starts and asks to place ``pods[i]``."""

    t_s: np.ndarray          # (n,) float64, non-decreasing, t_s[0] == 0
    pods: List[PodSpec]      # n scalar PodSpecs (python floats)

    @property
    def offered_rate_per_s(self) -> float:
        """Mean offered arrival rate over the trace (requests/sec)."""
        span = float(self.t_s[-1]) if len(self.t_s) > 1 else 0.0
        return float(len(self.t_s) - 1) / span if span > 0 else float("inf")


def trace_from_table(table, rate_per_s: float | None = None) -> ArrivalTrace:
    """Turn a sampled ``PodTable`` into an ``ArrivalTrace``.

    Inter-arrival gaps become absolute offsets (first arrival at t=0 — the
    leading gap is episode lead-in, not serving latency).  ``rate_per_s``
    rescales the time axis to that mean offered rate, preserving the arrival
    process's *shape* (burstiness, diurnal modulation) while sweeping load —
    how the placement_serve bench produces its offered-rate curve.
    """
    dt = np.asarray(table.dt_s, np.float64)
    t = np.cumsum(dt) - float(dt[0])
    if rate_per_s is not None:
        if rate_per_s <= 0:
            raise ValueError("rate_per_s must be positive")
        span = float(t[-1])
        if span > 0:
            t = t * ((len(t) - 1) / (span * rate_per_s))
        else:  # pure burst: spread at exactly the offered rate
            t = np.arange(len(t), dtype=np.float64) / rate_per_s
    specs = jax.tree.map(np.asarray, table.specs)
    pods = [
        PodSpec(cpu_request=float(specs.cpu_request[i]),
                cpu_demand=float(specs.cpu_demand[i]),
                mem_request=float(specs.mem_request[i]),
                mem_demand=float(specs.mem_demand[i]))
        for i in range(len(t))
    ]
    return ArrivalTrace(t_s=t, pods=pods)


def arrival_trace(key: jax.Array, cfg: EnvConfig, n_pods: int,
                  rate_per_s: float | None = None) -> ArrivalTrace:
    """Sample a scenario arrival stream as a daemon request trace.

    Uses the exact episode-stream sampler (``env.sample_pod_table``), so the
    daemon serves the same workload mixture + arrival process the scenario
    engine simulates; ``rate_per_s`` rescales to a target offered rate.
    """
    return trace_from_table(kenv.sample_pod_table(key, cfg, n_pods),
                            rate_per_s=rate_per_s)
