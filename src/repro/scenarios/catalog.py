"""Reusable scenario building blocks: node classes and pod types.

Numbers are in the environment's native units (millicores / MiB) and sized
against the paper's 4-vCPU slaves so the homogeneous paper cluster is just
one more entry in the catalog.
"""
from __future__ import annotations

from repro.core.types import NodeClass, PodType

# ---------------------------------------------------------------------------
# node classes
# ---------------------------------------------------------------------------

PAPER_SLAVE = NodeClass(
    name="paper-slave", count=4, cpu_capacity=4000.0, mem_capacity=16384.0,
    base_cpu_frac=(0.02, 0.2), requested_frac=(0.05, 0.8),
)

BIG_CPU = NodeClass(
    name="big-cpu", count=2, cpu_capacity=16000.0, mem_capacity=65536.0,
    max_pods=250, base_cpu_frac=(0.02, 0.12), requested_frac=(0.05, 0.4),
)

SMALL_EDGE = NodeClass(
    name="small-edge", count=6, cpu_capacity=2000.0, mem_capacity=4096.0,
    max_pods=30, base_cpu_frac=(0.05, 0.3), requested_frac=(0.1, 0.6),
)

MEM_HEAVY = NodeClass(
    name="mem-heavy", count=4, cpu_capacity=8000.0, mem_capacity=131072.0,
    max_pods=150, base_cpu_frac=(0.02, 0.15), requested_frac=(0.05, 0.45),
)

SPOT = NodeClass(
    name="spot", count=6, cpu_capacity=4000.0, mem_capacity=16384.0,
    unhealthy_prob=0.25, base_cpu_frac=(0.01, 0.1), requested_frac=(0.0, 0.3),
)

WARM_POOL = NodeClass(
    name="warm-pool", count=4, cpu_capacity=4000.0, mem_capacity=16384.0,
    image_cached_prob=1.0, base_cpu_frac=(0.02, 0.2), requested_frac=(0.05, 0.5),
)

NODE_CLASSES = {
    c.name: c
    for c in (PAPER_SLAVE, BIG_CPU, SMALL_EDGE, MEM_HEAVY, SPOT, WARM_POOL)
}

# ---------------------------------------------------------------------------
# pod types
# ---------------------------------------------------------------------------

# the paper's compute-intensive no-op burner (requests >> burns)
NOOP_PAPER = PodType(
    name="noop-paper", weight=1.0,
    cpu_request=140.0, cpu_demand=20.0, mem_request=128.0, mem_demand=100.0,
)

# training replica: big request, burns close to it, memory-hungry
TRAIN_HEAVY = PodType(
    name="train-heavy", weight=1.0,
    cpu_request=900.0, cpu_demand=780.0, mem_request=2048.0, mem_demand=1800.0,
)

# serving replica: small request, mostly idle between requests
SERVE_LIGHT = PodType(
    name="serve-light", weight=1.0,
    cpu_request=120.0, cpu_demand=60.0, mem_request=256.0, mem_demand=180.0,
)

# batch job: burns MORE than it requests (the classic noisy neighbour)
BATCH_BURST = PodType(
    name="batch-burst", weight=1.0,
    cpu_request=400.0, cpu_demand=520.0, mem_request=512.0, mem_demand=420.0,
)

# in-memory cache shard: negligible CPU, giant working set
MEM_CACHE = PodType(
    name="mem-cache", weight=1.0,
    cpu_request=100.0, cpu_demand=40.0, mem_request=4096.0, mem_demand=3900.0,
)

POD_TYPES = {
    p.name: p
    for p in (NOOP_PAPER, TRAIN_HEAVY, SERVE_LIGHT, BATCH_BURST, MEM_CACHE)
}


def weighted(pod: PodType, weight: float) -> PodType:
    """Catalog pod type with a scenario-specific mixture weight."""
    import dataclasses

    return dataclasses.replace(pod, weight=weight)
