"""Reusable scenario building blocks: node classes and pod types.

Numbers are in the environment's native units (millicores / MiB) and sized
against the paper's 4-vCPU slaves so the homogeneous paper cluster is just
one more entry in the catalog.
"""
from __future__ import annotations

from repro.core.types import NodeClass, PodType

# ---------------------------------------------------------------------------
# node classes
# ---------------------------------------------------------------------------

PAPER_SLAVE = NodeClass(
    name="paper-slave", count=4, cpu_capacity=4000.0, mem_capacity=16384.0,
    base_cpu_frac=(0.02, 0.2), requested_frac=(0.05, 0.8),
)

BIG_CPU = NodeClass(
    name="big-cpu", count=2, cpu_capacity=16000.0, mem_capacity=65536.0,
    max_pods=250, base_cpu_frac=(0.02, 0.12), requested_frac=(0.05, 0.4),
)

SMALL_EDGE = NodeClass(
    name="small-edge", count=6, cpu_capacity=2000.0, mem_capacity=4096.0,
    max_pods=30, base_cpu_frac=(0.05, 0.3), requested_frac=(0.1, 0.6),
)

MEM_HEAVY = NodeClass(
    name="mem-heavy", count=4, cpu_capacity=8000.0, mem_capacity=131072.0,
    max_pods=150, base_cpu_frac=(0.02, 0.15), requested_frac=(0.05, 0.45),
)

SPOT = NodeClass(
    name="spot", count=6, cpu_capacity=4000.0, mem_capacity=16384.0,
    unhealthy_prob=0.25, base_cpu_frac=(0.01, 0.1), requested_frac=(0.0, 0.3),
)

WARM_POOL = NodeClass(
    name="warm-pool", count=4, cpu_capacity=4000.0, mem_capacity=16384.0,
    image_cached_prob=1.0, base_cpu_frac=(0.02, 0.2), requested_frac=(0.05, 0.5),
)

# preemptible capacity that FAILS MID-EPISODE (finite MTBF): on average one
# outage every ~5 minutes of episode time, back in ~1 minute.  Pods on a dead
# node are evicted and re-enter the arrival stream — see env.run_episode.
PREEMPTIBLE = NodeClass(
    name="preemptible", count=6, cpu_capacity=4000.0, mem_capacity=16384.0,
    mtbf_s=300.0, mttr_s=60.0,
    base_cpu_frac=(0.01, 0.1), requested_frac=(0.0, 0.3),
)

# spot capacity that both starts flaky (unhealthy_prob) AND keeps flapping
# mid-episode — the harshest node class in the catalog.
SPOT_CHAOS = NodeClass(
    name="spot-chaos", count=6, cpu_capacity=4000.0, mem_capacity=16384.0,
    unhealthy_prob=0.15, mtbf_s=180.0, mttr_s=90.0,
    base_cpu_frac=(0.01, 0.1), requested_frac=(0.0, 0.3),
)

NODE_CLASSES = {
    c.name: c
    for c in (PAPER_SLAVE, BIG_CPU, SMALL_EDGE, MEM_HEAVY, SPOT, WARM_POOL,
              PREEMPTIBLE, SPOT_CHAOS)
}

# ---------------------------------------------------------------------------
# pod types
# ---------------------------------------------------------------------------

# the paper's compute-intensive no-op burner (requests >> burns)
NOOP_PAPER = PodType(
    name="noop-paper", weight=1.0,
    cpu_request=140.0, cpu_demand=20.0, mem_request=128.0, mem_demand=100.0,
)

# training replica: big request, burns close to it, memory-hungry
TRAIN_HEAVY = PodType(
    name="train-heavy", weight=1.0,
    cpu_request=900.0, cpu_demand=780.0, mem_request=2048.0, mem_demand=1800.0,
)

# serving replica: small request, mostly idle between requests
SERVE_LIGHT = PodType(
    name="serve-light", weight=1.0,
    cpu_request=120.0, cpu_demand=60.0, mem_request=256.0, mem_demand=180.0,
)

# batch job: burns MORE than it requests (the classic noisy neighbour)
BATCH_BURST = PodType(
    name="batch-burst", weight=1.0,
    cpu_request=400.0, cpu_demand=520.0, mem_request=512.0, mem_demand=420.0,
)

# in-memory cache shard: negligible CPU, giant working set
MEM_CACHE = PodType(
    name="mem-cache", weight=1.0,
    cpu_request=100.0, cpu_demand=40.0, mem_request=4096.0, mem_demand=3900.0,
)

# ---------------------------------------------------------------------------
# finite-lifetime pod types (churn / consolidation scenarios).  Durations are
# lognormal (mean, cv) — see env._sample_lifetimes; the catalog entries above
# keep the default lifetime of inf (they never finish), which is exactly the
# paper's static-burst experiment.
# ---------------------------------------------------------------------------

# short CI-style job: arrives in waves, burns hard, gone in under a minute
SHORT_JOB = PodType(
    name="short-job", weight=1.0,
    cpu_request=300.0, cpu_demand=350.0, mem_request=384.0, mem_demand=300.0,
    lifetime_mean_s=45.0, lifetime_cv=0.4,
)

# long-running training replica: outlives the episode's arrival wave but
# does finish — draining its node is worth planning for
LONG_TRAIN = PodType(
    name="long-train", weight=1.0,
    cpu_request=900.0, cpu_demand=780.0, mem_request=2048.0, mem_demand=1800.0,
    lifetime_mean_s=600.0, lifetime_cv=0.25,
)

# autoscaled serving replica: scaled up for a traffic wave, reaped after it
SERVE_CHURN = PodType(
    name="serve-churn", weight=1.0,
    cpu_request=120.0, cpu_demand=60.0, mem_request=256.0, mem_demand=180.0,
    lifetime_mean_s=90.0, lifetime_cv=0.6,
)

# medium-lived batch shard with a heavy straggler tail (cv ~ 1): a few
# stragglers pin otherwise-idle nodes — the consolidation pass's bread and
# butter
BATCH_STRAGGLER = PodType(
    name="batch-straggler", weight=1.0,
    cpu_request=250.0, cpu_demand=220.0, mem_request=512.0, mem_demand=400.0,
    lifetime_mean_s=150.0, lifetime_cv=1.0,
)

POD_TYPES = {
    p.name: p
    for p in (NOOP_PAPER, TRAIN_HEAVY, SERVE_LIGHT, BATCH_BURST, MEM_CACHE,
              SHORT_JOB, LONG_TRAIN, SERVE_CHURN, BATCH_STRAGGLER)
}


def weighted(pod: PodType, weight: float) -> PodType:
    """Catalog pod type with a scenario-specific mixture weight."""
    import dataclasses

    return dataclasses.replace(pod, weight=weight)


def with_lifetime(pod: PodType, mean_s: float, cv: float = 0.3) -> PodType:
    """Catalog pod type with a scenario-specific duration distribution."""
    import dataclasses

    return dataclasses.replace(pod, lifetime_mean_s=mean_s, lifetime_cv=cv)
