"""Named scenario registry.

Every entry is a fully declarative ``ScenarioConfig`` runnable via

    PYTHONPATH=src python -m benchmarks.run --scenario <name>

and convertible to an ``EnvConfig`` with ``make_env(name)``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.types import ArrivalConfig, EnvConfig, ScenarioConfig, scenario_env
from repro.scenarios import catalog as cat

_c = dataclasses.replace  # shrink a node class / retune a pod type in place


SCENARIOS: Dict[str, ScenarioConfig] = {}


def _register(scn: ScenarioConfig) -> ScenarioConfig:
    SCENARIOS[scn.name] = scn
    return scn


# 1. the paper's experiment, expressed as a scenario: homogeneous 4-slave
#    pool, 50 identical no-op pods arriving as a fixed burst.
PAPER_BURST = _register(ScenarioConfig(
    name="paper-burst",
    node_classes=(cat.PAPER_SLAVE,),
    pod_types=(cat.NOOP_PAPER,),
    arrival=ArrivalConfig(kind="burst"),
    n_pods=50,
))

# 2. big/small CPU split: two 16-core crunchers next to six 2-core edge
#    boxes; a mixed stream where train-heavy pods only really fit the big
#    nodes while serve-light pods fit anywhere.
HETERO_BIGSMALL = _register(ScenarioConfig(
    name="hetero-bigsmall",
    node_classes=(cat.BIG_CPU, cat.SMALL_EDGE),
    pod_types=(cat.weighted(cat.TRAIN_HEAVY, 0.25), cat.weighted(cat.SERVE_LIGHT, 0.75)),
    arrival=ArrivalConfig(kind="burst"),
    n_pods=60,
))

# 3. train/serve mixture on a mixed pool under a Poisson stream (the
#    AGMARL-DKS-style heterogeneous evaluation).
TRAIN_SERVE_MIX = _register(ScenarioConfig(
    name="train-serve-mix",
    node_classes=(cat.BIG_CPU, cat.PAPER_SLAVE),
    pod_types=(cat.weighted(cat.TRAIN_HEAVY, 0.3), cat.weighted(cat.SERVE_LIGHT, 0.7)),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=0.5),
    n_pods=60,
))

# 4. memory pressure: cache shards whose working sets dwarf their CPU needs,
#    on a pool where only half the nodes are memory-heavy.
MEMORY_PRESSURE = _register(ScenarioConfig(
    name="memory-pressure",
    node_classes=(cat.MEM_HEAVY, cat.PAPER_SLAVE),
    pod_types=(cat.weighted(cat.MEM_CACHE, 0.5), cat.weighted(cat.SERVE_LIGHT, 0.5)),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=0.4),
    n_pods=50,
))

# 5. flaky spot pool: a quarter of the spot nodes come up NotReady, so the
#    filtering phase actually bites; batch pods burn above their requests.
SPOT_FLAKY = _register(ScenarioConfig(
    name="spot-flaky",
    node_classes=(cat.SPOT, _c(cat.PAPER_SLAVE, count=2)),
    pod_types=(cat.weighted(cat.BATCH_BURST, 0.6), cat.weighted(cat.NOOP_PAPER, 0.4)),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=0.6),
    n_pods=50,
))

# 6. diurnal serving wave: warm image pool, light pods, arrival rate swinging
#    sinusoidally over a 20-minute "day".
DIURNAL_SERVE = _register(ScenarioConfig(
    name="diurnal-serve",
    node_classes=(cat.WARM_POOL, cat.PAPER_SLAVE),
    pod_types=(cat.SERVE_LIGHT,),
    arrival=ArrivalConfig(kind="diurnal", rate_per_s=0.5, period_s=1200.0, depth=0.8),
    n_pods=80,
))

# 7. batch storm: a dense Poisson burst of over-burning batch jobs onto big
#    nodes plus unreliable spot capacity.
BATCH_STORM = _register(ScenarioConfig(
    name="batch-storm",
    node_classes=(_c(cat.BIG_CPU, count=4), _c(cat.SPOT, count=4)),
    pod_types=(cat.BATCH_BURST,),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=1.5),
    n_pods=80,
))

# --- churn scenarios (finite pod lifetimes: the consolidation/energy story
# is only measurable when pods finish and release their nodes) --------------

# 9. short-job burst: a CI-style wave of sub-minute jobs on a widened paper
#    pool.  The arrival wave saturates the pool, then the whole wave dies —
#    nodes_active must fall back toward zero through the settle window.
SHORT_JOB_BURST = _register(ScenarioConfig(
    name="short-job-burst",
    node_classes=(_c(cat.PAPER_SLAVE, count=8),),
    pod_types=(cat.SHORT_JOB,),
    arrival=ArrivalConfig(kind="burst"),
    n_pods=60,
    settle_steps=60,
))

# 10. long-running training mix: training replicas that outlive the arrival
#     wave next to quickly-reaped serving churn, on a big/small pool.
LONGRUN_TRAIN_MIX = _register(ScenarioConfig(
    name="longrun-train-mix",
    node_classes=(cat.BIG_CPU, cat.PAPER_SLAVE),
    pod_types=(cat.weighted(cat.LONG_TRAIN, 0.3), cat.weighted(cat.SERVE_CHURN, 0.7)),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=0.5),
    n_pods=60,
    settle_steps=60,
))

# 11. diurnal churn: autoscaled serving replicas arriving on a daily wave and
#     being reaped ~90s later — load rises and falls, nodes empty in the
#     trough.
DIURNAL_CHURN = _register(ScenarioConfig(
    name="diurnal-churn",
    node_classes=(cat.WARM_POOL, cat.PAPER_SLAVE),
    pod_types=(cat.SERVE_CHURN,),
    arrival=ArrivalConfig(kind="diurnal", rate_per_s=0.8, period_s=600.0, depth=0.9),
    n_pods=100,
    settle_steps=45,
))

# 12. consolidation stress: medium-lived batch shards with a heavy straggler
#     tail (cv ~ 1) on a wide pool — a few stragglers pin otherwise-idle
#     nodes, exactly what the in-episode SDQN-n consolidation pass drains.
CONSOLIDATION_STRESS = _register(ScenarioConfig(
    name="consolidation-stress",
    node_classes=(_c(cat.PAPER_SLAVE, count=10),),
    pod_types=(cat.weighted(cat.BATCH_STRAGGLER, 0.7), cat.weighted(cat.SHORT_JOB, 0.3)),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=0.6),
    n_pods=80,
    settle_steps=75,
))

# --- chaos scenarios (finite MTBF: nodes fail MID-EPISODE, their pods are
# evicted and re-enter the arrival stream — see env.sample_failure_trace) ---

# 13. preemptible churn: autoscaled serving replicas on a pool where most
#     capacity is preemptible — placements must survive evictions, and the
#     reschedule ring is exercised continuously.
PREEMPTIBLE_FLAKY = _register(ScenarioConfig(
    name="preemptible-flaky",
    node_classes=(cat.PREEMPTIBLE, _c(cat.PAPER_SLAVE, count=2)),
    pod_types=(cat.SERVE_CHURN,),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=0.6),
    n_pods=60,
    settle_steps=45,
))

# 14. batch jobs on chaos-grade spot: over-burning batch shards on nodes
#     that both start NotReady and keep flapping — eviction storms hit
#     mid-wave, so where the scheduler parks the survivors matters.
BATCH_FLAKY = _register(ScenarioConfig(
    name="batch-flaky",
    node_classes=(cat.SPOT_CHAOS, _c(cat.BIG_CPU, count=1)),
    pod_types=(cat.weighted(cat.BATCH_STRAGGLER, 0.6), cat.weighted(cat.SHORT_JOB, 0.4)),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=0.7),
    n_pods=60,
    settle_steps=60,
))

# 15. mixed train/serve under light chaos: long training replicas (the
#     expensive thing to lose) next to serving churn, with a preemptible
#     slice of the pool — the policy should learn to keep the long jobs off
#     the flaky capacity.
TRAIN_FLAKY = _register(ScenarioConfig(
    name="train-flaky",
    node_classes=(cat.BIG_CPU, _c(cat.PREEMPTIBLE, count=4)),
    pod_types=(cat.weighted(cat.LONG_TRAIN, 0.3), cat.weighted(cat.SERVE_CHURN, 0.7)),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=0.5),
    n_pods=60,
    settle_steps=60,
))

# 8. fleet-scale heterogeneous pool for the scaling benchmarks.
FLEET_HETERO = _register(ScenarioConfig(
    name="fleet-hetero",
    node_classes=(
        _c(cat.BIG_CPU, count=256),
        _c(cat.PAPER_SLAVE, count=512),
        _c(cat.SMALL_EDGE, count=256),
    ),
    pod_types=(
        cat.weighted(cat.TRAIN_HEAVY, 0.2),
        cat.weighted(cat.SERVE_LIGHT, 0.6),
        cat.weighted(cat.BATCH_BURST, 0.2),
    ),
    arrival=ArrivalConfig(kind="poisson", rate_per_s=5.0),
    n_pods=200,
))

# --- cluster-of-clusters family (16–18): N identical 4096-node regional
# clusters federated into one scheduling domain, 4k → 128k nodes.  These
# exist to exercise the two-stage hierarchical sharded scoring path
# (``sched.shard``) — an episode rollout at 128k nodes is not the point, so
# the pod stream is small and the scoring benchmarks (benchmarks/
# fleet_scale.py) drive them per-decision.  They are registered like any
# scenario (make_env works) but excluded from the episode-sweep benches via
# SCORING_ONLY. -------------------------------------------------------------

_COC_CLUSTER = (          # one 4096-node regional cluster
    _c(cat.BIG_CPU, count=512),
    _c(cat.PAPER_SLAVE, count=2048),
    _c(cat.SMALL_EDGE, count=1536),
)


def _cluster_of_clusters(n_clusters: int, label: str) -> ScenarioConfig:
    return ScenarioConfig(
        name=f"cluster-of-clusters-{label}",
        node_classes=tuple(
            _c(nc, name=f"coc{i}-{nc.name}")
            for i in range(n_clusters) for nc in _COC_CLUSTER),
        pod_types=(
            cat.weighted(cat.TRAIN_HEAVY, 0.2),
            cat.weighted(cat.SERVE_LIGHT, 0.6),
            cat.weighted(cat.BATCH_BURST, 0.2),
        ),
        arrival=ArrivalConfig(kind="poisson", rate_per_s=5.0),
        n_pods=32,
    )


COC_4K = _register(_cluster_of_clusters(1, "4k"))
COC_16K = _register(_cluster_of_clusters(4, "16k"))
COC_64K = _register(_cluster_of_clusters(16, "64k"))
COC_128K = _register(_cluster_of_clusters(32, "128k"))

# scenarios meant for per-decision scoring benches, not episode sweeps:
# scenario_bench.sweep/smoke_rows skip them (episode physics at 10^5 nodes
# adds nothing the 1k fleet-hetero rollout doesn't already cover)
SCORING_ONLY = frozenset(
    n for n in SCENARIOS if n.startswith("cluster-of-clusters-"))


def scenario_names() -> List[str]:
    return sorted(SCENARIOS)


def get_scenario(name: str) -> ScenarioConfig:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {', '.join(scenario_names())}"
        ) from None


def make_env(name: str, randomize: bool = False, **overrides) -> EnvConfig:
    """EnvConfig for a registry scenario (randomize=True for training resets)."""
    return scenario_env(get_scenario(name), randomize=randomize, **overrides)


def training_mixture(names=None) -> List[EnvConfig]:
    """The scenario mixture one Q-net trains across (domain-randomized resets).

    Defaults to ``presets.SCENARIO_MIX_NAMES`` so the mixture is defined in
    exactly one place (lazy import: presets pulls in the training stack).
    """
    if names is None:
        from repro.core.presets import SCENARIO_MIX_NAMES
        names = SCENARIO_MIX_NAMES
    return [make_env(n, randomize=True) for n in names]
