"""Scenario subsystem: declarative heterogeneous workloads for the scheduler.

A scenario = node pool (classes of machines) x pod catalog (workload mixture)
x arrival process.  ``registry`` holds the named scenarios the benchmarks and
tests run; ``catalog`` holds the reusable building blocks; ``engine`` turns a
scenario + policy into episode metrics.
"""
from repro.scenarios.arrivals import ArrivalTrace, arrival_trace, trace_from_table
from repro.scenarios.catalog import NODE_CLASSES, POD_TYPES
from repro.scenarios.engine import batch_episode, evaluate_scenario, scenario_episode
from repro.scenarios.registry import (
    SCENARIOS,
    SCORING_ONLY,
    get_scenario,
    make_env,
    scenario_names,
    training_mixture,
)

__all__ = [
    "NODE_CLASSES",
    "POD_TYPES",
    "SCENARIOS",
    "SCORING_ONLY",
    "ArrivalTrace",
    "arrival_trace",
    "trace_from_table",
    "batch_episode",
    "evaluate_scenario",
    "get_scenario",
    "make_env",
    "scenario_episode",
    "scenario_names",
    "training_mixture",
]
