"""Run scenarios against scheduler policies and collect episode metrics."""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.core import env as kenv
from repro.core.types import EnvConfig


def default_n_pods(env_cfg: EnvConfig, n_pods: Optional[int] = None) -> int:
    if n_pods is not None:
        return n_pods
    return env_cfg.scenario.n_pods if env_cfg.scenario is not None else 50


def scenario_episode(env_cfg: EnvConfig, select: Callable,
                     n_pods: Optional[int] = None) -> Callable:
    """Jitted ``key -> (final_state, distribution, metric)`` for one scenario."""
    n = default_n_pods(env_cfg, n_pods)
    return jax.jit(lambda k: kenv.run_episode(k, env_cfg, select, n))


def evaluate_scenario(
    key: jax.Array,
    env_cfg: EnvConfig,
    select: Callable,
    trials: int = 3,
    n_pods: Optional[int] = None,
    episode: Optional[Callable] = None,
) -> Dict[str, float]:
    """Average the paper's metric (cluster-average CPU%) over `trials` episodes.

    Pass a prebuilt (already warmed) ``episode`` fn to keep jit compilation
    out of a caller's timing window — each ``scenario_episode`` call returns
    a fresh closure, so re-calling it would recompile.
    """
    ep = episode if episode is not None else scenario_episode(env_cfg, select, n_pods)
    mets, placed = [], []
    for t in range(trials):
        state, _, met = ep(jax.random.fold_in(key, t))
        mets.append(float(met))
        placed.append(int(np.asarray(state.exp_pods).sum()))
    return {
        "metric_mean": float(np.mean(mets)),
        "metric_std": float(np.std(mets)),
        "pods_placed_mean": float(np.mean(placed)),
        "trials": float(trials),
        "n_pods": float(default_n_pods(env_cfg, n_pods)),
        "n_nodes": float(env_cfg.n_nodes),
    }
