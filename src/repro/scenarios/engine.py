"""Run scenarios against scheduler policies and collect episode metrics.

Trial evaluation is delegated to the batched eval engine
(``repro.eval.engine``): all trials of a (scenario, scheduler) cell run as
one vmapped, jitted XLA launch instead of a Python loop of dispatches.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax

from repro.core import env as kenv
from repro.core.types import EnvConfig
from repro.eval import engine as eval_engine


def default_n_pods(env_cfg: EnvConfig, n_pods: Optional[int] = None) -> int:
    if n_pods is not None:
        return n_pods
    return env_cfg.scenario.n_pods if env_cfg.scenario is not None else 50


def scenario_episode(env_cfg: EnvConfig, select: Callable,
                     n_pods: Optional[int] = None,
                     consolidate: Optional[Callable] = None) -> Callable:
    """Jitted ``key -> (final_state, distribution, metric, dropped, stats)``.

    ``stats`` is the ``EpisodeStats`` of time-resolved lifecycle metrics;
    ``consolidate`` threads the in-episode SDQN-n pass through.
    """
    n = default_n_pods(env_cfg, n_pods)
    return jax.jit(lambda k: kenv.run_episode(k, env_cfg, select, n,
                                              consolidate=consolidate))


def batch_episode(env_cfg: EnvConfig, select: Callable,
                  n_pods: Optional[int] = None,
                  consolidate: Optional[Callable] = None) -> Callable:
    """Jitted ``keys (T, ...) -> TrialResults`` — the batched trial runner."""
    return eval_engine.make_batch_episode(env_cfg, select, n_pods, consolidate)


def evaluate_scenario(
    key: jax.Array,
    env_cfg: EnvConfig,
    select: Callable,
    trials: int = 3,
    n_pods: Optional[int] = None,
    episode: Optional[Callable] = None,
) -> Dict[str, float]:
    """Average the paper's metric (cluster-average CPU%) over `trials` episodes.

    Pass a prebuilt (already warmed) ``episode`` fn — now the *batched*
    runner from ``batch_episode`` — to keep jit compilation out of a
    caller's timing window.  Per-trial keys are ``fold_in(key, t)``, the
    same ladder the old per-trial loop used.
    """
    out = eval_engine.evaluate(key, env_cfg, select, trials=trials,
                               n_pods=n_pods, batch=episode)
    return out
