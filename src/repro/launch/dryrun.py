import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell against ShapeDtypeStruct inputs, print memory/cost analysis, and dump
the roofline terms to JSON.

MUST be run as its own process (the XLA_FLAGS line above runs before any
other import, including jax, because jax locks the device count on first
init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import SHAPES, get_config, list_archs, shape_applicable  # noqa: E402
from repro.launch import shapes as shp  # noqa: E402
from repro.launch import sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.optim import adam_init  # noqa: E402
from repro.roofline import flops  # noqa: E402
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms  # noqa: E402

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


CFG_OVERRIDES: Dict[str, Any] = {}
MICRO_OVERRIDE: Dict[str, int] = {}
MESH_OVERRIDE = None
SEQ_SHARD = True
DECODE_RESHARD = False


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (jitted_fn, example_args) for one cell."""
    import dataclasses as _dc

    cfg = get_config(arch)
    if CFG_OVERRIDES:
        cfg = _dc.replace(cfg, **CFG_OVERRIDES)
    shape = SHAPES[shape_name]
    params_shape = shp.params_specs(cfg)
    p_specs = sharding.param_specs(params_shape, cfg, mesh)
    p_named = sharding.to_named(p_specs, mesh)

    if shape.kind == "train":
        batch = shp.train_batch_specs(cfg, shape)
        b_named = sharding.to_named(sharding.input_sharding(mesh, batch), mesh)
        adam_cfg = steps.default_adam(cfg)
        opt_shape = jax.eval_shape(lambda p: adam_init(p, adam_cfg), params_shape)
        o_specs = sharding.opt_state_specs(opt_shape, p_specs, mesh)
        o_named = sharding.to_named(o_specs, mesh)
        nm = MICRO_OVERRIDE.get(arch) or steps.num_microbatches(arch, shape.global_batch)
        act = (sharding.activation_spec(mesh, shape.global_batch // nm, shape.seq_len)
               if SEQ_SHARD else None)
        fn, _ = steps.make_train_step(cfg, adam_cfg, num_microbatches=nm,
                                      q_chunk=min(512, shape.seq_len),
                                      act_sharding=act)
        jitted = jax.jit(fn, in_shardings=(p_named, o_named, b_named),
                         donate_argnums=(0, 1))
        return jitted, (params_shape, opt_shape, batch)

    if shape.kind == "prefill":
        batch = shp.prefill_batch_specs(cfg, shape)
        b_named = sharding.to_named(sharding.input_sharding(mesh, batch), mesh)
        act = (sharding.activation_spec(mesh, shape.global_batch, shape.seq_len)
               if SEQ_SHARD else None)
        fn = steps.make_prefill_step(cfg, q_chunk=min(256, shape.seq_len),
                                     act_sharding=act)
        jitted = jax.jit(fn, in_shardings=(p_named, b_named))
        return jitted, (params_shape, batch)

    # decode
    tokens, cache_shape, index = shp.decode_specs(cfg, SHAPES[shape_name])
    t_named = sharding.to_named(sharding.input_sharding(mesh, tokens), mesh)
    c_specs = sharding.cache_specs(cache_shape, cfg, mesh, shape.global_batch)
    c_named = sharding.to_named(c_specs, mesh)
    from jax.sharding import PartitionSpec as _P

    if DECODE_RESHARD:
        bax = sharding.batch_axis(mesh, shape.global_batch)
        fn = steps.make_decode_step(cfg, act_sharding=_P(bax, None, None),
                                    mlp_sharding=_P(None, None, None))
    else:
        fn = steps.make_decode_step(cfg)
    jitted = jax.jit(fn, in_shardings=(p_named, t_named, c_named, None),
                     donate_argnums=(2,))
    return jitted, (params_shape, tokens, cache_shape, index)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str) -> Dict[str, Any]:
    import dataclasses as _dc

    cfg = get_config(arch)
    if CFG_OVERRIDES:
        cfg = _dc.replace(cfg, **CFG_OVERRIDES)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        cell["status"] = "skipped"
        cell["reason"] = why
        print(f"[{arch} × {shape_name} × {mesh_name}] SKIP: {why}")
        return cell

    t0 = time.time()
    mesh = MESH_OVERRIDE() if MESH_OVERRIDE else make_production_mesh(multi_pod=multi_pod)
    with mesh:
        jitted, args = build_cell(arch, shape_name, mesh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                if hasattr(ma, k):
                    mem[k] = int(getattr(ma, k))
        except Exception as e:  # noqa: BLE001
            mem["error"] = str(e)

        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            cost = {k: float(v) for k, v in ca.items()
                    if k in ("flops", "bytes accessed", "transcendentals",
                             "optimal_seconds")
                    or k.startswith("bytes accessed")}
        except Exception as e:  # noqa: BLE001
            cost["error"] = str(e)

        coll = {}
        try:
            hlo = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo)
        except Exception as e:  # noqa: BLE001
            coll = {"error": str(e), "total_bytes": 0}

    n_chips = int(np.prod(list(mesh.shape.values())))
    analytic = flops.cell_flops(cfg, shape, remat_full=cfg.remat == "full")
    nm = steps.num_microbatches(arch, shape.global_batch) if shape.kind == "train" else 1
    hbm = flops.cell_hbm_bytes(cfg, shape, n_chips, num_microbatches=nm,
                               tp=mesh.shape["model"])
    cell.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem,
        cost=cost,
        collectives=coll,
        model_params=cfg.param_count(),
        active_params=cfg.active_param_count(),
        tokens=shape.global_batch * (1 if shape.is_decode else shape.seq_len),
        kind=shape.kind,
        num_microbatches=nm,
        analytic_hbm_bytes_per_chip=hbm,
    )
    hbm_used = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0) - mem.get("alias_size_in_bytes", 0))
    cell["hbm_bytes_per_chip"] = int(hbm_used)
    cell["fits_hbm_16g"] = bool(hbm_used <= 16 * 2**30)
    cell["roofline"] = roofline_terms(
        n_chips=n_chips,
        hlo_flops_global=analytic["hlo_flops"],
        model_flops=analytic["model_flops"],
        hbm_bytes_per_chip=hbm,
        collective_bytes_per_chip=float(coll.get("total_bytes", 0) or 0),
    )
    per_dev_gb = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)) / 2**30
    print(f"[{arch} × {shape_name} × {mesh_name}] OK lower={t_lower:.0f}s "
          f"compile={t_compile:.0f}s mem/dev={per_dev_gb:.2f}GiB fits16G={cell['fits_hbm_16g']} "
          f"coll={coll.get('total_bytes', 0):.3g}B "
          f"dominant={cell['roofline'].get('dominant')} "
          f"frac={cell['roofline'].get('roofline_fraction', 0):.2f}")
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="", choices=["", "none", "dots", "full"])
    ap.add_argument("--causal-buckets", type=int, default=0)
    ap.add_argument("--moe-dispatch", default="", choices=["", "global", "batched"])
    ap.add_argument("--mesh-shape", default="", help='e.g. "2,128" for a (data,model) override')
    ap.add_argument("--micro", type=int, default=0, help="microbatch-count override")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--cache-dtype", default="")
    ap.add_argument("--decode-reshard", action="store_true")
    args = ap.parse_args()

    global MESH_OVERRIDE, SEQ_SHARD, DECODE_RESHARD
    if args.no_seq_shard:
        SEQ_SHARD = False
    if args.decode_reshard:
        DECODE_RESHARD = True
    if args.remat:
        CFG_OVERRIDES["remat"] = args.remat
    if args.causal_buckets:
        CFG_OVERRIDES["causal_buckets"] = args.causal_buckets
    if args.moe_dispatch:
        CFG_OVERRIDES["moe_dispatch"] = args.moe_dispatch
    if args.cache_dtype:
        CFG_OVERRIDES["cache_dtype"] = args.cache_dtype
    if args.mesh_shape:
        dims = tuple(int(x) for x in args.mesh_shape.split(","))
        MESH_OVERRIDE = lambda: jax.make_mesh(dims, ("data", "model"))  # noqa: E731
    if args.micro:
        for a in list_archs():
            MICRO_OVERRIDE[a] = args.micro

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                try:
                    cell = run_cell(arch, shape_name, multi_pod, args.out)
                except Exception as e:  # noqa: BLE001
                    cell = {"arch": arch, "shape": shape_name,
                            "mesh": "2x16x16" if multi_pod else "16x16",
                            "status": "failed", "error": str(e)}
                    print(f"[{arch} × {shape_name}] FAILED: {e}")
                    traceback.print_exc()
                results.append(cell)
                mesh_tag = cell["mesh"].replace("x", "_")
                fname = f"{args.out}/{arch}_{shape_name}_{mesh_tag}.json"
                with open(fname, "w") as f:
                    json.dump(cell, f, indent=2, default=str)

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_fail = sum(1 for r in results if r["status"] == "failed")
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok, {n_skip} skipped (documented), {n_fail} failed")
    with open(f"{args.out}/summary.json", "w") as f:
        json.dump(results, f, indent=2, default=str)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
