"""``input_specs`` — ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation: the dry-run lowers
against these.  Modality frontends are stubs per the assignment: whisper
gets precomputed frame embeddings, internvl gets precomputed patch
embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as mdl


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((b, s), jnp.int32),
        "targets": _sds((b, s), jnp.int32),
        "loss_mask": _sds((b, s), jnp.float32),
    }
    if cfg.is_encoder_decoder:
        batch["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_vision_tokens:
        batch["patch_embeds"] = _sds((b, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    batch = train_batch_specs(cfg, shape)
    del batch["targets"], batch["loss_mask"]
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Dict[str, Any], Any, Any]:
    """(token specs, cache specs, index spec) for one decode step with a
    KV/SSM cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    tokens = {"tokens": _sds((b, 1), jnp.int32)}
    cache = jax.eval_shape(lambda: mdl.init_cache(cfg, b, s))
    index = _sds((), jnp.int32)
    return tokens, cache, index


def params_specs(cfg: ModelConfig) -> Any:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: mdl.init_params(k, cfg), key)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, kind: str = None) -> Dict[str, Any]:
    """The public entry: all model inputs for an (arch, shape) cell."""
    kind = kind or shape.kind
    if kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    tokens, cache, index = decode_specs(cfg, shape)
    return {"batch": tokens, "cache": cache, "index": index}
