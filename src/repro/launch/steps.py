"""Step builders: train (grad-accumulated + AdamW), prefill, decode.

These close over static config and are the units that ``dryrun.py`` lowers
for every (arch × shape × mesh) cell and that ``train.py`` / ``serve.py``
execute for real.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as mdl
from repro.optim import AdamConfig, adam_init, adam_update
from repro.optim.schedule import cosine_warmup


def default_adam(cfg: ModelConfig) -> AdamConfig:
    # moments in bf16 for the largest archs to bound optimizer memory
    big = cfg.param_count() > 60e9
    return AdamConfig(
        lr=3e-4,
        weight_decay=0.1,
        grad_clip_norm=1.0,
        moment_dtype="bfloat16" if big else "float32",
        master_dtype="" if big else "float32",
    )


def make_train_step(cfg: ModelConfig, adam_cfg: Optional[AdamConfig] = None,
                    num_microbatches: int = 1, q_chunk: int = 512,
                    mamba_chunk: int = 64, total_steps: int = 10000,
                    act_sharding=None):
    adam_cfg = adam_cfg or default_adam(cfg)
    schedule = cosine_warmup(adam_cfg.lr, 200, total_steps)

    def loss_fn(params, micro_batch):
        loss, metrics = mdl.loss_and_metrics(
            params, cfg, micro_batch, q_chunk=q_chunk, mamba_chunk=mamba_chunk,
            act_sharding=act_sharding,
        )
        return loss, metrics

    def train_step(params, opt_state, batch):
        if num_microbatches > 1:
            def split(x):
                b = x.shape[0]
                assert b % num_microbatches == 0, (b, num_microbatches)
                return x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def accumulate(acc, mb):
                (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, metrics

            grads, metrics = jax.lax.scan(accumulate, zeros, micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        params, opt_state, stats = adam_update(params, grads, opt_state, adam_cfg, schedule)
        metrics.update(stats)
        return params, opt_state, metrics

    return train_step, adam_cfg


def make_prefill_step(cfg: ModelConfig, q_chunk: int = 512, mamba_chunk: int = 64,
                      act_sharding=None):
    def prefill_step(params, batch):
        logits, cache = mdl.prefill(
            params, cfg, batch["tokens"], batch, q_chunk=q_chunk, mamba_chunk=mamba_chunk,
            act_sharding=act_sharding,
        )
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, q_chunk: int = 512, act_sharding=None,
                     mlp_sharding=None):
    def decode_step(params, batch, cache, index):
        logits, new_cache = mdl.decode_step(
            params, cfg, batch["tokens"], cache, index, q_chunk=q_chunk,
            act_sharding=act_sharding, mlp_sharding=mlp_sharding,
        )
        return logits, new_cache

    return decode_step


def init_train_state(key, cfg: ModelConfig, adam_cfg: Optional[AdamConfig] = None):
    adam_cfg = adam_cfg or default_adam(cfg)
    params = mdl.init_params(key, cfg)
    opt_state = adam_init(params, adam_cfg)
    return params, opt_state


# per-arch microbatch sizes for train_4k (bounds activation + MoE dispatch
# memory on the 256-chip mesh; global batch 256)
TRAIN_MICROBATCH: Dict[str, int] = {
    "olmo-1b": 256,
    "granite-8b": 128,
    "qwen2-moe-a2.7b": 64,
    "whisper-medium": 256,
    "falcon-mamba-7b": 64,
    "dbrx-132b": 32,
    "internvl2-76b": 32,
    "command-r-plus-104b": 16,
    "jamba-1.5-large-398b": 16,
    "llama3-405b": 16,
}


def num_microbatches(arch: str, global_batch: int) -> int:
    micro = TRAIN_MICROBATCH.get(arch, 32)
    return max(1, global_batch // micro)
