"""Production mesh construction and the seed×env training-layout planner.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16x16 = 256 chips ("data","model");
multi-pod: 2 pods x 256 = 512 chips ("pod","data","model") — the "pod" axis
carries only gradient all-reduce (DCN-economical DP across pods).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1-device mesh with the same axis names (CPU tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_train_mesh(n_data: int | None = None):
    """Data-only mesh over the local devices for Anakin-style RL training.

    The training engine shards seed/env batches over ``data`` and keeps the
    tiny Table-4 learner replicated, so ``model`` stays 1.  Defaults to all
    visible devices; on the 1-device CPU container this is the host mesh.
    """
    n = n_data if n_data is not None else len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# joint seed×env layout planning for the seed-parallel training engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SeedEnvLayout:
    """How ``train_seeds``'s (n_seeds, n_envs) batch maps onto devices.

    ``mesh`` is a 2-D ``("seed", "data")`` mesh over every device of the
    source mesh: the seed ladder shards over ``seed`` (``seed_shards``
    device groups, each holding whole training replicas) and, inside each
    group, the per-seed env batch shards over ``data`` (``env_shards``
    devices).  ``env_shards == 1`` degenerates to pure seed sharding — one
    flattened parallel axis — and ``seed_shards == 1`` to pure env sharding;
    both are just the 2-D layout with a trivial axis, so the engine runs one
    code path.  Hashable (meshes hash by device ids + axis names), so the
    layout can ride along as a jit static.
    """

    mesh: jax.sharding.Mesh
    seed_shards: int
    env_shards: int


def _split_seed_env(n_seeds: int, n_envs: int, n_dev: int) -> Optional[tuple]:
    """Factor ``n_dev = s * e`` with ``s | n_seeds`` and ``e | n_envs``,
    maximizing ``s`` (whole replicas per device are the cheapest layout:
    zero cross-device traffic until selection).  Returns ``None`` when the
    device count does not divide the total ``n_seeds * n_envs`` batch.

    Such a split always exists when ``n_seeds * n_envs % n_dev == 0``: for
    every prime power ``p^k`` of ``n_dev``, the seed axis takes
    ``min(k, multiplicity of p in n_seeds)`` factors and the env axis covers
    the remainder (which it can, since the product divides).
    """
    if n_dev <= 0 or (n_seeds * n_envs) % n_dev != 0:
        return None
    s, rem, p = 1, n_dev, 2
    while rem > 1:
        while rem % p == 0:
            if n_seeds % (s * p) == 0:
                s *= p
            rem //= p
        p += 1 if p == 2 else 2
    e = n_dev // s
    if n_envs % e != 0:  # unreachable when the product divides; kept as a guard
        return None
    return s, e


# ---------------------------------------------------------------------------
# fleet-axis layout planning for two-stage hierarchical sharded scoring
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetLayout:
    """How a fleet's N node columns map onto shards for two-stage scoring.

    The node axis splits into ``shards`` contiguous slices of ``shard_size``
    nodes (the last slice padded with infeasible filler up to
    ``padded = shards * shard_size``); each shard scores its own columns and
    reduces to a per-shard top-k in-kernel, and only the tiny
    ``shards × k`` candidate set is merged globally — no full N-length score
    vector ever materializes on one device (``sched.shard``).

    ``mesh`` is an optional 1-D ``("data",)`` device mesh: when present the
    shard axis is pinned to it with sharding constraints so each device
    holds ``shard_size`` node columns; when ``None`` the same two-stage
    program runs on one device (forced-shard benchmarking / tests — the
    reduction tree is identical, only the placement differs).  Hashable, so
    a layout can ride along as a jit static.
    """

    shards: int
    shard_size: int
    n_nodes: int
    mesh: Optional[jax.sharding.Mesh] = None

    @property
    def padded(self) -> int:
        return self.shards * self.shard_size


def plan_fleet_layout(n_nodes: int, mesh=None, *,
                      shards: Optional[int] = None) -> Optional[FleetLayout]:
    """Pick the node-column sharding for a two-stage scoring launch.

    ``shards`` forces an explicit shard count (any ``n_nodes``, padded to
    divisibility — the single-device benchmarking/test path).  Otherwise the
    plan follows ``mesh``: one shard per device of its flattened device set.
    Returns ``None`` — run today's unsharded program, bit-identically —
    when the result would be a single shard: no mesh and no forced count, a
    1-device mesh, or a fleet smaller than the device count.
    """
    if shards is not None:
        if shards <= 1 or n_nodes < shards:
            return None
        size = -(-n_nodes // shards)
        lmesh = None
        if mesh is not None and int(mesh.devices.size) == shards:
            lmesh = jax.sharding.Mesh(mesh.devices.reshape(shards), ("data",))
        return FleetLayout(shards=shards, shard_size=size, n_nodes=n_nodes,
                           mesh=lmesh)
    if mesh is None:
        return None
    n_dev = int(mesh.devices.size)
    if n_dev <= 1 or n_nodes < n_dev:
        return None
    lmesh = jax.sharding.Mesh(mesh.devices.reshape(n_dev), ("data",))
    return FleetLayout(shards=n_dev, shard_size=-(-n_nodes // n_dev),
                       n_nodes=n_nodes, mesh=lmesh)


def plan_seed_env_layout(n_seeds: int, n_envs: int, mesh) -> Optional[SeedEnvLayout]:
    """Pick the joint seed×env sharding for a ``train_seeds`` launch.

    Given the candidate count, the per-seed env batch and a device mesh,
    returns a :class:`SeedEnvLayout` whose 2-D ``("seed", "data")`` mesh
    keeps **all** devices busy whenever the device count divides
    ``n_seeds * n_envs`` — the case PR 3's seed-only sharding left on the table
    whenever ``n_seeds < n_devices`` (e.g. 2 seeds on a 4-device host ran on
    2 devices; the joint layout runs them as a (2, 2) grid).  ``None`` means
    run unsharded: no mesh, a single device, or an indivisible batch (the
    bit-compatible single-device fallback).
    """
    if mesh is None:
        return None
    n_dev = int(mesh.devices.size)
    if n_dev <= 1:
        return None
    split = _split_seed_env(n_seeds, n_envs, n_dev)
    if split is None:
        return None
    s, e = split
    lmesh = jax.sharding.Mesh(mesh.devices.reshape(s, e), ("seed", "data"))
    return SeedEnvLayout(mesh=lmesh, seed_shards=s, env_shards=e)
