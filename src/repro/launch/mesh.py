"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16x16 = 256 chips ("data","model");
multi-pod: 2 pods x 256 = 512 chips ("pod","data","model") — the "pod" axis
carries only gradient all-reduce (DCN-economical DP across pods).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1-device mesh with the same axis names (CPU tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_train_mesh(n_data: int | None = None):
    """Data-only mesh over the local devices for Anakin-style RL training.

    The training engine shards seed/env batches over ``data`` and keeps the
    tiny Table-4 learner replicated, so ``model`` stays 1.  Defaults to all
    visible devices; on the 1-device CPU container this is the host mesh.
    """
    n = n_data if n_data is not None else len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
