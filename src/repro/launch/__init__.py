"""Launch layer: production meshes, sharding rules, step builders, dry-run,
training and serving drivers."""
