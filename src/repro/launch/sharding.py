"""Sharding rules: parameter / input / cache PartitionSpecs for the
production meshes.

Scheme: TP ("model") × FSDP ("data") × optional DP ("pod", multi-pod).
  * up-projections  (L, In, Out): In over data (ZeRO-3 gather-on-use),
    Out over model (megatron column-parallel)
  * down-projections (L, In, Out): In over model (row-parallel), Out over data
  * embeddings: vocab over model (TP logits), d_model over data
  * MoE experts: expert dim over model when E % tp == 0 (EP), otherwise
    TP-within-expert on the FFN dim (qwen2-moe: 60 experts on a 16-way axis)
  * decode KV caches: sequence dim over model (XLA-level split-KV decoding),
    batch over data — batch-1 long-context shards S over data×model
  * norms/scalars: replicated
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes ("pod" folds into batch as outer DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def batch_axis(mesh: Mesh, global_batch: int):
    """Axis (or axes tuple) for the batch dim; None => replicated."""
    axes = batch_axes(mesh)
    if global_batch % _axes_size(mesh, axes) == 0:
        return axes
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return "data"
    return None


def param_spec(path: Tuple[str, ...], leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, keyed by its tree path."""
    name = path[-1]
    stacked = path[0] in ("layers", "encoder")  # leading num_blocks dim
    tp, fsdp = "model", "data"
    nd = leaf.ndim

    def maybe(dim_size: int, axis: Optional[str]) -> Optional[str]:
        return axis if axis and dim_size % mesh.shape[axis] == 0 else None

    if name == "embed":
        return P(maybe(leaf.shape[0], tp), maybe(leaf.shape[1], fsdp))
    if name == "lm_head":
        return P(maybe(leaf.shape[0], fsdp), maybe(leaf.shape[1], tp))

    # norm scales / biases / tiny vectors: replicate
    if nd - (1 if stacked else 0) <= 1:
        if stacked and nd == 2 and name in ("dt_bias", "conv_b", "D", "bq", "bk", "bv"):
            return P(None, maybe(leaf.shape[1], tp))
        return P()

    if nd == 4:  # MoE expert weights: (L, E, In, Out)
        _, e, d_in, d_out = leaf.shape
        if e % mesh.shape[tp] == 0:  # expert parallelism
            return P(None, tp, maybe(d_in, fsdp), None)
        # TP-within-expert (qwen2-moe): shard the FFN dim; keep In on FSDP
        # (replicating In was tested and REFUTED: the unsharded (E,C,*)
        # buffers all-reduce ~1 TiB/chip/step — see EXPERIMENTS.md §Perf)
        if name == "w_down":
            return P(None, None, maybe(d_in, tp), maybe(d_out, fsdp))
        return P(None, None, maybe(d_in, fsdp), maybe(d_out, tp))

    if nd == 3 and stacked:
        _, d_in, d_out = leaf.shape
        if name in ("w_down", "wo", "out_proj", "dt_proj"):
            return P(None, maybe(d_in, tp), maybe(d_out, fsdp))
        if name in ("router", "x_proj", "A_log", "shared_gate"):
            fst = tp if name in ("x_proj", "A_log") else fsdp
            return P(None, maybe(d_in, fst), None)
        if name == "conv_w":  # (L, cw, di)
            return P(None, None, maybe(d_out, tp))
        return P(None, maybe(d_in, fsdp), maybe(d_out, tp))

    if nd == 2:
        return P(maybe(leaf.shape[0], fsdp), maybe(leaf.shape[1], tp))
    return P()


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(p.key if hasattr(p, "key") else str(p) for p in path)


def param_specs(params_shape: Any, cfg: ModelConfig, mesh: Mesh):
    """Tree of PartitionSpecs matching a params (shape) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(_path_keys(path), leaf, cfg, mesh), params_shape
    )


def opt_state_specs(opt_shape: Any, p_specs: Any, mesh: Mesh):
    """Adam state: step replicated; m/v/master mirror the param specs."""
    out = {"step": P(), "m": p_specs, "v": p_specs}
    if "master" in opt_shape:
        out["master"] = p_specs
    return out


def input_sharding(mesh: Mesh, batch: dict):
    """Specs for a train/prefill batch dict: batch dim sharded, rest replicated."""
    gb = jax.tree.leaves(batch)[0].shape[0]
    b = batch_axis(mesh, gb)
    return {
        k: P(b, *([None] * (v.ndim - 1))) if v.ndim >= 1 else P()
        for k, v in batch.items()
    }


def cache_specs(cache_shape: Any, cfg: ModelConfig, mesh: Mesh, global_batch: int):
    """Decode-cache specs: KV sequence over model (split-KV), batch over data.

    For batch-1 long-context the sequence dim is sharded over data×model.
    """
    b_axis = batch_axis(mesh, global_batch)
    seq_ax: Any = ("data", "model") if b_axis is None else "model"
    if isinstance(seq_ax, tuple):
        seq_ax = tuple(a for a in seq_ax if a in mesh.axis_names) or "model"

    def visit(path, leaf):
        name = _path_keys(path)[-1]
        tp_ok = lambda d: "model" if d % mesh.shape["model"] == 0 else None  # noqa: E731
        if name in ("k", "v"):  # (nb, B, S, Hkv, hd)
            s = leaf.shape[2]
            ax = seq_ax if s % _axes_size(mesh, seq_ax) == 0 else None
            return P(None, b_axis, ax, None, None)
        if name in ("xk", "xv"):  # (nb, B, enc_seq, Hkv, hd)
            return P(None, b_axis, None, None, None)
        if name == "conv":  # (nb, B, cw-1, di)
            return P(None, b_axis, None, tp_ok(leaf.shape[3]))
        if name == "h":  # (nb, B, di, n)
            return P(None, b_axis, tp_ok(leaf.shape[2]), None)
        return P()

    return jax.tree_util.tree_map_with_path(visit, cache_shape)


def activation_spec(mesh: Mesh, micro_batch: int, seq_len: int) -> P:
    """Residual-stream constraint (B, S, D): batch over data(/pod), sequence
    over model (Megatron-style sequence parallelism between blocks — keeps
    the scan carry and saved activations 256-way sharded)."""
    b = batch_axis(mesh, micro_batch)
    s_ax = "model" if seq_len % mesh.shape["model"] == 0 else None
    return P(b, s_ax, None)


def to_named(tree_specs: Any, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
