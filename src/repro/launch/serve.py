"""Batched serving driver with SDQN request routing.

Serves a small LM with continuous batching: requests arrive in waves, the
SDQN placement *daemon* (the paper's scheduler as a continuously-serving
loop, ``repro.sched.daemon``) routes each request wave to one of several
model-server replicas based on replica load features — waves are submitted
as placement requests, batch-scored in one device launch, and bound with
optimistic concurrency — then each replica runs prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \\
        --replicas 4 --requests 64 --gen-tokens 16 \\
        --qnet-path runs/rl/ckpt     # repro.checkpoint dir (or legacy .npz)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as mdl
from repro.sched.daemon import DaemonConfig, FleetSubstrate, PlacementDaemon
from repro.sched.placement import JobSpec, fresh_fleet


def sample_requests(key, n, vocab, prompt_len):
    return jax.random.randint(key, (n, prompt_len), 0, vocab)


def load_policy(path: str, key: jax.Array, policy: str = "mlp"):
    """SDQN routing params + their policy class: ``(params, PolicySpec)``.

    ``path`` is a ``repro.checkpoint`` directory (the trainer's ``ckpt.save``
    layout, latest step), a legacy flat ``.npz`` (always the Table-4 MLP), or
    empty for a fresh init of ``policy``.  Checkpoint directories carry their
    policy class in the manifest (``core.policy.checkpoint_metadata``), so a
    single ``--qnet-path`` restores ANY registered variant; pre-registry
    checkpoints with no metadata fall back to ``policy``.
    """
    from repro.core import policy as policy_mod

    if not path:
        spec = policy_mod.get(policy)
        return spec.init(key), spec
    if path.endswith(".npz"):
        loaded = np.load(path)
        return ({k: jnp.asarray(loaded[k]) for k in loaded.files},
                policy_mod.get("mlp"))
    return policy_mod.restore_checkpoint(path, default_policy=policy)


def load_qnet(path: str, key: jax.Array) -> dict:
    """Legacy entry point: just the params (MLP default).  Prefer
    ``load_policy``, which also recovers the checkpoint's policy class."""
    params, _ = load_policy(path, key)
    return params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--wave-size", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--qnet-path", default="",
                    help="trained SDQN params: repro.checkpoint dir or legacy "
                         "npz; fresh init if empty")
    ap.add_argument("--policy", default="mlp",
                    help="policy class (core.policy registry) when --qnet-path "
                         "is empty or carries no policy metadata; checkpoint "
                         "metadata wins otherwise")
    ap.add_argument("--online", action="store_true",
                    help="close the loop: record every realized routing "
                         "decision (FleetTransitionRecorder) and fine-tune "
                         "the routing policy on the realized rewards "
                         "(OnlineRefresher; params hot-swap atomically at "
                         "batch-cut boundaries)")
    ap.add_argument("--online-steps", type=int, default=4,
                    help="refresh cycles to run after the routing burst "
                         "(with --online)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = mdl.init_params(key, cfg)

    max_len = args.prompt_len + args.gen_tokens

    @jax.jit
    def prefill_fn(p, tokens):
        logits, cache = mdl.prefill(p, cfg, tokens, {}, q_chunk=64)
        return logits, cache

    @jax.jit
    def decode_fn(p, tok, cache, idx):
        return mdl.decode_step(p, cfg, tok, cache, idx)

    # SDQN routing across replicas, served by the placement daemon: waves are
    # submitted as requests, batch-scored in one launch, optimistically bound
    qparams, qspec = load_policy(args.qnet_path, jax.random.fold_in(key, 1),
                                 policy=args.policy)
    fleet = fresh_fleet(args.replicas, jax.random.fold_in(key, 2))
    waves = args.requests // args.wave_size
    sub = FleetSubstrate(fleet, policy=qspec)
    recorder = None
    if args.online:
        from repro.core.types import FEATURE_DIM
        from repro.sched.online import FleetTransitionRecorder

        if qspec.feature_dim != FEATURE_DIM:
            raise SystemExit(
                f"--online needs a policy with the canonical afterstate "
                f"feature width ({FEATURE_DIM}); {qspec.name} trains on "
                f"{qspec.feature_dim}-wide rows")
        recorder = FleetTransitionRecorder(fleet)
    daemon = PlacementDaemon(
        sub, qparams,
        DaemonConfig(batch_size=max(min(waves, 8), 1), max_wait_s=0.0),
        decision_hook=recorder.record if recorder else None)
    daemon.warmup()
    job = JobSpec(cpu_pct_demand=100.0 / max(waves, 1), kind="serve")

    for _ in range(waves):
        daemon.submit(job)
    daemon.drain()
    assignments = [d.node for d in sorted(daemon.decisions)]

    if args.online:
        # after external churn (replica restarts, manual unbinds) the shadow
        # must be rebased first: recorder.resync(sub.live) — this burst is a
        # pure submit/bind trace, so a plain drain/train/publish cycle works
        from repro.sched.online import OnlineRefresher

        ref = OnlineRefresher(daemon, recorder, spec=qspec)
        ref.warmup()
        for _ in range(args.online_steps):
            ref.step()
        loss = "n/a" if ref.last_loss is None else f"{ref.last_loss:.4f}"
        print(f"[serve] online refresh: {recorder.drained} transitions "
              f"recorded, {ref.steps} refresh steps, {ref.swaps} param "
              f"swaps, last_loss={loss}")

    t0 = time.time()
    generated = 0
    for w, replica in enumerate(assignments):
        kw = jax.random.fold_in(key, 100 + w)
        prompts = sample_requests(kw, args.wave_size, cfg.vocab_size, args.prompt_len)
        logits, cache = prefill_fn(params, prompts)
        # pad the prefill cache out to max_len for decoding
        def pad(leaf):
            if leaf.ndim == 5 and leaf.shape[2] == args.prompt_len:  # (nb,B,S,H,hd)
                pad_width = [(0, 0)] * 5
                pad_width[2] = (0, args.gen_tokens)
                return jnp.pad(leaf, pad_width)
            return leaf
        cache = jax.tree.map(pad, cache)

        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens = [tok]
        for i in range(args.gen_tokens - 1):
            logits, cache = decode_fn(params, tok, cache, jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
        generated += args.wave_size * args.gen_tokens

    dt = time.time() - t0
    placed = [a for a in assignments if a >= 0]
    counts = np.bincount(np.asarray(placed, np.int64), minlength=args.replicas)
    print(f"[serve] {args.requests} requests, {generated} tokens in {dt:.1f}s "
          f"({generated / dt:.1f} tok/s)")
    print(f"[serve] SDQN routing ({qspec.name}) across replicas: "
          f"{counts.tolist()} "
          f"({daemon.metrics.batches} daemon batches, "
          f"{daemon.metrics.device_launches} scoring launches, "
          f"{daemon.metrics.conflicts} bind conflicts)")
    print(f"[serve] replica load (cpu%): "
          f"{np.round(np.asarray(sub.live.cpu_pct), 1).tolist()}")
    return counts


if __name__ == "__main__":
    main()
