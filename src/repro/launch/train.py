"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \\
        --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/run1

Features: sharded train step (pjit), gradient accumulation, cosine schedule,
async checkpointing with auto-resume, deterministic seek-able data, fault
simulation (--fail-at N exits mid-run; rerunning resumes from the last
checkpoint), step-time stats feeding the straggler monitor.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax

from repro.checkpoint import CheckpointManager, latest_step, restore
from repro.configs.base import get_config
from repro.data import DataConfig, make_loader
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.models import model as mdl
from repro.optim import adam_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=0, help="simulate a crash at step N")
    ap.add_argument("--d-model", type=int, default=0, help="override width (e.g. ~100M model)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    overrides = {}
    if args.d_model:
        overrides.update(d_model=args.d_model, head_dim=0)
    if args.layers:
        overrides["num_layers"] = args.layers
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh = make_host_mesh() if jax.device_count() == 1 else None
    adam_cfg = dataclasses.replace(steps.default_adam(cfg), lr=args.lr)
    train_step, _ = steps.make_train_step(
        cfg, adam_cfg, num_microbatches=args.microbatches,
        q_chunk=min(512, args.seq), total_steps=args.steps,
    )
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    key = jax.random.PRNGKey(args.seed)
    params = mdl.init_params(key, cfg)
    opt_state = adam_init(params, adam_cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))

    start_step = 0
    manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if manager is not None and latest_step(args.ckpt_dir) is not None:
        state_like = {"params": params, "opt": opt_state}
        restored = restore(args.ckpt_dir, state_like)
        params, opt_state = restored["params"], restored["opt"]
        start_step = latest_step(args.ckpt_dir) + 1
        print(f"[train] resumed from step {start_step - 1}")

    data_cfg = DataConfig(batch=args.batch, seq_len=args.seq,
                          vocab=cfg.vocab_size, seed=args.seed)
    loader = make_loader(data_cfg, model_cfg=cfg, start_step=start_step)

    print(f"[train] arch={cfg.name} params={n_params:,} steps={start_step}..{args.steps}")
    t_last, losses = time.time(), []
    for step, batch in zip(range(start_step, args.steps), loader):
        if args.fail_at and step == args.fail_at:
            print(f"[train] SIMULATED FAILURE at step {step}", flush=True)
            sys.exit(17)
        params, opt_state, metrics = jitted(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = (time.time() - t_last) / max(args.log_every, 1)
            t_last = time.time()
            print(f"  step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['accuracy']):.3f} gnorm={float(metrics['grad_norm']):.2f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms/step", flush=True)
        if manager is not None and step and step % args.ckpt_every == 0:
            manager.save_async(step, {"params": params, "opt": opt_state},
                               extra={"arch": cfg.name})
    if manager is not None:
        manager.save_async(args.steps - 1, {"params": params, "opt": opt_state},
                           extra={"arch": cfg.name})
        manager.wait()
    if len(losses) > 20:
        first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
        print(f"[train] loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
