"""Sharded data loading with background prefetch.

Two sources: the synthetic stream (default) and a memmapped token file
(`.bin` of uint16/uint32 tokens).  Each host loads only its slice of the
global batch (per-host sharding for multi-host deployments); a background
thread keeps a small prefetch queue full so step time never blocks on data.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import synthetic_batches


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq_len: int = 512
    vocab: int = 50304
    seed: int = 0
    token_file: Optional[str] = None
    token_dtype: str = "uint16"
    prefetch: int = 2
    host_index: int = 0
    host_count: int = 1


def _memmap_batches(cfg: DataConfig, start_step: int) -> Iterator[Dict[str, jnp.ndarray]]:
    data = np.memmap(cfg.token_file, dtype=np.dtype(cfg.token_dtype), mode="r")
    tokens_per_batch = cfg.batch * (cfg.seq_len + 1)
    n_batches = len(data) // tokens_per_batch
    rng = np.random.RandomState(cfg.seed)
    order = rng.permutation(n_batches)
    step = start_step
    while True:
        idx = order[step % n_batches]
        flat = np.asarray(data[idx * tokens_per_batch : (idx + 1) * tokens_per_batch])
        toks = flat.reshape(cfg.batch, cfg.seq_len + 1).astype(np.int32)
        yield {
            "tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:]),
            "loss_mask": jnp.ones((cfg.batch, cfg.seq_len), jnp.float32),
        }
        step += 1


def _host_slice(batch: Dict[str, jnp.ndarray], cfg: DataConfig) -> Dict[str, jnp.ndarray]:
    if cfg.host_count == 1:
        return batch
    per_host = batch["tokens"].shape[0] // cfg.host_count
    lo = cfg.host_index * per_host
    return jax.tree.map(lambda x: x[lo : lo + per_host], batch)


def make_loader(cfg: DataConfig, model_cfg=None, start_step: int = 0) -> Iterator[dict]:
    """Prefetching iterator over per-host training batches (seek-able)."""
    if cfg.token_file:
        source = _memmap_batches(cfg, start_step)
    else:
        source = synthetic_batches(cfg.seed, cfg.batch, cfg.seq_len, cfg.vocab,
                                   cfg=model_cfg, start_step=start_step)

    q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
    stop = threading.Event()

    def worker():
        try:
            for item in source:
                if stop.is_set():
                    return
                q.put(_host_slice(item, cfg))
        except BaseException as e:  # noqa: BLE001 - surface errors to the consumer
            q.put(e)

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            item = q.get()
            if isinstance(item, BaseException):
                raise item
            return item

        def close(self):
            stop.set()

    return _Iter()
