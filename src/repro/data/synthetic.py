"""Deterministic synthetic LM data.

A counter-based generator (stateless, seek-able by step index) producing a
structured pseudo-language: Zipfian unigrams + a Markov back-off so that the
loss actually decreases during the example training runs (pure-uniform
tokens give no learnable signal).
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp


def synthetic_lm_tokens(key: jax.Array, batch: int, seq_len: int, vocab: int) -> jnp.ndarray:
    """Zipf-Markov token stream: t_{i+1} = f(t_i) with noise."""
    k1, k2, k3 = jax.random.split(key, 3)
    v_eff = min(vocab, 32768)
    # zipfian initial tokens
    u = jax.random.uniform(k1, (batch,))
    first = (v_eff * (jnp.exp(u * jnp.log(1.0 + v_eff)) - 1.0) / v_eff).astype(jnp.int32) % v_eff

    # deterministic "grammar": one fixed affine map (a learnable 1-gram
    # transition table) + occasional resample for stochasticity
    noise = jax.random.uniform(k3, (batch, seq_len))

    def step(tok, i):
        nxt = (tok * 37 + 11) % v_eff
        resample = noise[:, i] < 0.15
        rnd = (tok * 17 + i) % v_eff
        tok = jnp.where(resample, rnd, nxt).astype(jnp.int32)
        return tok, tok

    _, toks = jax.lax.scan(step, first, jnp.arange(seq_len))
    return toks.T  # (batch, seq_len)


def synthetic_batches(
    seed: int, batch: int, seq_len: int, vocab: int, cfg=None, start_step: int = 0
) -> Iterator[Dict[str, jnp.ndarray]]:
    """Infinite iterator of train batches; seek-able via start_step (resume)."""
    step = start_step
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        toks = synthetic_lm_tokens(key, batch, seq_len + 1, vocab)
        out = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "loss_mask": jnp.ones((batch, seq_len), jnp.float32),
        }
        if cfg is not None and cfg.is_encoder_decoder:
            fkey = jax.random.fold_in(key, 1)
            out["frames"] = 0.02 * jax.random.normal(
                fkey, (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16
            )
        if cfg is not None and cfg.num_vision_tokens:
            pkey = jax.random.fold_in(key, 2)
            out["patch_embeds"] = 0.02 * jax.random.normal(
                pkey, (batch, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16
            )
        yield out
        step += 1
