from repro.data.loader import DataConfig, make_loader  # noqa: F401
from repro.data.synthetic import synthetic_batches, synthetic_lm_tokens  # noqa: F401
