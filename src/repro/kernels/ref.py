"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Deliberately simple direct implementations — O(S^2) attention materializing
the full score matrix, step-by-step sequential scan — used by the kernel
sweep tests (``tests/test_kernels.py``) via ``assert_allclose``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    if groups == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, groups, d)).reshape(b, s, h * groups, d)


def flash_attention_ref(q, k, v, *, causal=True):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    if causal:
        qpos = jnp.arange(sq) + (skv - sq)
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len):
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); kv_len: () or (B,)."""
    b, hq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    lens = jnp.broadcast_to(jnp.asarray(kv_len), (b,))
    mask = jnp.arange(skv)[None, None, :] < lens[:, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def mamba_scan_ref(x, dt, a, bmat, cmat, d_skip, h0):
    """Sequential reference recurrence. Shapes as in kernels.mamba_scan."""
    bsz, s, di = x.shape

    def step(h, args):
        x_t, dt_t, b_t, c_t = args  # (B, di), (B, di), (B, N), (B, N)
        da = jnp.exp(dt_t[..., None] * a[None])              # (B, di, N)
        h = da * h + (dt_t * x_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + x_t.astype(jnp.float32) * d_skip
        return h, y

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1),
          bmat.swapaxes(0, 1), cmat.swapaxes(0, 1))
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), hT


def sdqn_score_ref(feats, w1, b1, w2, b2):
    h = jnp.maximum(feats.astype(jnp.float32) @ w1 + b1, 0.0)
    return (h @ w2 + b2)[..., 0]
