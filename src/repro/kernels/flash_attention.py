"""Pallas TPU flash-attention forward (blocked online-softmax, causal GQA).

Grid: (batch*heads, q_blocks, kv_blocks) — the last axis is sequential on
TPU, so the (m, l, acc) online-softmax state lives in VMEM scratch and is
carried across kv blocks.  Block sizes are chosen so q/k/v tiles and the
accumulator fit VMEM with MXU-aligned (multiple-of-128) matmul dims.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific pallas helpers (present in jax>=0.4.31)
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover - CPU-only envs without the TPU module
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, block_q, block_k, causal, seq_q, seq_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q + (seq_k - seq_q)  # align causal diagonal
    k_start = ki * block_k
    # skip blocks that lie entirely above the causal diagonal
    run = (not causal) or (q_start + block_q - 1 >= k_start)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)        # (bq, d)
        k = k_ref[0].astype(jnp.float32)        # (bk, d)
        v = v_ref[0].astype(jnp.float32)        # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[...]                      # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                   # (bq, bk)
        corr = jnp.exp(m_prev - m_new)           # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0

    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    scale = 1.0 / math.sqrt(d)

    def kv_index(bh, qi, ki):
        return ((bh // hq) * hkv + (bh % hq) // group, ki, 0)

    grid = (b * hq, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, seq_q=sq, seq_k=skv,
    )
    scratch = [
        jax.ShapeDtypeStruct((block_q, 1), jnp.float32),
        jax.ShapeDtypeStruct((block_q, 1), jnp.float32),
        jax.ShapeDtypeStruct((block_q, d), jnp.float32),
    ]
    if _VMEM is not None:
        scratch = [_VMEM(s.shape, s.dtype) for s in scratch]
    compiler_params = None
    if pltpu is not None and not interpret:
        cp = getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")
        compiler_params = cp(dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
