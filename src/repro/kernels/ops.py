"""Jit'd public wrappers around the Pallas kernels with backend dispatch.

Policy:
  * on TPU       -> the Pallas kernel (compiled)
  * on CPU/GPU   -> the XLA path (chunked-jnp implementations from
                    ``repro.models`` — semantically identical, memory-safe)
  * ``mode="interpret"`` -> the Pallas kernel body executed in interpret
                    mode (used by the kernel correctness sweeps on CPU)
  * ``mode="ref"`` -> the pure-jnp oracle

The model code calls these entry points, so the same model runs under
dry-run lowering on the CPU container and under real kernels on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba_scan as _ms
from repro.kernels import ref
from repro.kernels import sdqn_score as _ss


def _default_mode() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def flash_attention(q, k, v, *, causal=True, mode: Optional[str] = None,
                    block_q: int = 256, block_k: int = 256):
    mode = mode or _default_mode()
    if mode == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    if mode == "interpret":
        return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                   block_k=block_k, interpret=True)
    if mode == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    from repro.models import layers  # XLA path: query-chunked online attention

    return layers.attention(q, k, v, causal=causal, q_chunk=block_q)


def decode_attention(q, k, v, kv_len, *, mode: Optional[str] = None, block_k: int = 512):
    mode = mode or _default_mode()
    if mode == "pallas":
        return _da.decode_attention(q, k, v, kv_len, block_k=block_k)
    if mode == "interpret":
        return _da.decode_attention(q, k, v, kv_len, block_k=block_k, interpret=True)
    return ref.decode_attention_ref(q, k, v, kv_len)


def mamba_scan(x, dt, a, bmat, cmat, d_skip, h0, *, mode: Optional[str] = None,
               block_d: int = 512, block_s: int = 256, chunk: int = 64):
    mode = mode or _default_mode()
    if mode == "pallas":
        return _ms.mamba_scan(x, dt, a, bmat, cmat, d_skip, h0,
                              block_d=block_d, block_s=block_s)
    if mode == "interpret":
        return _ms.mamba_scan(x, dt, a, bmat, cmat, d_skip, h0,
                              block_d=block_d, block_s=block_s, interpret=True)
    if mode == "ref":
        return ref.mamba_scan_ref(x, dt, a, bmat, cmat, d_skip, h0)
    from repro.models import mamba  # XLA path: chunked associative scan

    return mamba.selective_scan(x, dt, a, bmat, cmat, d_skip, h0, chunk=chunk)


def _mlp_weights(params):
    """The fused SDQN kernels hardwire the Table-4 MLP over the canonical
    ``types.FEATURE_DIM``-wide afterstate row; reject any other policy
    class's params up front (wider sequence-policy rows must take the
    unfused ``PolicySpec.score_set`` path, never the column kernels)."""
    from repro.core.types import FEATURE_DIM

    w1 = params["w1"]
    if w1.shape[0] != FEATURE_DIM:
        raise ValueError(
            f"fused SDQN kernels score {FEATURE_DIM}-wide afterstate rows; "
            f"got w1 input width {w1.shape[0]} (non-MLP policy params?)")
    return w1, params["b1"], params["w2"], params["b2"]


def sdqn_score(feats, params, *, mode: Optional[str] = None, block_n: int = 1024):
    """Score N nodes through the Table-4 Q-net. params: repro.core.dqn pytree."""
    mode = mode or _default_mode()
    w1, b1, w2, b2 = _mlp_weights(params)
    if mode == "pallas":
        return _ss.sdqn_score(feats, w1, b1, w2, b2, block_n=block_n)
    if mode == "interpret":
        return _ss.sdqn_score(feats, w1, b1, w2, b2, block_n=block_n, interpret=True)
    return ref.sdqn_score_ref(feats, w1, b1, w2, b2)


def _afterstate_inputs(state, pod, cfg, params, pull_cost=None):
    """(12 raw columns, scalar pack, w1, b1, w2) for the afterstate kernels.

    ``pull_cost`` overrides the in-flight pull-contention scalar — a GLOBAL
    reduction over ``startup_cpu`` that sharded scoring (``sched.shard``)
    must compute once from the full fleet and thread into every shard.
    """
    from repro.core import env as kenv

    cols = (
        state.base_cpu, state.pods_cpu, state.startup_cpu,
        state.num_pods, state.exp_pods, state.mem_used,
        state.image_cached, state.healthy, state.uptime_hours,
        state.cpu_capacity, state.mem_capacity, state.max_pods,
    )
    pull = kenv.pull_cost_now(state, cfg) if pull_cost is None else pull_cost
    scalars = jnp.zeros((_ss._N_SCALARS,), jnp.float32)
    scalars = scalars.at[_ss._S_CPU_DEMAND].set(pod.cpu_demand)
    scalars = scalars.at[_ss._S_MEM_DEMAND].set(pod.mem_demand)
    scalars = scalars.at[_ss._S_PULL].set(pull)
    scalars = scalars.at[_ss._S_WARM].set(cfg.warm_start_cost)
    scalars = scalars.at[_ss._S_OVERHEAD].set(cfg.node_active_overhead)
    scalars = scalars.at[_ss._S_CROWD_KNEE].set(cfg.crowd_knee)
    scalars = scalars.at[_ss._S_CROWD_COEFF].set(cfg.crowd_coeff)
    scalars = scalars.at[_ss._S_CONT_KNEE].set(cfg.contention_knee)
    scalars = scalars.at[_ss._S_CONT_COEFF].set(cfg.contention_coeff)
    scalars = scalars.at[_ss._S_UPTIME_SCALE].set(kenv.FEATURE_SCALE[4])
    scalars = scalars.at[_ss._S_EXP_SCALE].set(kenv.FEATURE_SCALE[5])
    w1, b1, w2, b2 = _mlp_weights(params)
    scalars = scalars.at[_ss._S_B2].set(jnp.reshape(b2, ()))
    return cols, scalars, w1, b1, w2


def sdqn_score_afterstate(state, pod, cfg, params, *, mode: Optional[str] = None,
                          block_n: int = 1024, pull_cost=None):
    """Q-values (N,) of every candidate afterstate, features fused in-kernel.

    Accepts the raw ``ClusterState`` columns plus the pod's placement delta
    and mirrors ``env.hypothetical_place``'s O(N) arithmetic inside the
    scoring kernel, so the (N, 6) afterstate feature matrix is never
    materialized in HBM.  ``mode``: ``pallas`` (TPU) / ``interpret`` /
    ``xla`` (fused jnp twin, default off-TPU) / ``ref`` (unfused oracle:
    ``hypothetical_place`` + ``dqn.qvalues``).
    """
    from repro.core import env as kenv

    mode = mode or ("pallas" if jax.default_backend() == "tpu" else "xla")
    if mode == "ref":
        from repro.core import dqn

        after = kenv.hypothetical_place(state, pod, cfg, pull_cost=pull_cost)
        return dqn.qvalues(params, kenv.normalize_features(after))

    cols, scalars, w1, b1, w2 = _afterstate_inputs(state, pod, cfg, params,
                                                   pull_cost)
    if mode == "xla":
        return _ss.sdqn_score_afterstate_xla(cols, scalars, w1, b1, w2)
    return _ss.sdqn_score_afterstate(cols, scalars, w1, b1, w2,
                                     block_n=block_n,
                                     interpret=(mode == "interpret"))


def sdqn_topk_afterstate(state, pod, cfg, params, *, k: int = 4,
                         mode: Optional[str] = None, block_n: int = 1024,
                         pull_cost=None):
    """((k,) scores, (k,) node indices): the feasible top-k of one shard's
    candidate afterstates, scored AND reduced in-kernel.

    The per-shard stage of two-stage hierarchical scoring (``sched.shard``):
    the k8s filtering phase (``env.feasible``) and the Q-net both run inside
    the kernel, and only k candidates per shard ever reach HBM.  Infeasible
    nodes carry ``-inf``; ties break to the lowest index (``jnp.argmax``'s
    first-occurrence rule), so merging shard candidates reproduces the flat
    masked argmax exactly.  ``mode="ref"`` is the unfused oracle:
    ``hypothetical_place`` + ``qvalues`` + ``feasible`` + ``lax.top_k``.
    """
    from repro.core import env as kenv

    mode = mode or ("pallas" if jax.default_backend() == "tpu" else "xla")
    if mode == "ref":
        from repro.core import dqn

        after = kenv.hypothetical_place(state, pod, cfg, pull_cost=pull_cost)
        q = dqn.qvalues(params, kenv.normalize_features(after))
        ok = kenv.feasible(state, pod, cfg)
        return jax.lax.top_k(jnp.where(ok, q, -jnp.inf), min(k, q.shape[0]))

    cols, scalars, w1, b1, w2 = _afterstate_inputs(state, pod, cfg, params,
                                                   pull_cost)
    cols = cols + (state.cpu_requested, state.mem_requested)
    scalars = scalars.at[_ss._S_CPU_REQ].set(pod.cpu_request)
    scalars = scalars.at[_ss._S_MEM_REQ].set(pod.mem_request)
    if mode == "xla":
        return _ss.sdqn_score_afterstate_topk_xla(cols, scalars, w1, b1, w2,
                                                  k=k)
    return _ss.sdqn_score_afterstate_topk(cols, scalars, w1, b1, w2, k=k,
                                          block_n=block_n,
                                          interpret=(mode == "interpret"))


def sdqn_score_delta(cols, deltas, params, *, mode: Optional[str] = None,
                     block_n: int = 1024):
    """Q((cols + deltas) / FEATURE_SCALE) for column-structured fleets.

    The serving-path scorer (``sched.placement``): six raw feature columns
    plus the job's afterstate delta, assembled and scored in one fused pass
    (Pallas on TPU, fused XLA twin elsewhere, ``ref`` = stack + qvalues).
    """
    from repro.core import env as kenv

    mode = mode or ("pallas" if jax.default_backend() == "tpu" else "xla")
    w1, b1, w2, b2 = _mlp_weights(params)
    if mode == "ref":
        feats = (jnp.stack(cols, axis=-1) + deltas[None, :]) / kenv.FEATURE_SCALE
        return ref.sdqn_score_ref(feats, w1, b1, w2, b2)
    if mode == "xla":
        return _ss.sdqn_score_cols_xla(tuple(cols), deltas, kenv.FEATURE_SCALE,
                                       w1, b1, w2, b2)
    return _ss.sdqn_score_cols(tuple(cols), deltas, kenv.FEATURE_SCALE, w1, b1,
                               w2, b2, block_n=block_n,
                               interpret=(mode == "interpret"))


def sdqn_topk_delta(cols, deltas, params, *, k: int = 4,
                    mode: Optional[str] = None, block_n: int = 1024,
                    ceilings=(88.0, 95.0, 100.0 + 1e-6)):
    """((k,) scores, (k,) host indices): feasible top-k of the column scorer.

    The FleetState arm of per-shard top-k scoring: the
    ``PlacementEngine.feasible`` predicates (healthy + post-delta cpu / mem /
    job-util ceilings) and the Q-net both run in-kernel, emitting only k
    candidates per shard.  ``ceilings`` are the three predicate bounds (the
    default mirrors ``PlacementEngine``'s 88 / 95 / 100).
    """
    from repro.core import env as kenv

    mode = mode or ("pallas" if jax.default_backend() == "tpu" else "xla")
    w1, b1, w2, b2 = _mlp_weights(params)
    if mode == "ref":
        feats = (jnp.stack(cols, axis=-1) + deltas[None, :]) / kenv.FEATURE_SCALE
        q = ref.sdqn_score_ref(feats, w1, b1, w2, b2)
        cl = jnp.asarray(ceilings, jnp.float32)
        ok = ((cols[3] > 0.5) & (cols[0] + deltas[0] <= cl[0])
              & (cols[1] + deltas[1] <= cl[1])
              & (cols[2] + deltas[2] <= cl[2]))
        return jax.lax.top_k(jnp.where(ok, q, -jnp.inf), min(k, q.shape[0]))
    if mode == "xla":
        return _ss.sdqn_score_cols_topk_xla(tuple(cols), deltas,
                                            kenv.FEATURE_SCALE, w1, b1, w2,
                                            b2, ceilings, k=k)
    return _ss.sdqn_score_cols_topk(tuple(cols), deltas, kenv.FEATURE_SCALE,
                                    w1, b1, w2, b2, ceilings, k=k,
                                    block_n=block_n,
                                    interpret=(mode == "interpret"))
