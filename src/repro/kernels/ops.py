"""Jit'd public wrappers around the Pallas kernels with backend dispatch.

Policy:
  * on TPU       -> the Pallas kernel (compiled)
  * on CPU/GPU   -> the XLA path (chunked-jnp implementations from
                    ``repro.models`` — semantically identical, memory-safe)
  * ``mode="interpret"`` -> the Pallas kernel body executed in interpret
                    mode (used by the kernel correctness sweeps on CPU)
  * ``mode="ref"`` -> the pure-jnp oracle

The model code calls these entry points, so the same model runs under
dry-run lowering on the CPU container and under real kernels on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba_scan as _ms
from repro.kernels import ref
from repro.kernels import sdqn_score as _ss


def _default_mode() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def flash_attention(q, k, v, *, causal=True, mode: Optional[str] = None,
                    block_q: int = 256, block_k: int = 256):
    mode = mode or _default_mode()
    if mode == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    if mode == "interpret":
        return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                   block_k=block_k, interpret=True)
    if mode == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    from repro.models import layers  # XLA path: query-chunked online attention

    return layers.attention(q, k, v, causal=causal, q_chunk=block_q)


def decode_attention(q, k, v, kv_len, *, mode: Optional[str] = None, block_k: int = 512):
    mode = mode or _default_mode()
    if mode == "pallas":
        return _da.decode_attention(q, k, v, kv_len, block_k=block_k)
    if mode == "interpret":
        return _da.decode_attention(q, k, v, kv_len, block_k=block_k, interpret=True)
    return ref.decode_attention_ref(q, k, v, kv_len)


def mamba_scan(x, dt, a, bmat, cmat, d_skip, h0, *, mode: Optional[str] = None,
               block_d: int = 512, block_s: int = 256, chunk: int = 64):
    mode = mode or _default_mode()
    if mode == "pallas":
        return _ms.mamba_scan(x, dt, a, bmat, cmat, d_skip, h0,
                              block_d=block_d, block_s=block_s)
    if mode == "interpret":
        return _ms.mamba_scan(x, dt, a, bmat, cmat, d_skip, h0,
                              block_d=block_d, block_s=block_s, interpret=True)
    if mode == "ref":
        return ref.mamba_scan_ref(x, dt, a, bmat, cmat, d_skip, h0)
    from repro.models import mamba  # XLA path: chunked associative scan

    return mamba.selective_scan(x, dt, a, bmat, cmat, d_skip, h0, chunk=chunk)


def sdqn_score(feats, params, *, mode: Optional[str] = None, block_n: int = 1024):
    """Score N nodes through the Table-4 Q-net. params: repro.core.dqn pytree."""
    mode = mode or _default_mode()
    w1, b1, w2, b2 = params["w1"], params["b1"], params["w2"], params["b2"]
    if mode == "pallas":
        return _ss.sdqn_score(feats, w1, b1, w2, b2, block_n=block_n)
    if mode == "interpret":
        return _ss.sdqn_score(feats, w1, b1, w2, b2, block_n=block_n, interpret=True)
    return ref.sdqn_score_ref(feats, w1, b1, w2, b2)
