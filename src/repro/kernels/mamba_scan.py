"""Pallas TPU selective-scan (Mamba-1) forward.

TPU adaptation of the CUDA selective-scan: instead of warp-level parallel
prefix sums, the state (bd, N) lives in vector registers / VMEM and the
kernel walks the sequence with a ``fori_loop``; parallelism comes from the
grid over (batch, d_inner blocks) — the d_inner axis is wide (8k+ lanes on
falcon-mamba), which is where the VPU earns its keep.  The sequence axis is
blocked via the grid's sequential last dimension so x/dt tiles of shape
(block_s, bd) stream through VMEM instead of requiring the whole sequence
resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
                 y_ref, hT_ref, h_ref, *, block_s, n_state):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    a = a_ref[...].astype(jnp.float32)              # (bd, N)
    dskip = d_ref[...].astype(jnp.float32)          # (1, bd)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # (bd,)
        x_t = x_ref[0, t, :].astype(jnp.float32)    # (bd,)
        b_t = b_ref[0, t, :].astype(jnp.float32)    # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)    # (N,)
        da = jnp.exp(dt_t[:, None] * a)             # (bd, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1) + x_t * dskip[0]
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h

    @pl.when(si == ns - 1)
    def _final():
        hT_ref[0] = h


@functools.partial(jax.jit, static_argnames=("block_d", "block_s", "interpret"))
def mamba_scan(
    x: jnp.ndarray,      # (B, S, di)
    dt: jnp.ndarray,     # (B, S, di) fp32
    a: jnp.ndarray,      # (di, N) fp32 (negative)
    bmat: jnp.ndarray,   # (B, S, N) fp32
    cmat: jnp.ndarray,   # (B, S, N) fp32
    d_skip: jnp.ndarray,  # (di,) fp32
    h0: jnp.ndarray,     # (B, di, N) fp32
    *,
    block_d: int = 512,
    block_s: int = 256,
    interpret: bool = False,
):
    """Returns (y (B, S, di), hT (B, di, N))."""
    bsz, s, di = x.shape
    n = a.shape[-1]
    block_d = min(block_d, di)
    block_s = min(block_s, s)
    assert di % block_d == 0 and s % block_s == 0

    grid = (bsz, di // block_d, s // block_s)
    scratch = [jax.ShapeDtypeStruct((block_d, n), jnp.float32)]
    if _VMEM is not None:
        scratch = [_VMEM(sc.shape, sc.dtype) for sc in scratch]

    y, ht = pl.pallas_call(
        functools.partial(_scan_kernel, block_s=block_s, n_state=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b, dd, ss: (b, ss, dd)),  # x
            pl.BlockSpec((1, block_s, block_d), lambda b, dd, ss: (b, ss, dd)),  # dt
            pl.BlockSpec((1, block_s, n), lambda b, dd, ss: (b, ss, 0)),         # B
            pl.BlockSpec((1, block_s, n), lambda b, dd, ss: (b, ss, 0)),         # C
            pl.BlockSpec((block_d, n), lambda b, dd, ss: (dd, 0)),               # A
            pl.BlockSpec((1, block_d), lambda b, dd, ss: (0, dd)),               # D
            pl.BlockSpec((1, block_d, n), lambda b, dd, ss: (b, dd, 0)),         # h0
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b, dd, ss: (b, ss, dd)),
            pl.BlockSpec((1, block_d, n), lambda b, dd, ss: (b, dd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), x.dtype),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, dt, jnp.asarray(bmat, jnp.float32), jnp.asarray(cmat, jnp.float32),
      jnp.asarray(a, jnp.float32), d_skip.reshape(1, di), h0)
    return y, ht
