"""Pallas TPU fused SDQN node-scoring kernels.

The paper's hot loop at fleet scale: score N candidate nodes through the
6->32->1 Q-network (Table 4).  Three entry points:

* ``sdqn_score`` — score a pre-built (N, 6) feature matrix.  Both matmuls
  and the ReLU are fused in one VMEM pass; at N ~ 10^5-10^6 nodes the layer
  is memory-bound and the fusion removes two HBM round-trips of the (N, 32)
  intermediate.
* ``sdqn_score_afterstate`` — the full afterstate scorer: takes the *raw*
  per-node ``ClusterState`` columns plus the pod's placement delta and
  computes the Table-2 afterstate features (mirroring the O(N)
  ``env.hypothetical_place`` arithmetic: startup transient, CFS crowding,
  contention knee), normalizes them, and applies the Q-net — all inside the
  kernel.  The (N, 6) afterstate matrix never touches HBM, which is the
  dominant traffic of the scoring path in both training and serving.
* ``sdqn_score_cols`` — afterstate scoring for column-structured fleets
  (``sched.placement``): six raw feature columns plus a per-feature
  afterstate delta, features assembled and scored in-kernel.

Each kernel has a ``*_xla`` twin with identical arithmetic (broadcast
multiply-accumulate, no (N, 6) stack, no GEMM) used as the fused fallback on
CPU/GPU backends and as the reference for the interpret-mode sweeps.
Per-node columns are viewed as (N // block_n, block_n) so each grid step
streams ``block_n`` nodes through the lane dimension; weights and the
scalar pack stay resident in VMEM/SMEM across the whole grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _score_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)           # (bn, F)
    h = jax.lax.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b1_ref[...], 0.0)        # (bn, H)
    q = jax.lax.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (q + b2_ref[...]).astype(o_ref.dtype)  # (bn, 1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sdqn_score(
    feats: jnp.ndarray,  # (N, F) float32 — normalized Table-2 features
    w1: jnp.ndarray,     # (F, H)
    b1: jnp.ndarray,     # (H,)
    w2: jnp.ndarray,     # (H, 1)
    b2: jnp.ndarray,     # (1,)
    *,
    block_n: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns Q-values (N,)."""
    n, f = feats.shape
    h = w1.shape[1]
    block_n = min(block_n, n)
    pad_n = (-n) % block_n
    if pad_n:
        feats = jnp.pad(feats, ((0, pad_n), (0, 0)))
    np_ = feats.shape[0]

    out = pl.pallas_call(
        _score_kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((1, h), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(feats, w1, b1.reshape(1, h), w2, b2.reshape(1, 1))
    return out[:n, 0]


# ---------------------------------------------------------------------------
# fused afterstate scoring: raw state columns + placement delta -> Q, with
# the Table-2 afterstate features computed in-kernel (no (N, 6) in HBM)
# ---------------------------------------------------------------------------

# scalar-pack layout shared by the afterstate kernel and its XLA twin
_S_CPU_DEMAND, _S_MEM_DEMAND, _S_PULL, _S_WARM, _S_OVERHEAD = 0, 1, 2, 3, 4
_S_CROWD_KNEE, _S_CROWD_COEFF, _S_CONT_KNEE, _S_CONT_COEFF = 5, 6, 7, 8
_S_UPTIME_SCALE, _S_EXP_SCALE, _S_B2 = 9, 10, 11
# the top-k variants also filter in-kernel, so the pod's *requests* (the k8s
# filtering phase operates on requests, not demands) ride in the pack too
_S_CPU_REQ, _S_MEM_REQ = 12, 13
_N_SCALARS = 16  # padded pack width


def _afterstate_norm_features(base_cpu, pods_cpu, startup_cpu, num_pods,
                              exp_pods, mem_used, cached, healthy, uptime,
                              cap, mem_cap, max_pods, s):
    """Normalized Table-2 afterstate features, elementwise on any shape.

    ``s(i)`` reads scalar ``i`` of the pack.  Mirrors the placement delta of
    ``env.hypothetical_place`` + ``env._node_cpu_used`` + normalization
    exactly: one definition shared by the Pallas kernel body (operating on
    (1, block_n) tiles) and the fused XLA twin (operating on (N,) columns).
    """
    start_cost = jnp.where(cached > 0.5, s(_S_WARM), s(_S_PULL))
    num_pods1 = num_pods + 1.0
    exp_pods1 = exp_pods + 1.0
    crowd = jnp.maximum(num_pods1 - s(_S_CROWD_KNEE), 0.0)
    # the placed node is always active, so the overhead term is unconditional
    raw = (base_cpu + s(_S_OVERHEAD) + pods_cpu + s(_S_CPU_DEMAND)
           + startup_cpu + start_cost + s(_S_CROWD_COEFF) * crowd * crowd)
    util = raw / cap
    over = jnp.maximum(util - s(_S_CONT_KNEE), 0.0)
    used = jnp.minimum(raw + s(_S_CONT_COEFF) * over * over * cap, cap)
    return (
        used / cap,                                  # 100 * used/cap, /100
        (mem_used + s(_S_MEM_DEMAND)) / mem_cap,     # 100 * mem/cap, /100
        num_pods1 / max_pods,                        # 100 * pods/max, /100
        healthy,
        uptime / s(_S_UPTIME_SCALE),
        exp_pods1 / s(_S_EXP_SCALE),
    )


def _afterstate_kernel(base_ref, pcpu_ref, scpu_ref, npod_ref, epod_ref,
                       mem_ref, cached_ref, health_ref, up_ref, cap_ref,
                       mcap_ref, mpod_ref, scal_ref, w1t_ref, b1_ref, w2_ref,
                       o_ref):
    def s(i):
        return scal_ref[0, i]

    feats = _afterstate_norm_features(
        base_ref[...], pcpu_ref[...], scpu_ref[...], npod_ref[...],
        epod_ref[...], mem_ref[...], cached_ref[...], health_ref[...],
        up_ref[...], cap_ref[...], mcap_ref[...], mpod_ref[...], s,
    )  # six (1, bn) rows
    w1t = w1t_ref[...]                               # (H, 6)
    h = b1_ref[...]                                  # (H, 1) broadcasts
    for f in range(6):
        h = h + w1t[:, f:f + 1] * feats[f]           # (H, 1) * (1, bn)
    q = jnp.sum(jnp.maximum(h, 0.0) * w2_ref[...], axis=0, keepdims=True)
    o_ref[...] = q + s(_S_B2)


def _grid_cols(cols, n, block_n, pad_value=0.0):
    """Pad each (N,) column to a block multiple and view as (G, block_n)."""
    pad_n = (-n) % block_n
    out = []
    for c in cols:
        c = c.astype(jnp.float32)
        if pad_n:
            c = jnp.pad(c, (0, pad_n), constant_values=pad_value)
        out.append(c.reshape(-1, block_n))
    return out


def _scalar_spec():
    if pltpu is not None:
        return pl.BlockSpec(memory_space=pltpu.SMEM)
    return pl.BlockSpec((1, _N_SCALARS), lambda i: (0, 0))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sdqn_score_afterstate(
    node_cols: tuple,    # 12 x (N,): base_cpu, pods_cpu, startup_cpu,
    #                      num_pods, exp_pods, mem_used, image_cached,
    #                      healthy, uptime_hours, cpu_capacity,
    #                      mem_capacity, max_pods
    scalars: jnp.ndarray,  # (_N_SCALARS,) pack, see _S_* layout
    w1: jnp.ndarray,     # (F, H)
    b1: jnp.ndarray,     # (H,)
    w2: jnp.ndarray,     # (H, 1)
    *,
    block_n: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """Q-values (N,) for every candidate afterstate, features fused in-kernel."""
    n = node_cols[0].shape[0]
    h = w1.shape[1]
    # capacities pad with 1 so padded lanes stay finite (they are sliced off)
    grids = _grid_cols(node_cols[:9], n, block_n) + _grid_cols(
        node_cols[9:], n, block_n, pad_value=1.0)
    g = grids[0].shape[0]
    col_spec = pl.BlockSpec((1, block_n), lambda i: (i, 0))

    out = pl.pallas_call(
        _afterstate_kernel,
        grid=(g,),
        in_specs=[col_spec] * 12 + [
            _scalar_spec(),
            pl.BlockSpec((h, 6), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, block_n), jnp.float32),
        interpret=interpret,
    )(*grids, scalars.reshape(1, _N_SCALARS), w1.T, b1.reshape(h, 1), w2)
    return out.reshape(-1)[:n]


@jax.jit
def sdqn_score_afterstate_xla(node_cols: tuple, scalars: jnp.ndarray,
                              w1: jnp.ndarray, b1: jnp.ndarray,
                              w2: jnp.ndarray) -> jnp.ndarray:
    """Fused XLA twin of the afterstate kernel (CPU/GPU fallback).

    Same arithmetic, expressed as broadcast multiply-accumulates over the
    raw columns so XLA fuses the whole scorer into one elementwise loop —
    no (N, 6) feature stack, no GEMM dispatch, no (N, H) round-trip.
    """
    cols = [c.astype(jnp.float32) for c in node_cols]

    def s(i):
        return scalars[i]

    feats = _afterstate_norm_features(*cols, s)
    hid = b1[None, :]                                # (1, H)
    for f in range(6):
        hid = hid + feats[f][:, None] * w1[f][None, :]
    return jnp.sum(jnp.maximum(hid, 0.0) * w2[:, 0][None, :], axis=-1) + s(_S_B2)


# ---------------------------------------------------------------------------
# fused column scoring for feature-structured fleets (sched.placement):
# six raw feature columns + per-feature afterstate delta -> Q in one pass
# ---------------------------------------------------------------------------


def _cols_kernel(c0, c1, c2, c3, c4, c5, scal_ref, w1t_ref, b1_ref, w2_ref,
                 o_ref):
    cols = (c0, c1, c2, c3, c4, c5)
    w1t = w1t_ref[...]                               # (H, 6), scale pre-folded
    h = b1_ref[...]                                  # (H, 1)
    for f in range(6):
        h = h + w1t[:, f:f + 1] * (cols[f][...] + scal_ref[0, f])
    q = jnp.sum(jnp.maximum(h, 0.0) * w2_ref[...], axis=0, keepdims=True)
    o_ref[...] = q + scal_ref[0, 6]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sdqn_score_cols(
    cols: tuple,          # 6 x (N,) raw feature columns
    deltas: jnp.ndarray,  # (6,) afterstate delta per feature (raw units)
    scale: jnp.ndarray,   # (6,) feature normalization (env.FEATURE_SCALE)
    w1: jnp.ndarray,      # (F, H)
    b1: jnp.ndarray,      # (H,)
    w2: jnp.ndarray,      # (H, 1)
    b2: jnp.ndarray,      # (1,)
    *,
    block_n: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """Q((cols + deltas) / scale) without materializing the (N, 6) matrix.

    Normalization folds into the first-layer weights (w1[f] / scale[f]), so
    the kernel streams the six raw columns straight into the MAC.
    """
    n = cols[0].shape[0]
    h = w1.shape[1]
    grids = _grid_cols(cols, n, block_n)
    g = grids[0].shape[0]
    col_spec = pl.BlockSpec((1, block_n), lambda i: (i, 0))
    scal = jnp.zeros((_N_SCALARS,), jnp.float32)
    scal = scal.at[:6].set(deltas.astype(jnp.float32))
    scal = scal.at[6].set(jnp.reshape(b2, ()))
    w1n = w1 / scale[:, None]

    out = pl.pallas_call(
        _cols_kernel,
        grid=(g,),
        in_specs=[col_spec] * 6 + [
            _scalar_spec(),
            pl.BlockSpec((h, 6), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, block_n), jnp.float32),
        interpret=interpret,
    )(*grids, scal.reshape(1, _N_SCALARS), w1n.T, b1.reshape(h, 1), w2)
    return out.reshape(-1)[:n]


@jax.jit
def sdqn_score_cols_xla(cols: tuple, deltas: jnp.ndarray, scale: jnp.ndarray,
                        w1: jnp.ndarray, b1: jnp.ndarray, w2: jnp.ndarray,
                        b2: jnp.ndarray) -> jnp.ndarray:
    """Fused XLA twin of ``sdqn_score_cols`` (CPU/GPU fallback)."""
    w1n = w1 / scale[:, None]
    hid = b1[None, :]
    for f in range(6):
        hid = hid + (cols[f].astype(jnp.float32) + deltas[f])[:, None] * w1n[f][None, :]
    return jnp.sum(jnp.maximum(hid, 0.0) * w2[:, 0][None, :], axis=-1) + b2[0]


# ---------------------------------------------------------------------------
# in-kernel per-shard top-k: score + filter + reduce without ever writing the
# shard's full score vector to HBM.  The two-stage hierarchical dispatch
# (``sched.shard``) runs one of these per node shard and merges the tiny
# (shards, k) candidate sets globally.
# ---------------------------------------------------------------------------

# tie-break sentinel: "no index".  A plain Python literal on purpose — a
# jnp constant here would be captured by the Pallas kernel closure as a
# traced value, which pallas_call rejects.
_IDX_INF = 2**31 - 1


def _iter_topk(scores, idx, k: int):
    """k iterative (max, first-index) extractions over the last axis.

    Ties break to the LOWEST index — exactly ``jnp.argmax``'s first-
    occurrence rule, applied k times — so a hierarchical merge of these
    candidates reproduces the flat argmax bit-for-bit.  Elementwise max /
    where / min only (no sort, no gather), so the same definition runs
    inside a Pallas TPU kernel body on (1, block_n) tiles and in the XLA
    twins on (N,) columns.  Returns ((..., k) values, (..., k) indices);
    exhausted positions carry ``-inf`` / ``_IDX_INF``.
    """
    vals, ids = [], []
    for _ in range(k):
        m = jnp.max(scores, axis=-1, keepdims=True)
        a = jnp.min(jnp.where(scores == m, idx, _IDX_INF), axis=-1,
                    keepdims=True)
        vals.append(m)
        ids.append(a)
        scores = jnp.where(idx == a, -jnp.inf, scores)
    return jnp.concatenate(vals, axis=-1), jnp.concatenate(ids, axis=-1)


def _merge_topk(vals, idx, k: int):
    """Merge (G, k) per-block candidates into the global (k,) top-k.

    ``lax.top_k`` over the block-major flatten keeps ties in ascending flat
    position; blocks cover ascending index ranges and ``_iter_topk`` emits
    within-block ties in ascending index, so the merged ties stay in
    ascending GLOBAL index — the first-occurrence argmax rule survives the
    hierarchy.  Same routine merges shard candidates in ``sched.shard``.
    """
    flat_v, flat_i = vals.reshape(-1), idx.reshape(-1)
    top_v, pos = jax.lax.top_k(flat_v, k)
    return top_v, flat_i[pos]


def _afterstate_topk_kernel(k, base_ref, pcpu_ref, scpu_ref, npod_ref,
                            epod_ref, mem_ref, cached_ref, health_ref, up_ref,
                            cap_ref, mcap_ref, mpod_ref, creq_ref, mreq_ref,
                            scal_ref, w1t_ref, b1_ref, w2_ref, ov_ref, oi_ref):
    def s(i):
        return scal_ref[0, i]

    feats = _afterstate_norm_features(
        base_ref[...], pcpu_ref[...], scpu_ref[...], npod_ref[...],
        epod_ref[...], mem_ref[...], cached_ref[...], health_ref[...],
        up_ref[...], cap_ref[...], mcap_ref[...], mpod_ref[...], s,
    )
    w1t = w1t_ref[...]
    h = b1_ref[...]
    for f in range(6):
        h = h + w1t[:, f:f + 1] * feats[f]
    q = jnp.sum(jnp.maximum(h, 0.0) * w2_ref[...], axis=0, keepdims=True)
    q = q + s(_S_B2)                                 # (1, bn)
    # k8s filtering phase, in-kernel (env.feasible): padded lanes arrive with
    # healthy == 0 and capacity == 1, so they are masked right here
    ok = ((health_ref[...] > 0.5)
          & (creq_ref[...] + s(_S_CPU_REQ) <= cap_ref[...])
          & (mreq_ref[...] + s(_S_MEM_REQ) <= mcap_ref[...])
          & (npod_ref[...] < mpod_ref[...]))
    bn = q.shape[-1]
    gidx = (pl.program_id(0) * bn
            + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1))
    vals, ids = _iter_topk(jnp.where(ok, q, -jnp.inf), gidx, k)
    ov_ref[...] = vals
    oi_ref[...] = ids


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def sdqn_score_afterstate_topk(
    node_cols: tuple,      # 14 x (N,): the 12 afterstate columns (see
    #                        ``sdqn_score_afterstate``) + cpu_requested,
    #                        mem_requested (filtering-phase columns)
    scalars: jnp.ndarray,  # (_N_SCALARS,) pack incl. _S_CPU_REQ/_S_MEM_REQ
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    *,
    k: int = 4,
    block_n: int = 1024,
    interpret: bool = False,
):
    """((k,) scores, (k,) indices): the shard's feasible top-k, in-kernel.

    Each grid step reduces its block to k candidates before anything leaves
    the kernel, so HBM traffic is O(G * k) instead of O(N) — the full score
    vector never materializes.  Infeasible nodes score ``-inf``; an
    all-infeasible shard returns all ``-inf`` (the merge layer maps that to
    the NO_PLACEMENT sentinel).
    """
    n = node_cols[0].shape[0]
    h = w1.shape[1]
    block_n = max(min(block_n, n), k)
    grids = _grid_cols(node_cols[:9], n, block_n) + _grid_cols(
        node_cols[9:12], n, block_n, pad_value=1.0) + _grid_cols(
        node_cols[12:], n, block_n)
    g = grids[0].shape[0]
    col_spec = pl.BlockSpec((1, block_n), lambda i: (i, 0))

    vals, idx = pl.pallas_call(
        functools.partial(_afterstate_topk_kernel, k),
        grid=(g,),
        in_specs=[col_spec] * 14 + [
            _scalar_spec(),
            pl.BlockSpec((h, 6), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((g, k), jnp.float32),
                   jax.ShapeDtypeStruct((g, k), jnp.int32)],
        interpret=interpret,
    )(*grids, scalars.reshape(1, _N_SCALARS), w1.T, b1.reshape(h, 1), w2)
    return _merge_topk(vals, idx, k)


@functools.partial(jax.jit, static_argnames=("k",))
def sdqn_score_afterstate_topk_xla(node_cols: tuple, scalars: jnp.ndarray,
                                   w1: jnp.ndarray, b1: jnp.ndarray,
                                   w2: jnp.ndarray, *, k: int = 4):
    """XLA twin: fused scoring + in-register filtering + ``lax.top_k``.

    ``lax.top_k`` breaks ties to the lowest index, matching the kernel's
    iterative extraction exactly; the shard-local (N,) intermediate lives
    only inside this fused computation.
    """
    cols = [c.astype(jnp.float32) for c in node_cols]
    q = sdqn_score_afterstate_xla(tuple(cols[:12]), scalars, w1, b1, w2)
    ok = ((cols[7] > 0.5)
          & (cols[12] + scalars[_S_CPU_REQ] <= cols[9])
          & (cols[13] + scalars[_S_MEM_REQ] <= cols[10])
          & (cols[3] < cols[11]))
    k = min(k, q.shape[0])
    return jax.lax.top_k(jnp.where(ok, q, -jnp.inf), k)


def _cols_topk_kernel(k, c0, c1, c2, c3, c4, c5, scal_ref, w1t_ref, b1_ref,
                      w2_ref, ov_ref, oi_ref):
    cols = (c0, c1, c2, c3, c4, c5)
    w1t = w1t_ref[...]
    h = b1_ref[...]
    for f in range(6):
        h = h + w1t[:, f:f + 1] * (cols[f][...] + scal_ref[0, f])
    q = jnp.sum(jnp.maximum(h, 0.0) * w2_ref[...], axis=0, keepdims=True)
    q = q + scal_ref[0, 6]
    # PlacementEngine.feasible, in-kernel: healthy + post-delta ceilings on
    # the cpu / mem / job-util percent columns (scalars 7..9)
    ok = ((c3[...] > 0.5)
          & (c0[...] + scal_ref[0, 0] <= scal_ref[0, 7])
          & (c1[...] + scal_ref[0, 1] <= scal_ref[0, 8])
          & (c2[...] + scal_ref[0, 2] <= scal_ref[0, 9]))
    bn = q.shape[-1]
    gidx = (pl.program_id(0) * bn
            + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1))
    vals, ids = _iter_topk(jnp.where(ok, q, -jnp.inf), gidx, k)
    ov_ref[...] = vals
    oi_ref[...] = ids


def _cols_topk_scalars(deltas, b2, ceilings):
    scal = jnp.zeros((_N_SCALARS,), jnp.float32)
    scal = scal.at[:6].set(deltas.astype(jnp.float32))
    scal = scal.at[6].set(jnp.reshape(b2, ()))
    scal = scal.at[7:10].set(jnp.asarray(ceilings, jnp.float32))
    return scal


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def sdqn_score_cols_topk(
    cols: tuple,
    deltas: jnp.ndarray,
    scale: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    ceilings,          # (3,): max cpu_pct, max mem_pct, max job_util_pct
    *,
    k: int = 4,
    block_n: int = 1024,
    interpret: bool = False,
):
    """Per-shard feasible top-k of ``sdqn_score_cols``, reduced in-kernel."""
    n = cols[0].shape[0]
    h = w1.shape[1]
    block_n = max(min(block_n, n), k)
    # healthy (col 3) pads 0 -> infeasible; the rest pad 0 and stay finite
    grids = _grid_cols(cols, n, block_n)
    g = grids[0].shape[0]
    col_spec = pl.BlockSpec((1, block_n), lambda i: (i, 0))
    scal = _cols_topk_scalars(deltas, b2, ceilings)
    w1n = w1 / scale[:, None]

    vals, idx = pl.pallas_call(
        functools.partial(_cols_topk_kernel, k),
        grid=(g,),
        in_specs=[col_spec] * 6 + [
            _scalar_spec(),
            pl.BlockSpec((h, 6), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((g, k), jnp.float32),
                   jax.ShapeDtypeStruct((g, k), jnp.int32)],
        interpret=interpret,
    )(*grids, scal.reshape(1, _N_SCALARS), w1n.T, b1.reshape(h, 1), w2)
    return _merge_topk(vals, idx, k)


@functools.partial(jax.jit, static_argnames=("k",))
def sdqn_score_cols_topk_xla(cols: tuple, deltas: jnp.ndarray,
                             scale: jnp.ndarray, w1: jnp.ndarray,
                             b1: jnp.ndarray, w2: jnp.ndarray,
                             b2: jnp.ndarray, ceilings, *, k: int = 4):
    """XLA twin of ``sdqn_score_cols_topk`` (fused score + mask + top_k)."""
    q = sdqn_score_cols_xla(cols, deltas, scale, w1, b1, w2, b2)
    cl = jnp.asarray(ceilings, jnp.float32)
    ok = ((cols[3] > 0.5)
          & (cols[0] + deltas[0] <= cl[0])
          & (cols[1] + deltas[1] <= cl[1])
          & (cols[2] + deltas[2] <= cl[2]))
    k = min(k, q.shape[0])
    return jax.lax.top_k(jnp.where(ok, q, -jnp.inf), k)
