"""Pallas TPU fused SDQN node-scoring kernel.

The paper's hot loop at fleet scale: score N candidate nodes through the
6->32->1 Q-network (Table 4).  Both matmuls and the ReLU are fused in one
VMEM pass over the node-feature matrix — at N ~ 10^5-10^6 nodes the layer
is memory-bound and the fusion removes two HBM round-trips of the (N, 32)
intermediate.  Feature/hidden dims are zero-padded to lane width by the
wrapper; weights stay resident in VMEM across the whole grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _score_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)           # (bn, F)
    h = jax.lax.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b1_ref[...], 0.0)        # (bn, H)
    q = jax.lax.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (q + b2_ref[...]).astype(o_ref.dtype)  # (bn, 1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sdqn_score(
    feats: jnp.ndarray,  # (N, F) float32 — normalized Table-2 features
    w1: jnp.ndarray,     # (F, H)
    b1: jnp.ndarray,     # (H,)
    w2: jnp.ndarray,     # (H, 1)
    b2: jnp.ndarray,     # (1,)
    *,
    block_n: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns Q-values (N,)."""
    n, f = feats.shape
    h = w1.shape[1]
    block_n = min(block_n, n)
    pad_n = (-n) % block_n
    if pad_n:
        feats = jnp.pad(feats, ((0, pad_n), (0, 0)))
    np_ = feats.shape[0]

    out = pl.pallas_call(
        _score_kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((1, h), lambda i: (0, 0)),
            pl.BlockSpec((h, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(feats, w1, b1.reshape(1, h), w2, b2.reshape(1, 1))
    return out[:n, 0]
