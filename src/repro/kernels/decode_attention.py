"""Pallas TPU flash-decode: one query token vs. a long KV cache.

Grid: (batch*heads, kv_blocks) with online-softmax state in VMEM scratch
(split-KV flash-decoding adapted to the TPU sequential-grid idiom: instead of
CUDA-style inter-SM parallel splits + a reduction pass, the kv axis is the
sequential innermost grid dimension and partial (m, l, acc) are carried in
scratch — one pass, no separate combine kernel needed).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                   scale, block_k):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0, 0]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (1, d)
        k = k_ref[0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale  # (1, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kv_len: jnp.ndarray,
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); kv_len: () or (B,) -> (B, Hq, D)."""
    b, hq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    block_k = min(block_k, skv)
    assert skv % block_k == 0

    qr = q.reshape(b * hq, 1, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1, 1), (b, 1))
    scale = 1.0 / math.sqrt(d)

    def kv_index(bh, ki):
        return ((bh // hq) * hkv + (bh % hq) // group, ki, 0)

    grid = (b * hq, skv // block_k)
    scratch = [
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, d), jnp.float32),
    ]
    if _VMEM is not None:
        scratch = [_VMEM(s.shape, s.dtype) for s in scratch]

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh // hq, 0)),
            pl.BlockSpec((1, 1, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(b, hq, d)
