"""Pallas TPU kernels for the perf-critical compute paths, with pure-jnp
oracles (ref.py) and backend-dispatching wrappers (ops.py)."""
from repro.kernels import ops, ref  # noqa: F401
