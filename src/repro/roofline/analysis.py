"""Three-term roofline from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / link_bw        (per-chip bytes — the
                      compiled SPMD module is the per-device program, so the
                      parsed collective operand sizes are already per chip)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

``cost_analysis()`` on the XLA:CPU backend reports FLOPs for the per-device
SPMD module; we therefore multiply by ``n_chips`` to recover global HLO
FLOPs before applying the formula (validated against 6·N·D for the dense
LMs — see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link


HW = Hardware()

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)

_TUPLE_COLLECTIVE_RE = re.compile(
    r"=\s*\(([^)]+)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


# computation defs may have nested parens in tuple signatures — only anchor
# on the leading name and the trailing "{"
_COMP_DEF_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)"
    r".*?condition=%?([\w.\-]+)"
    r".*?body=%?([\w.\-]+)"
)
_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")


def _parse_line_collective(line: str):
    """Returns (op, bytes) if the line is a collective, else None."""
    if "-done(" in line:
        return None  # the matching -start already counted this transfer
    m = _TUPLE_COLLECTIVE_RE.search(line)
    if m:
        total = sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(m.group(1)))
        return m.group(2), total
    m = _COLLECTIVE_RE.search(line)
    if m and m.group(1) in _DTYPE_BYTES:
        return m.group(3), _shape_bytes(m.group(1), m.group(2))
    return None


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Sum result sizes of every collective op in (per-device) HLO text,
    multiplied by the trip counts of enclosing while loops.

    ``lax.scan`` lowers to ``while`` whose condition compares the induction
    variable against a constant — collectives inside scan-over-layers /
    microbatch-accumulation bodies execute ``trip`` times per step, so the
    per-computation totals are scaled by the (possibly nested) trip counts.
    ``-start`` ops are counted; matching ``-done`` ops are not.
    """
    # 1. split into computations
    comps: Dict[str, list] = {}
    current = "__top__"
    comps[current] = []
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if not line.startswith(" ") and stripped.endswith("{"):
            m = _COMP_DEF_RE.match(stripped)
            if m:
                current = m.group(1)
                comps[current] = []
                continue
        comps.setdefault(current, []).append(line)

    # 2. per-computation: own collective bytes, outgoing edges, cond constants
    own: Dict[str, Dict[str, int]] = {}
    whiles: Dict[str, list] = {}
    plain_refs: Dict[str, set] = {}
    cond_consts: Dict[str, int] = {}
    ref_re = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
    for name, lines in comps.items():
        own[name] = {}
        whiles[name] = []
        plain_refs[name] = set()
        max_const = 0
        for line in lines:
            got = _parse_line_collective(line)
            if got:
                op, nbytes = got
                own[name][op] = own[name].get(op, 0) + nbytes
            wm = _WHILE_RE.search(line)
            if wm:
                whiles[name].append((wm.group(1), wm.group(2)))
            elif "to_apply=" in line or "calls=" in line:
                # follow call/fusion edges (closed_call bodies hold the scans);
                # reducer to_apply regions are harmless (no collectives inside)
                for rm in ref_re.finditer(line):
                    plain_refs[name].add(rm.group(1))
            cm = _CONST_RE.search(line)
            if cm:
                max_const = max(max_const, int(cm.group(1)))
        cond_consts[name] = max_const

    # 3. recursively accumulate:
    #    bytes(comp) = own + sum(trip * bytes(while body)) + sum(bytes(callees))
    memo: Dict[str, Dict[str, int]] = {}
    in_progress: set = set()

    def total(name: str, depth: int = 0) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        if name in in_progress or depth > 16:
            return {}
        in_progress.add(name)
        acc = dict(own.get(name, {}))
        for cond, body in whiles.get(name, []):
            trip = max(cond_consts.get(cond, 1), 1)
            for op, nbytes in total(body, depth + 1).items():
                acc[op] = acc.get(op, 0) + trip * nbytes
            for op, nbytes in total(cond, depth + 1).items():
                acc[op] = acc.get(op, 0) + nbytes
        for callee in plain_refs.get(name, ()):
            for op, nbytes in total(callee, depth + 1).items():
                acc[op] = acc.get(op, 0) + nbytes
        in_progress.discard(name)
        memo[name] = acc
        return acc

    entry = None
    for name in comps:
        if name.startswith("main") or "entry" in name.lower():
            entry = name
            break
    per_op: Dict[str, int] = {}
    roots = [entry] if entry else [n for n in comps if whiles.get(n) or own.get(n)]
    if entry:
        per_op = dict(total(entry))
    else:
        # fallback: flat sum without trip adjustment
        for name in comps:
            for op, nbytes in own.get(name, {}).items():
                per_op[op] = per_op.get(op, 0) + nbytes

    flat_counts: Dict[str, int] = {}
    for name, lines in comps.items():
        for line in lines:
            got = _parse_line_collective(line)
            if got:
                flat_counts[got[0]] = flat_counts.get(got[0], 0) + 1
    return {
        "per_op_bytes": per_op,
        "per_op_counts": flat_counts,
        "total_bytes": int(sum(per_op.values())),
        "entry": entry or "flat",
    }


def roofline_terms(
    *,
    n_chips: int,
    hlo_flops_global: float,
    model_flops: float,
    hbm_bytes_per_chip: float,
    collective_bytes_per_chip: float,
    hw: Hardware = HW,
) -> Dict[str, Any]:
    """The three roofline terms + bottleneck for one cell.

    hlo_flops_global: analytic implementation FLOPs (see flops.py).
    hbm_bytes_per_chip: analytic HBM traffic per chip.
    collective_bytes_per_chip: trip-adjusted per-chip collective bytes
    (the compiled SPMD module is the per-device program).
    """
    compute_s = hlo_flops_global / (n_chips * hw.peak_flops)
    memory_s = hbm_bytes_per_chip / hw.hbm_bw
    collective_s = collective_bytes_per_chip / hw.ici_bw

    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get) if any(v > 0 for v in terms.values()) else "n/a"
    bound = max(terms.values()) if any(terms.values()) else 0.0
    ideal = model_flops / (n_chips * hw.peak_flops) if n_chips else 0.0
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": (model_flops / hlo_flops_global) if hlo_flops_global else 0.0,
        "roofline_fraction": (ideal / bound) if bound else 0.0,
        "step_time_lower_bound_s": bound,
    }
