"""Analytic FLOP and HBM-byte models per (arch × shape).

``compiled.cost_analysis()`` counts each ``lax.scan`` body ONCE, so with
scan-over-layers (and microbatch scans) its FLOPs under-count by the trip
counts.  The roofline therefore uses this analytic model — exact matmul
accounting of the implementation as written (e.g. the chunked-attention XLA
path computes full-S scores per query chunk, so causal training costs
2·S²·H·hd, not the triangular minimum — the gap shows up in
``useful_flops_ratio`` by design).  Raw cost_analysis numbers are kept in
the dry-run JSON for reference.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import block_spec


def _attn_flops_per_token(cfg: ModelConfig, kv_len: float, causal: bool = True) -> float:
    hq, hkv, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, cfg.d_model
    proj = 2 * d * (hq + 2 * hkv) * hd + 2 * hq * hd * d
    # scores + value-combine; the XLA chunked path computes full-length scores
    # unless bucketed-causal is on (G buckets => (G+1)/2G of full length)
    g = max(cfg.causal_buckets, 1)
    eff_len = kv_len * (g + 1) / (2 * g) if (causal and g > 1) else kv_len
    mix = 2 * 2 * eff_len * hq * hd
    return proj + mix


def _mlp_flops_per_token(cfg: ModelConfig, ff: int) -> float:
    n_mats = 3 if cfg.act == "silu" else 2
    return n_mats * 2 * cfg.d_model * ff


def _moe_flops_per_token(cfg: ModelConfig) -> float:
    e_ff = cfg.moe_d_ff or cfg.d_ff
    f = 2 * cfg.d_model * cfg.moe_num_experts          # router
    f += cfg.moe_top_k * _mlp_flops_per_token(cfg, e_ff)
    if cfg.moe_shared_d_ff:
        f += _mlp_flops_per_token(cfg, cfg.moe_shared_d_ff) + 2 * cfg.d_model
    return f


def _mamba_flops_per_token(cfg: ModelConfig) -> float:
    d, di, n, r, cw = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    f = 2 * d * 2 * di                 # in_proj
    f += 2 * cw * di                   # depthwise conv
    f += 2 * di * (r + 2 * n)          # x_proj
    f += 2 * r * di                    # dt_proj
    f += 10 * di * n                   # discretize + recurrence + C-dot
    f += 2 * di * d                    # out_proj
    return f


def forward_flops_per_token(cfg: ModelConfig, kv_len: float) -> float:
    """Decoder-side forward FLOPs for one token attending to kv_len keys."""
    total = 0.0
    spec = block_spec(cfg)
    blocks = cfg.num_layers // len(spec)
    for sub in spec:
        if sub.mixer == "attn":
            total += _attn_flops_per_token(cfg, kv_len)
        else:
            total += _mamba_flops_per_token(cfg)
        if sub.cross:
            total += _attn_flops_per_token(cfg, cfg.enc_seq)
        if sub.ffn == "moe":
            total += _moe_flops_per_token(cfg)
        elif sub.ffn == "mlp":
            total += _mlp_flops_per_token(cfg, cfg.d_ff)
    return total * blocks


def cell_flops(cfg: ModelConfig, shape: ShapeConfig, *, remat_full: bool = True) -> Dict[str, float]:
    """Global FLOPs for one step of this cell, as implemented."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        fwd = b * s * forward_flops_per_token(cfg, kv_len=s)
        fwd += b * s * 2 * cfg.d_model * cfg.padded_vocab          # logits
        if cfg.is_encoder_decoder:
            enc = b * cfg.enc_seq * (
                _attn_flops_per_token(cfg, cfg.enc_seq) + _mlp_flops_per_token(cfg, cfg.d_ff)
            ) * cfg.enc_layers
            fwd += enc
        mult = 3 + (1 if remat_full else 0)   # fwd + 2x bwd + remat re-fwd
        hlo = fwd * mult
        model = 6 * cfg.active_param_count() * b * s
    elif shape.kind == "prefill":
        fwd = b * s * forward_flops_per_token(cfg, kv_len=s)
        fwd += b * 2 * cfg.d_model * cfg.padded_vocab              # last-pos logits
        if cfg.is_encoder_decoder:
            fwd += b * cfg.enc_seq * (
                _attn_flops_per_token(cfg, cfg.enc_seq) + _mlp_flops_per_token(cfg, cfg.d_ff)
            ) * cfg.enc_layers
        hlo = fwd
        model = 2 * cfg.active_param_count() * b * s
    else:  # decode: one token against a kv_len cache
        fwd = b * 1 * forward_flops_per_token(cfg, kv_len=s)
        fwd += b * 2 * cfg.d_model * cfg.padded_vocab
        hlo = fwd
        model = 2 * cfg.active_param_count() * b
    return {"hlo_flops": hlo, "model_flops": model}


def cell_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
                   num_microbatches: int = 1, tp: int = 16) -> float:
    """Per-chip HBM traffic estimate for one step (documented napkin model).

    weights: each microbatch reads the (TP-sharded) weights for fwd and bwd,
    plus remat re-read; grads accumulate read+write fp32; optimizer update
    reads/writes moments+master.
    activations: ~24 bytes/elem/layer of (tokens_local × d_model) traffic
    fwd+bwd, plus attention score traffic for the chunked implementation.
    kv cache: decode reads the whole local cache shard once.
    """
    p_bytes = cfg.param_count() * 2            # bf16
    p_local = p_bytes / n_chips
    p_gathered = p_bytes / tp                  # after FSDP all-gather, per chip
    b, s = shape.global_batch, shape.seq_len
    bpe = 2

    if shape.kind == "train":
        nm = num_microbatches
        w = p_gathered * nm * 3                # fwd + bwd + remat reads
        w += p_local * 4 * 2 * nm              # fp32 grad accum rw
        w += p_local * 4 * 6                   # adam m/v/master rw
        dp = max(n_chips / tp, 1)
        tokens_local = b * s / dp
        act = 0.0
        for mult, width in ((24, cfg.d_model), (6, cfg.d_ff or cfg.d_inner)):
            act += mult * tokens_local * width * bpe * cfg.num_layers / max(tp, 1)
        # attention scores traffic (full-S chunked): 2 passes of B·H·S² fp32
        if cfg.num_heads:
            spec = block_spec(cfg)
            n_attn = cfg.num_layers * sum(1 for sub in spec if sub.mixer == "attn") // len(spec)
            act += 2 * (b / dp) * (cfg.num_heads / tp) * s * s * 4 * n_attn
        return w + act
    if shape.kind == "prefill":
        tokens_local = b * s / max(n_chips / tp, 1)
        w = p_gathered
        act = 10 * tokens_local * cfg.d_model * bpe * cfg.num_layers / max(tp, 1)
        return w + act
    # decode: weight-stationary (XLA keeps weights fully sharded and
    # all-reduces the tiny single-token activations — confirmed by the
    # near-zero collective bytes in the compiled HLO): p/n_chips per chip
    w = p_bytes / n_chips
    if cfg.num_heads:
        n_attn = sum(1 for sub in block_spec(cfg) if sub.mixer == "attn")
        blocks = cfg.num_layers // len(block_spec(cfg))
        cache_bpe = 1 if cfg.cache_dtype.startswith("float8") else 2
        cache = (
            blocks * n_attn * b * s * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * cache_bpe
        ) / n_chips
        w += cache
    if cfg.family in ("ssm", "hybrid"):
        n_mamba = sum(1 for sub in block_spec(cfg) if sub.mixer == "mamba")
        blocks = cfg.num_layers // len(block_spec(cfg))
        w += blocks * n_mamba * b * cfg.d_inner * cfg.ssm_state * 4 / n_chips
    return w
