"""AdamW in pure JAX (no optax available offline).

Supports mixed precision: bf16 params with fp32 master copies + fp32 moments
(``master_dtype``), or fully low-precision states for memory-limited configs
(``moment_dtype="bfloat16"`` — used by the biggest assigned archs, see
EXPERIMENTS.md §Dry-run memory notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3          # paper Table 4: Adam, lr=0.001
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: float = 0.0   # 0 => off
    moment_dtype: str = "float32"
    master_dtype: str = "float32"  # "" => update params in their own dtype


def adam_init(params: Any, cfg: AdamConfig) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params),
    }
    if cfg.master_dtype:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.dtype(cfg.master_dtype)), params
        )
    return state


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(
    params: Any,
    grads: Any,
    state: dict,
    cfg: AdamConfig,
    lr_schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = cfg.lr if lr_schedule is None else lr_schedule(step)
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd_moment(m, g, beta):
        return (beta * m.astype(jnp.float32) + (1 - beta) * g.astype(jnp.float32)).astype(mdt)

    new_m = jax.tree.map(lambda m, g: upd_moment(m, g, b1), state["m"], grads)
    new_v = jax.tree.map(lambda v, g: upd_moment(v, g * g, b2), state["v"], grads)

    masters = state.get("master", params)

    def upd_param(p, m, v):
        mhat = m.astype(jnp.float32) / bc1
        vhat = v.astype(jnp.float32) / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_masters = jax.tree.map(upd_param, masters, new_m, new_v)
    new_state = {"step": step, "m": new_m, "v": new_v}
    if "master" in state:
        new_state["master"] = new_masters
        new_params = jax.tree.map(
            lambda mp, p: mp.astype(p.dtype), new_masters, params
        )
    else:
        new_params = new_masters
    stats = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, new_state, stats
