"""Seed-parallel, mesh-sharded training engine (see ``repro.train.engine``)."""
from repro.train.engine import (  # noqa: F401
    Selection,
    seed_fold_keys,
    select_best,
    train_and_select,
    train_seeds,
)
