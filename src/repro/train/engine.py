"""Multi-candidate training engine: every seed of ``train_and_select`` is one
XLA program.

The paper's "Algorithm Selection and Scheduler Development" step trains
several candidate SDQN/SDQN-n policies and keeps the best on held-out
validation bursts.  Sequentially that costs ``n_seeds`` full training
dispatches from Python; here the *entire* training scan —
``lax.scan(episodes) ∘ lax.scan(arrivals) ∘ vmap(n_envs)`` — is vmapped once
more over the seed ladder, so all candidates compile once and run as a
single launch:

    stacked_params, metrics = train_seeds(key, cfg, rl, n_seeds=4)

The seed keys are ``fold_in(key, s)`` — the exact ladder the sequential loop
used — so per-seed results match the one-seed-at-a-time path exactly up to
float reassociation (vmap batches the learner's matmul/reduction
accumulations; the drift is ~1e-9 per step, pinned to <=1e-6 in tests, and
the PRNG streams are identical).
Validation feeds the stacked params through one batched evaluator
(``eval.engine.make_multi_param_evaluator``: all (seed, trial) episodes in
one launch) and the winner is a NaN-guarded on-device argmin.

On a mesh, ``launch.mesh.plan_seed_env_layout`` picks the joint seed×env
layout: a 2-D ``("seed", "data")`` grid that shards the seed ladder over
``seed`` (whole training replicas per device group — the cheapest layout:
zero cross-device traffic until selection) and each seed's ``n_envs`` batch
over ``data``, so **all** devices are busy whenever the device count
divides ``n_seeds * n_envs``.  ``env_shards == 1`` degenerates to PR 3's pure
seed sharding (one flattened parallel axis), ``seed_shards == 1`` to pure
env sharding; an indivisible batch — and ``mesh=None``, the CPU/test
default — runs the bit-compatible single-device vmap.
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import policy as policy_mod, schedulers, train_rl
from repro.core.types import EnvConfig
from repro.eval import engine as eval_engine
from repro.launch import mesh as meshmod


def seed_fold_keys(key: jax.Array, n_seeds: int) -> jax.Array:
    """(S, ...) candidate-seed keys, identical to ``fold_in(key, s)``."""
    return jax.vmap(lambda s: jax.random.fold_in(key, s))(jnp.arange(n_seeds))


@functools.partial(jax.jit, static_argnames=("env_cfg", "rl", "mesh"))
def _seed_train(keys, env_cfg: EnvConfig, rl: train_rl.RLConfig, mesh=None):
    """Jitted ``(seed_keys) -> (stacked_params, stacked_metrics)``; jax's own
    cache keys on the static (env_cfg, rl, mesh), so repeated selection
    rounds (benchmark sweeps, hyperparameter scans) reuse one executable.

    ``plan_seed_env_layout`` maps the (n_seeds, n_envs) batch onto the mesh:
    the key ladder is pinned to the layout's ``seed`` axis and, when the
    layout splits devices across envs too (``env_shards > 1``), the inner
    ``train``'s ``n_envs`` constraints run under ``vmap(spmd_axis_name=
    "seed")`` so every batched ``with_sharding_constraint`` spec re-anchors
    as ``("seed", ..., "data")`` instead of being dropped on the batched
    seed dimension.  No layout (``mesh=None``, one device, indivisible
    batch) is the plain single-device vmap, bit-compatible with PR 3.
    """
    layout = meshmod.plan_seed_env_layout(keys.shape[0], rl.n_envs, mesh)
    if layout is None:
        return jax.vmap(lambda k: train_rl.train(k, env_cfg, rl))(keys)
    from jax.sharding import NamedSharding, PartitionSpec as P

    keys = jax.lax.with_sharding_constraint(
        keys, NamedSharding(layout.mesh, P("seed")))
    if layout.env_shards == 1:
        # pure seed sharding: whole replicas per device, no inner constraints
        return jax.vmap(lambda k: train_rl.train(k, env_cfg, rl))(keys)
    return jax.vmap(
        lambda k: train_rl.train(k, env_cfg, rl, mesh=layout.mesh),
        spmd_axis_name="seed",
    )(keys)


def train_seeds(
    key: jax.Array,
    env_cfg: EnvConfig,
    rl: train_rl.RLConfig,
    n_seeds: int,
    mesh=None,
) -> Tuple[dict, dict]:
    """Train ``n_seeds`` candidate policies in ONE compiled launch.

    Returns (stacked qparams with leading seed dim, stacked metrics dict of
    (S, episodes) arrays).  Seed s of the stack equals
    ``train(fold_in(key, s), ...)``: same PRNG streams, values equal up to
    float reassociation from batching (<=1e-6 over a training run).
    """
    return _seed_train(seed_fold_keys(key, n_seeds), env_cfg, rl, mesh)


class Selection(NamedTuple):
    """``select_best``'s result; unpacks as ``(params, metric, diverged)``."""

    params: dict
    metric: jnp.ndarray    # () guarded validation metric of the winner
    diverged: jnp.ndarray  # () bool: EVERY candidate was NaN — params are
                           # the seed-0 fallback, not a real selection


def select_best(stacked_params: dict, metrics: jnp.ndarray) -> Selection:
    """NaN-guarded candidate selection: (params of best seed, its metric,
    all-NaN warning flag).

    NaN metrics never win (``x < NaN`` and ``NaN < x`` are both False, so a
    naive running-min would keep its ``inf`` start and return no params at
    all) — they are demoted to ``+inf`` before the argmin.  If *every* seed
    is NaN the argmin lands on seed 0, so callers always get real params —
    and ``diverged`` is True so they can tell "seed 0 won" apart from
    "everything diverged" (the metric alone cannot: both report seed 0).
    """
    guarded = jnp.where(jnp.isnan(metrics), jnp.inf, metrics)
    best = jnp.argmin(guarded)
    return Selection(jax.tree.map(lambda x: x[best], stacked_params),
                     guarded[best], jnp.all(jnp.isnan(metrics)))


def train_and_select(
    key: jax.Array,
    train_cfg: EnvConfig,
    eval_cfg: EnvConfig,
    rl: train_rl.RLConfig,
    n_seeds: int = 4,
    val_trials: int = 12,
    val_pods: Optional[int] = 50,
    mesh=None,
):
    """Seed-parallel train + batched validation + on-device selection.

    The engine form of ``train_rl.train_and_select`` (which delegates here):
    one launch trains all seeds, one launch runs all (seed, trial)
    validation episodes, and the argmin happens on device.  Returns
    ``(best_params, float(best_val_metric))``.
    """
    stacked, _ = train_seeds(key, train_cfg, rl, n_seeds, mesh=mesh)
    # validation uses the same policy class that trained: the factory pair
    # form threads sequence specs' history carry through each episode; for
    # "mlp" it scores identically to make_sdqn_selector (same qvalues path)
    spec = policy_mod.get(rl.policy)
    evaluator = eval_engine.make_multi_param_evaluator(
        eval_cfg, lambda p: schedulers.make_policy_selector(spec, p, eval_cfg),
        val_pods)
    val_keys = eval_engine.fixed_trial_keys(5000, val_trials)
    metrics = jnp.mean(evaluator(stacked, val_keys).metric, axis=1)   # (S,)
    best_params, best_metric, diverged = select_best(stacked, metrics)
    if bool(diverged):
        warnings.warn(
            f"train_and_select: every candidate's validation metric was NaN "
            f"({n_seeds} seeds) — returning seed 0's params unselected; "
            f"treat them as diverged",
            RuntimeWarning, stacklevel=2)
    return best_params, float(best_metric)
