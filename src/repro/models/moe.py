"""Mixture-of-Experts layer (top-k routed + optional shared expert).

Dispatch is sort-based (MegaBlocks/MaxText style): token→expert assignments
are sorted by expert id, gathered into a dense (E, C, D) buffer with a
capacity bound, pushed through per-expert SwiGLU weights with a single
batched einsum, and scattered back weighted by the router probabilities.
This keeps memory at O(E·C·D) (bounded by the capacity factor) instead of the
O(T·E·C) of one-hot dispatch masks, and lowers cleanly under pjit with
experts sharded on the ``model`` axis (EP) — or, when E is not divisible by
the TP degree (qwen2-moe: 60 experts on a 16-way axis), with the expert FFN
dimension sharded instead (TP-within-expert).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def init_moe(key, cfg, dtype) -> dict:
    d = cfg.d_model
    e = cfg.moe_num_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 6)
    out_scale = 1.0 / math.sqrt(2 * cfg.num_layers * ff)
    p = {
        "router": layers.dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": layers.dense_init(ks[1], (e, d, ff), dtype),
        "w_up": layers.dense_init(ks[2], (e, d, ff), dtype),
        "w_down": layers.dense_init(ks[3], (e, ff, d), dtype, scale=out_scale),
    }
    if cfg.moe_shared_d_ff:
        p["shared"] = layers.init_mlp(ks[4], d, cfg.moe_shared_d_ff, cfg.act, dtype, cfg.num_layers)
        p["shared_gate"] = layers.dense_init(ks[5], (d, 1), jnp.float32)
    return p


def route(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (T, D) -> (weights (T,k) fp32 normalized, idx (T,k) int32)."""
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (T, E)
    weights, idx = jax.lax.top_k(logits, top_k)
    weights = jax.nn.softmax(weights, axis=-1)
    return weights, idx.astype(jnp.int32)


def dispatch_indices(idx: jnp.ndarray, num_experts: int, capacity: int):
    """Sort-based dispatch bookkeeping.

    idx: (T, k) expert assignment. Returns (token_of_slot (E*C,), valid mask,
    slot_of_assignment (T, k), within-capacity mask (T, k)).
    """
    t, k = idx.shape
    flat_expert = idx.reshape(-1)  # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_expert, stable=True)  # group by expert
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    # position within the expert's group
    counts = jnp.bincount(flat_expert, length=num_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_expert].astype(jnp.int32)
    keep = pos_in_expert < capacity
    slot = sorted_expert.astype(jnp.int32) * capacity + jnp.minimum(pos_in_expert, capacity - 1)
    # token index occupying each (expert, capacity) slot; -1 = empty
    token_of_slot = jnp.full((num_experts * capacity,), -1, jnp.int32)
    token_of_slot = token_of_slot.at[jnp.where(keep, slot, num_experts * capacity - 1)].set(
        jnp.where(keep, sorted_token, -1), mode="drop"
    )
    # map back: for each (token, k) assignment, which slot holds it
    inv = jnp.zeros((t * k,), jnp.int32).at[order].set(jnp.where(keep, slot, -1))
    slot_of_assignment = inv.reshape(t, k)
    return token_of_slot, slot_of_assignment


def apply_moe(params: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D).

    ``cfg.moe_dispatch == "batched"``: route each batch row independently
    (vmap) — dispatch stays local to the row's data shard instead of
    gathering the full global token set; capacity is per-row (see §Perf).
    """
    if cfg.moe_dispatch == "batched" and x.shape[0] > 1:
        return jax.vmap(lambda xr: _apply_moe_global(params, xr[None], cfg)[0])(x)
    return _apply_moe_global(params, x, cfg)


def _apply_moe_global(params: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    xf = x.reshape(b * s, d)
    t = b * s
    capacity = max(int(math.ceil(t * k / e * cfg.moe_capacity_factor)), 1)
    # round capacity for TPU-friendly layouts
    capacity = ((capacity + 7) // 8) * 8

    weights, idx = route(params["router"], xf, k)
    token_of_slot, slot_of_assignment = dispatch_indices(idx, e, capacity)

    # gather tokens into expert buffers: (E, C, D)
    gathered = jnp.where(
        (token_of_slot >= 0)[:, None],
        xf[jnp.maximum(token_of_slot, 0)],
        jnp.zeros((1, d), xf.dtype),
    ).reshape(e, capacity, d)

    # per-expert SwiGLU
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", gathered, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", gathered, params["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(e * capacity, d)

    # scatter back, weighted; dropped tokens (slot == -1) contribute zero
    safe_slot = jnp.maximum(slot_of_assignment, 0)  # (T, k)
    per_assign = out_buf[safe_slot]  # (T, k, D)
    w = weights * (slot_of_assignment >= 0)
    combined = jnp.einsum("tkd,tk->td", per_assign.astype(jnp.float32), w)
    out = combined.astype(x.dtype)

    if "shared" in params:
        shared = layers.apply_mlp(params["shared"], xf, cfg.act)
        gate = jax.nn.sigmoid((xf.astype(jnp.float32) @ params["shared_gate"]))
        out = out + (gate * shared.astype(jnp.float32)).astype(x.dtype)
    return out.reshape(b, s, d)


def load_balance_loss(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int) -> jnp.ndarray:
    """Switch-style auxiliary load-balancing loss (mean over tokens)."""
    t = x.shape[0] * x.shape[1]
    xf = x.reshape(t, -1)
    logits = (xf.astype(jnp.float32) @ router_w)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    _, idx = jax.lax.top_k(logits, top_k)
    e = logits.shape[-1]
    hard = jnp.zeros_like(probs).at[jnp.arange(t)[:, None], idx].set(1.0)
    frac_tokens = hard.mean(axis=0) / top_k
    frac_probs = probs.mean(axis=0)
    return e * jnp.sum(frac_tokens * frac_probs)
