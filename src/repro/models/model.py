"""Unified LM-family model: dense / MoE / SSM / hybrid / enc-dec / VLM.

One code path covers all ten assigned architectures.  Layers are grouped into
*blocks* (the repeating unit — one layer for homogeneous archs, a period of
``attn_period`` layers for jamba) and the model scans over stacked block
parameters (``lax.scan``), keeping HLO size O(1) in depth.  KV / SSM caches
are pytrees stacked the same way so prefill and decode scan in lockstep with
the parameters.

Public entry points:
  init_params, loss_and_metrics (train), prefill, decode_step, init_cache
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, mamba, moe


@dataclasses.dataclass(frozen=True)
class SubLayer:
    mixer: str  # "attn" | "mamba"
    ffn: str    # "mlp" | "moe" | "none"
    cross: bool = False  # enc-dec cross attention after the mixer
    causal: bool = True


def block_spec(cfg: ModelConfig) -> List[SubLayer]:
    """The repeating sub-layer structure of one scan block (decoder side)."""
    if cfg.family == "ssm":
        return [SubLayer("mamba", "none")]  # mamba-1 blocks have no separate FFN
    if cfg.attn_period:  # hybrid (jamba)
        subs = []
        for j in range(cfg.attn_period):
            mixer = "attn" if j % cfg.attn_period == cfg.attn_offset else "mamba"
            use_moe = cfg.moe_num_experts and (j % cfg.moe_every == cfg.moe_every - 1)
            subs.append(SubLayer(mixer, "moe" if use_moe else "mlp"))
        return subs
    ffn = "moe" if cfg.moe_num_experts else "mlp"
    return [SubLayer("attn", ffn, cross=cfg.is_encoder_decoder)]


def num_blocks(cfg: ModelConfig) -> int:
    spec = block_spec(cfg)
    assert cfg.num_layers % len(spec) == 0, (cfg.num_layers, len(spec))
    return cfg.num_layers // len(spec)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stacked(init_fn, key, nb: int):
    return jax.vmap(init_fn)(jax.random.split(key, nb))


def _init_sublayer(key, sub: SubLayer, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": layers.init_norm(cfg.norm, cfg.d_model, dtype)}
    if sub.mixer == "attn":
        p["attn"] = layers.init_attention(ks[0], cfg, dtype)
    else:
        p["mamba"] = mamba.init_mamba(ks[1], cfg, dtype)
    if sub.cross:
        p["cross_norm"] = layers.init_norm(cfg.norm, cfg.d_model, dtype)
        p["cross"] = layers.init_attention(ks[2], cfg, dtype)
    if sub.ffn != "none":
        p["norm2"] = layers.init_norm(cfg.norm, cfg.d_model, dtype)
        if sub.ffn == "moe":
            p["moe"] = moe.init_moe(ks[3], cfg, dtype)
        else:
            p["mlp"] = layers.init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg.act, dtype, cfg.num_layers)
    return p


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    nb = num_blocks(cfg)
    spec = block_spec(cfg)

    params: Dict[str, Any] = {
        "embed": layers.embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "final_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
        "layers": {
            f"sub{j}": _stacked(lambda k, s=sub: _init_sublayer(k, s, cfg, dtype), ks[1 + (j % 4)], nb)
            for j, sub in enumerate(spec)
        },
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(ks[5], (cfg.d_model, cfg.padded_vocab), dtype, scale=cfg.d_model**-0.5)
    if cfg.is_encoder_decoder:
        enc_sub = SubLayer("attn", "mlp", causal=False)
        params["encoder"] = {
            "layers": {
                "sub0": _stacked(lambda k: _init_sublayer(k, enc_sub, cfg, dtype), ks[6], cfg.enc_layers)
            },
            "final_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    """Decode cache pytree: per sub-layer, stacked over blocks."""
    dtype = dtype or jnp.dtype(cfg.cache_dtype)
    nb = num_blocks(cfg)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    cache: Dict[str, Any] = {}
    for j, sub in enumerate(block_spec(cfg)):
        c: Dict[str, Any] = {}
        if sub.mixer == "attn":
            c["k"] = jnp.zeros((nb, batch, max_len, hkv, hd), dtype)
            c["v"] = jnp.zeros((nb, batch, max_len, hkv, hd), dtype)
        else:
            c["conv"] = jnp.zeros((nb, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)
            c["h"] = jnp.zeros((nb, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
        if sub.cross:
            c["xk"] = jnp.zeros((nb, batch, cfg.enc_seq, hkv, hd), dtype)
            c["xv"] = jnp.zeros((nb, batch, cfg.enc_seq, hkv, hd), dtype)
        cache[f"sub{j}"] = c
    return cache


# ---------------------------------------------------------------------------
# forward machinery
# ---------------------------------------------------------------------------


def _embed_tokens(params, cfg, tokens, extra: Optional[dict]) -> jnp.ndarray:
    x = params["embed"][tokens]  # (B, S, D)
    if cfg.num_vision_tokens and extra is not None and "patch_embeds" in extra:
        nv = extra["patch_embeds"].shape[1]
        x = jnp.concatenate([extra["patch_embeds"].astype(x.dtype), x[:, nv:]], axis=1)
    return x


def _sinusoidal(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _run_attn(sp, x, cfg, *, positions, causal, q_chunk, cache_kv=None, cache_index=None,
              kv_override=None, collect_kv=False):
    """One attention sub-layer body (shared by train / prefill / decode)."""
    q, k, v = layers.attention_qkv(sp, x, cfg)
    if kv_override is not None:  # cross attention: kv precomputed from encoder
        k, v = kv_override
        o = layers.attention(q, k, v, causal=False, q_chunk=q_chunk)
        return layers.attention_out(sp, o), None
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    new_kv = None
    if cache_kv is not None and cache_index is not None:  # decode: write + attend
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_index, axis=1)
        o = layers.attention(q, ck, cv, causal=False, q_chunk=q_chunk,
                             kv_len=cache_index + 1, q_offset=cache_index)
        new_kv = (ck, cv)
    else:
        o = layers.attention(q, k, v, causal=causal, q_chunk=q_chunk,
                             causal_buckets=cfg.causal_buckets)
        if collect_kv:
            new_kv = (k, v)
    return layers.attention_out(sp, o), new_kv


def _cross_kv(sp, enc_out, cfg):
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    b, s, _ = enc_out.shape
    k = (enc_out @ sp["wk"]).reshape(b, s, hkv, hd)
    v = (enc_out @ sp["wv"]).reshape(b, s, hkv, hd)
    return k, v


def _block_fn(block_params, x, cfg, spec, *, mode, positions, q_chunk, mamba_chunk,
              block_cache=None, cache_index=None, enc_out=None, act_sharding=None,
              mlp_sharding=None):
    """Run one block (all sub-layers). Returns (x, new_block_cache, aux_loss)."""
    if act_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, act_sharding)
    new_cache: Dict[str, Any] = {}
    aux = jnp.float32(0.0)
    for j, sub in enumerate(spec):
        sp = block_params[f"sub{j}"]
        sc = block_cache[f"sub{j}"] if block_cache is not None else None
        ncache: Dict[str, Any] = {}
        h = layers.apply_norm(cfg.norm, sp["norm1"], x)
        if sub.mixer == "attn":
            if mode == "decode":
                out, kv = _run_attn(sp["attn"], h, cfg, positions=positions, causal=True,
                                    q_chunk=q_chunk, cache_kv=(sc["k"], sc["v"]),
                                    cache_index=cache_index)
                ncache["k"], ncache["v"] = kv
            else:
                out, kv = _run_attn(sp["attn"], h, cfg, positions=positions,
                                    causal=sub.causal, q_chunk=q_chunk,
                                    collect_kv=mode == "prefill")
                if mode == "prefill":
                    ncache["k"], ncache["v"] = kv
        else:  # mamba
            if mode == "decode":
                out, (conv, hstate) = mamba.decode_mamba(sp["mamba"], h, cfg, (sc["conv"], sc["h"]))
                ncache["conv"], ncache["h"] = conv, hstate
            else:
                out, (conv, hstate) = mamba.apply_mamba(sp["mamba"], h, cfg, chunk=mamba_chunk)
                if mode == "prefill":
                    ncache["conv"], ncache["h"] = conv.astype(jnp.bfloat16), hstate
        x = x + out

        if sub.cross:
            hc = layers.apply_norm(cfg.norm, sp["cross_norm"], x)
            if mode == "decode":
                kv = (sc["xk"], sc["xv"])
                ncache["xk"], ncache["xv"] = kv  # pass through so cache structure persists
            else:
                kv = _cross_kv(sp["cross"], enc_out, cfg)
                if mode == "prefill":
                    ncache["xk"], ncache["xv"] = kv
            out, _ = _run_attn(sp["cross"], hc, cfg, positions=positions, causal=False,
                               q_chunk=q_chunk, kv_override=kv)
            x = x + out

        if sub.ffn != "none":
            if mlp_sharding is not None:
                # serving: replicate the tiny single-token activations so the
                # FSDP-sharded FFN weights are consumed in place (partial
                # matmul + small all-reduce) instead of gathered per layer
                x = jax.lax.with_sharding_constraint(x, mlp_sharding)
            h2 = layers.apply_norm(cfg.norm, sp["norm2"], x)
            if sub.ffn == "moe":
                out = moe.apply_moe(sp["moe"], h2, cfg)
                if mode == "train":
                    aux = aux + moe.load_balance_loss(sp["moe"]["router"], h2, cfg.moe_top_k)
            else:
                out = layers.apply_mlp(sp["mlp"], h2, cfg.act)
            x = x + out
        new_cache[f"sub{j}"] = ncache
    return x, new_cache, aux


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


def _scan_blocks(params, x, cfg, *, mode, positions, q_chunk, mamba_chunk,
                 cache=None, cache_index=None, enc_out=None, stack_key="layers",
                 spec=None, act_sharding=None, mlp_sharding=None):
    spec = spec or block_spec(cfg)

    def body(carry, scanned):
        xc, aux_c = carry
        if cache is not None:
            bp, bc = scanned
        else:
            bp, bc = scanned, None
        xc, ncache, aux = _block_fn(bp, xc, cfg, spec, mode=mode, positions=positions,
                                    q_chunk=q_chunk, mamba_chunk=mamba_chunk,
                                    block_cache=bc, cache_index=cache_index,
                                    enc_out=enc_out, act_sharding=act_sharding,
                                    mlp_sharding=mlp_sharding)
        return (xc, aux_c + aux), ncache

    policy = _remat_policy(cfg)
    if policy is not None:
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)
    elif cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (params[stack_key], cache) if cache is not None else params[stack_key]
    if cfg.scan_layers:
        (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    else:
        carry = (x, jnp.float32(0.0))
        outs = []
        nb = jax.tree_util.tree_leaves(params[stack_key])[0].shape[0]
        for i in range(nb):
            sl = jax.tree.map(lambda a: a[i], xs)
            carry, nc = body(carry, sl)
            outs.append(nc)
        x, aux = carry
        new_cache = jax.tree.map(lambda *a: jnp.stack(a), *outs) if outs and outs[0] else None
    return x, aux, new_cache


def _encode(params, cfg, frames, q_chunk):
    """Whisper encoder over precomputed (stub) frame embeddings."""
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model).astype(frames.dtype)
    spec = [SubLayer("attn", "mlp", causal=False)]
    positions = jnp.arange(frames.shape[1])
    x, _, _ = _scan_blocks(params["encoder"], x, cfg, mode="train", positions=positions,
                           q_chunk=q_chunk, mamba_chunk=64, spec=spec)
    return layers.apply_norm(cfg.norm, params["encoder"]["final_norm"], x)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray, extra: Optional[dict] = None,
            *, mode: str = "train", cache=None, cache_index=None,
            q_chunk: int = 512, mamba_chunk: int = 64, act_sharding=None,
            mlp_sharding=None):
    """Returns (hidden_states, new_cache, aux_loss)."""
    x = _embed_tokens(params, cfg, tokens, extra)
    enc_out = None
    if cfg.is_encoder_decoder and mode != "decode":
        enc_out = _encode(params, cfg, extra["frames"], q_chunk)
    if mode == "decode":
        positions = jnp.asarray(cache_index)
        x_pos = positions[None] if positions.ndim == 0 else positions
        positions = jnp.broadcast_to(x_pos, (1,))
    else:
        positions = jnp.arange(tokens.shape[1])
    x, aux, new_cache = _scan_blocks(params, x, cfg, mode=mode, positions=positions,
                                     q_chunk=q_chunk, mamba_chunk=mamba_chunk,
                                     cache=cache, cache_index=cache_index, enc_out=enc_out,
                                     act_sharding=act_sharding, mlp_sharding=mlp_sharding)
    x = layers.apply_norm(cfg.norm, params["final_norm"], x)
    return x, new_cache, aux


def logits_from_hidden(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=jnp.float32)


def loss_and_metrics(params, cfg: ModelConfig, batch: dict,
                     *, q_chunk: int = 512, mamba_chunk: int = 64,
                     aux_weight: float = 0.01, z_weight: float = 1e-4,
                     act_sharding=None):
    """Causal-LM loss. batch: tokens, targets, (loss_mask), (frames/patch_embeds)."""
    x, _, aux = forward(params, cfg, batch["tokens"], batch, mode="train",
                        q_chunk=q_chunk, mamba_chunk=mamba_chunk,
                        act_sharding=act_sharding)
    logits = logits_from_hidden(params, cfg, x)  # (B, S, Vp) fp32
    targets = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    zloss = ((logz**2) * mask).sum() / denom
    loss = ce + z_weight * zloss + aux_weight * aux
    metrics = {"loss": loss, "ce": ce, "zloss": zloss, "aux": aux,
               "accuracy": ((logits.argmax(-1) == targets) * mask).sum() / denom}
    return loss, metrics


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray, extra: Optional[dict] = None,
            *, q_chunk: int = 512, mamba_chunk: int = 64, act_sharding=None):
    """Run the prompt, return (last-token logits, cache ready for decode)."""
    x, cache, _ = forward(params, cfg, tokens, extra, mode="prefill",
                          q_chunk=q_chunk, mamba_chunk=mamba_chunk,
                          act_sharding=act_sharding)
    logits = logits_from_hidden(params, cfg, x[:, -1:])
    return logits[:, 0], cache


def decode_step(params, cfg: ModelConfig, tokens: jnp.ndarray, cache, cache_index,
                *, q_chunk: int = 512, act_sharding=None, mlp_sharding=None):
    """One token: tokens (B, 1), cache_index = #tokens already cached.

    Returns (logits (B, Vp), new_cache).
    """
    x, new_cache, _ = forward(params, cfg, tokens, mode="decode", cache=cache,
                              cache_index=cache_index, q_chunk=q_chunk,
                              act_sharding=act_sharding, mlp_sharding=mlp_sharding)
    logits = logits_from_hidden(params, cfg, x[:, -1:])
    return logits[:, 0], new_cache
