"""Mamba-1 selective-state-space block (falcon-mamba / jamba substrate).

Training/prefill uses a *chunked* selective scan: ``lax.scan`` over sequence
chunks with an in-chunk ``associative_scan`` — O(S·d_inner·N) memory bounded
per chunk, parallel within a chunk, sequential across chunks.  On TPU the
Pallas ``mamba_scan`` kernel implements the same chunking in VMEM
(``repro.kernels``); this module is the XLA path and the semantic reference.
Decode carries (conv_state, ssm_state) and is O(1) per token.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def init_mamba(key, cfg, dtype) -> dict:
    d, di, n, r, cw = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    dt = jnp.exp(
        jax.random.uniform(ks[4], (di,), jnp.float32)
        * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    return {
        "in_proj": layers.dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": layers.dense_init(ks[1], (cw, di), dtype, scale=1.0 / math.sqrt(cw)),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers.dense_init(ks[2], (di, r + 2 * n), dtype),
        "dt_proj": layers.dense_init(ks[3], (r, di), dtype, scale=r**-0.5),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": layers.dense_init(ks[5], (di, d), dtype, scale=1.0 / math.sqrt(2 * cfg.num_layers * di)),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, S, di), w: (cw, di)."""
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(cw):  # cw is tiny (4): unrolled adds, no conv primitive needed
        out = out + pad[:, j : j + x.shape[1], :] * w[j][None, None, :]
    return out + b[None, None, :]


def _ssm_params(params: dict, x: jnp.ndarray, n: int, r: int):
    """x: (B, S, di) -> dt (B,S,di) fp32, Bmat/Cmat (B,S,N) fp32."""
    proj = (x @ params["x_proj"]).astype(jnp.float32)
    dt, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"].astype(jnp.float32) + params["dt_bias"])
    return dt, bmat, cmat


def selective_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    a: jnp.ndarray,
    bmat: jnp.ndarray,
    cmat: jnp.ndarray,
    d_skip: jnp.ndarray,
    h0: jnp.ndarray,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked selective scan.

    x: (B, S, di)   input sequence (post conv+silu)
    dt: (B, S, di)  fp32 discretization steps
    a: (di, N)      fp32 (negative) state matrix
    bmat/cmat: (B, S, N) fp32 input/output projections
    h0: (B, di, N)  fp32 incoming state
    Returns (y (B, S, di), h_final (B, di, N)).
    """
    bsz, s, di = x.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # chunk the raw inputs; discretized (B, chunk, di, N) tensors are built
    # INSIDE the loop body so only one chunk's worth is ever materialized
    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    x_c = to_chunks(x.astype(jnp.float32))                 # (nc, B, chunk, di)
    dt_c = to_chunks(dt)
    bm_c = to_chunks(bmat)                                 # (nc, B, chunk, N)
    cm_c = to_chunks(cmat)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def body(h, args):
        x_k, dt_k, bm_k, cm_k = args
        da_k = jnp.exp(dt_k[..., None] * a[None, None])     # (B, chunk, di, N)
        dbx_k = (dt_k * x_k)[..., None] * bm_k[:, :, None, :]
        acum, bcum = jax.lax.associative_scan(combine, (da_k, dbx_k), axis=1)
        h_t = acum * h[:, None] + bcum                      # (B, chunk, di, N)
        y = jnp.einsum("bcdn,bcn->bcd", h_t, cm_k)
        return h_t[:, -1], y

    h_final, y = jax.lax.scan(body, h0, (x_c, dt_c, bm_c, cm_c))
    y = y.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :]
    return y.astype(x.dtype), h_final


def apply_mamba(params: dict, x: jnp.ndarray, cfg, h0=None, conv0=None, chunk: int = 64):
    """Full block for train/prefill. x: (B, S, D) -> (B, S, D).

    Returns (out, (conv_state, ssm_state)) so prefill can seed decode.
    """
    bsz, s, _ = x.shape
    di, n, r, cw = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    if conv0 is not None:  # continue from cached conv tail
        xi_ext = jnp.concatenate([conv0.astype(xi.dtype), xi], axis=1)
        conv_out = _causal_conv(xi_ext, params["conv_w"], params["conv_b"])[:, cw - 1 :]
    else:
        conv_out = _causal_conv(xi, params["conv_w"], params["conv_b"])
    xi = jax.nn.silu(conv_out)

    dt, bmat, cmat = _ssm_params(params, xi, n, r)
    a = -jnp.exp(params["A_log"])
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)
    y, h_final = selective_scan(xi, dt, a, bmat, cmat, params["D"], h0, chunk)
    out = (y * jax.nn.silu(z)) @ params["out_proj"]
    conv_state = (
        jnp.concatenate([conv0.astype(xi.dtype), x @ params["in_proj"]], axis=1)
        if conv0 is not None
        else (x @ params["in_proj"])
    )[:, -(cw - 1) :, :di]
    return out, (conv_state, h_final)


def decode_mamba(params: dict, x: jnp.ndarray, cfg, state):
    """One-token decode. x: (B, 1, D); state = (conv_state (B,cw-1,di), h (B,di,N))."""
    conv_state, h = state
    di, n, r, cw = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    xz = x @ params["in_proj"]  # (B,1,2di)
    xi, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([conv_state.astype(xi.dtype), xi], axis=1)  # (B,cw,di)
    conv = jnp.einsum("bcd,cd->bd", window, params["conv_w"]) + params["conv_b"]
    xi1 = jax.nn.silu(conv)[:, None, :]  # (B,1,di)

    dt, bmat, cmat = _ssm_params(params, xi1, n, r)
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt[:, 0, :, None] * a[None])              # (B,di,N)
    dbx = (dt[:, 0] * xi1[:, 0].astype(jnp.float32))[..., None] * bmat[:, 0, None, :]
    h_new = da * h + dbx
    y = jnp.einsum("bdn,bn->bd", h_new, cmat[:, 0]) + xi1[:, 0].astype(jnp.float32) * params["D"]
    out = (y.astype(x.dtype)[:, None, :] * jax.nn.silu(z)) @ params["out_proj"]
    new_conv = window[:, 1:]
    return out, (new_conv, h_new)


def init_mamba_state(cfg, batch: int):
    return (
        jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.bfloat16),
        jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    )
