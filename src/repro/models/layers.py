"""Core neural layers (pure JAX, no flax).

Parameters are plain nested dicts of ``jnp.ndarray``.  Every ``init_*``
function takes a PRNG key and returns the param pytree; every ``apply``-style
function is functional and jit-safe.

Attention is implemented with *query chunking* (``lax.scan`` over query
blocks): peak memory is O(chunk * S) instead of O(S^2), which is what makes
the 32k-prefill dry-run memory analysis honest without a Pallas dependency on
the CPU backend (on TPU, ``repro.kernels.ops`` swaps in the real kernels).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(norm: str, d: int, dtype) -> dict:
    if norm == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if norm == "layernorm_np":  # olmo: non-parametric LN
        return {}
    raise ValueError(norm)


def apply_norm(norm: str, params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if norm == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
        x = x * params["scale"].astype(jnp.float32)
    elif norm in ("layernorm", "layernorm_np"):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + eps)
        if norm == "layernorm":
            x = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(norm)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, chunked over queries)
# ---------------------------------------------------------------------------


def repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, Hkv, hd) -> (B, S, Hkv*groups, hd)."""
    if groups == 1:
        return x
    b, s, h, d = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, groups, d))
    return x.reshape(b, s, h * groups, d)


def _attend_block(q, k, v, mask, scale):
    """Grouped-GQA attention block — KV heads are NEVER repeated/materialized.

    q: (B, C, Hq, hd), k/v: (B, S, Hkv, hd), mask: (C, S) or None.
    The query heads are reshaped to (Hkv, G) groups and contracted against
    the raw KV heads; at 128 q-heads / 8 kv-heads × 32k keys the repeated-KV
    tensor this avoids is ~16× the cache itself (§Perf, llama3 decode).
    """
    b, c, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, c, hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    if mask is not None:
        scores = jnp.where(mask[None, None, None, :, :], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, c, hq, hd)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    q_chunk: int = 512,
    kv_len: Optional[jnp.ndarray] = None,
    q_offset: Optional[jnp.ndarray] = None,
    causal_buckets: int = 1,
) -> jnp.ndarray:
    """GQA attention.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd).
    kv_len: optional scalar — valid prefix length of k/v (decode with cache).
    q_offset: optional scalar — absolute position of q[0] (decode).
    causal_buckets > 1: split the query chunks into buckets where bucket g
    only attends K[: (g+1)·Skv/buckets] — skips fully-masked key regions with
    static shapes (saves up to (1 - (B+1)/(2B)) of score FLOPs; §Perf).
    Returns (B, Sq, Hq, hd).
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if k.dtype != q.dtype:  # low-precision (fp8) cache storage
        k = k.astype(q.dtype)
        v = v.astype(q.dtype)
    scale = 1.0 / math.sqrt(hd)

    kpos = jnp.arange(skv)
    valid = kpos[None, :] < kv_len if kv_len is not None else None

    if sq % q_chunk != 0:  # non-divisible (e.g. whisper's 1500 frames)
        q_chunk = next((c for c in range(q_chunk, 0, -1) if sq % c == 0), sq)
    if sq <= q_chunk:
        mask = None
        if causal and sq > 1:
            off = q_offset if q_offset is not None else 0
            qpos = jnp.arange(sq) + off
            mask = qpos[:, None] >= kpos[None, :]
        if valid is not None:
            mask = valid if mask is None else jnp.logical_and(mask, valid)
        if mask is not None and mask.shape[0] == 1:
            mask = jnp.broadcast_to(mask, (sq, skv))
        return _attend_block(q, k, v, mask, scale)
    n_chunks = sq // q_chunk

    if (causal_buckets > 1 and causal and sq == skv and valid is None
            and q_offset is None and n_chunks % causal_buckets == 0
            and skv % causal_buckets == 0):
        # bucketed lower-triangle: bucket g's queries see only K[: (g+1)·Skv/G]
        per = n_chunks // causal_buckets
        kv_step = skv // causal_buckets
        outs = []
        for g in range(causal_buckets):
            lo, hi = g * per * q_chunk, (g + 1) * per * q_chunk
            outs.append(attention(
                q[:, lo:hi], k[:, : (g + 1) * kv_step], v[:, : (g + 1) * kv_step],
                causal=True, q_chunk=q_chunk, q_offset=jnp.int32(lo),
            ))
        return jnp.concatenate(outs, axis=1)

    qs = q.reshape(b, n_chunks, q_chunk, hq, hd).transpose(1, 0, 2, 3, 4)

    def body(_, args):
        i, qc = args
        off = i * q_chunk + (q_offset if q_offset is not None else 0)
        qpos = jnp.arange(q_chunk) + off
        mask = qpos[:, None] >= kpos[None, :] if causal else jnp.ones((q_chunk, skv), bool)
        if valid is not None:
            mask = jnp.logical_and(mask, valid)
        return None, _attend_block(qc, k, v, mask, scale)

    _, out = jax.lax.scan(body, None, (jnp.arange(n_chunks), qs))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, hd)


def init_attention(key, cfg, dtype) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": dense_init(ks[3], (hq * hd, d), dtype, scale=1.0 / math.sqrt(2 * cfg.num_layers * hq * hd)),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def attention_qkv(params: dict, x: jnp.ndarray, cfg):
    """Project x -> (q, k, v) with RoPE left to the caller."""
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.use_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (
        q.reshape(b, s, hq, hd),
        k.reshape(b, s, hkv, hd),
        v.reshape(b, s, hkv, hd),
    )


def attention_out(params: dict, o: jnp.ndarray) -> jnp.ndarray:
    b, s, h, hd = o.shape
    return o.reshape(b, s, h * hd) @ params["wo"]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, ff: int, act: str, dtype, num_layers: int = 1) -> dict:
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / math.sqrt(2 * num_layers * ff)
    if act == "silu":
        return {
            "w_gate": dense_init(ks[0], (d, ff), dtype),
            "w_up": dense_init(ks[1], (d, ff), dtype),
            "w_down": dense_init(ks[2], (ff, d), dtype, scale=out_scale),
        }
    return {
        "w_up": dense_init(ks[0], (d, ff), dtype),
        "w_down": dense_init(ks[1], (ff, d), dtype, scale=out_scale),
    }


def apply_mlp(params: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]
