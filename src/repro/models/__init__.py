from repro.models import layers, mamba, moe, model  # noqa: F401
