"""Sharded, mesh-agnostic checkpointing (no orbax offline — built from
scratch).

Format: one directory per step containing
  * ``manifest.json`` — tree structure, per-leaf shapes/dtypes, step metadata,
    and a content checksum per shard file;
  * ``shard_<host>.npz`` — each host saves the leaves it owns (addressable
    shards), keyed by flattened tree path.

Restore is *elastic*: the manifest stores only the logical layout, so arrays
are rebuilt and re-sharded onto whatever mesh is alive (fault-tolerant
restart onto fewer/more hosts).  Saving is double-buffered on a background
thread (``CheckpointManager``) with a keep-N retention policy.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
        )
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Write one checkpoint synchronously. Returns the step directory."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    flat = _flatten(tree)
    host = jax.process_index()
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # npz cannot round-trip ml_dtypes (bf16 etc.) — store them as uint8 views;
    # the manifest records the true dtype/shape for restore
    savable = {
        k: (v.view(np.uint8) if v.dtype.type.__module__.startswith("ml_dtypes") else v)
        for k, v in arrays.items()
    }
    shard_path = os.path.join(tmp_dir, f"shard_{host:05d}.npz")
    np.savez(shard_path, **savable)
    digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()

    manifest = {
        "step": step,
        "format": 1,
        "extra": extra or {},
        "hosts": jax.process_count(),
        "leaves": {
            k: {"shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype)}
            for k, v in arrays.items()
        },
        "checksums": {f"shard_{host:05d}.npz": digest},
    }
    manifest["content_digest"] = content_digest(manifest)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)  # atomic publish
    return step_dir


def content_digest(manifest: dict) -> str:
    """Whole-checkpoint integrity digest over the manifest's logical content.

    sha256 of the canonical (sorted-keys) JSON of the leaf layout plus the
    per-shard checksums — so a truncated shard, a dropped leaf, or a
    hand-edited manifest all change the digest.  The digest itself and the
    free-form ``extra`` metadata are excluded (extra may be legitimately
    rewritten by tooling without touching the arrays).
    """
    body = {"leaves": manifest.get("leaves", {}),
            "checksums": manifest.get("checksums", {}),
            "step": manifest.get("step"),
            "format": manifest.get("format")}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True).encode()).hexdigest()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def read_extra(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """The ``extra`` metadata dict recorded at ``save(...)`` time (e.g. the
    policy-class record ``core.policy.checkpoint_metadata`` writes), without
    touching the array shards.  {} for checkpoints saved with no extra."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(step_dir, "manifest.json")))
    return manifest.get("extra") or {}


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None, validate: bool = True) -> Any:
    """Rebuild a pytree from a checkpoint, re-sharding onto `shardings`.

    tree_like: a pytree (arrays or ShapeDtypeStructs) giving the structure.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(step_dir, "manifest.json")))
    if validate and "content_digest" in manifest:
        if manifest["content_digest"] != content_digest(manifest):
            raise IOError(
                f"manifest content digest mismatch in {step_dir} "
                "(corrupted or hand-edited checkpoint)")

    data: Dict[str, np.ndarray] = {}
    for fname in sorted(os.listdir(step_dir)):
        if not fname.startswith("shard_"):
            continue
        path = os.path.join(step_dir, fname)
        if validate and fname in manifest.get("checksums", {}):
            digest = hashlib.sha256(open(path, "rb").read()).hexdigest()
            if digest != manifest["checksums"][fname]:
                raise IOError(f"checksum mismatch in {path}")
        with np.load(path) as npz:
            for k in npz.files:
                data[k] = npz[k]

    flat_like = _flatten(tree_like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out_flat = {}
    for key, like in flat_like.items():
        if key not in data:
            raise KeyError(f"leaf {key!r} missing from checkpoint step {step}")
        raw = data[key]
        want = np.dtype(like.dtype)
        if raw.dtype == np.uint8 and want.type.__module__.startswith("ml_dtypes"):
            raw = raw.view(want).reshape(manifest["leaves"][key]["shape"])
        arr = jnp.asarray(raw, dtype=like.dtype)
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != expected {like.shape}")
        if key in flat_shard and flat_shard[key] is not None:
            arr = jax.device_put(arr, flat_shard[key])
        out_flat[key] = arr

    treedef = jax.tree_util.tree_structure(tree_like)
    leaves_in_order = [out_flat[k] for k in _flatten(tree_like)]
    return jax.tree_util.tree_unflatten(treedef, leaves_in_order)


class CheckpointManager:
    """Async double-buffered writer with keep-N retention."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # snapshot to host memory on the caller thread (consistent view)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
