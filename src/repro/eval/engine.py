"""Batched evaluation engine: vmapped trials, one XLA launch per batch.

The paper's claims rest on many-trial comparisons across schedulers and
scenarios.  Looping Python over jitted single episodes pays one dispatch
(and often one re-jit) per trial; at "64 trials x 4 schedulers x 8
scenarios" that is ~2000 dispatches.  This engine vmaps ``env.run_episode``
over the trial keys and jits once per (scenario, scheduler), so the same
sweep is a handful of XLA launches:

    batch = make_batch_episode(env_cfg, select, n_pods)   # jit once
    trials = batch(trial_keys(key, 64))                   # one launch
    summary = summarize(trials)                           # mean / CI / drops

``TrialResults`` carries the per-trial outputs (dt-weighted average-CPU%
metric, pod distributions, experiment-pod distributions, dropped counts);
``summarize`` reduces them to mean / std / 95% CI plus drop totals.  For
seed-selection loops where the *policy parameters* change between calls but
the scenario/scheduler shape does not, ``make_param_evaluator`` closes over
a selector *factory* instead, so all seeds share one compilation.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as kenv
from repro.core.types import EnvConfig


class TrialResults(NamedTuple):
    """Per-trial episode outputs, leading dim = trials."""

    metric: jnp.ndarray        # (T,) dt-weighted cluster-average CPU%
    distribution: jnp.ndarray  # (T, N) final pods per node (tenant + ours)
    exp_pods: jnp.ndarray      # (T, N) final experiment pods per node
    dropped: jnp.ndarray       # (T,) int32 arrivals with no feasible node
    placed: jnp.ndarray        # (T,) int32 admitted arrivals (n - dropped;
                               # churn scenarios retire some before episode end)
    nodes_active: jnp.ndarray  # (T,) time-averaged active-node count
    nodes_active_final: jnp.ndarray  # (T,) int32 active nodes at episode end
    node_seconds: jnp.ndarray  # (T,) integral of active nodes over wall-clock
    energy_wh: jnp.ndarray     # (T,) energy billed to the workload
    retired: jnp.ndarray       # (T,) int32 pods completed + released


def trial_keys(key: jax.Array, trials: int) -> jax.Array:
    """(T, ...) independent trial keys, identical to ``fold_in(key, t)``."""
    return jax.vmap(lambda t: jax.random.fold_in(key, t))(jnp.arange(trials))


def fixed_trial_keys(seed0: int, trials: int) -> jax.Array:
    """Keys ``PRNGKey(seed0 + t)`` — the benchmark-protocol key ladder."""
    return jnp.stack([jax.random.PRNGKey(seed0 + t) for t in range(trials)])


def _default_n_pods(env_cfg: EnvConfig, n_pods: Optional[int]) -> int:
    if n_pods is not None:
        return n_pods
    return env_cfg.scenario.n_pods if env_cfg.scenario is not None else 50


def _split_carrying(select):
    """Normalize a selector to ``(select, carry0)``.

    Factories for sequence policy classes (``schedulers.make_policy_selector``)
    return ``(select, carry0)`` pairs; plain selectors (and stateless-policy
    pairs, whose carry is None) evaluate exactly as before.
    """
    if isinstance(select, tuple):
        return select
    return select, None


def _trial_fn(env_cfg: EnvConfig, select: Callable, n: int,
              consolidate: Optional[Callable] = None) -> Callable:
    """The shared per-trial body: ``key -> TrialResults`` for one episode."""
    select, carry0 = _split_carrying(select)

    def one(k):
        res = kenv.run_episode(k, env_cfg, select, n, consolidate=consolidate,
                               select_carry=carry0)
        state, dropped, stats = res.state, res.dropped, res.stats
        return TrialResults(
            metric=res.metric,
            distribution=res.placements,
            exp_pods=state.exp_pods,
            dropped=dropped,
            # bound = arrivals the filter phase admitted; on churn scenarios
            # the final exp_pods undercounts it (retired pods left already)
            placed=jnp.int32(n) - dropped,
            nodes_active=stats.nodes_active_mean,
            nodes_active_final=stats.nodes_active_final,
            node_seconds=stats.node_seconds,
            energy_wh=stats.energy_wh,
            retired=stats.retired,
        )

    return one


def make_batch_episode(env_cfg: EnvConfig, select: Callable,
                       n_pods: Optional[int] = None,
                       consolidate: Optional[Callable] = None) -> Callable:
    """Jitted ``(T, key) -> TrialResults``: all trials in one XLA launch.

    Compiles once per (env_cfg, select, n_pods, T) — hold on to the returned
    callable across measurement rounds to keep jit out of timing windows.
    ``consolidate`` threads the in-episode SDQN-n consolidation pass through
    to ``run_episode`` (active when ``env_cfg.consolidate_every_s > 0``).
    """
    n = _default_n_pods(env_cfg, n_pods)
    return jax.jit(jax.vmap(_trial_fn(env_cfg, select, n, consolidate)))


def make_param_evaluator(env_cfg: EnvConfig, selector_factory: Callable,
                         n_pods: Optional[int] = None) -> Callable:
    """Jitted ``(params, keys) -> TrialResults`` for seed-selection loops.

    ``selector_factory(params) -> (key, state, pod) -> action`` is rebuilt
    inside the trace, so policies with identical pytree structure (every
    seed of a training run) share one compilation instead of re-jitting
    per candidate.  A factory may instead return a ``(select, carry0)``
    pair (``schedulers.make_policy_selector``) — sequence policy classes
    thread their history carry through each scanned episode.
    """
    n = _default_n_pods(env_cfg, n_pods)

    @jax.jit
    def run(params, keys):
        return jax.vmap(_trial_fn(env_cfg, selector_factory(params), n))(keys)

    return run


def make_multi_param_evaluator(env_cfg: EnvConfig, selector_factory: Callable,
                               n_pods: Optional[int] = None) -> Callable:
    """Jitted ``(stacked_params, keys) -> TrialResults`` with (S, T) leading
    dims: every (candidate, trial) episode of a seed-selection round in one
    XLA launch.

    ``stacked_params`` carries a leading seed dimension on every leaf (the
    output of ``repro.train.engine.train_seeds``); ``keys`` is shared across
    candidates so they are validated on identical bursts.
    """
    n = _default_n_pods(env_cfg, n_pods)

    @jax.jit
    def run(stacked_params, keys):
        def per_candidate(params):
            return jax.vmap(_trial_fn(env_cfg, selector_factory(params), n))(keys)

        return jax.vmap(per_candidate)(stacked_params)

    return run


def summarize(trials: TrialResults) -> Dict[str, float]:
    """Mean / std / 95% CI of the paper metric, plus drop/placement stats and
    the lifecycle consolidation metrics (active nodes, node-seconds, energy)."""
    mets = np.asarray(trials.metric, np.float64)
    dropped = np.asarray(trials.dropped, np.float64)
    t = mets.shape[0]
    std = float(mets.std())
    return {
        "metric_mean": float(mets.mean()),
        "metric_std": std,
        "metric_ci95": float(1.96 * std / np.sqrt(max(t, 1))),
        "dropped_mean": float(dropped.mean()),
        "dropped_max": float(dropped.max()),
        "pods_placed_mean": float(np.asarray(trials.placed, np.float64).mean()),
        "nodes_active_mean": float(np.asarray(trials.nodes_active, np.float64).mean()),
        "nodes_active_final_mean": float(
            np.asarray(trials.nodes_active_final, np.float64).mean()),
        "node_seconds_mean": float(np.asarray(trials.node_seconds, np.float64).mean()),
        "energy_wh_mean": float(np.asarray(trials.energy_wh, np.float64).mean()),
        "retired_mean": float(np.asarray(trials.retired, np.float64).mean()),
        "trials": float(t),
    }


def evaluate(key: jax.Array, env_cfg: EnvConfig, select: Callable,
             trials: int = 3, n_pods: Optional[int] = None,
             batch: Optional[Callable] = None,
             consolidate: Optional[Callable] = None) -> Dict[str, float]:
    """One-call evaluation: batched trials + summary dict.

    Pass a prebuilt ``batch`` (from ``make_batch_episode``) to amortize
    compilation across measurement rounds — a prebuilt batch already baked
    its consolidation pass in, so combining it with ``consolidate`` here
    would silently drop the pass.
    """
    if batch is not None and consolidate is not None:
        raise ValueError("pass consolidate to make_batch_episode, not to "
                         "evaluate, when supplying a prebuilt batch")
    ep = batch if batch is not None else make_batch_episode(
        env_cfg, select, n_pods, consolidate)
    res = ep(trial_keys(key, trials))
    out = summarize(res)
    out["n_pods"] = float(_default_n_pods(env_cfg, n_pods))
    out["n_nodes"] = float(env_cfg.n_nodes)
    return out
