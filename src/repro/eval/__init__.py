"""Batched scheduler evaluation (vmapped trials, jit once per cell)."""
from repro.eval.engine import (  # noqa: F401
    TrialResults,
    evaluate,
    fixed_trial_keys,
    make_batch_episode,
    make_param_evaluator,
    summarize,
    trial_keys,
)

__all__ = [
    "TrialResults",
    "evaluate",
    "fixed_trial_keys",
    "make_batch_episode",
    "make_param_evaluator",
    "summarize",
    "trial_keys",
]
