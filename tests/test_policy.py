"""Policy-class registry suite (``repro.core.policy``).

Pins the PolicySpec contract across every layer it threads through:
registry invariants; "mlp" bit-compatibility with the pre-registry
``core.dqn`` paths (scoring AND the learner step); the attention scorer's
singleton-set exactness (softmax over one key is the identity); the mamba
step-vs-scan encoder parity; versioned checkpoint round-trips for all three
policy classes plus the legacy-MLP manifest fallback; and the NO_PLACEMENT
sentinel invariant — no registered policy ever places onto an infeasible
node — as fixed cases and as a hypothesis property.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies as strat
from repro.checkpoint import ckpt
from repro.core import dqn, env as kenv, policy as policy_mod, schedulers, \
    train_rl
from repro.core.types import FEATURE_DIM, NO_PLACEMENT, paper_cluster

CFG = paper_cluster()
ALL_POLICIES = sorted(policy_mod.names())


@pytest.fixture(scope="module")
def state():
    return kenv.reset(jax.random.PRNGKey(1), CFG)


def _params(name, seed=0):
    spec = policy_mod.get(name)
    return spec, spec.init(jax.random.PRNGKey(seed))


def _select_node(spec, params, key, state, pod):
    """Run one selection through ``make_policy_selector``, whatever the
    spec's carry protocol."""
    select, carry0 = schedulers.make_policy_selector(spec, params, CFG)
    if carry0 is None:
        return select(key, state, pod)
    node, _ = select(key, state, pod, carry0)
    return node


def _oversized_pod():
    """Infeasible on every node of the paper cluster (requests >> capacity)."""
    p = kenv.default_pod(CFG)
    return p._replace(cpu_request=p.cpu_request * 1e6,
                      mem_request=p.mem_request * 1e6)


class TestRegistry:
    def test_ships_all_three_policy_classes(self):
        assert {"mlp", "attention", "mamba"} <= set(policy_mod.names())

    def test_unknown_name_lists_registry(self):
        with pytest.raises(KeyError, match="mlp"):
            policy_mod.get("no-such-policy")

    def test_sequence_spec_requires_encoder(self):
        with pytest.raises(ValueError, match="no encoder"):
            policy_mod.register(policy_mod.PolicySpec(
                name="broken", feature_dim=8, embed_dim=2,
                init=dqn.init_qnet, qvalues=dqn.qvalues,
                score_set=dqn.qvalues))
        assert "broken" not in policy_mod.names()

    def test_feature_dims_are_base_plus_embed(self):
        for name in ALL_POLICIES:
            spec = policy_mod.get(name)
            assert spec.feature_dim == FEATURE_DIM + spec.embed_dim

    def test_only_mlp_is_fused_capable(self):
        assert policy_mod.get("mlp").fused_kernel
        assert not policy_mod.get("attention").fused_kernel
        assert not policy_mod.get("mamba").fused_kernel


class TestMlpBitCompat:
    def test_scoring_identical_with_and_without_spec(self, state):
        """``score_afterstates(policy=MLP)`` must be the EXACT pre-registry
        computation — same function objects, same trace, zero drift."""
        spec, params = _params("mlp")
        pod = kenv.default_pod(CFG)
        ref = schedulers.score_afterstates(params, state, pod, CFG)
        got = schedulers.score_afterstates(params, state, pod, CFG,
                                           policy=spec)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_spec_reuses_dqn_functions(self):
        spec = policy_mod.get("mlp")
        assert spec.init is dqn.init_qnet
        assert spec.qvalues is dqn.qvalues

    def test_generic_train_step_matches_dqn_train_step(self):
        spec, params = _params("mlp")
        _, opt_state = policy_mod.init_train_state(spec, jax.random.PRNGKey(0))
        feats = jax.random.normal(jax.random.PRNGKey(2), (16, FEATURE_DIM))
        targets = jax.random.normal(jax.random.PRNGKey(3), (16,))
        w = jnp.ones((16,))
        ref = dqn.train_step(params, opt_state, feats, targets, w)
        got = policy_mod.make_train_step(spec)(params, opt_state, feats,
                                               targets, w)
        np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(ref[2]))
        for got_leaf, ref_leaf in zip(jax.tree.leaves(got[0]),
                                      jax.tree.leaves(ref[0])):
            np.testing.assert_array_equal(np.asarray(got_leaf),
                                          np.asarray(ref_leaf))


class TestAttention:
    def test_singleton_set_matches_pointwise_qvalues(self):
        """softmax over one key == identity, so the set scorer on an N=1 set
        must equal ``qvalues`` on the same row — the property that makes the
        pointwise replay/learner path exact, not an approximation."""
        spec, params = _params("attention")
        row = jax.random.normal(jax.random.PRNGKey(4), (1, FEATURE_DIM))
        set_q = spec.score_set(params, row)
        point_q = spec.qvalues(params, row)
        np.testing.assert_allclose(np.asarray(set_q), np.asarray(point_q),
                                   atol=1e-5, rtol=1e-5)

    def test_set_scoring_mixes_context(self):
        """On a multi-node set, a change to node j's features must move node
        i's score — the whole point of attending over the candidate set."""
        spec, params = _params("attention")
        feats = jax.random.normal(jax.random.PRNGKey(5), (4, FEATURE_DIM))
        base = np.asarray(spec.score_set(params, feats))
        bumped = np.asarray(spec.score_set(params, feats.at[3].add(2.0)))
        assert abs(bumped[0] - base[0]) > 1e-7

    def test_interpret_kernel_matches_xla_fallback(self):
        spec, params = _params("attention")
        feats = jax.random.normal(jax.random.PRNGKey(6), (4, FEATURE_DIM))
        xla = policy_mod.attention_score_set(params, feats, mode="xla")
        ref = policy_mod.attention_score_set(params, feats, mode="ref")
        np.testing.assert_allclose(np.asarray(xla), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


class TestMambaEncoder:
    def test_step_fold_matches_sequence_scan(self):
        """Folding ``encode_step`` arrival-by-arrival must equal the one-shot
        ``mamba_encode_sequence`` re-encode (the ``kernels.mamba_scan``
        path) — embeds AND final carry."""
        spec, params = _params("mamba")
        t = 6
        workloads = jax.random.uniform(jax.random.PRNGKey(7),
                                       (t, policy_mod.ENCODER_IN))
        carry = spec.carry_init(params)
        stepped = []
        for i in range(t):
            carry, emb = spec.encode_step(params, carry, workloads[i])
            stepped.append(emb)
        embeds, h_final = policy_mod.mamba_encode_sequence(params, workloads)
        np.testing.assert_allclose(np.asarray(embeds), np.asarray(stepped),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(h_final), np.asarray(carry),
                                   atol=1e-5, rtol=1e-5)

    def test_carry_shape_is_static(self):
        spec, params = _params("mamba")
        carry = spec.carry_init(params)
        wf = jnp.zeros((policy_mod.ENCODER_IN,))
        carry2, emb = spec.encode_step(params, carry, wf)
        assert carry2.shape == carry.shape and carry2.dtype == carry.dtype
        assert emb.shape == (spec.embed_dim,)

    def test_history_conditions_scores(self, state):
        """Two different arrival histories must score the same afterstates
        differently — the sequence policy actually uses its memory."""
        spec, params = _params("mamba")
        pod = kenv.default_pod(CFG)
        feats = kenv.normalize_features(
            kenv.hypothetical_place(state, pod, CFG))
        wf_a = jnp.full((policy_mod.ENCODER_IN,), 0.9)
        wf_b = jnp.full((policy_mod.ENCODER_IN,), 0.1)
        _, emb_a = spec.encode_step(params, spec.carry_init(params), wf_a)
        _, emb_b = spec.encode_step(params, spec.carry_init(params), wf_b)
        q_a = schedulers.score_afterstates(params, state, pod, CFG,
                                           policy=spec, embed=emb_a)
        q_b = schedulers.score_afterstates(params, state, pod, CFG,
                                           policy=spec, embed=emb_b)
        assert feats.shape[-1] == FEATURE_DIM
        assert np.abs(np.asarray(q_a) - np.asarray(q_b)).max() > 1e-7


class TestCheckpointRoundTrip:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_roundtrip_restores_params_and_spec(self, tmp_path, policy):
        spec, params = _params(policy, seed=11)
        policy_mod.save_checkpoint(str(tmp_path), 3, params, spec)
        restored, got_spec = policy_mod.restore_checkpoint(str(tmp_path))
        assert got_spec is spec
        got_leaves, got_def = jax.tree.flatten(restored)
        ref_leaves, ref_def = jax.tree.flatten(params)
        assert got_def == ref_def
        for got, ref in zip(got_leaves, ref_leaves):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_metadata_records_class_and_hyperparams(self, tmp_path, policy):
        spec, params = _params(policy)
        policy_mod.save_checkpoint(str(tmp_path), 0, params, spec)
        meta = ckpt.read_extra(str(tmp_path))
        assert meta["policy"] == policy
        assert meta["feature_dim"] == spec.feature_dim
        assert meta["hyperparams"] == dict(spec.hyperparams)
        assert meta["policy_ckpt_version"] == policy_mod.POLICY_CKPT_VERSION

    def test_legacy_manifest_falls_back_to_mlp(self, tmp_path):
        """Checkpoints written by the pre-registry trainer (plain
        ``ckpt.save``, no policy record) must keep restoring as the MLP."""
        params = dqn.init_qnet(jax.random.PRNGKey(12))
        ckpt.save(str(tmp_path), 0, params)
        restored, spec = policy_mod.restore_checkpoint(str(tmp_path))
        assert spec.name == "mlp"
        for k in params:
            np.testing.assert_array_equal(np.asarray(restored[k]),
                                          np.asarray(params[k]))

    def test_serve_load_policy_recovers_variant(self, tmp_path):
        from repro.launch import serve

        spec, params = _params("mamba")
        policy_mod.save_checkpoint(str(tmp_path), 0, params, spec)
        loaded, got_spec = serve.load_policy(str(tmp_path),
                                             jax.random.PRNGKey(0))
        assert got_spec.name == "mamba"
        assert jax.tree.structure(loaded) == jax.tree.structure(params)


class TestTrainerIntegration:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_replay_row_width_follows_spec(self, policy):
        spec = policy_mod.get(policy)
        rl = train_rl.RLConfig(n_envs=2, buffer_capacity=64, policy=policy)
        carry = train_rl._init_carry(jax.random.PRNGKey(0), rl)
        assert carry.buffer.n_features == spec.feature_dim


class TestNoPlacementSentinel:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_infeasible_burst_returns_sentinel(self, state, policy):
        spec, params = _params(policy)
        node = _select_node(spec, params, jax.random.PRNGKey(0), state,
                            _oversized_pod())
        assert int(node) == NO_PLACEMENT

    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_feasible_pod_places_on_feasible_node(self, state, policy):
        spec, params = _params(policy)
        pod = kenv.default_pod(CFG)
        node = _select_node(spec, params, jax.random.PRNGKey(0), state, pod)
        ok = np.asarray(kenv.feasible(state, pod, CFG))
        assert 0 <= int(node) < CFG.n_nodes
        assert ok[int(node)]


if strat.HAVE_HYPOTHESIS:
    from hypothesis import given

    @given(seed=strat.seeds(), policy=strat.st.sampled_from(ALL_POLICIES),
           frac=strat.st.floats(0.05, 3.0, allow_nan=False,
                                allow_infinity=False))
    def test_property_never_places_infeasible(seed, policy, frac):
        """For ANY pod size and ANY registered policy class, the selector
        either returns a node the filtering phase admits or the
        NO_PLACEMENT sentinel — an infeasible node never outranks the
        sentinel path, whatever the Q-scores say."""
        key = jax.random.PRNGKey(seed)
        state = kenv.reset(key, CFG)
        base = kenv.default_pod(CFG)
        pod = base._replace(
            cpu_request=base.cpu_request * frac * 20.0,
            mem_request=base.mem_request * frac * 20.0)
        spec, params = _params(policy, seed=seed % 7)
        node = int(_select_node(spec, params, key, state, pod))
        ok = np.asarray(kenv.feasible(state, pod, CFG))
        if node == NO_PLACEMENT:
            assert not ok.any()
        else:
            assert ok[node]
else:  # pragma: no cover - exercised when the [test] extra is absent
    def test_property_never_places_infeasible():
        pytest.importorskip("hypothesis")
