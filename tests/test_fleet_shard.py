"""Two-stage hierarchical sharded fleet scoring (``sched.shard``).

Everything here runs at N=97, shards=5 on purpose: 97 % 5 != 0 exercises the
infeasible-pad lanes (padded slots must never win a merge), and the parity
assertions pin the module's core contract — the two-stage candidate merge
selects exactly the node the flat masked argmax would, ties included.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, dqn, env as kenv, policy as pol
from repro.core.types import NO_PLACEMENT, fleet_cluster
from repro.launch.mesh import FleetLayout, plan_fleet_layout
from repro.sched import api, placement, shard
from repro.sched.daemon import ClusterSubstrate, DaemonConfig, PlacementDaemon

N = 97          # deliberately not divisible by SHARDS: forces padded lanes
SHARDS = 5
CFG = fleet_cluster(N)
STATE = kenv.reset(jax.random.PRNGKey(0), CFG)
POD = kenv.default_pod(CFG)
PARAMS = dqn.init_qnet(jax.random.PRNGKey(0))
LAYOUT = plan_fleet_layout(N, shards=SHARDS)


def _flat_choice(state=STATE, **kw):
    return int(api.select(state, POD, params=PARAMS, cfg=CFG, shard=False, **kw))


def _policy_kit(name):
    """(spec, params, embed) for a registry policy — sequence specs get one
    encoder step over the test pod's workload features."""
    spec = pol.get(name)
    params = spec.init(jax.random.PRNGKey(2))
    embed = None
    if spec.embed_dim:
        carry = spec.carry_init(params)
        _, embed = spec.encode_step(params, carry,
                                    pol.pod_workload_features(POD))
    return spec, params, embed


class TestLayoutResolution:
    def test_knob_mapping(self):
        assert shard.resolve_layout(None, N) is None
        assert shard.resolve_layout(False, N) is None
        lay = shard.resolve_layout(SHARDS, N)
        assert isinstance(lay, FleetLayout) and lay.shards == SHARDS
        assert shard.resolve_layout(lay, N) is lay
        # "auto" on a single device is the bit-identical flat fallback
        if len(jax.devices()) <= 1:
            assert shard.resolve_layout("auto", N) is None

    def test_rejects_bogus_knobs(self):
        with pytest.raises(ValueError):
            shard.resolve_layout(True, N)
        with pytest.raises(ValueError):
            shard.resolve_layout("bogus", N)

    def test_plan_geometry(self):
        assert LAYOUT.shards == SHARDS
        assert LAYOUT.padded == SHARDS * LAYOUT.shard_size
        assert 0 <= LAYOUT.padded - N < LAYOUT.shard_size
        # degenerate plans collapse to no layout at all
        assert plan_fleet_layout(3, shards=5) is None
        assert plan_fleet_layout(N, shards=1) is None


class TestShardedSelection:
    @pytest.mark.parametrize("shards", [2, 5, 8])
    def test_matches_flat_argmax(self, shards):
        lay = plan_fleet_layout(N, shards=shards)
        got = int(api.select(STATE, POD, params=PARAMS, cfg=CFG, shard=lay))
        assert got == _flat_choice()

    def test_topk_candidates_match_flat_scores(self):
        vals, idx = api.topk(STATE, POD, params=PARAMS, cfg=CFG, shard=LAYOUT)
        q = np.asarray(api.score(STATE, POD, params=PARAMS, cfg=CFG,
                                 shard=False))
        ok = np.asarray(kenv.feasible(STATE, POD, CFG))
        masked = np.where(ok, q, -np.inf)
        vals, idx = np.asarray(vals), np.asarray(idx)
        # winner == flat argmax; merged list is descending with -inf/-1 tails
        assert idx[0] == int(np.argmax(masked))
        assert np.all(np.diff(vals) <= 1e-6)
        finite = np.isfinite(vals)
        assert np.all(idx[finite] >= 0) and np.all(idx[~finite] == -1)
        # no node appears twice, and each candidate carries its flat score
        assert len(np.unique(idx[finite])) == finite.sum()
        np.testing.assert_allclose(vals[finite], masked[idx[finite]],
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("k", [1, 4])
    def test_k_does_not_change_winner(self, k):
        got = int(shard.select_candidates(STATE, POD, params=PARAMS, cfg=CFG,
                                          layout=LAYOUT, k=k))
        assert got == _flat_choice()

    @pytest.mark.parametrize("fused", ["interpret", True])
    def test_in_kernel_topk_matches_unfused(self, fused):
        # the fused per-shard top-k (Pallas interpret body AND its XLA twin)
        # must emit the same candidates as the unfused lax.top_k reduction
        vref, iref = shard.cluster_topk(PARAMS, STATE, POD, CFG, LAYOUT,
                                        fused=False)
        v, i = shard.cluster_topk(PARAMS, STATE, POD, CFG, LAYOUT, fused=fused)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(iref))
        np.testing.assert_allclose(np.asarray(v), np.asarray(vref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("k", [1, 3])
    def test_tie_breaks_to_lowest_feasible_index(self, k):
        # constant scores tie every node: first-occurrence argmax semantics
        # must survive the per-shard top-k AND the global merge
        const = lambda p, feats: jnp.zeros(feats.shape[0])
        state = STATE._replace(
            healthy=STATE.healthy.at[:3].set(False))
        got = int(shard.select_candidates(state, POD, params=PARAMS, cfg=CFG,
                                          layout=LAYOUT, k=k, score_fn=const))
        want = _flat_choice(state, score_fn=const)
        assert got == want
        ok = np.asarray(kenv.feasible(state, POD, CFG))
        assert got == int(np.argmax(ok))        # the lowest feasible index

    def test_all_infeasible_is_no_placement(self):
        state = STATE._replace(healthy=jnp.zeros(N, bool))
        got = shard.select_candidates(state, POD, params=PARAMS, cfg=CFG,
                                      layout=LAYOUT)
        assert int(got) == NO_PLACEMENT
        vals, idx = api.topk(state, POD, params=PARAMS, cfg=CFG, shard=LAYOUT)
        assert not np.isfinite(np.asarray(vals)).any()
        assert np.all(np.asarray(idx) == -1)

    def test_single_device_auto_is_bit_identical(self):
        if len(jax.devices()) > 1:
            pytest.skip("multi-device: 'auto' legitimately shards")
        qa = api.score(STATE, POD, params=PARAMS, cfg=CFG, shard="auto")
        qf = api.score(STATE, POD, params=PARAMS, cfg=CFG, shard=False)
        np.testing.assert_array_equal(np.asarray(qa), np.asarray(qf))
        assert int(api.select(STATE, POD, params=PARAMS, cfg=CFG,
                              shard="auto")) == _flat_choice()

    def test_guard_degrades_to_heuristic_candidates(self):
        bad = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), PARAMS)
        got = shard.select_candidates(STATE, POD, params=bad, cfg=CFG,
                                      layout=LAYOUT, guard=True)
        q = np.asarray(baselines.kube_scores(STATE, POD, CFG))
        ok = np.asarray(kenv.feasible(STATE, POD, CFG))
        assert int(got) == int(np.argmax(np.where(ok, q, -np.inf)))


class TestShardedScores:
    def test_matches_flat_within_tolerance(self):
        q = np.asarray(api.score(STATE, POD, params=PARAMS, cfg=CFG,
                                 shard=False))
        qs = np.asarray(api.score(STATE, POD, params=PARAMS, cfg=CFG,
                                  shard=LAYOUT))
        assert qs.shape == (N,)
        np.testing.assert_allclose(qs, q, rtol=1e-5, atol=1e-5)

    def test_pull_cost_is_global_not_per_shard(self):
        # in-flight startups concentrated in ONE shard must inflate every
        # shard's scores identically — pull_cost_now is a global reduction
        startup = jnp.zeros(N).at[:4].set(0.9 * CFG.image_pull_cost)
        state = STATE._replace(startup_cpu=startup)
        assert float(kenv.pull_cost_now(state, CFG)) > float(
            kenv.pull_cost_now(STATE, CFG))
        q = np.asarray(api.score(state, POD, params=PARAMS, cfg=CFG,
                                 shard=False))
        qs = np.asarray(api.score(state, POD, params=PARAMS, cfg=CFG,
                                  shard=LAYOUT))
        np.testing.assert_allclose(qs, q, rtol=1e-5, atol=1e-5)


class TestPolicyClasses:
    @pytest.mark.parametrize("name", pol.names())
    def test_sharded_selection_consistent(self, name):
        spec, params, embed = _policy_kit(name)
        got = int(shard.select_candidates(STATE, POD, params=params, cfg=CFG,
                                          layout=LAYOUT, policy=spec,
                                          embed=embed))
        # the two-stage merge must agree with the argmax of its OWN sharded
        # score vector (for "attention" that vector is block-local by
        # construction, so this — not flat parity — is the contract)
        qs = np.asarray(api.score(STATE, POD, params=params, cfg=CFG,
                                  shard=LAYOUT, policy=spec, embed=embed))
        ok = np.asarray(kenv.feasible(STATE, POD, CFG))
        assert got == int(np.argmax(np.where(ok, qs, -np.inf)))
        if name != "attention":  # pointwise classes: exact flat parity too
            qf = np.asarray(api.score(STATE, POD, params=params, cfg=CFG,
                                      shard=False, policy=spec, embed=embed))
            assert got == int(np.argmax(np.where(ok, qf, -np.inf)))


class TestFleetSubstrate:
    def test_sharded_select_matches_engine(self):
        fleet = placement.fresh_fleet(N)
        job = placement.JobSpec(cpu_pct_demand=4.0)
        lay = plan_fleet_layout(N, shards=SHARDS)
        got = int(shard.select_candidates(fleet, job, params=PARAMS,
                                          layout=lay))
        eng = placement.PlacementEngine(PARAMS)
        choice, _ = eng.select(fleet, job)
        assert got == int(choice)

    def test_engine_select_stays_on_device(self):
        # the serving-path bugfix: select must not force a host sync — it
        # returns a 0-d device array, callers sync at their own boundary
        eng = placement.PlacementEngine(PARAMS)
        fleet = placement.fresh_fleet(8)
        choice, scores = eng.select(fleet, placement.JobSpec())
        assert isinstance(choice, jnp.ndarray) and choice.shape == ()
        assert choice.dtype == jnp.int32
        assert scores.shape == (8,)
        dead = fleet._replace(healthy=jnp.zeros(8))
        choice, _ = eng.select(dead, placement.JobSpec())
        assert int(choice) == placement.NO_HOST


class TestDaemonSharded:
    def test_decisions_match_unsharded_daemon(self):
        cfgd = DaemonConfig(batch_size=3, max_wait_s=1e9)
        pods = [kenv.default_pod(CFG) for _ in range(6)]
        nodes = {}
        for label, layout in (("flat", None), ("sharded", LAYOUT)):
            sub = ClusterSubstrate(STATE, CFG, layout=layout)
            d = PlacementDaemon(sub, PARAMS, cfgd, clock=lambda: 0.0)
            for p in pods:
                d.submit(p)
            d.drain()
            nodes[label] = [dec.node for dec in d.decisions]
        assert len(nodes["sharded"]) == 6
        assert nodes["sharded"] == nodes["flat"]


class TestGatesManifest:
    ROOT = pathlib.Path(__file__).resolve().parents[1]

    def _manifest(self):
        with open(self.ROOT / "benchmarks" / "gates.json") as f:
            return json.load(f)

    def test_schema_and_suites(self):
        m = self._manifest()
        assert m["schema"] == "repro-gates-v1"
        names = [s["name"] for s in m["suites"]]
        assert len(names) == len(set(names))
        assert "fleet_scale" in names            # the new suite is gated...
        assert "fleet_scale" in [s["name"] for s in m["nightly"]]  # ...and swept
        for suite in m["suites"] + m["nightly"]:
            assert suite["run_args"], f"{suite['name']}: empty run_args"
            assert all(a.startswith("--") or not a.startswith("-")
                       for a in suite["run_args"])

    def test_baselines_exist_and_contain_gated_rows(self):
        for suite in self._manifest()["suites"]:
            base = self.ROOT / suite["baseline"]
            assert base.exists(), f"{suite['name']}: missing {suite['baseline']}"
            with open(base) as f:
                rows = {r["name"] for r in json.load(f)["rows"]}
            for key in ("throughput_rows", "latency_rows"):
                for row in suite.get(key, ()):
                    assert row in rows, (
                        f"{suite['name']}: gated row {row!r} absent from "
                        f"{suite['baseline']}")

    def test_run_flags_are_real(self):
        src = (self.ROOT / "benchmarks" / "run.py").read_text()
        for suite in self._manifest()["suites"] + self._manifest()["nightly"]:
            flag = suite["run_args"][0]
            assert f'"{flag}"' in src, f"unknown bench flag {flag}"
