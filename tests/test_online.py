"""Online-learning loop suite: recorder ring parity, double-buffered param
swaps, and the serving-path invariants with a refresher in the loop.

Pins the contracts of ``repro.sched.online`` (+ its satellites from the same
change): the daemon-recorded transition stream is bit-identical to the
offline ``train_rl.realized_transition`` fold; a mid-batch ``set_params``
publish never mixes into an in-flight batch (one params read per batch cut);
attaching a recorder is invisible to the decision stream; the
bound+dropped+shed == submitted ledger holds with refresh cycles interleaved
at arbitrary points; ``replay_add(n_valid=...)`` masked adds match sequential
one-row adds bit-for-bit; the TOPSIS scorer's closeness/selector contracts;
``make_reward_fn``'s energy_weight validation; and the split
bind-vs-shed latency metrics.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies as strat
from repro.core import dqn, env as kenv, policy as policy_mod, rewards, train_rl
from repro.core.replay import replay_add, replay_init
from repro.core.types import FEATURE_DIM, NO_PLACEMENT, PodSpec, paper_cluster
from repro.sched import api, topsis
from repro.sched.daemon import (
    ClusterSubstrate,
    DaemonConfig,
    DaemonMetrics,
    LatencyReservoir,
    PlacementDaemon,
)
from repro.sched.online import OnlineRefresher, TransitionRecorder
from repro.sched.placement import JobSpec, fresh_fleet

CFG = paper_cluster()


@pytest.fixture(scope="module")
def qparams():
    return dqn.init_qnet(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def state():
    return kenv.reset(jax.random.PRNGKey(1), CFG)


def _pods(n, seed=7):
    table = kenv.sample_pod_table(jax.random.PRNGKey(seed), CFG, n)
    return [jax.tree.map(lambda x: x[i], table.specs) for i in range(n)]


OVERSIZED = PodSpec(cpu_request=1e9, cpu_demand=1e9,
                    mem_request=1e9, mem_demand=1e9)


# ---------------------------------------------------------------------------
# tentpole: recorder ring parity with the offline transition arithmetic
# ---------------------------------------------------------------------------


def test_recorder_ring_parity_bit_for_bit(state, qparams):
    """The ring a served daemon's recorder produces == the ring the offline
    transition body produces from the same (pod, action) stream, bitwise —
    including a weight-0 row for the dropped (infeasible) arrival and a
    partial final drain chunk."""
    rfn = rewards.make_reward_fn("sdqn_n", efficiency_weight=50.0)
    stream = []
    rec = TransitionRecorder(state, CFG, capacity=64, reward_fn=rfn, chunk=8)

    def hook(pod, action):
        stream.append((pod, action))
        rec.record(pod, action)

    sub = ClusterSubstrate(state, CFG)
    d = PlacementDaemon(sub, qparams,
                        DaemonConfig(batch_size=4, max_wait_s=0.0),
                        decision_hook=hook)
    pods = _pods(20)
    pods.insert(5, OVERSIZED)            # guaranteed drop -> weight-0 row
    for pod in pods:
        d.submit(pod)
    d.drain()
    assert len(stream) == rec.pending == 21   # 21 = partial 8-chunk tail
    assert any(a == NO_PLACEMENT for _, a in stream)
    rec.drain()

    @jax.jit
    def fold(shadow, buf, pod, a):
        shadow, stored, r = train_rl.realized_transition(shadow, pod, a,
                                                         CFG, rfn)
        w = (a >= 0).astype(jnp.float32)
        return shadow, replay_add(buf, stored[None], r[None], w[None])

    shadow = jax.tree.map(jnp.asarray, state)
    buf = replay_init(64, n_features=FEATURE_DIM, lane=1)
    for pod, a in stream:
        shadow, buf = fold(shadow, buf, pod, jnp.asarray(a, jnp.int32))

    assert int(rec.buffer.size) == int(buf.size) == 21
    assert int(rec.buffer.ptr) == int(buf.ptr)
    np.testing.assert_array_equal(np.asarray(rec.buffer.data),
                                  np.asarray(buf.data))
    # the shadow tracked the same trajectory the offline fold walked
    for a, b in zip(jax.tree.leaves(rec._shadow), jax.tree.leaves(shadow)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_recorder_warmup_is_a_bitwise_noop(state):
    rec = TransitionRecorder(state, CFG, capacity=32, chunk=8)
    rec.record(kenv.default_pod(CFG), 1)
    rec.drain()
    before = jax.tree.map(np.asarray, (rec._shadow, rec.buffer))
    rec.warmup()
    after = jax.tree.map(np.asarray, (rec._shadow, rec.buffer))
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, b)


def test_recorder_bounded_drain(state):
    rec = TransitionRecorder(state, CFG, capacity=64, chunk=4)
    pod = kenv.default_pod(CFG)
    for _ in range(11):
        rec.record(pod, 0)
    assert rec.drain(max_chunks=2) == 8       # two chunks of 4
    assert rec.pending == 3
    assert rec.drain() == 3                   # the tail on the next cycle
    assert rec.drained == 11


def test_resync_rebases_shadow_on_live(state, qparams):
    sub = ClusterSubstrate(state, CFG)
    rec = TransitionRecorder(state, CFG)
    d = PlacementDaemon(sub, qparams,
                        DaemonConfig(batch_size=2, max_wait_s=0.0),
                        decision_hook=rec.record)
    for pod in _pods(4):
        d.submit(pod)
    d.drain()
    sub.live.healthy[2] = False               # churn the stream never carried
    rec.resync(sub.live)
    assert rec.pending == 0                   # resync drains first
    for a, b in zip(jax.tree.leaves(rec._shadow),
                    jax.tree.leaves(jax.tree.map(jnp.asarray, sub.live))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# tentpole: double-buffered params, atomic per-batch swap
# ---------------------------------------------------------------------------


def test_param_swap_is_atomic_at_batch_cuts(state, qparams):
    """A publish from inside a batch (decision hook fires between a batch's
    decisions) must not mix into that batch: params are read ONCE per batch
    cut, so batch 1 scores entirely under the old pytree and the swap takes
    effect exactly at the next cut."""
    p2 = dqn.init_qnet(jax.random.PRNGKey(9))
    sub = ClusterSubstrate(state, CFG)
    d = PlacementDaemon(sub, qparams,
                        DaemonConfig(batch_size=4, max_wait_s=0.0),
                        decision_hook=lambda pod, node: d.set_params(p2))
    real, seen = d._scorer, []

    def spy(params, snap, pods, carry, n):
        seen.append(params)
        return real(params, snap, pods, carry, n)

    d._scorer = spy
    pod = kenv.default_pod(CFG)
    for _ in range(4):
        d.submit(pod)
    d.flush()        # hook publishes p2 four times DURING this batch
    for _ in range(4):
        d.submit(pod)
    d.flush()
    assert len(seen) == 2, "one params read per batch"
    assert seen[0] is qparams, "mid-batch publish leaked into its own batch"
    assert seen[1] is p2, "publish missed the next batch cut"


def test_refresher_publishes_back_buffer(state, qparams):
    sub = ClusterSubstrate(state, CFG)
    rec = TransitionRecorder(state, CFG)
    d = PlacementDaemon(sub, qparams,
                        DaemonConfig(batch_size=2, max_wait_s=0.0),
                        decision_hook=rec.record)
    ref = OnlineRefresher(d, rec, batch_size=8, seed=3)
    assert ref.step() is None                 # empty ring: nothing to learn
    assert (ref.steps, ref.swaps) == (0, 0)
    for pod in _pods(4):
        d.submit(pod)
    d.drain()
    loss = ref.step()
    assert loss is not None and np.isfinite(loss)
    assert (ref.steps, ref.swaps) == (1, 1)
    assert d._params is ref.params            # the atomic reference flip
    assert d._params is not qparams


def test_refresher_warmup_publishes_nothing(state, qparams):
    sub = ClusterSubstrate(state, CFG)
    rec = TransitionRecorder(state, CFG)
    d = PlacementDaemon(sub, qparams,
                        DaemonConfig(batch_size=2, max_wait_s=0.0),
                        decision_hook=rec.record)
    ref = OnlineRefresher(d, rec)
    back, key = ref._back, ref._key
    ref.warmup()
    assert d._params is qparams               # nothing published
    assert ref._back is back                  # back buffer untouched
    np.testing.assert_array_equal(np.asarray(ref._key), np.asarray(key))
    assert ref.steps == 0


def test_refresher_disabled_is_bit_identical(state, qparams):
    """A daemon with the full online plumbing attached but the refresher
    never stepped serves the EXACT decision stream of a bare daemon."""

    def run(online):
        sub = ClusterSubstrate(state, CFG)
        rec = TransitionRecorder(state, CFG) if online else None
        d = PlacementDaemon(sub, qparams,
                            DaemonConfig(batch_size=4, max_wait_s=0.0),
                            decision_hook=rec.record if online else None)
        if online:
            OnlineRefresher(d, rec).warmup()  # construct + warm, never step
        for pod in _pods(16, seed=11):
            d.submit(pod)
        d.drain()
        return ([(dec.req_id, dec.node) for dec in d.decisions], sub.live)

    bare_dec, bare_live = run(False)
    online_dec, online_live = run(True)
    assert bare_dec == online_dec
    for a, b in zip(jax.tree.leaves(bare_live), jax.tree.leaves(online_live)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# ledger conservation with refresh cycles interleaved (fixed + hypothesis)
# ---------------------------------------------------------------------------


def _check_online_ledger_conservation(seed, ops):
    """bound + dropped + shed == submitted through arbitrary interleavings
    of submits/advances/polls/flushes with a refresh cycle (drain + train +
    publish) injected between ops — and shed requests, which are never
    scored, never reach the recorder."""
    state = kenv.reset(jax.random.PRNGKey(seed), CFG)
    sub = ClusterSubstrate(state, CFG)
    rec = TransitionRecorder(state, CFG, capacity=64, chunk=4)
    t = [0.0]
    d = PlacementDaemon(
        sub, dqn.init_qnet(jax.random.PRNGKey(0)),
        DaemonConfig(batch_size=3, max_wait_s=0.05, max_retries=2,
                     queue_cap=5),
        clock=lambda: t[0], decision_hook=rec.record)
    ref = OnlineRefresher(d, rec, batch_size=8, drain_chunks_per_step=1)
    cap = float(np.min(np.asarray(sub.live.cpu_capacity)))
    mem_cap = float(np.min(np.asarray(sub.live.mem_capacity)))
    for i, (op, arg) in enumerate(ops):
        if op == "submit":
            d.submit(PodSpec(cpu_request=arg * cap,
                             cpu_demand=0.5 * arg * cap,
                             mem_request=arg * mem_cap,
                             mem_demand=0.2 * arg * mem_cap))
        elif op == "advance":
            t[0] += arg
            d.poll()
        elif op == "poll":
            d.poll()
        elif op == "flush":
            d.flush()
        if i % 2 == 1:
            ref.step()                        # refresh mid-stream
    d.drain()
    ref.step()
    m = d.metrics
    assert m.bound + m.dropped + m.shed == m.submitted
    assert len(d.decisions) == m.submitted
    assert rec.recorded == m.bound + m.dropped, \
        "shed requests must never produce transitions"
    rec.drain()
    assert rec.drained == rec.recorded
    assert int(rec.buffer.size) == min(rec.recorded, 64)


def test_online_ledger_conservation_fixed_cases():
    _check_online_ledger_conservation(
        0, [("submit", 0.2), ("submit", 1.4), ("flush", 0.0),
            ("submit", 0.3), ("advance", 0.06), ("flush", 0.0)])
    # backpressure: shed requests while refresh cycles run between ops
    _check_online_ledger_conservation(
        3, [("submit", 0.2)] * 9 + [("flush", 0.0), ("submit", 0.4),
                                    ("flush", 0.0)])
    _check_online_ledger_conservation(
        7, [("submit", 0.25), ("advance", 0.06)] * 6)


if strat.HAVE_HYPOTHESIS:
    from hypothesis import given

    @given(seed=strat.seeds(), ops=strat.daemon_ops())
    def test_property_online_ledger_conservation(seed, ops):
        _check_online_ledger_conservation(seed, ops)
else:  # pragma: no cover - the [test] extra is installed in CI
    def test_property_online_ledger_conservation():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# satellites: replay masked adds, opt-state warm start
# ---------------------------------------------------------------------------


def test_replay_masked_add_matches_sequential_adds():
    """replay_add(n_valid=k) over a padded chunk == k sequential one-row
    adds, bit-for-bit, including across the ring wrap."""
    rng = np.random.default_rng(0)
    a = replay_init(8, n_features=3, lane=1)
    b = replay_init(8, n_features=3, lane=1)
    for n_valid in (3, 0, 4, 2, 4):           # 13 rows through a cap-8 ring
        feats = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
        targets = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
        weights = jnp.asarray(rng.random(size=(4,)), jnp.float32)
        a = replay_add(a, feats, targets, weights, n_valid=n_valid)
        for i in range(n_valid):
            b = replay_add(b, feats[i:i + 1], targets[i:i + 1],
                           weights[i:i + 1])
        assert int(a.ptr) == int(b.ptr) and int(a.size) == int(b.size)
        np.testing.assert_array_equal(np.asarray(a.data), np.asarray(b.data))


def test_replay_masked_add_rejects_bad_shapes():
    with pytest.raises(ValueError, match="lane-1"):
        replay_add(replay_init(8, n_features=3, lane=4),
                   jnp.zeros((4, 3)), jnp.zeros((4,)), n_valid=2)
    with pytest.raises(ValueError, match="exceeds capacity"):
        replay_add(replay_init(4, n_features=3, lane=1),
                   jnp.zeros((8, 3)), jnp.zeros((8,)), n_valid=2)


def test_make_opt_state_warm_starts_existing_params(qparams):
    opt = policy_mod.make_opt_state(qparams)
    spec = policy_mod.get("mlp")
    step = policy_mod.make_train_step(spec)
    feats = jnp.ones((4, FEATURE_DIM), jnp.float32)
    p2, opt2, loss, _ = step(qparams, opt, feats, jnp.ones((4,)),
                             jnp.ones((4,)))
    assert np.isfinite(float(loss))
    # fresh moments for the SAME pytree: structure matches, params moved
    assert jax.tree.structure(p2) == jax.tree.structure(qparams)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(p2), jax.tree.leaves(qparams)))


# ---------------------------------------------------------------------------
# satellites: TOPSIS scorer
# ---------------------------------------------------------------------------


class TestTopsis:
    def test_closeness_range_and_ranking(self):
        # row 0 strictly dominates (lower on every cost column) -> top score
        crit = jnp.asarray([[0.1, 0.1, 0.0, 0.1],
                            [0.5, 0.4, 1.0, 0.3],
                            [0.9, 0.8, 1.0, 0.6]])
        c = topsis.closeness(crit)
        assert c.shape == (3,)
        assert np.all(np.asarray(c) >= 0.0) and np.all(np.asarray(c) <= 1.0)
        assert int(np.argmax(np.asarray(c))) == 0
        assert float(c[1]) > float(c[2])

    def test_closeness_degenerate_uniform(self):
        # all candidates identical: no preference, and NO NaNs
        c = topsis.closeness(jnp.ones((5, 4)))
        assert np.all(np.isfinite(np.asarray(c)))
        np.testing.assert_allclose(np.asarray(c), np.asarray(c)[0])

    def test_cluster_scores_and_selector(self, state):
        pod = kenv.default_pod(CFG)
        q = topsis.topsis_scores(state, pod, cfg=CFG)
        assert q.shape == (CFG.n_nodes,)
        assert np.all(np.isfinite(np.asarray(q)))
        sel = topsis.make_topsis_selector(CFG)
        node = int(sel(jax.random.PRNGKey(0), state, pod))
        assert 0 <= node < CFG.n_nodes
        assert bool(kenv.feasible(state, pod, CFG)[node])
        # infeasible everywhere -> NO_PLACEMENT, like every selector
        assert int(sel(jax.random.PRNGKey(0), state, OVERSIZED)) == \
            NO_PLACEMENT

    def test_fleet_dispatch_and_api_parity(self, state):
        fleet = fresh_fleet(6, jax.random.PRNGKey(2))
        job = JobSpec(cpu_pct_demand=10.0)
        qf = topsis.topsis_scores(fleet, job)
        assert qf.shape == (6,) and np.all(np.isfinite(np.asarray(qf)))
        np.testing.assert_array_equal(
            np.asarray(api.topsis_score(fleet, job)), np.asarray(qf))
        pod = kenv.default_pod(CFG)
        np.testing.assert_array_equal(
            np.asarray(api.topsis_score(state, pod, cfg=CFG)),
            np.asarray(topsis.topsis_scores(state, pod, cfg=CFG)))

    def test_cluster_requires_cfg(self, state):
        with pytest.raises(ValueError, match="cfg"):
            topsis.topsis_scores(state, kenv.default_pod(CFG))

    def test_energy_weight_prefers_warm_nodes(self, state):
        """Scaling the wake-cost column steers placement away from idle
        nodes — the knob the Pareto sweep turns."""
        live = jax.tree.map(np.array, state)
        live.exp_pods[:] = 0
        live.exp_pods[1] = 3                  # one warm node
        st = jax.tree.map(jnp.asarray, live)
        pod = kenv.default_pod(CFG)
        green = topsis.topsis_scores(st, pod, cfg=CFG,
                                     weights=(0.05, 0.05, 0.9, 0.0))
        assert int(np.argmax(np.asarray(green))) == 1


# ---------------------------------------------------------------------------
# satellites: energy_weight validation, latency split, empty reservoir
# ---------------------------------------------------------------------------


class TestRewardValidation:
    def test_rejects_bool(self):
        with pytest.raises(TypeError, match="plain Python number"):
            rewards.make_reward_fn("sdqn", energy_weight=True)

    def test_rejects_arrays(self):
        with pytest.raises(TypeError, match="plain Python number"):
            rewards.make_reward_fn("sdqn", energy_weight=jnp.float32(1.0))
        with pytest.raises(TypeError, match="plain Python number"):
            rewards.make_reward_fn("sdqn", energy_weight=np.asarray(1.0))
        # np.float64 IS a Python float subclass: accepted by design
        assert callable(rewards.make_reward_fn("sdqn",
                                               energy_weight=np.float64(1.0)))

    def test_rejects_negative(self):
        with pytest.raises(ValueError, match=">= 0"):
            rewards.make_reward_fn("sdqn", energy_weight=-0.5)

    def test_zero_is_exactly_off(self, state):
        base = rewards.make_reward_fn("sdqn")
        z = rewards.make_reward_fn("sdqn", energy_weight=0.0)
        assert z is base or z.__code__ is base.__code__
        assert rewards.make_reward_fn("sdqn", energy_weight=0) is not None
        assert callable(rewards.make_reward_fn("sdqn", energy_weight=1.5))


class TestLatencySplit:
    def test_bind_and_shed_streams_are_separate(self, state, qparams):
        t = [0.0]
        sub = ClusterSubstrate(state, CFG)
        d = PlacementDaemon(sub, qparams,
                            DaemonConfig(batch_size=8, max_wait_s=10.0,
                                         queue_cap=2),
                            clock=lambda: t[0])
        pod = kenv.default_pod(CFG)
        d.submit(pod)
        t[0] = 0.5
        d.submit(pod)
        d.submit(pod)                         # cap hit: oldest shed at 0.5s
        d.drain()
        m = d.metrics
        assert m.shed == 1 and m.bound == 2
        assert len(m.shed_wait_s) == 1 and len(m.bind_latencies_s) == 2
        assert m.shed_wait_s.percentile(50) == pytest.approx(0.5)

    def test_latencies_s_deprecation_shim(self):
        m = DaemonMetrics()
        m.bind_latencies_s.append(0.25)
        with pytest.warns(DeprecationWarning, match="bind_latencies_s"):
            legacy = m.latencies_s
        assert legacy is m.bind_latencies_s

    def test_empty_reservoir_percentile_is_nan(self):
        r = LatencyReservoir()
        assert np.isnan(r.percentile(99.0))
        assert np.isnan(r.p50()) and np.isnan(r.p99())
        r.append(1.0)
        assert r.p99() == pytest.approx(1.0)
