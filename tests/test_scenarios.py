"""Scenario subsystem tests: registry integrity, episode determinism,
feasibility invariants under heterogeneous capacities, and equivalence of the
O(N) incremental afterstate scorer against the vmap reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.core import env as kenv, schedulers, train_rl
from repro.core.types import PodSpec, paper_cluster, training_cluster

HETERO = ("hetero-bigsmall", "train-serve-mix", "memory-pressure", "spot-flaky")


class TestRegistry:
    def test_at_least_six_scenarios(self):
        names = scenarios.scenario_names()
        assert len(names) >= 6
        for name in names:
            scn = scenarios.get_scenario(name)
            assert scn.name == name
            assert len(scn.node_classes) >= 1 and len(scn.pod_types) >= 1
            assert scn.n_nodes == sum(c.count for c in scn.node_classes)

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError, match="unknown scenario"):
            scenarios.get_scenario("nope")

    def test_make_env_tracks_pool_size(self):
        for name in scenarios.scenario_names():
            env_cfg = scenarios.make_env(name)
            assert env_cfg.n_nodes == scenarios.get_scenario(name).n_nodes
            assert env_cfg.scenario is scenarios.get_scenario(name)

    def test_heterogeneous_capacities_materialize(self):
        env_cfg = scenarios.make_env("hetero-bigsmall")
        state = kenv.reset(jax.random.PRNGKey(0), env_cfg)
        cap = np.asarray(state.cpu_capacity)
        classes = scenarios.get_scenario("hetero-bigsmall").node_classes
        expect = np.concatenate([np.full(c.count, c.cpu_capacity) for c in classes])
        np.testing.assert_array_equal(cap, expect)
        # base load scales with class capacity (big nodes carry more)
        base = np.asarray(state.base_cpu)
        assert base.max() <= cap.max()
        assert bool(np.all(base <= cap * 0.35))


class TestPodTable:
    def test_burst_table_matches_default_pod(self):
        cfg = paper_cluster()
        table = kenv.sample_pod_table(jax.random.PRNGKey(0), cfg, 20)
        np.testing.assert_allclose(np.asarray(table.specs.cpu_request),
                                   np.full(20, cfg.pod_cpu_request))
        np.testing.assert_allclose(np.asarray(table.dt_s),
                                   np.full(20, cfg.schedule_dt_s))

    def test_table_is_deterministic(self):
        env_cfg = scenarios.make_env("train-serve-mix")
        t1 = kenv.sample_pod_table(jax.random.PRNGKey(3), env_cfg, 64)
        t2 = kenv.sample_pod_table(jax.random.PRNGKey(3), env_cfg, 64)
        for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_mixture_weights_respected(self):
        env_cfg = scenarios.make_env("train-serve-mix")  # 30% train / 70% serve
        table = kenv.sample_pod_table(jax.random.PRNGKey(0), env_cfg, 2000)
        frac_train = float(np.mean(np.asarray(table.type_idx) == 0))
        assert 0.2 < frac_train < 0.4
        # specs gather the per-type catalog entries
        scn = env_cfg.scenario
        req = np.asarray(table.specs.cpu_request)
        idx = np.asarray(table.type_idx)
        for i, p in enumerate(scn.pod_types):
            assert np.all(req[idx == i] == p.cpu_request)

    def test_poisson_gaps(self):
        env_cfg = scenarios.make_env("spot-flaky")
        rate = env_cfg.scenario.arrival.rate_per_s
        table = kenv.sample_pod_table(jax.random.PRNGKey(1), env_cfg, 4000)
        dt = np.asarray(table.dt_s)
        assert np.all(dt > 0)
        assert np.mean(dt) == pytest.approx(1.0 / rate, rel=0.1)

    def test_diurnal_gaps_modulate(self):
        env_cfg = scenarios.make_env("diurnal-serve")
        table = kenv.sample_pod_table(jax.random.PRNGKey(1), env_cfg, 2000)
        dt = np.asarray(table.dt_s)
        assert np.all(dt > 0) and np.all(np.isfinite(dt))
        # the wave makes gaps systematically longer in the trough than the
        # crest — far beyond what a constant-rate stream's noise produces
        assert dt.max() / max(dt.min(), 1e-9) > 20.0


class TestEpisodes:
    def test_episode_deterministic_per_key(self):
        for name in ("hetero-bigsmall", "diurnal-serve"):
            env_cfg = scenarios.make_env(name)
            sel = schedulers.make_kube_selector(env_cfg)
            ep = scenarios.scenario_episode(env_cfg, sel)
            s1, d1, m1, _, _ = ep(jax.random.PRNGKey(5))
            s2, d2, m2, _, _ = ep(jax.random.PRNGKey(5))
            assert float(m1) == float(m2)
            np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
            s3, _, m3, _, _ = ep(jax.random.PRNGKey(6))
            assert not np.array_equal(np.asarray(s1.base_cpu), np.asarray(s3.base_cpu))

    def test_reset_key_disjoint_from_action_keys(self):
        """run_episode must derive reset and action keys from disjoint splits
        (the seed reused `key` for both, correlating layout with noise)."""
        cfg = paper_cluster()
        key = jax.random.PRNGKey(9)
        sel = schedulers.make_kube_selector(cfg)
        final = kenv.run_episode(key, cfg, sel, 10).state
        expected = kenv.reset(jax.random.split(key, 3)[0], cfg)
        # base_cpu is invariant through placements/ticks: the episode's
        # initial layout must be exactly reset(first split), not reset(key)
        np.testing.assert_array_equal(np.asarray(final.base_cpu),
                                      np.asarray(expected.base_cpu))
        old = kenv.reset(key, cfg)
        assert not np.array_equal(np.asarray(final.base_cpu), np.asarray(old.base_cpu))

    @pytest.mark.parametrize("name", HETERO)
    def test_feasibility_invariants(self, name):
        env_cfg = scenarios.make_env(name)
        sel = schedulers.make_kube_selector(env_cfg)
        ep = scenarios.scenario_episode(env_cfg, sel, n_pods=30)
        for seed in (0, 1):
            state, _, metric, _, _ = ep(jax.random.PRNGKey(seed))
            cap = np.asarray(state.cpu_capacity)
            assert bool(np.all(np.asarray(state.cpu_requested) <= cap + 1e-3))
            assert bool(np.all(np.asarray(state.mem_requested)
                               <= np.asarray(state.mem_capacity) + 1e-3))
            assert bool(np.all(np.asarray(state.num_pods)
                               <= np.asarray(state.max_pods)))
            assert bool(np.all(np.asarray(state.exp_pods)[~np.asarray(state.healthy)] == 0))
            assert np.isfinite(float(metric))

    def test_randomized_resets_stay_physical(self):
        """Domain-randomized training resets must respect each node class's
        own memory and pod-slot capacity (a 4 GiB edge node must not wake up
        hosting a big node's worth of pods)."""
        for name in HETERO:
            env_cfg = scenarios.make_env(name, randomize=True)
            for seed in range(4):
                state = kenv.reset(jax.random.PRNGKey(seed), env_cfg)
                assert bool(np.all(np.asarray(state.mem_used)
                                   <= np.asarray(state.mem_capacity))), name
                assert bool(np.all(np.asarray(state.mem_requested)
                                   <= np.asarray(state.mem_capacity))), name
                assert bool(np.all(np.asarray(state.num_pods)
                                   <= np.asarray(state.max_pods))), name
                feats = np.asarray(kenv.features(state, env_cfg))
                assert feats[:, 1].max() <= 100.0 + 1e-3, name  # mem%

    def test_feasible_respects_per_node_capacity(self):
        env_cfg = scenarios.make_env("hetero-bigsmall")
        state = kenv.reset(jax.random.PRNGKey(0), env_cfg)
        # a pod requesting more than a small-edge node's total capacity
        big_pod = PodSpec(cpu_request=jnp.float32(3000.0), cpu_demand=jnp.float32(2500.0),
                          mem_request=jnp.float32(1024.0), mem_demand=jnp.float32(900.0))
        ok = np.asarray(kenv.feasible(state, big_pod, env_cfg))
        small = np.asarray(state.cpu_capacity) < 3000.0
        assert not ok[small].any()


class TestAfterstateEquivalence:
    def _pods(self):
        return [
            kenv.default_pod(paper_cluster()),
            PodSpec(cpu_request=jnp.float32(900.0), cpu_demand=jnp.float32(780.0),
                    mem_request=jnp.float32(2048.0), mem_demand=jnp.float32(1800.0)),
        ]

    def _states(self):
        out = []
        for cfg in (paper_cluster(), training_cluster(),
                    scenarios.make_env("hetero-bigsmall"),
                    scenarios.make_env("spot-flaky", randomize=True)):
            for seed in (0, 1, 2):
                out.append((kenv.reset(jax.random.PRNGKey(seed), cfg), cfg))
        return out

    def test_fast_matches_reference(self):
        for state, cfg in self._states():
            for pod in self._pods():
                fast = np.asarray(kenv.hypothetical_place(state, pod, cfg))
                ref = np.asarray(kenv.hypothetical_place_reference(state, pod, cfg))
                np.testing.assert_allclose(fast, ref, atol=1e-5, rtol=1e-5)

    def test_fast_matches_reference_under_jit(self):
        cfg = scenarios.make_env("memory-pressure")
        state = kenv.reset(jax.random.PRNGKey(7), cfg)
        pod = self._pods()[1]
        fast = jax.jit(lambda s: kenv.hypothetical_place(s, pod, cfg))(state)
        ref = jax.jit(lambda s: kenv.hypothetical_place_reference(s, pod, cfg))(state)
        np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_rows_match_full_transition(self):
        """Row i of the fast path == features(place(state, i))[i] exactly."""
        cfg = scenarios.make_env("hetero-bigsmall")
        state = kenv.reset(jax.random.PRNGKey(3), cfg)
        pod = self._pods()[0]
        fast = np.asarray(kenv.hypothetical_place(state, pod, cfg))
        for i in (0, 3, cfg.n_nodes - 1):
            placed = kenv.place(state, jnp.int32(i), pod, cfg)
            row = np.asarray(kenv.features(placed, cfg))[i]
            np.testing.assert_allclose(fast[i], row, atol=1e-5, rtol=1e-5)

    def test_mid_episode_states_match(self):
        """Equivalence must hold on evolved states (startup transients, warm
        caches, crowded nodes), not just fresh resets."""
        cfg = scenarios.make_env("batch-storm")
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        pod = self._pods()[0]
        for step, a in enumerate([0, 0, 1, 5, 5, 5, 2]):
            state = kenv.place(state, jnp.int32(a), pod, cfg)
            if step % 2:
                state = kenv.tick(state, cfg, cfg.schedule_dt_s)
            fast = np.asarray(kenv.hypothetical_place(state, pod, cfg))
            ref = np.asarray(kenv.hypothetical_place_reference(state, pod, cfg))
            np.testing.assert_allclose(fast, ref, atol=1e-5, rtol=1e-5)


class TestMixtureTraining:
    def test_train_mixture_smoke(self):
        rl = train_rl.RLConfig(variant="sdqn", episodes=4, pods_per_episode=6,
                               n_envs=2, buffer_capacity=128, batch_size=16)
        cfgs = [scenarios.make_env(n, randomize=True)
                for n in ("paper-burst", "hetero-bigsmall")]
        params, metrics = train_rl.train_mixture(jax.random.PRNGKey(0), cfgs, rl,
                                                 rounds=2)
        assert metrics["loss"].shape == (4,)
        for leaf in jax.tree.leaves(params):
            assert bool(jnp.all(jnp.isfinite(leaf)))
        # the mixture-trained net drives a scenario it never saw
        env_cfg = scenarios.make_env("memory-pressure")
        sel = schedulers.make_sdqn_selector(params, env_cfg)
        res = scenarios.evaluate_scenario(jax.random.PRNGKey(1), env_cfg, sel,
                                          trials=1, n_pods=10)
        assert np.isfinite(res["metric_mean"])
        assert res["pods_placed_mean"] == 10.0

    def test_train_mixture_honors_episode_budget(self):
        """episodes smaller than cfgs*rounds must not be silently inflated."""
        rl = train_rl.RLConfig(variant="sdqn", episodes=5, pods_per_episode=4,
                               n_envs=2, buffer_capacity=64, batch_size=8)
        cfgs = [scenarios.make_env(n, randomize=True)
                for n in ("paper-burst", "hetero-bigsmall")]
        _, metrics = train_rl.train_mixture(jax.random.PRNGKey(0), cfgs, rl,
                                            rounds=4)
        assert metrics["loss"].shape == (5,)
