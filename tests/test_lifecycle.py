"""Pod-lifecycle tests: lifetime sampling, expiry-ledger conservation,
static-table parity (lifetime = inf reproduces the pre-lifecycle episodes
bit-for-bit), churn metrics, the jit-safe consolidation pass, and the
lifecycle CI gate."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.core import dqn, env as kenv, rewards, schedulers
from repro.core.types import paper_cluster

class TestLifetimeSampling:
    def test_default_pod_runs_forever(self):
        table = kenv.sample_pod_table(jax.random.PRNGKey(0), paper_cluster(), 16)
        assert bool(np.all(np.isinf(np.asarray(table.lifetime_s))))

    def test_static_scenarios_run_forever(self):
        cfg = scenarios.make_env("hetero-bigsmall")
        table = kenv.sample_pod_table(jax.random.PRNGKey(0), cfg, 32)
        assert bool(np.all(np.isinf(np.asarray(table.lifetime_s))))

    def test_lifetime_mean_matches_pod_type(self):
        cfg = scenarios.make_env("short-job-burst")  # single 45s-mean type
        table = kenv.sample_pod_table(jax.random.PRNGKey(1), cfg, 4000)
        life = np.asarray(table.lifetime_s)
        assert np.all(np.isfinite(life)) and np.all(life > 0)
        assert np.mean(life) == pytest.approx(45.0, rel=0.1)

    def test_lifetimes_decorrelated_from_types_and_gaps(self):
        """The lifetime stream draws from fold_in(key, 3): the type/gap draws
        of pre-lifecycle tables must be unchanged by its addition."""
        cfg = scenarios.make_env("longrun-train-mix")
        t1 = kenv.sample_pod_table(jax.random.PRNGKey(5), cfg, 64)
        t2 = kenv.sample_pod_table(jax.random.PRNGKey(5), cfg, 64)
        np.testing.assert_array_equal(np.asarray(t1.lifetime_s),
                                      np.asarray(t2.lifetime_s))
        # per-type means follow each catalog entry
        life = np.asarray(t1.lifetime_s)
        idx = np.asarray(t1.type_idx)
        means = [p.lifetime_mean_s for p in cfg.scenario.pod_types]
        assert means[0] > means[1]  # long-train outlives serve-churn
        assert life[idx == 0].mean() > life[idx == 1].mean()


class TestRetireExpired:
    def _place_two(self):
        cfg = paper_cluster()
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        pod = kenv.default_pod(cfg)
        ledger = kenv.ledger_init(4)
        st = kenv.place(state, jnp.int32(0), pod, cfg)
        ledger = kenv.ledger_record(ledger, 0, jnp.int32(0),
                                    st.time_s + 10.0, pod)
        st = kenv.place(st, jnp.int32(1), pod, cfg)
        ledger = kenv.ledger_record(ledger, 1, jnp.int32(1),
                                    st.time_s + 100.0, pod)
        return cfg, state, st, ledger, pod

    def test_releases_exactly_what_was_acquired(self):
        cfg, before, st, ledger, pod = self._place_two()
        st = kenv.tick(st, cfg, 20.0)  # pod 0 expires, pod 1 lives
        st, ledger, n = kenv.retire_expired(st, ledger)
        assert int(n) == 1
        np.testing.assert_allclose(np.asarray(st.exp_pods),
                                   np.asarray(before.exp_pods) + [0, 1, 0, 0])
        np.testing.assert_allclose(
            np.asarray(st.cpu_requested),
            np.asarray(before.cpu_requested) + [0, float(pod.cpu_request), 0, 0],
            rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(st.mem_used),
            np.asarray(before.mem_used) + [0, float(pod.mem_demand), 0, 0],
            rtol=1e-6)
        # retiring again is a no-op: the slot was freed
        st2, ledger2, n2 = kenv.retire_expired(st, ledger)
        assert int(n2) == 0
        np.testing.assert_array_equal(np.asarray(st2.exp_pods),
                                      np.asarray(st.exp_pods))

    def test_dropped_arrivals_never_retire(self):
        cfg = paper_cluster()
        pod = kenv.default_pod(cfg)
        ledger = kenv.ledger_record(kenv.ledger_init(2), 0,
                                    jnp.int32(kenv.NO_NODE), 5.0, pod)
        state = kenv.tick(kenv.reset(jax.random.PRNGKey(0), cfg), cfg, 100.0)
        st, ledger, n = kenv.retire_expired(state, ledger)
        assert int(n) == 0


class TestConservation:
    @pytest.mark.parametrize("name", ["short-job-burst", "consolidation-stress"])
    def test_fleet_returns_to_reset_utilization(self, name):
        """Every resource a pod acquires is released on expiry: after a long
        settle window all experiment pods are dead and the pod-accounting
        columns are back at their reset values."""
        cfg = scenarios.make_env(name, settle_steps=400)
        sel = schedulers.make_kube_selector(cfg)
        key = jax.random.PRNGKey(3)
        n = cfg.scenario.n_pods
        res = jax.jit(lambda k: kenv.run_episode(k, cfg, sel, n))(key)
        final, dropped, stats = res.state, res.dropped, res.stats
        assert int(stats.retired) == n - int(dropped)
        assert int(stats.nodes_active_final) == 0
        reset_state = kenv.reset(jax.random.split(key, 3)[0], cfg)
        np.testing.assert_array_equal(np.asarray(final.exp_pods), 0)
        np.testing.assert_array_equal(np.asarray(final.num_pods),
                                      np.asarray(reset_state.num_pods))
        for col in ("cpu_requested", "mem_requested", "pods_cpu", "mem_used"):
            np.testing.assert_allclose(
                np.asarray(getattr(final, col)),
                np.asarray(getattr(reset_state, col)),
                rtol=1e-4, atol=0.5, err_msg=col)


def _static_reference_episode(key, cfg, select, n_pods, table):
    """The pre-lifecycle ``run_episode`` loop (place/tick/integrate only):
    the parity ground truth the ledgered episode must reproduce when no pod
    ever expires."""
    k_reset, _, k_act = jax.random.split(key, 3)
    state = kenv.reset(k_reset, cfg)

    def sched_step(carry, xs):
        st, acc, cnt = carry
        k, pod, dt = xs
        a = select(k, st, pod)
        st = kenv.place(st, a, pod, cfg)
        st = kenv.tick(st, cfg, dt)
        m = kenv.average_cpu_utilization(st, cfg)
        return (st, acc + m * dt, cnt + dt), a

    keys = jax.random.split(k_act, n_pods)
    (state, acc, cnt), actions = jax.lax.scan(
        sched_step, (state, jnp.float32(0.0), jnp.float32(0.0)),
        (keys, table.specs, table.dt_s))

    def settle_step(carry, _):
        st, acc, cnt = carry
        st = kenv.tick(st, cfg, cfg.schedule_dt_s)
        m = kenv.average_cpu_utilization(st, cfg)
        return (st, acc + m * cfg.schedule_dt_s, cnt + cfg.schedule_dt_s), None

    (state, acc, cnt), _ = jax.lax.scan(
        settle_step, (state, acc, cnt), None, length=cfg.settle_steps)
    return state, acc / cnt, actions


class TestStaticParity:
    @pytest.mark.parametrize("cfg_name", [None, "hetero-bigsmall", "spot-flaky"])
    def test_inf_lifetime_reproduces_static_trajectories(self, cfg_name):
        """lifetime = inf must pin old-vs-new trajectories to <= 1e-6 (they
        are the same program: retirement masks are identically false)."""
        cfg = paper_cluster() if cfg_name is None else scenarios.make_env(cfg_name)
        sel = schedulers.make_kube_selector(cfg)
        key = jax.random.PRNGKey(11)
        n = 25
        table = kenv.sample_pod_table(jax.random.split(key, 3)[1], cfg, n)
        assert bool(np.all(np.isinf(np.asarray(table.lifetime_s))))
        ref_state, ref_metric, _ = jax.jit(
            lambda k: _static_reference_episode(k, cfg, sel, n, table))(key)
        res = jax.jit(
            lambda k: kenv.run_episode(k, cfg, sel, n, pod_table=table))(key)
        new_state, new_metric, stats = res.state, res.metric, res.stats
        assert int(stats.retired) == 0
        np.testing.assert_allclose(float(ref_metric), float(new_metric),
                                   rtol=1e-6)
        for name, a, b in zip(ref_state._fields, ref_state, new_state):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6, err_msg=name)

    def test_finite_lifetimes_diverge_from_static(self):
        """Sanity: with real churn the ledgered episode is NOT the static one
        (pods die, the metric window sees the drain)."""
        cfg = scenarios.make_env("short-job-burst")
        sel = schedulers.make_kube_selector(cfg)
        key = jax.random.PRNGKey(11)
        n = 25
        table = kenv.sample_pod_table(jax.random.split(key, 3)[1], cfg, n)
        _, ref_metric, _ = jax.jit(
            lambda k: _static_reference_episode(k, cfg, sel, n, table))(key)
        res = jax.jit(
            lambda k: kenv.run_episode(k, cfg, sel, n, pod_table=table))(key)
        new_metric, stats = res.metric, res.stats
        assert int(stats.retired) > 0
        assert float(new_metric) < float(ref_metric)  # drained cluster is idler


class TestChurnEpisodes:
    def test_nodes_active_falls_after_arrival_wave(self):
        cfg = scenarios.make_env("short-job-burst")
        sel = schedulers.make_kube_selector(cfg)
        stats = jax.jit(
            lambda k: kenv.run_episode(k, cfg, sel, cfg.scenario.n_pods))(
                jax.random.PRNGKey(0)).stats
        assert int(stats.retired) > 0
        assert int(stats.nodes_active_final) < int(stats.nodes_active_peak)
        assert float(stats.nodes_active_mean) < float(stats.nodes_active_peak)

    def test_stats_are_consistent_integrals(self):
        cfg = scenarios.make_env("diurnal-churn")
        sel = schedulers.make_kube_selector(cfg)
        stats = jax.jit(
            lambda k: kenv.run_episode(k, cfg, sel, 40))(
                jax.random.PRNGKey(1)).stats
        assert float(stats.node_seconds) > 0.0
        assert float(stats.energy_wh) > 0.0
        assert 0.0 < float(stats.nodes_active_mean) <= float(stats.nodes_active_peak)
        assert int(stats.nodes_active_peak) <= cfg.n_nodes

    def test_settle_override_materializes(self):
        cfg = scenarios.make_env("short-job-burst")
        assert cfg.settle_steps == 60
        cfg2 = scenarios.make_env("short-job-burst", settle_steps=5)
        assert cfg2.settle_steps == 5  # explicit override wins


class TestConsolidator:
    def _loaded_state(self, cfg, pods_per_node):
        """A cluster with `pods_per_node[i]` experiment pods on node i, all
        ledgered with long lifetimes."""
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        pod = kenv.default_pod(cfg)
        ledger = kenv.ledger_init(int(sum(pods_per_node)))
        slot = 0
        for node, k in enumerate(pods_per_node):
            for _ in range(k):
                state = kenv.place(state, jnp.int32(node), pod, cfg)
                ledger = kenv.ledger_record(ledger, slot, jnp.int32(node),
                                            state.time_s + 1e6, pod)
                slot += 1
        return state, ledger, pod

    def test_drains_low_occupancy_nodes(self):
        from repro.sched import elastic

        cfg = paper_cluster()
        qp = dqn.init_qnet(jax.random.PRNGKey(2))
        state, ledger, pod = self._loaded_state(cfg, (1, 6, 1, 0))
        cons = jax.jit(elastic.make_consolidator(qp, cfg, max_migrations=4,
                                                 idle_threshold=2))
        new_state, new_ledger, moved = cons(state, ledger)
        assert int(moved) >= 1
        # conservation: nothing created or destroyed, just moved
        assert int(new_state.exp_pods.sum()) == int(state.exp_pods.sum())
        np.testing.assert_allclose(float(new_state.pods_cpu.sum()),
                                   float(state.pods_cpu.sum()), rtol=1e-6)
        assert int(kenv.nodes_active(new_state)) <= int(kenv.nodes_active(state))
        # the ledger tracks the migrations: rows live on the new hosts
        live = np.asarray(new_ledger.node)
        counts = np.bincount(live[live >= 0], minlength=cfg.n_nodes)
        np.testing.assert_array_equal(counts, np.asarray(new_state.exp_pods))

    def test_noop_on_empty_and_saturated_clusters(self):
        from repro.sched import elastic

        cfg = paper_cluster()
        qp = dqn.init_qnet(jax.random.PRNGKey(2))
        cons = jax.jit(elastic.make_consolidator(qp, cfg))
        # empty: nothing to drain
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        ledger = kenv.ledger_init(4)
        new_state, _, moved = cons(state, ledger)
        assert int(moved) == 0
        np.testing.assert_array_equal(np.asarray(new_state.exp_pods),
                                      np.asarray(state.exp_pods))
        # every node above the idle threshold: no drain source
        state, ledger, _ = self._loaded_state(cfg, (5, 5, 5, 5))
        _, _, moved = cons(state, ledger)
        assert int(moved) == 0

    def test_already_packed_cluster_is_a_fixed_point(self):
        """A lone pod (maximally packed already) must stay put — targets must
        be at least as loaded as the source was BEFORE removal, else the pass
        ping-pongs the pod between empty nodes paying pull costs."""
        from repro.sched import elastic

        cfg = paper_cluster()
        qp = dqn.init_qnet(jax.random.PRNGKey(2))
        cons = jax.jit(elastic.make_consolidator(qp, cfg, max_migrations=4))
        state, ledger, _ = self._loaded_state(cfg, (1, 0, 0, 0))
        new_state, _, moved = cons(state, ledger)
        assert int(moved) == 0
        np.testing.assert_array_equal(np.asarray(new_state.exp_pods),
                                      np.asarray(state.exp_pods))
        np.testing.assert_allclose(np.asarray(new_state.startup_cpu),
                                   np.asarray(state.startup_cpu))

    def test_consolidated_episode_keeps_fewer_nodes_awake(self):
        """The in-episode pass must not *increase* active nodes, and the
        episode must stay conservation-clean under it."""
        from repro.sched import elastic

        base = scenarios.make_env("consolidation-stress", settle_steps=400)
        qp = dqn.init_qnet(jax.random.PRNGKey(4))
        cfg = dataclasses.replace(base, consolidate_every_s=30.0)
        sel = schedulers.make_sdqn_selector(qp, cfg)
        cons = elastic.make_consolidator(qp, cfg)
        n = 40
        key = jax.random.PRNGKey(6)
        plain = jax.jit(lambda k: kenv.run_episode(k, base, sel, n))(key)
        packed = jax.jit(lambda k: kenv.run_episode(
            k, cfg, sel, n, consolidate=cons))(key)
        assert (float(packed.stats.node_seconds)
                <= float(plain.stats.node_seconds) * 1.05)
        # all pods still die and release everything
        assert int(packed.stats.nodes_active_final) == 0
        np.testing.assert_array_equal(np.asarray(packed.state.exp_pods), 0)


class TestEnergyReward:
    def test_energy_term_counts_newly_active_nodes(self):
        before = jnp.array([2, 0, 1, 0])
        assert float(rewards.energy_term(before, jnp.array([2, 1, 1, 0]))) == 1.0
        assert float(rewards.energy_term(before, jnp.array([3, 0, 1, 0]))) == 0.0

    def test_reward_fn_prefers_packing_under_energy_weight(self):
        feats = jnp.zeros((4, 6))
        action = jnp.int32(1)
        ok = jnp.ones((4,), bool)
        before = jnp.array([3, 0, 0, 0])
        packed_after = jnp.array([4, 0, 0, 0])
        spread_after = jnp.array([3, 1, 0, 0])
        for variant in ("sdqn", "sdqn_n"):
            fn = rewards.make_reward_fn(variant, energy_weight=15.0)
            fn0 = rewards.make_reward_fn(variant, energy_weight=0.0)
            gap = float(fn(feats, feats, ok, action, before, packed_after)
                        - fn(feats, feats, ok, action, before, spread_after))
            gap0 = float(fn0(feats, feats, ok, action, before, packed_after)
                         - fn0(feats, feats, ok, action, before, spread_after))
            assert gap - gap0 == pytest.approx(15.0), variant


class TestLifecycleGate:
    def _payload(self, ratios, throughput=250.0):
        rows = []
        for scn, (kube, sdqnn) in ratios.items():
            rows.append({"name": f"lifecycle_{scn}_kube", "us_per_call": 0.0,
                         "derived": kube})
            rows.append({"name": f"lifecycle_{scn}_sdqn", "us_per_call": 0.0,
                         "derived": (kube + sdqnn) / 2})
            rows.append({"name": f"lifecycle_{scn}_sdqnn", "us_per_call": 0.0,
                         "derived": sdqnn})
            rows.append({"name": f"lifecycle_{scn}_sdqnn_energy_wh",
                         "us_per_call": 0.0, "derived": 1.0})
        rows.append({"name": "lifecycle_episode_throughput", "us_per_call": 0.0,
                     "derived": throughput})
        return {"rows": rows}

    def test_gate_passes_within_tolerance(self):
        from benchmarks import check_smoke

        base = self._payload({"short-job-burst": (4.0, 2.0)})
        cur = self._payload({"short-job-burst": (4.0, 2.1)})
        rc = check_smoke.compare(cur, base, 0.10, lifecycle=True,
                                 throughput_rows=["lifecycle_episode_throughput"],
                                 throughput_tolerance=0.5)
        assert rc == 0

    def test_gate_fails_on_consolidation_regression(self):
        from benchmarks import check_smoke

        base = self._payload({"short-job-burst": (4.0, 2.0)})
        cur = self._payload({"short-job-burst": (4.0, 3.5)})  # ratio 0.5 -> 0.875
        assert check_smoke.compare(cur, base, 0.10, lifecycle=True) == 1

    def test_gate_fails_on_throughput_collapse(self):
        from benchmarks import check_smoke

        base = self._payload({"short-job-burst": (4.0, 2.0)}, throughput=250.0)
        cur = self._payload({"short-job-burst": (4.0, 2.0)}, throughput=50.0)
        rc = check_smoke.compare(cur, base, 0.10, lifecycle=True,
                                 throughput_rows=["lifecycle_episode_throughput"],
                                 throughput_tolerance=0.5)
        assert rc == 1

    def test_gate_fails_on_missing_scenario(self):
        from benchmarks import check_smoke

        base = self._payload({"short-job-burst": (4.0, 2.0),
                              "diurnal-churn": (5.0, 2.0)})
        cur = self._payload({"short-job-burst": (4.0, 2.0)})
        assert check_smoke.compare(cur, base, 0.10, lifecycle=True) == 1


class TestEvalEngineLifecycle:
    def test_batched_trials_surface_lifecycle_stats(self):
        from repro.eval import engine as eval_engine

        cfg = scenarios.make_env("short-job-burst")
        sel = schedulers.make_kube_selector(cfg)
        res = eval_engine.make_batch_episode(cfg, sel, 20)(
            eval_engine.trial_keys(jax.random.PRNGKey(0), 3))
        assert res.nodes_active.shape == (3,)
        assert bool(np.all(np.asarray(res.retired) > 0))
        out = eval_engine.summarize(res)
        for k in ("nodes_active_mean", "nodes_active_final_mean",
                  "node_seconds_mean", "energy_wh_mean", "retired_mean"):
            assert k in out, k
        assert out["retired_mean"] > 0
