"""Batched evaluation engine tests: equivalence with the per-trial loop,
summary statistics, and the seed-selection evaluator."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dqn, env as kenv, schedulers
from repro.core.types import paper_cluster
from repro.eval import engine as eval_engine

CFG = paper_cluster()


class TestBatchEpisode:
    def test_matches_per_trial_loop_exactly(self):
        """vmap over trial keys must reproduce the Python loop bit-for-bit:
        same keys -> same episodes, just one launch instead of T dispatches."""
        sel = schedulers.make_kube_selector(CFG)
        trials = 4
        batch = eval_engine.make_batch_episode(CFG, sel, 30)
        keys = eval_engine.trial_keys(jax.random.PRNGKey(7), trials)
        res = batch(keys)
        ep = jax.jit(lambda k: kenv.run_episode(k, CFG, sel, 30))
        for t in range(trials):
            r = ep(jax.random.fold_in(jax.random.PRNGKey(7), t))
            assert float(res.metric[t]) == float(r.metric)
            np.testing.assert_array_equal(np.asarray(res.distribution[t]),
                                          np.asarray(r.placements))
            np.testing.assert_array_equal(np.asarray(res.exp_pods[t]),
                                          np.asarray(r.state.exp_pods))
            assert int(res.dropped[t]) == int(r.dropped)

    def test_shapes(self):
        sel = schedulers.make_kube_selector(CFG)
        res = eval_engine.make_batch_episode(CFG, sel, 10)(
            eval_engine.trial_keys(jax.random.PRNGKey(0), 5))
        assert res.metric.shape == (5,)
        assert res.distribution.shape == (5, CFG.n_nodes)
        assert res.exp_pods.shape == (5, CFG.n_nodes)
        assert res.dropped.shape == (5,)
        assert res.placed.shape == (5,)
        for field in ("nodes_active", "nodes_active_final", "node_seconds",
                      "energy_wh", "retired"):
            assert getattr(res, field).shape == (5,), field

    def test_fixed_trial_keys_match_prng_ladder(self):
        keys = eval_engine.fixed_trial_keys(100, 3)
        for t in range(3):
            np.testing.assert_array_equal(np.asarray(keys[t]),
                                          np.asarray(jax.random.PRNGKey(100 + t)))


class TestSummarize:
    def test_summary_fields(self):
        sel = schedulers.make_kube_selector(CFG)
        out = eval_engine.evaluate(jax.random.PRNGKey(0), CFG, sel,
                                   trials=4, n_pods=20)
        for k in ("metric_mean", "metric_std", "metric_ci95", "dropped_mean",
                  "dropped_max", "pods_placed_mean", "trials", "n_pods",
                  "n_nodes"):
            assert k in out, k
        assert out["trials"] == 4.0
        assert out["n_pods"] == 20.0
        assert out["n_nodes"] == float(CFG.n_nodes)
        assert 5.0 < out["metric_mean"] < 60.0
        assert out["dropped_mean"] == 0.0
        assert out["pods_placed_mean"] == 20.0

    def test_ci_shrinks_with_trials(self):
        def tr(metric):
            t = metric.shape[0]
            z = jnp.zeros((t,), jnp.int32)
            f = jnp.zeros((t,))
            return eval_engine.TrialResults(
                metric, jnp.zeros((t, 2)), jnp.zeros((t, 2)), z, z,
                f, z, f, f, z)

        m = jnp.array([20.0, 30.0] * 8)  # same spread at every length
        few = eval_engine.summarize(tr(m[:4]))
        many = eval_engine.summarize(tr(m))
        assert many["metric_std"] == few["metric_std"]
        assert many["metric_ci95"] == few["metric_ci95"] / 2.0


class TestParamEvaluator:
    def test_matches_direct_selector(self):
        params = dqn.init_qnet(jax.random.PRNGKey(0))
        evaluator = eval_engine.make_param_evaluator(
            CFG, lambda p: schedulers.make_sdqn_selector(p, CFG), 20)
        keys = eval_engine.fixed_trial_keys(5000, 3)
        res = evaluator(params, keys)
        direct = eval_engine.make_batch_episode(
            CFG, schedulers.make_sdqn_selector(params, CFG), 20)(keys)
        np.testing.assert_allclose(np.asarray(res.metric),
                                   np.asarray(direct.metric), rtol=1e-6)

    def test_distinguishes_params(self):
        evaluator = eval_engine.make_param_evaluator(
            CFG, lambda p: schedulers.make_sdqn_selector(p, CFG), 20)
        keys = eval_engine.fixed_trial_keys(5000, 2)
        m0 = evaluator(dqn.init_qnet(jax.random.PRNGKey(0)), keys).metric
        m1 = evaluator(dqn.init_qnet(jax.random.PRNGKey(3)), keys).metric
        assert np.asarray(m0).shape == np.asarray(m1).shape == (2,)
        # different Q-nets place differently on at least one trial
        assert not np.allclose(np.asarray(m0), np.asarray(m1))
