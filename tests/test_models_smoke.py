"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as mdl

ARCHS = list(list_archs())


def make_batch(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens,
             "loss_mask": jnp.ones((b, s), jnp.float32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_vision_tokens:
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = mdl.init_params(key, cfg)
    batch = make_batch(cfg, key)

    def loss_fn(p):
        return mdl.loss_and_metrics(p, cfg, batch, q_chunk=8, mamba_chunk=8)

    (loss, metrics), grads = jax.jit(
        lambda p: jax.value_and_grad(lambda q: loss_fn(q), has_aux=True)(p)
    )(params)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """Greedy decode after prefill must equal teacher-forced next-token
    argmax from the full forward pass (cache correctness)."""
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = mdl.init_params(key, cfg)
    b, s = 2, 16
    batch = make_batch(cfg, key, b, s)

    logits_pre, cache = jax.jit(
        lambda p: mdl.prefill(p, cfg, batch["tokens"], batch, q_chunk=8, mamba_chunk=8)
    )(params)
    assert logits_pre.shape == (b, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits_pre)))

    # full forward gives the same last-position logits
    x, _, _ = mdl.forward(params, cfg, batch["tokens"], batch, mode="train",
                          q_chunk=8, mamba_chunk=8)
    logits_full = mdl.logits_from_hidden(params, cfg, x[:, -1:])[:, 0]
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)

    # decode one token continuing from the prefill cache
    def pad(leaf):
        if leaf.ndim == 5 and leaf.shape[2] == s:
            width = [(0, 0)] * 5
            width[2] = (0, 4)
            return jnp.pad(leaf, width)
        return leaf

    cache = jax.tree.map(pad, cache)
    nxt = jnp.argmax(logits_pre, -1)[:, None].astype(jnp.int32)
    logits_dec, cache2 = jax.jit(
        lambda p, t, c: mdl.decode_step(p, cfg, t, c, jnp.int32(s))
    )(params, nxt, cache)
    assert logits_dec.shape == (b, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits_dec)))
    # cache structure is stable across steps
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


def test_full_configs_match_assignment():
    """The registered full configs carry the exact assigned hyper-parameters."""
    expect = {
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    # family-specific extras
    q = get_config("qwen2-moe-a2.7b")
    assert (q.moe_num_experts, q.moe_top_k) == (60, 4)
    dbx = get_config("dbrx-132b")
    assert (dbx.moe_num_experts, dbx.moe_top_k) == (16, 4)
    fm = get_config("falcon-mamba-7b")
    assert fm.ssm_state == 16
    jm = get_config("jamba-1.5-large-398b")
    assert (jm.moe_num_experts, jm.moe_top_k, jm.attn_period) == (16, 2, 8)


def test_param_counts_in_expected_range():
    """Analytic parameter counts should be near the nameplate sizes."""
    for arch, lo, hi in [
        ("olmo-1b", 0.9e9, 1.6e9),
        ("granite-8b", 7e9, 9.5e9),
        ("llama3-405b", 380e9, 430e9),
        ("command-r-plus-104b", 95e9, 125e9),
        ("dbrx-132b", 120e9, 145e9),
        ("falcon-mamba-7b", 6e9, 8.5e9),
        ("jamba-1.5-large-398b", 370e9, 420e9),
        ("internvl2-76b", 65e9, 80e9),
    ]:
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3g}"
