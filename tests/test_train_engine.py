"""Seed-parallel training-engine tests: equivalence with the sequential seed
loop, joint seed×env layout planning + mesh-constraint parity, fused in-loop
afterstate scoring, NaN-guarded candidate selection, and replay-sampling
regressions."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dqn, env as kenv, policy as policy_mod, rewards, \
    schedulers, train_rl
from repro.core.replay import replay_add, replay_init, replay_sample
from repro.core.types import fleet_cluster, paper_cluster, training_cluster
from repro.eval import engine as eval_engine
from repro.launch import mesh as meshmod
from repro.train import engine

TCFG = training_cluster()
# tiny but complete: bootstrap on, replay wraps (cap 64 < 2*3*5 stores... it
# does not wrap here, wraparound is covered by TestReplaySampling directly)
RL = train_rl.RLConfig(variant="sdqn", episodes=3, pods_per_episode=5,
                       n_envs=2, batch_size=16, buffer_capacity=64)


def _train_sequential(key, n_seeds, rl=RL, cfg=TCFG):
    train_fn = jax.jit(lambda k: train_rl.train(k, cfg, rl))
    return [train_fn(jax.random.fold_in(key, s)) for s in range(n_seeds)]


class TestSeedParallel:
    def test_matches_sequential_per_seed(self):
        """One vmapped launch == the per-seed sequential loop, seed by seed.

        Same ``fold_in(key, s)`` ladder, same PRNG streams; values agree to
        float-reassociation tolerance (vmap batches the learner's matmul and
        reduction accumulations, which drifts ~1e-9/step — there is no
        semantic divergence, pinned here at 1e-6).
        """
        key = jax.random.PRNGKey(0)
        seqs = _train_sequential(key, 3)
        stacked, metrics = engine.train_seeds(key, TCFG, RL, 3)
        for s in range(3):
            for name, leaf in seqs[s][0].items():
                np.testing.assert_allclose(np.asarray(stacked[name][s]),
                                           np.asarray(leaf),
                                           atol=1e-6, rtol=1e-5, err_msg=name)
            for m in ("loss", "reward", "avg_cpu"):
                np.testing.assert_allclose(np.asarray(metrics[m][s]),
                                           np.asarray(seqs[s][1][m]),
                                           atol=1e-6, rtol=1e-5, err_msg=m)

    @pytest.mark.parametrize("policy", sorted(policy_mod.names()))
    def test_matches_sequential_per_seed_all_policy_classes(self, policy):
        """Every registered policy class trains through the UNCHANGED
        seed-parallel engine: one vmapped launch == the per-seed sequential
        loop, whatever the params pytree looks like (nested for mamba)."""
        rl = dataclasses.replace(RL, policy=policy, episodes=2)
        key = jax.random.PRNGKey(5)
        seqs = _train_sequential(key, 2, rl=rl)
        stacked, metrics = engine.train_seeds(key, TCFG, rl, 2)
        stacked_leaves, treedef = jax.tree.flatten(stacked)
        for s in range(2):
            seq_leaves, seq_def = jax.tree.flatten(seqs[s][0])
            assert seq_def == treedef
            for got, want in zip(stacked_leaves, seq_leaves):
                np.testing.assert_allclose(np.asarray(got[s]),
                                           np.asarray(want),
                                           atol=1e-6, rtol=1e-5)
            for m in ("loss", "reward", "avg_cpu"):
                np.testing.assert_allclose(np.asarray(metrics[m][s]),
                                           np.asarray(seqs[s][1][m]),
                                           atol=1e-6, rtol=1e-5, err_msg=m)

    def test_seed_keys_match_fold_in_ladder(self):
        keys = engine.seed_fold_keys(jax.random.PRNGKey(3), 4)
        for s in range(4):
            np.testing.assert_array_equal(
                np.asarray(keys[s]),
                np.asarray(jax.random.fold_in(jax.random.PRNGKey(3), s)))

    def test_host_mesh_parity(self):
        """The seed-axis sharding constraint must not change results (here on
        the 1-device host mesh — the CPU fallback the tests always take)."""
        key = jax.random.PRNGKey(1)
        plain, _ = engine.train_seeds(key, TCFG, RL, 2)
        sharded, _ = engine.train_seeds(key, TCFG, RL, 2,
                                        mesh=meshmod.make_host_mesh())
        for name in plain:
            np.testing.assert_allclose(np.asarray(sharded[name]),
                                       np.asarray(plain[name]),
                                       atol=1e-6, rtol=1e-5, err_msg=name)

    def test_train_env_mesh_parity(self):
        """``train(mesh=...)``'s n_envs ``data`` constraint is numerics-
        neutral; an indivisible batch falls back to the identity program."""
        key = jax.random.PRNGKey(2)
        ref, _ = jax.jit(lambda k: train_rl.train(k, TCFG, RL))(key)
        mesh = meshmod.make_train_mesh()
        got, _ = train_rl.train(key, TCFG, RL, mesh=mesh)
        for name in ref:
            np.testing.assert_allclose(np.asarray(got[name]),
                                       np.asarray(ref[name]),
                                       atol=1e-6, rtol=1e-5, err_msg=name)

    def test_train_and_select_matches_sequential_selection(self):
        """The engine must pick the same candidate the old Python loop did
        and return that candidate's params."""
        key = jax.random.PRNGKey(4)
        n_seeds, val_trials, val_pods = 2, 2, 8
        # the pre-engine path: sequential train + per-seed batched validation
        evaluator = eval_engine.make_param_evaluator(
            TCFG, lambda p: schedulers.make_sdqn_selector(p, TCFG), val_pods)
        val_keys = eval_engine.fixed_trial_keys(5000, val_trials)
        best_params, best_metric = None, jnp.inf
        for params, _ in _train_sequential(key, n_seeds):
            metric = jnp.mean(evaluator(params, val_keys).metric)
            if metric < best_metric:
                best_params, best_metric = params, metric
        got_params, got_metric = train_rl.train_and_select(
            key, TCFG, TCFG, RL, n_seeds=n_seeds, val_trials=val_trials,
            val_pods=val_pods)
        assert got_params is not None
        np.testing.assert_allclose(got_metric, float(best_metric), rtol=1e-4)
        for name in best_params:
            np.testing.assert_allclose(np.asarray(got_params[name]),
                                       np.asarray(best_params[name]),
                                       atol=1e-6, rtol=1e-5, err_msg=name)


class TestSelectBest:
    def _stack(self):
        return {"w": jnp.arange(3.0).reshape(3, 1)}

    def test_picks_min(self):
        p, v, diverged = engine.select_best(self._stack(),
                                            jnp.array([3.0, 1.0, 2.0]))
        assert float(v) == 1.0 and float(p["w"][0]) == 1.0
        assert not bool(diverged)

    def test_nan_never_wins(self):
        """NaN validation metrics must not beat finite ones (every NaN
        comparison is False, so the old running-min returned (None, inf))."""
        p, v, diverged = engine.select_best(self._stack(),
                                            jnp.array([jnp.nan, 2.0, jnp.nan]))
        assert float(v) == 2.0 and float(p["w"][0]) == 1.0
        assert not bool(diverged)  # one finite seed is a real selection

    def test_all_nan_falls_back_to_seed0_and_warns(self):
        """All-NaN still returns real params (seed 0), but the ``diverged``
        flag must distinguish that fallback from seed 0 *winning* — the
        metric alone cannot (callers see inf either way)."""
        p, v, diverged = engine.select_best(self._stack(),
                                            jnp.full((3,), jnp.nan))
        assert np.isinf(float(v)) and float(p["w"][0]) == 0.0
        assert bool(diverged)

    def test_train_and_select_warns_on_divergence(self, monkeypatch):
        """The engine surfaces the all-NaN case as a RuntimeWarning instead
        of silently handing back seed 0."""
        import pytest

        def fake_train_seeds(key, cfg, rl, n_seeds, mesh=None):
            return {"w": jnp.zeros((n_seeds, 1))}, {}

        class FakeEval:
            def __call__(self, stacked, keys):
                class R:
                    metric = jnp.full((2, 3), jnp.nan)
                return R()

        monkeypatch.setattr(engine, "train_seeds", fake_train_seeds)
        monkeypatch.setattr(engine.eval_engine, "make_multi_param_evaluator",
                            lambda *a, **k: FakeEval())
        with pytest.warns(RuntimeWarning, match="NaN"):
            params, metric = engine.train_and_select(
                jax.random.PRNGKey(0), TCFG, TCFG, RL, n_seeds=2,
                val_trials=3)
        assert np.isinf(metric) and params is not None


class TestLayoutPlanner:
    """``plan_seed_env_layout``: the joint seed×env device split."""

    def test_split_prefers_seed_axis(self):
        assert meshmod._split_seed_env(4, 16, 4) == (4, 1)
        assert meshmod._split_seed_env(8, 16, 4) == (4, 1)

    def test_split_joint_when_seeds_short(self):
        assert meshmod._split_seed_env(2, 16, 4) == (2, 2)
        assert meshmod._split_seed_env(2, 16, 8) == (2, 4)
        assert meshmod._split_seed_env(6, 10, 4) == (2, 2)
        assert meshmod._split_seed_env(9, 8, 6) == (3, 2)

    def test_split_env_only(self):
        assert meshmod._split_seed_env(3, 16, 4) == (1, 4)
        assert meshmod._split_seed_env(1, 8, 2) == (1, 2)

    def test_split_indivisible(self):
        assert meshmod._split_seed_env(3, 5, 4) is None
        assert meshmod._split_seed_env(2, 2, 8) is None  # batch < devices
        assert meshmod._split_seed_env(2, 16, 0) is None

    def test_split_always_exists_when_product_divides(self):
        """Number theory pin: the greedy prime split never misses a valid
        factorization when n_seeds * n_envs % n_dev == 0."""
        for n_seeds in range(1, 13):
            for n_envs in range(1, 17):
                for n_dev in range(1, 17):
                    got = meshmod._split_seed_env(n_seeds, n_envs, n_dev)
                    if (n_seeds * n_envs) % n_dev == 0:
                        s, e = got
                        assert s * e == n_dev
                        assert n_seeds % s == 0 and n_envs % e == 0
                    else:
                        assert got is None

    def test_single_device_and_no_mesh_plan_none(self):
        assert meshmod.plan_seed_env_layout(4, 16, None) is None
        assert meshmod.plan_seed_env_layout(
            4, 16, meshmod.make_host_mesh()) is None

    def test_layout_is_hashable_jit_static(self):
        lay = meshmod.SeedEnvLayout(meshmod.make_host_mesh(), 1, 1)
        assert hash(lay) == hash(
            meshmod.SeedEnvLayout(meshmod.make_host_mesh(), 1, 1))


class TestJointShardingParity:
    """Multi-device parity for the joint layouts, in a child process (the
    host platform can only be split into >1 device before jax initializes).

    One child covers the three layout paths on a forced 4-device host:
    joint (2, 2) at n_seeds=2, env-only (1, 4) at n_seeds=3 with the seed
    axis indivisible, and the full fallback at an indivisible batch — each
    pinned <= 1e-6 against the unsharded program with the identical
    ``fold_in`` PRNG ladder."""

    _CHILD = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core import train_rl
        from repro.core.types import training_cluster
        from repro.launch import mesh as meshmod
        from repro.train import engine

        cfg = training_cluster()
        key = jax.random.PRNGKey(0)
        mesh4 = meshmod.make_train_mesh(4)
        checks = {}

        def parity(tag, rl, n_seeds):
            ref, rm = engine.train_seeds(key, cfg, rl, n_seeds)
            got, gm = engine.train_seeds(key, cfg, rl, n_seeds, mesh=mesh4)
            # the repo-wide parity pin: atol 1e-6 with rtol 1e-5 headroom for
            # float reassociation on O(10-100) metrics (see
            # TestSeedParallel.test_matches_sequential_per_seed)
            for name in ref:
                np.testing.assert_allclose(np.asarray(got[name]),
                                           np.asarray(ref[name]),
                                           atol=1e-6, rtol=1e-5,
                                           err_msg=f"{tag}:{name}")
            for k in rm:
                np.testing.assert_allclose(np.asarray(gm[k]),
                                           np.asarray(rm[k]),
                                           atol=1e-6, rtol=1e-5,
                                           err_msg=f"{tag}:{k}")
            checks[tag] = "ok"

        rl4 = train_rl.RLConfig(episodes=2, pods_per_episode=5, n_envs=4,
                                batch_size=16, buffer_capacity=64)
        lay = meshmod.plan_seed_env_layout(2, 4, mesh4)
        assert (lay.seed_shards, lay.env_shards) == (2, 2), lay
        parity("joint_2x2", rl4, 2)

        lay = meshmod.plan_seed_env_layout(3, 4, mesh4)
        assert (lay.seed_shards, lay.env_shards) == (1, 4), lay
        parity("env_only_1x4", rl4, 3)

        rl5 = train_rl.RLConfig(episodes=1, pods_per_episode=4, n_envs=5,
                                batch_size=16, buffer_capacity=60)
        assert meshmod.plan_seed_env_layout(3, 5, mesh4) is None
        parity("fallback_unsharded", rl5, 3)

        print("PARITY" + json.dumps(checks))
    """)

    def test_joint_and_fallback_match_unsharded(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            " --xla_force_host_platform_device_count=4").strip()
        env["JAX_PLATFORM_NAME"] = "cpu"
        # the child must resolve the same repro tree whether the suite runs
        # from PYTHONPATH=src or an editable install
        import repro

        # __path__ (not __file__): repro is a namespace package
        src_dir = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        env["PYTHONPATH"] = os.pathsep.join(
            [src_dir] + [p for p in (env.get("PYTHONPATH"),) if p])
        out = subprocess.run([sys.executable, "-c", self._CHILD], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("PARITY")][-1]
        checks = json.loads(line[len("PARITY"):])
        assert set(checks) == {"joint_2x2", "env_only_1x4",
                               "fallback_unsharded"}
        assert all(v == "ok" for v in checks.values()), checks


class TestFusedInLoopScoring:
    def _setup(self, cfg):
        state = kenv.reset(jax.random.PRNGKey(0), cfg)
        return state, kenv.default_pod(cfg)

    def test_hypothetical_place_one_matches_matrix_paper(self):
        cfg = paper_cluster()
        state, pod = self._setup(cfg)
        full = kenv.hypothetical_place(state, pod, cfg)
        for i in range(cfg.n_nodes):
            np.testing.assert_array_equal(
                np.asarray(kenv.hypothetical_place_one(state, pod, cfg,
                                                       jnp.int32(i))),
                np.asarray(full[i]))

    def test_hypothetical_place_one_matches_matrix_fleet(self):
        cfg = fleet_cluster(4096)
        state, pod = self._setup(cfg)
        full = kenv.hypothetical_place(state, pod, cfg)
        for i in (0, 1, 2047, 4095):
            np.testing.assert_allclose(
                np.asarray(kenv.hypothetical_place_one(state, pod, cfg,
                                                       jnp.int32(i))),
                np.asarray(full[i]), atol=1e-5)

    def test_training_scoring_matches_reference_paper_cluster(self):
        """In-loop scoring == hypothetical_place + qvalues on the 4-node
        paper cluster (N < FUSED_SCORE_MIN_NODES: the identical jnp path)."""
        cfg = paper_cluster()
        state, pod = self._setup(cfg)
        qp = dqn.init_qnet(jax.random.PRNGKey(1))
        ref = dqn.qvalues(qp, kenv.normalize_features(
            kenv.hypothetical_place(state, pod, cfg)))
        got = schedulers.score_afterstates(qp, state, pod, cfg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_training_scoring_matches_reference_fleet(self):
        """At 4096 nodes the training loop's scoring dispatches to the fused
        kernel path; it must agree with the unfused reference to <=1e-5."""
        cfg = fleet_cluster(4096)
        state, pod = self._setup(cfg)
        qp = dqn.init_qnet(jax.random.PRNGKey(1))
        ref = dqn.qvalues(qp, kenv.normalize_features(
            kenv.hypothetical_place(state, pod, cfg)))
        got = schedulers.score_afterstates(qp, state, pod, cfg)
        assert got.shape == (4096,)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)

    def test_transition_matches_unfused_reference(self):
        """`_transition` (shared helper + fused dispatch) reproduces the old
        inline body: same action, same stored afterstate, same reward."""
        cfg = TCFG
        state, pod = self._setup(cfg)
        qp = dqn.init_qnet(jax.random.PRNGKey(1))
        rl = RL
        reward_fn = rewards.make_reward_fn(rl.variant, rl.consolidation_n,
                                           rl.efficiency_weight)
        key = jax.random.PRNGKey(7)

        # the pre-refactor transition body, verbatim
        ok = kenv.feasible(state, pod, cfg)
        after_all = kenv.hypothetical_place(state, pod, cfg)
        q = dqn.qvalues(qp, kenv.normalize_features(after_all))
        action = schedulers.masked_argmax(key, q, ok, 0.1)
        ref_state = kenv.place(state, action, pod, cfg)
        ref_r = reward_fn(kenv.features(ref_state, cfg),
                          kenv.features(state, cfg), ok, action,
                          state.exp_pods, ref_state.exp_pods)
        ref_stored = kenv.normalize_features(after_all[jnp.maximum(action, 0)])

        new_state, stored, r, got_action = train_rl._transition(
            key, qp, state, pod, cfg.schedule_dt_s, cfg, 0.1, reward_fn)
        assert int(got_action) == int(action)
        np.testing.assert_array_equal(np.asarray(stored), np.asarray(ref_stored))
        np.testing.assert_allclose(float(r), float(ref_r) * train_rl.REWARD_SCALE,
                                   rtol=1e-6)


class TestMultiParamEvaluator:
    def test_matches_per_seed_evaluator(self):
        cfg = paper_cluster()
        stacked = jax.vmap(dqn.init_qnet)(engine.seed_fold_keys(
            jax.random.PRNGKey(0), 2))
        keys = eval_engine.fixed_trial_keys(5000, 3)
        multi = eval_engine.make_multi_param_evaluator(
            cfg, lambda p: schedulers.make_sdqn_selector(p, cfg), 10)
        res = multi(stacked, keys)
        assert res.metric.shape == (2, 3)
        single = eval_engine.make_param_evaluator(
            cfg, lambda p: schedulers.make_sdqn_selector(p, cfg), 10)
        for s in range(2):
            params = jax.tree.map(lambda x: x[s], stacked)
            np.testing.assert_allclose(np.asarray(res.metric[s]),
                                       np.asarray(single(params, keys).metric),
                                       rtol=1e-6)


class TestReplaySampling:
    """`replay_sample` draws from [0, size): indices are in-range by
    construction — these regressions pin it across fill levels."""

    def _buf(self, cap, n):
        buf = replay_init(cap)
        feats = jnp.tile(jnp.arange(n, dtype=jnp.float32)[:, None], (1, 6))
        return replay_add(buf, feats, jnp.arange(n, dtype=jnp.float32))

    def _assert_samples_live(self, buf, live_targets, batch=64):
        for t in range(5):
            feats, targets, w = replay_sample(buf, jax.random.PRNGKey(t), batch)
            assert set(np.asarray(targets).tolist()) <= live_targets
            np.testing.assert_array_equal(np.asarray(w), np.ones((batch,)))
            # stored rows are (target, target, ..., target): sampling must
            # return rows aligned with their targets
            np.testing.assert_array_equal(np.asarray(feats[:, 0]),
                                          np.asarray(targets))

    def test_partial_fill(self):
        buf = self._buf(8, 3)
        assert int(buf.size) == 3
        self._assert_samples_live(buf, {0.0, 1.0, 2.0})

    def test_exact_fill(self):
        buf = self._buf(8, 8)
        assert int(buf.size) == 8 and int(buf.ptr) == 0
        self._assert_samples_live(buf, set(float(i) for i in range(8)))

    def test_wraparound_overwrite(self):
        """12 adds into cap=8: slots 0-3 now hold entries 8-11; every sample
        must come from the live set {4..11}, never a stale overwritten row."""
        buf = self._buf(8, 12)
        assert int(buf.size) == 8 and int(buf.ptr) == 4
        self._assert_samples_live(buf, set(float(i) for i in range(4, 12)))

    def test_empty_buffer_zero_weights(self):
        buf = replay_init(8)
        _, _, w = replay_sample(buf, jax.random.PRNGKey(0), 16)
        np.testing.assert_array_equal(np.asarray(w), np.zeros((16,)))


class TestReplayLaneLayout:
    """The training loop's ring is lane-structured by ``n_envs``."""

    def test_init_carry_lane_matches_env_batch(self):
        carry = train_rl._init_carry(jax.random.PRNGKey(0), RL)
        assert carry.buffer.lane == RL.n_envs
        assert carry.buffer.capacity == RL.buffer_capacity

    def test_init_carry_lane_falls_back_when_indivisible(self):
        rl = train_rl.RLConfig(n_envs=3, buffer_capacity=64)
        carry = train_rl._init_carry(jax.random.PRNGKey(0), rl)
        assert carry.buffer.lane == 1
        assert carry.buffer.capacity == 64


class TestSupervisedSharedTransition:
    def test_lstm_scorer_trains_through_shared_helper(self):
        from repro.core import baselines

        params = train_rl.train_supervised_scorer(
            jax.random.PRNGKey(0), TCFG, baselines.init_lstm,
            baselines.lstm_score, episodes=2, pods_per_episode=4, n_envs=2)
        assert all(np.isfinite(np.asarray(leaf)).all()
                   for leaf in jax.tree.leaves(params))

    def test_transformer_scorer_trains_through_shared_helper(self):
        from repro.core import baselines

        params = train_rl.train_supervised_scorer(
            jax.random.PRNGKey(0), TCFG, baselines.init_transformer,
            baselines.transformer_score, episodes=2, pods_per_episode=4,
            n_envs=2)
        assert all(np.isfinite(np.asarray(leaf)).all()
                   for leaf in jax.tree.leaves(params))
