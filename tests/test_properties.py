"""Property-based invariant suites for the jit/vmap-heavy surface.

Three substrates, one file: the PodLedger lifecycle (retirement releases
exactly what placement acquired, never more), the replay ring (sampling is
always in-range across wraparound; dropped weight-0 transitions never train),
and the SDQN-n consolidator (packing is monotone, drained nodes are never
re-targeted, passes terminate).  Strategies come from ``tests/strategies.py``;
example budgets from the profiles in ``tests/conftest.py``.

Every property has a hypothesis-free fixed-case twin so the invariants stay
exercised on a bare ``pip install -e .`` (the [test] extra is only required
for the randomized tier).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import strategies as strat
from repro.core import dqn, env as kenv
from repro.core.replay import Replay, replay_add, replay_init, replay_sample
from repro.core.types import PodSpec, fleet_cluster, paper_cluster
from repro.sched import daemon as sched_daemon, elastic

# ---------------------------------------------------------------------------
# PodLedger lifecycle invariants
# ---------------------------------------------------------------------------

_LEDGER_CFG = paper_cluster()


def _check_ledger_invariants(seed, events):
    """Arbitrary arrival/advance interleavings never corrupt the accounting.

    Invariants checked after every event and at the force-drained end state:
      * retirement never drives CPU/mem requests, compute demand, memory use
        or pod slots negative on any node;
      * capacity is conserved — once every pod has retired, each accounting
        column returns to its reset value (startup transients and the image
        cache persist by design: pulling is not undone by a pod finishing).
    """
    cfg = _LEDGER_CFG
    state0 = kenv.reset(jax.random.PRNGKey(seed), cfg)
    pod = kenv.default_pod(cfg)
    state, ledger = state0, kenv.ledger_init(len(events))
    retired_total = 0
    for slot, (node, lifetime_s, advance_s) in enumerate(events):
        state = kenv.place(state, jnp.int32(node), pod, cfg)
        ledger = kenv.ledger_record(ledger, slot, jnp.int32(node),
                                    state.time_s + lifetime_s, pod)
        state = kenv.tick(state, cfg, advance_s)
        state, ledger, n_ret = kenv.retire_expired(state, ledger)
        retired_total += int(n_ret)
        for col in ("num_pods", "exp_pods"):
            assert int(getattr(state, col).min()) >= 0, col
        for col in ("cpu_requested", "mem_requested", "pods_cpu", "mem_used"):
            assert float(getattr(state, col).min()) >= -1e-3, col
    # drain: advance past every expiry, retire everything still live
    state = kenv.tick(state, cfg, 1e9)
    state, ledger, n_ret = kenv.retire_expired(state, ledger)
    retired_total += int(n_ret)
    assert retired_total == len(events)
    assert bool(jnp.all(ledger.node == -1))  # every slot freed
    np.testing.assert_array_equal(np.asarray(state.num_pods),
                                  np.asarray(state0.num_pods))
    np.testing.assert_array_equal(np.asarray(state.exp_pods),
                                  np.asarray(state0.exp_pods))
    for col in ("cpu_requested", "mem_requested", "pods_cpu", "mem_used"):
        np.testing.assert_allclose(np.asarray(getattr(state, col)),
                                   np.asarray(getattr(state0, col)),
                                   atol=1e-3, err_msg=col)


def test_ledger_invariants_fixed_cases():
    _check_ledger_invariants(0, [(0, 5.0, 10.0), (1, 100.0, 1.0),
                                 (1, 1.0, 2.0), (3, 50.0, 200.0)])
    _check_ledger_invariants(3, [(2, 0.5, 0.0)] * 6 + [(0, 600.0, 0.0)])
    _check_ledger_invariants(9, [(n % 4, 30.0, 29.0) for n in range(10)])


# ---------------------------------------------------------------------------
# replay ring invariants (numpy mirror model)
# ---------------------------------------------------------------------------


def _drive_ring(cap, lane, ops):
    """Run an add/sample op sequence against the ring AND a python model.

    Transitions get globally unique targets (a running counter), so a
    sampled row identifies exactly which stored transition it came from —
    in-range means "its counter is in the model's live window", and the
    weight rule is checked per identity, not in aggregate.
    """
    buf = replay_init(cap, lane=lane)
    model = {}  # linear slot -> (counter, weight)
    ptr = counter = 0
    for op in ops:
        if op[0] == "add":
            _, n, mask_seed = op
            n = n * lane  # lane-aligned widths (lane=1 keeps raw sizes)
            rng = np.random.RandomState(mask_seed)
            w = (rng.rand(n) > 0.3).astype(np.float32)
            vals = np.arange(counter, counter + n, dtype=np.float32)
            buf = replay_add(buf, jnp.tile(jnp.asarray(vals)[:, None], (1, 6)),
                             jnp.asarray(vals), jnp.asarray(w))
            for i in range(n):
                model[(ptr + i) % cap] = (vals[i], w[i])
            ptr = (ptr + n) % cap
            counter += n
        else:
            _, batch, key_seed = op
            feats, targets, weights = replay_sample(
                buf, jax.random.PRNGKey(key_seed), batch)
            live = dict(model.values())  # counter -> weight
            if not model:
                np.testing.assert_array_equal(np.asarray(weights),
                                              np.zeros(batch, np.float32))
                continue
            for f, t, w in zip(np.asarray(feats), np.asarray(targets),
                               np.asarray(weights)):
                assert t in live, f"sampled {t}: not a live transition"
                np.testing.assert_array_equal(f, np.full(6, t, np.float32))
                assert w == live[t], (
                    f"transition {t} stored weight {live[t]} sampled as {w}")
    assert int(buf.size) == min(len(model), cap)
    assert int(buf.ptr) == ptr


def _check_ring(ops):
    _drive_ring(cap=16, lane=1, ops=ops)
    _drive_ring(cap=16, lane=4, ops=ops)


def test_ring_invariants_fixed_cases():
    _check_ring([("add", 3, 0), ("sample", 32, 1)])
    # wraparound: 7 + 6 + 5 adds into cap=16 (x lane), samples in between
    _check_ring([("add", 7, 1), ("sample", 8, 2), ("add", 6, 3),
                 ("add", 5, 4), ("sample", 64, 5)])
    _check_ring([("sample", 4, 0), ("add", 1, 7), ("sample", 16, 8)])


class _OldReplay:
    """The pre-rework layout, verbatim semantics: three per-column arrays,
    modular scatter writes, three gathers per sample.  The parity pin below
    is what lets the fused ring claim 'transition streams unchanged'."""

    def __init__(self, capacity, n_features=6):
        self.feats = jnp.zeros((capacity, n_features), jnp.float32)
        self.targets = jnp.zeros((capacity,), jnp.float32)
        self.weights = jnp.zeros((capacity,), jnp.float32)
        self.ptr = jnp.zeros((), jnp.int32)
        self.size = jnp.zeros((), jnp.int32)

    def add(self, feats, targets, weights=None):
        cap = self.feats.shape[0]
        b = feats.shape[0]
        if weights is None:
            weights = jnp.ones((b,), jnp.float32)
        idx = (self.ptr + jnp.arange(b, dtype=jnp.int32)) % cap
        self.feats = self.feats.at[idx].set(feats)
        self.targets = self.targets.at[idx].set(targets)
        self.weights = self.weights.at[idx].set(weights.astype(jnp.float32))
        self.ptr = (self.ptr + b) % cap
        self.size = jnp.minimum(self.size + b, cap)

    def sample(self, key, batch):
        idx = jax.random.randint(key, (batch,), 0, jnp.maximum(self.size, 1))
        return self.feats[idx], self.targets[idx], self.weights[idx] * (self.size > 0)


def _check_old_new_parity(ops, lane):
    """New fused ring == the old three-array buffer, stream for stream,
    under the identical PRNG ladder (same sample keys, same draws).

    cap=64 keeps every single add narrower than the ring: the old scatter's
    behavior on an over-wide add was undefined (repeated indices), so parity
    is only claimed on the widths the training loop actually produces — the
    new ring's deterministic keep-the-tail rule for b > cap is pinned by the
    invariant suite above instead."""
    cap = 64
    old = _OldReplay(cap)
    new = replay_init(cap, lane=lane)
    counter = 0
    for op in ops:
        if op[0] == "add":
            _, n, mask_seed = op
            n = n * lane
            w = jnp.asarray(
                (np.random.RandomState(mask_seed).rand(n) > 0.3), jnp.float32)
            vals = jnp.arange(counter, counter + n, dtype=jnp.float32)
            feats = jnp.tile(vals[:, None], (1, 6))
            old.add(feats, vals, w)
            new = replay_add(new, feats, vals, w)
            counter += n
        else:
            _, batch, key_seed = op
            key = jax.random.PRNGKey(key_seed)
            fo, to, wo = old.sample(key, batch)
            fn, tn, wn = replay_sample(new, key, batch)
            np.testing.assert_array_equal(np.asarray(fn), np.asarray(fo))
            np.testing.assert_array_equal(np.asarray(tn), np.asarray(to))
            np.testing.assert_array_equal(np.asarray(wn), np.asarray(wo))
    assert int(new.ptr) == int(old.ptr) and int(new.size) == int(old.size)
    np.testing.assert_array_equal(np.asarray(new.feats), np.asarray(old.feats))
    np.testing.assert_array_equal(np.asarray(new.targets),
                                  np.asarray(old.targets))
    np.testing.assert_array_equal(np.asarray(new.weights),
                                  np.asarray(old.weights))


def test_old_new_replay_parity_fixed_cases():
    ops = [("add", 7, 1), ("sample", 33, 2), ("add", 6, 3), ("sample", 5, 4),
           ("add", 5, 5), ("sample", 64, 6)]
    _check_old_new_parity(ops, lane=1)
    _check_old_new_parity(ops, lane=4)  # DUS fast path, same linear layout


def test_replay_add_rejects_misaligned_width():
    buf = replay_init(16, lane=4)
    with pytest.raises(ValueError):
        replay_add(buf, jnp.ones((3, 6)), jnp.ones((3,)))
    with pytest.raises(ValueError):
        replay_init(16, lane=5)  # lane must divide capacity


def test_replay_flat_views_match_layout():
    """The ``feats``/``targets``/``weights`` properties present the fused
    (slot, lane) ring in linear transition order."""
    buf = replay_init(8, lane=2)
    feats = jnp.arange(6, dtype=jnp.float32)[None, :] + jnp.arange(4)[:, None]
    buf = replay_add(buf, feats, jnp.arange(4.0), jnp.array([1., 0., 1., 1.]))
    np.testing.assert_array_equal(np.asarray(buf.targets[:4]),
                                  np.arange(4, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(buf.weights[:4]),
                                  np.array([1., 0., 1., 1.], np.float32))
    np.testing.assert_array_equal(np.asarray(buf.feats[:4]), np.asarray(feats))
    assert isinstance(buf, Replay) and buf.capacity == 8 and buf.lane == 2


# ---------------------------------------------------------------------------
# consolidator properties (SDQN-n green pass)
# ---------------------------------------------------------------------------

_CONS_CFG = fleet_cluster(6)
_CONS_QP = dqn.init_qnet(jax.random.PRNGKey(0))
# jitted once at import: every property example reuses the same executables
# (re-wrapping per example would recompile the consolidation kernel each time)
_CONS_1 = jax.jit(elastic.make_consolidator(_CONS_QP, _CONS_CFG, max_migrations=1))
_CONS_4 = jax.jit(elastic.make_consolidator(_CONS_QP, _CONS_CFG, max_migrations=4))


def _churn_state(seed, trace):
    """An initially-fresh cluster with ``trace``'s pods bound + ledgered."""
    cfg = _CONS_CFG
    state = kenv.reset(jax.random.PRNGKey(seed), cfg)
    pod = kenv.default_pod(cfg)
    ledger = kenv.ledger_init(len(trace))
    for slot, (node, lifetime_s) in enumerate(trace):
        state = kenv.place(state, jnp.int32(node), pod, cfg)
        ledger = kenv.ledger_record(ledger, slot, jnp.int32(node),
                                    state.time_s + lifetime_s, pod)
    return cfg, state, ledger


def _check_consolidator_monotone(seed, trace):
    """Single-migration passes, iterated to the fixed point.

    Per move: the target was at least as loaded as the source (measured on
    the state the kernel saw: source's pod removed, source's pre-removal
    count as the bar) and is never the source itself.  Globally: pod count
    conserved, active nodes non-increasing, and the pass sequence terminates
    (monotone packing strictly grows sum(exp^2), so no ping-pong cycles).
    """
    cfg, state, ledger = _churn_state(seed, trace)
    cons = _CONS_1
    total = int(state.exp_pods.sum())
    bound = 2 * total * cfg.n_nodes + 5
    for _ in range(bound):
        nodes_before = np.asarray(state.exp_pods)
        led_before = np.asarray(ledger.node)
        state2, ledger2, moved = cons(state, ledger)
        if int(moved) == 0:
            # fixed point: the pass must be the exact identity
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            break
        changed = np.nonzero(led_before != np.asarray(ledger2.node))[0]
        assert changed.size == 1 == int(moved)
        row = int(changed[0])
        src, tgt = int(led_before[row]), int(ledger2.node[row])
        assert src != tgt
        pod = jax.tree.map(lambda c: c[row], ledger.spec)
        st_rm = kenv.remove_pod(state, jnp.int32(src), pod)
        assert int(st_rm.exp_pods[tgt]) >= int(nodes_before[src])
        assert int(state2.exp_pods.sum()) == total  # pods conserved
        assert int(kenv.nodes_active(state2)) <= int(kenv.nodes_active(state))
        state, ledger = state2, ledger2
    else:
        pytest.fail(f"no fixed point within {bound} single-move passes")


def _check_consolidator_no_pingpong(seed, trace):
    """One full pass (max_migrations=4): a node the pass fully drained never
    receives a migrated pod in that same pass (targets must carry at least
    the source's load, and a drained node carries none)."""
    cfg, state, ledger = _churn_state(seed, trace)
    cons = _CONS_4
    state2, ledger2, moved = cons(state, ledger)
    assert int(state2.exp_pods.sum()) == int(state.exp_pods.sum())
    assert int(kenv.nodes_active(state2)) <= int(kenv.nodes_active(state))
    drained = (np.asarray(state.exp_pods) > 0) & (np.asarray(state2.exp_pods) == 0)
    changed = np.nonzero(np.asarray(ledger.node) != np.asarray(ledger2.node))[0]
    for row in changed:
        tgt = int(ledger2.node[row])
        assert not drained[tgt], (
            f"pod re-bound onto node {tgt}, which this pass drained")


def test_consolidator_fixed_cases():
    _check_consolidator_monotone(0, [(0, 100.0), (1, 200.0)])
    _check_consolidator_monotone(1, [(n % 3, 60.0 * (n + 1)) for n in range(8)])
    _check_consolidator_no_pingpong(0, [(0, 100.0), (1, 200.0), (2, 300.0)])
    _check_consolidator_no_pingpong(5, [(n % 5, 90.0) for n in range(10)])


# ---------------------------------------------------------------------------
# placement-daemon invariants
# ---------------------------------------------------------------------------

_DAEMON_Q = dqn.init_qnet(jax.random.PRNGKey(2))


def _check_daemon_never_binds_infeasible(seed, ops):
    """No interleaving of submits, clock advances, polls and flushes makes
    the daemon bind an infeasible pod (``sched.daemon``'s optimistic-bind
    re-validation contract).  After every op AND after the final drain:

      * CPU/mem *requests* never exceed any node's capacity;
      * no node exceeds its max-pods slot ceiling;
      * the unhealthy node never gains a pod;
      * every submitted request eventually resolves (bound or dropped).

    Oversized submissions (request > capacity) must fall out as drops, never
    as overshooting binds.
    """
    cfg = paper_cluster()
    state = kenv.reset(jax.random.PRNGKey(seed), cfg)
    sub = sched_daemon.ClusterSubstrate(state, cfg)
    sub.live.healthy[0] = False
    pods0 = sub.live.num_pods.copy()
    t = [0.0]
    d = sched_daemon.PlacementDaemon(
        sub, _DAEMON_Q,
        sched_daemon.DaemonConfig(batch_size=3, max_wait_s=0.05,
                                  max_retries=2),
        clock=lambda: t[0])
    cap = float(np.min(np.asarray(sub.live.cpu_capacity)))
    mem_cap = float(np.min(np.asarray(sub.live.mem_capacity)))

    def check():
        lv = sub.live
        assert np.all(lv.cpu_requested <= np.asarray(lv.cpu_capacity) + 1e-3)
        assert np.all(lv.mem_requested <= np.asarray(lv.mem_capacity) + 1e-3)
        assert np.all(lv.num_pods <= lv.max_pods)
        assert lv.num_pods[0] == pods0[0], "bound onto the unhealthy node"

    for op, arg in ops:
        if op == "submit":
            d.submit(PodSpec(cpu_request=arg * cap,
                             cpu_demand=0.5 * arg * cap,
                             mem_request=arg * mem_cap,
                             mem_demand=0.2 * arg * mem_cap))
        elif op == "advance":
            t[0] += arg
            d.poll()
        elif op == "poll":
            d.poll()
        elif op == "flush":
            d.flush()
        check()
    d.drain()
    check()
    assert d.metrics.bound + d.metrics.dropped == d.metrics.submitted
    assert len(d.decisions) == d.metrics.submitted


def test_daemon_invariants_fixed_cases():
    _check_daemon_never_binds_infeasible(
        0, [("submit", 0.2), ("submit", 1.4), ("poll", 0.0),
            ("submit", 0.3), ("advance", 0.06), ("flush", 0.0)])
    # a burst bigger than the cluster can hold: the tail must drop cleanly
    _check_daemon_never_binds_infeasible(
        4, [("submit", 0.6)] * 9 + [("flush", 0.0)] * 3)
    # max-wait cuts partial batches between every submit
    _check_daemon_never_binds_infeasible(
        7, [("submit", 0.25), ("advance", 0.06)] * 5)


def _check_daemon_chaos_accounting(seed, ops):
    """Injected node fail/recover events never break the request ledger.

    The self-healing contract under arbitrary interleavings of submits,
    clock advances, polls, node failures and recoveries:

      * the daemon NEVER binds onto a node while it is failed — a failed
        node's pod count only falls (watchdog evictions) until it recovers;
      * with ``queue_cap`` set, the pending queue never exceeds the cap;
      * after the final drain every submitted request (including the
        watchdog's eviction resubmits) resolved to exactly ONE of
        {bound, dropped, shed}: ``bound + dropped + shed == submitted`` and
        one ``Decision`` per submission.
    """
    cfg = paper_cluster()
    state = kenv.reset(jax.random.PRNGKey(seed), cfg)
    sub = sched_daemon.ClusterSubstrate(state, cfg)
    t = [0.0]
    d = sched_daemon.PlacementDaemon(
        sub, _DAEMON_Q,
        sched_daemon.DaemonConfig(batch_size=3, max_wait_s=0.05,
                                  max_retries=2, queue_cap=6),
        clock=lambda: t[0])
    cap = float(np.min(np.asarray(sub.live.cpu_capacity)))
    mem_cap = float(np.min(np.asarray(sub.live.mem_capacity)))
    failed = {}          # node -> num_pods at failure time

    def check():
        lv = sub.live
        for node, pods_at_fail in failed.items():
            assert not lv.healthy[node]
            assert lv.num_pods[node] <= pods_at_fail, \
                "bound onto a failed node"
        assert d.pending <= 6

    for op, arg in ops:
        if op == "submit":
            d.submit(PodSpec(cpu_request=arg * cap,
                             cpu_demand=0.5 * arg * cap,
                             mem_request=arg * mem_cap,
                             mem_demand=0.2 * arg * mem_cap))
        elif op == "advance":
            t[0] += arg
            d.poll()
        elif op == "poll":
            d.poll()
        elif op == "flush":
            d.flush()
        elif op == "fail":
            node = int(arg) % cfg.n_nodes
            d.fail_node(node)
            failed[node] = int(sub.live.num_pods[node])
        elif op == "recover":
            node = int(arg) % cfg.n_nodes
            d.recover_node(node)
            failed.pop(node, None)
        check()
    d.drain()
    check()
    m = d.metrics
    assert m.bound + m.dropped + m.shed == m.submitted
    assert len(d.decisions) == m.submitted


def test_daemon_chaos_accounting_fixed_cases():
    # fail mid-stream, keep submitting, recover, drain
    _check_daemon_chaos_accounting(
        1, [("submit", 0.3)] * 4 + [("flush", 0.0), ("fail", 2)]
           + [("submit", 0.3)] * 3 + [("recover", 2), ("flush", 0.0)])
    # eviction storm: bind a burst, then fail several nodes back to back
    _check_daemon_chaos_accounting(
        5, [("submit", 0.4)] * 6 + [("flush", 0.0)]
           + [("fail", 0), ("fail", 1), ("fail", 2), ("flush", 0.0)])
    # backpressure under chaos: more submits than queue_cap while failed
    _check_daemon_chaos_accounting(
        9, [("fail", 3)] + [("submit", 0.2)] * 10 + [("flush", 0.0)])


# ---------------------------------------------------------------------------
# the hypothesis tier (randomized versions of everything above)
# ---------------------------------------------------------------------------

if strat.HAVE_HYPOTHESIS:
    from hypothesis import given

    @given(seed=strat.seeds(), events=strat.pod_events())
    def test_property_ledger_invariants(seed, events):
        _check_ledger_invariants(seed, events)

    @given(ops=strat.replay_ops())
    def test_property_ring_invariants(ops):
        _check_ring(ops)

    @given(ops=strat.replay_ops(max_ops=10))
    def test_property_old_new_replay_parity(ops):
        _check_old_new_parity(ops, lane=1)
        _check_old_new_parity(ops, lane=4)

    @given(seed=strat.seeds(), trace=strat.churn_traces())
    def test_property_consolidator_monotone(seed, trace):
        _check_consolidator_monotone(seed, trace)

    @given(seed=strat.seeds(), trace=strat.churn_traces())
    def test_property_consolidator_no_pingpong(seed, trace):
        _check_consolidator_no_pingpong(seed, trace)

    @given(seed=strat.seeds(), ops=strat.daemon_ops())
    def test_property_daemon_never_binds_infeasible(seed, ops):
        _check_daemon_never_binds_infeasible(seed, ops)

    @given(seed=strat.seeds(), ops=strat.chaos_daemon_ops())
    def test_property_daemon_chaos_accounting(seed, ops):
        _check_daemon_chaos_accounting(seed, ops)

else:  # pragma: no cover - the [test] extra is installed in CI

    def test_property_suites_need_hypothesis():
        pytest.importorskip("hypothesis")
