"""Chaos-hardening tests: failure traces, mid-episode eviction/reschedule,
flaky scenarios, and checkpoint-corruption fallback.

The load-bearing guarantees pinned here:

  * an EMPTY failure trace reproduces the no-trace episode within 1e-6 for
    EVERY registered policy class (the chaos path is exactly a no-op when
    nothing fails);
  * the eviction ledger balances — ``evicted == rescheduled + lost`` — under
    plain calls, ``jit``, and ``vmap``;
  * a corrupted checkpoint (truncated shard, garbled manifest, hand-edited
    digest) degrades to a fresh init under ``on_corrupt="fallback"`` and
    raises otherwise; a *missing* checkpoint always raises.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dqn, env as kenv, policy as policy_mod, schedulers
from repro.core.types import NodeClass, paper_cluster
from repro.scenarios import registry

CHAOS_SCENARIOS = ("preemptible-flaky", "batch-flaky", "train-flaky")


# ---------------------------------------------------------------------------
# failure-trace sampling
# ---------------------------------------------------------------------------


class TestFailureTrace:
    def test_no_scenario_cluster_never_fails(self):
        cfg = paper_cluster()
        assert not kenv.has_chaos(cfg)
        trace = kenv.sample_failure_trace(jax.random.PRNGKey(0), cfg)
        assert bool(jnp.all(jnp.isinf(trace.fail_s)))
        assert not bool(jnp.any(jnp.isnan(trace.fail_s)))
        assert not bool(jnp.any(jnp.isnan(trace.recover_s)))
        down = kenv.trace_down(trace, jnp.float32(1e9))
        assert not bool(jnp.any(down))

    def test_flaky_scenario_samples_finite_windows(self):
        cfg = registry.make_env("preemptible-flaky")
        assert kenv.has_chaos(cfg)
        trace = kenv.sample_failure_trace(jax.random.PRNGKey(1), cfg)
        assert trace.fail_s.shape == (cfg.chaos_cycles, cfg.n_nodes)
        # the preemptible class fails; the reliable slaves never do
        assert bool(jnp.any(jnp.isfinite(trace.fail_s)))
        assert bool(jnp.any(jnp.isinf(trace.fail_s)))
        assert not bool(jnp.any(jnp.isnan(trace.recover_s)))
        # windows are ordered and strictly positive-length where finite
        finite = jnp.isfinite(trace.fail_s)
        assert bool(jnp.all(jnp.where(finite,
                                      trace.recover_s > trace.fail_s, True)))

    def test_trace_down_window_semantics(self):
        trace = kenv.FailureTrace(
            fail_s=jnp.asarray([[10.0, jnp.inf]], jnp.float32),
            recover_s=jnp.asarray([[20.0, jnp.inf]], jnp.float32))
        for t, expect in ((5.0, [False, False]), (10.0, [True, False]),
                          (19.9, [True, False]), (20.0, [False, False])):
            got = np.asarray(kenv.trace_down(trace, jnp.float32(t)))
            np.testing.assert_array_equal(got, expect)


class TestRescheduleRing:
    def test_overflow_is_counted_not_silent(self):
        q = kenv.reschedule_queue_init(2)
        mask = jnp.asarray([True, True, True, False], bool)
        vals = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
        q2, lost = kenv._queue_push(q, mask, vals, 2)
        assert int(q2.count) == 2
        assert int(lost) == 1
        np.testing.assert_array_equal(np.asarray(q2.slot), [0, 1])

    def test_push_wraps_around_head(self):
        q = kenv.reschedule_queue_init(3)._replace(head=jnp.int32(2))
        mask = jnp.asarray([True, True, False], bool)
        vals = jnp.asarray([7.0, 8.0, 0.0], jnp.float32)
        q2, lost = kenv._queue_push(q, mask, vals, 3)
        assert int(lost) == 0
        assert int(q2.count) == 2
        # ring positions 2 and 0 (wrap), oldest-first
        assert int(q2.slot[2]) == 0 and int(q2.slot[0]) == 1


# ---------------------------------------------------------------------------
# empty-trace parity across every policy class
# ---------------------------------------------------------------------------


def _selectors(cfg):
    """(name, select, carry) for kube + every registered policy class."""
    out = [("kube", schedulers.make_kube_selector(cfg), None),
           ("sdqn", schedulers.make_sdqn_selector(
               dqn.init_qnet(jax.random.PRNGKey(0)), cfg), None)]
    for name in policy_mod.names():
        spec = policy_mod.get(name)
        params = spec.init(jax.random.PRNGKey(1))
        select, carry = schedulers.make_policy_selector(spec, params, cfg)
        out.append((name, select, carry))
    return out


class TestEmptyTraceParity:
    N_PODS = 12

    @pytest.mark.parametrize("scenario", [None, "diurnal-churn"])
    def test_all_policy_classes(self, scenario):
        cfg = paper_cluster() if scenario is None \
            else registry.make_env(scenario)
        empty = kenv.empty_failure_trace(cfg.n_nodes, cfg.chaos_cycles)
        for name, select, carry in _selectors(cfg):
            ref = kenv.run_episode(jax.random.PRNGKey(7), cfg, select,
                                   self.N_PODS, select_carry=carry)
            got = kenv.run_episode(jax.random.PRNGKey(7), cfg, select,
                                   self.N_PODS, select_carry=carry,
                                   failure_trace=empty)
            assert abs(float(ref.metric) - float(got.metric)) <= 1e-6, name
            np.testing.assert_array_equal(np.asarray(ref.placements),
                                          np.asarray(got.placements),
                                          err_msg=name)
            assert int(got.stats.evicted) == 0, name
            assert int(got.stats.lost) == 0, name


# ---------------------------------------------------------------------------
# eviction accounting under chaos
# ---------------------------------------------------------------------------


def _flaky_cfg(**overrides):
    # aggressive MTBF so a short episode reliably sees failures
    import dataclasses

    scn = registry.get_scenario("preemptible-flaky")
    flaky = dataclasses.replace(scn, node_classes=tuple(
        dataclasses.replace(c, mtbf_s=60.0, mttr_s=30.0)
        if np.isfinite(c.mtbf_s) else c
        for c in scn.node_classes))
    return registry.scenario_env(flaky, **overrides)


class TestEvictionInvariant:
    def test_evicted_balances_rescheduled_plus_lost(self):
        cfg = _flaky_cfg()
        select = schedulers.make_kube_selector(cfg)
        res = kenv.run_episode(jax.random.PRNGKey(3), cfg, select, 40)
        evicted = int(res.stats.evicted)
        assert evicted > 0, "chaos scenario produced no evictions"
        assert evicted == int(res.stats.rescheduled) + int(res.stats.lost)

    def test_invariant_under_jit_and_vmap(self):
        cfg = _flaky_cfg()
        qparams = dqn.init_qnet(jax.random.PRNGKey(0))
        select = schedulers.make_sdqn_selector(qparams, cfg)

        @jax.jit
        def run(key):
            return kenv.run_episode(key, cfg, select, 24)

        keys = jax.random.split(jax.random.PRNGKey(9), 4)
        res = jax.vmap(run)(keys)
        evicted = np.asarray(res.stats.evicted)
        balance = np.asarray(res.stats.rescheduled) + np.asarray(res.stats.lost)
        np.testing.assert_array_equal(evicted, balance)
        assert evicted.sum() > 0

    def test_reschedules_bounded_by_evictions(self):
        cfg = _flaky_cfg()
        select = schedulers.make_kube_selector(cfg)
        res = kenv.run_episode(jax.random.PRNGKey(5), cfg, select, 40)
        assert 0 <= int(res.stats.rescheduled) <= int(res.stats.evicted)
        assert 0 <= int(res.stats.lost) <= int(res.stats.evicted)


# ---------------------------------------------------------------------------
# flaky scenario registration
# ---------------------------------------------------------------------------


class TestFlakyScenarios:
    def test_registered_with_chaos_classes(self):
        names = registry.scenario_names()
        for name in CHAOS_SCENARIOS:
            assert name in names
            cfg = registry.make_env(name)
            assert kenv.has_chaos(cfg)

    def test_chaos_preset_exists(self):
        from repro.core.presets import CHAOS_MIX_NAMES, SDQN_CHAOS_PRESET

        assert set(CHAOS_MIX_NAMES) == set(CHAOS_SCENARIOS)
        assert SDQN_CHAOS_PRESET.variant == "sdqn"

    def test_nodeclass_defaults_are_reliable(self):
        nc = NodeClass(name="x", count=1, cpu_capacity=4000.0,
                       mem_capacity=8000.0)
        assert not np.isfinite(nc.mtbf_s)


# ---------------------------------------------------------------------------
# checkpoint integrity: digest + corruption fallback
# ---------------------------------------------------------------------------


def _save_mlp(tmp_path):
    spec = policy_mod.get("mlp")
    params = spec.init(jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    policy_mod.save_checkpoint(d, 3, params, spec)
    return d, params


def _step_dir(d):
    return os.path.join(d, "step_00000003")


class TestCheckpointIntegrity:
    def test_roundtrip_with_digest(self, tmp_path):
        d, params = _save_mlp(tmp_path)
        manifest = json.load(open(os.path.join(_step_dir(d), "manifest.json")))
        assert "content_digest" in manifest
        restored, spec = policy_mod.restore_checkpoint(d)
        assert spec.name == "mlp"
        jax.tree.map(np.testing.assert_array_equal, restored, params)

    def test_hand_edited_manifest_fails_digest(self, tmp_path):
        d, _ = _save_mlp(tmp_path)
        path = os.path.join(_step_dir(d), "manifest.json")
        manifest = json.load(open(path))
        next(iter(manifest["leaves"].values()))["shape"] = [1]
        json.dump(manifest, open(path, "w"))
        with pytest.raises(IOError, match="digest mismatch"):
            policy_mod.restore_checkpoint(d)

    def test_truncated_shard_raises_by_default(self, tmp_path):
        d, _ = _save_mlp(tmp_path)
        shard = os.path.join(_step_dir(d), "shard_00000.npz")
        blob = open(shard, "rb").read()
        open(shard, "wb").write(blob[: len(blob) // 2])
        with pytest.raises(IOError):
            policy_mod.restore_checkpoint(d)

    @pytest.mark.parametrize("damage", ["manifest", "shard"])
    def test_fallback_returns_fresh_init(self, tmp_path, damage):
        d, _ = _save_mlp(tmp_path)
        if damage == "manifest":
            open(os.path.join(_step_dir(d), "manifest.json"), "w").write("{oops")
        else:
            shard = os.path.join(_step_dir(d), "shard_00000.npz")
            open(shard, "wb").write(b"not an npz")
        with pytest.warns(RuntimeWarning, match="falling back"):
            params, spec = policy_mod.restore_checkpoint(
                d, on_corrupt="fallback")
        assert spec.name == "mlp"
        template = spec.init(jax.random.PRNGKey(0))
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.shape(a), np.shape(b)), params, template)

    def test_missing_checkpoint_always_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            policy_mod.restore_checkpoint(str(tmp_path / "nope"),
                                          on_corrupt="fallback")
