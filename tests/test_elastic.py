"""Elastic/fault-tolerance integration: checkpoint resharding across mesh
changes, straggler-driven evacuation preserving job counts, and the
consolidation→restore loop."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import restore, save
from repro.core import dqn
from repro.sched import JobSpec, PlacementEngine, StragglerMonitor
from repro.sched.placement import fresh_fleet


class TestElasticRestore:
    def test_restore_onto_different_sharding(self, tmp_path):
        """A checkpoint written unsharded restores onto an explicit sharding
        (the single-device analogue of mesh-change restarts)."""
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        save(str(tmp_path), 0, tree)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shardings = {"w": NamedSharding(mesh, P("data", "model"))}
        like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        out = restore(str(tmp_path), like, shardings=shardings)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
        assert out["w"].sharding == shardings["w"]

    def test_restore_survives_extra_leaves_on_disk(self, tmp_path):
        """Forward-compat: restoring a subtree of a larger checkpoint."""
        save(str(tmp_path), 0, {"a": jnp.ones(3), "b": jnp.zeros(2)})
        out = restore(str(tmp_path), {"a": jax.ShapeDtypeStruct((3,), jnp.float32)})
        assert float(out["a"].sum()) == 3.0


class TestFailureRecoveryLoop:
    def test_straggler_then_consolidation(self):
        """Evacuate a straggler, then consolidate — job conservation holds."""
        engine = PlacementEngine(dqn.init_qnet(jax.random.PRNGKey(0)))
        fleet = fresh_fleet(8, jax.random.PRNGKey(1))
        job = JobSpec(cpu_pct_demand=3.0)
        fleet, _ = engine.place_batch(fleet, 24, job)
        total = int(fleet.num_jobs.sum())

        mon = StragglerMonitor(window=8, threshold=1.5)
        for _ in range(8):
            for h in range(8):
                mon.record(h, 3.0 if h == 5 else 1.0)
        assert mon.stragglers() == [5]
        fleet, migrations = mon.evacuate(engine, fleet, job)
        assert int(fleet.num_jobs.sum()) == total  # jobs conserved
        assert int(fleet.num_jobs[5]) == 0

        from repro.sched.elastic import consolidation_plan

        plan = consolidation_plan(engine, fleet, job, idle_threshold_jobs=2)
        assert plan.projected_avg_cpu_after <= plan.projected_avg_cpu_before + 1e-3

    def test_evacuated_host_heals_on_fresh_fast_samples(self):
        engine = PlacementEngine(dqn.init_qnet(jax.random.PRNGKey(0)))
        fleet = fresh_fleet(8, jax.random.PRNGKey(1))
        job = JobSpec(cpu_pct_demand=3.0)
        mon = StragglerMonitor(window=8, threshold=1.5)
        for _ in range(8):
            for h in range(8):
                mon.record(h, 3.0 if h == 5 else 1.0)
        fleet, _ = mon.evacuate(engine, fleet, job)
        assert mon.evacuated == [5]
        assert float(fleet.healthy[5]) == 0.0
        # no fresh samples yet: auto-heal refuses
        fleet, healed = mon.recover(fleet)
        assert healed == []
        # still-slow fresh samples: stays out of the fleet
        for _ in range(4):
            mon.record(5, 3.0)
            mon.record(0, 1.0)
        fleet, healed = mon.recover(fleet)
        assert healed == []
        # fast fresh samples: rejoins
        for _ in range(8):
            mon.record(5, 1.0)
        fleet, healed = mon.recover(fleet)
        assert healed == [5]
        assert mon.evacuated == []
        assert float(fleet.healthy[5]) == 1.0

    def test_evacuation_honors_no_placement_sentinel(self):
        """With no feasible target anywhere, evacuated jobs drain off with
        their host instead of being force-placed."""
        engine = PlacementEngine(dqn.init_qnet(jax.random.PRNGKey(0)))
        fleet = fresh_fleet(4, jax.random.PRNGKey(2))
        job = JobSpec(cpu_pct_demand=3.0)
        for _ in range(3):                     # pin jobs onto host 0
            fleet = engine.place(fleet, 0, job)
        # every OTHER host is already down: nothing can take host 0's jobs
        fleet = fleet._replace(healthy=jnp.asarray([1.0, 0.0, 0.0, 0.0]))
        mon = StragglerMonitor()
        assert int(fleet.num_jobs[0]) > 0
        fleet, migrations = mon.evacuate(engine, fleet, job, hosts=[0])
        assert migrations == []
        assert int(fleet.num_jobs[0]) == 0
        assert mon.evacuated == [0]

    def test_unhealthy_fleet_rejects_placement(self):
        engine = PlacementEngine(dqn.init_qnet(jax.random.PRNGKey(0)))
        fleet = fresh_fleet(4)
        fleet = fleet._replace(healthy=jnp.zeros(4))
        host, scores = engine.select(fleet, JobSpec())
        assert not bool(np.isfinite(np.asarray(scores)).any())
