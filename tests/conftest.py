import jax
import pytest

# Tests run on the single CPU device (the 512-device override is ONLY for
# the dry-run process — see src/repro/launch/dryrun.py).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
