import os

import jax
import pytest

# Tests run on the single CPU device (the 512-device override is ONLY for
# the dry-run process — see src/repro/launch/dryrun.py).
jax.config.update("jax_platform_name", "cpu")

# Hypothesis tiers (no-op when the [test] extra is absent — the property
# suites then degrade to skips, see tests/strategies.py):
#   ci      — the PR-lane budget: few examples, no deadline (jit compiles
#             inside examples blow any per-example deadline).
#   nightly — the scheduled lane: an order of magnitude more examples, the
#             budget a cron job can afford and a PR cannot.
# Select with HYPOTHESIS_PROFILE=ci|nightly|dev; default is the ci budget so
# a plain local `pytest` run stays fast.
try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.register_profile("nightly", max_examples=250, deadline=None)
    settings.register_profile("dev", max_examples=10, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:  # pragma: no cover - exercised when [test] extra absent
    pass


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
