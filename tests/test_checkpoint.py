"""Checkpoint save/restore, retention, fault-tolerant resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16), jnp.float32),
                   "b": jnp.zeros((16,), jnp.bfloat16)},
        "opt": {"step": jnp.int32(7), "m": {"w": jnp.ones((8, 16))}},
    }


class TestSaveRestore:
    def test_roundtrip(self, tmp_path):
        t = tree()
        save(str(tmp_path), 10, t)
        assert latest_step(str(tmp_path)) == 10
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        out = restore(str(tmp_path), like)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype

    def test_latest_of_many(self, tmp_path):
        for s in (1, 5, 3):
            save(str(tmp_path), s, tree(s))
        assert latest_step(str(tmp_path)) == 5

    def test_restore_specific_step(self, tmp_path):
        save(str(tmp_path), 1, tree(1))
        save(str(tmp_path), 2, tree(2))
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree())
        out1 = restore(str(tmp_path), like, step=1)
        np.testing.assert_array_equal(
            np.asarray(out1["params"]["w"]), np.asarray(tree(1)["params"]["w"]))

    def test_corruption_detected(self, tmp_path):
        save(str(tmp_path), 3, tree())
        shard = os.path.join(str(tmp_path), "step_00000003", "shard_00000.npz")
        with open(shard, "r+b") as f:
            f.seek(100)
            f.write(b"\x00" * 32)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree())
        with pytest.raises(Exception):
            restore(str(tmp_path), like)

    def test_shape_mismatch_rejected(self, tmp_path):
        save(str(tmp_path), 4, tree())
        bad_like = tree()
        bad_like["params"]["w"] = jax.ShapeDtypeStruct((9, 16), jnp.float32)
        with pytest.raises(ValueError):
            restore(str(tmp_path), bad_like)


class TestManager:
    def test_async_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in range(5):
            mgr.save_async(s, tree(s))
        mgr.wait()
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(str(tmp_path)))
        assert steps == [3, 4]


class TestTrainResume:
    def test_crash_and_resume_bitwise(self, tmp_path):
        """Train N steps with a simulated crash + resume; final state must be
        usable and training must continue from the checkpointed step."""
        from repro.launch import train as train_mod

        ckpt = str(tmp_path / "run")
        args = ["--arch", "olmo-1b", "--smoke", "--steps", "30", "--batch", "2",
                "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "10",
                "--log-every", "50"]
        with pytest.raises(SystemExit) as e:
            train_mod.main(args + ["--fail-at", "15"])
        assert e.value.code == 17
        assert latest_step(ckpt) == 10
        losses = train_mod.main(args)  # resumes from step 11
        assert len(losses) == 30 - 11
