"""Shared hypothesis strategies for the property-based test tier.

Every property suite imports from here instead of hand-rolling its own
``try: import hypothesis`` guard: ``HAVE_HYPOTHESIS`` says whether the
[test] extra is present, and the strategies cover the three substrates the
jit/vmap-heavy surface is built on — clusters (seeds + action traces), pod
tables (arrival/retire interleavings), and replay-ring op sequences.

Modules degrade gracefully without hypothesis (the seed suite must pass on
a bare ``pip install -e .``):

    import strategies as strat  # tests/ is on sys.path under pytest

    if strat.HAVE_HYPOTHESIS:
        from hypothesis import given

        @given(trace=strat.action_traces())
        def test_property_x(trace): ...
    else:
        def test_property_x():
            pytest.importorskip("hypothesis")

Example budgets/deadlines come from the profiles registered in
``tests/conftest.py`` (``HYPOTHESIS_PROFILE=ci|nightly|dev``) — strategies
here deliberately carry no ``@settings`` so the nightly lane can scale the
example count without editing every suite.
"""
from __future__ import annotations

try:
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when [test] extra absent
    st = None
    HAVE_HYPOTHESIS = False


def seeds():
    """PRNG seeds for ``reset``/``sample_pod_table`` — the full int32 range."""
    return st.integers(0, 2**31 - 1)


def action_traces(n_nodes: int = 4, max_len: int = 30):
    """Node-index sequences driving ``place``/``tick`` on a small cluster."""
    return st.lists(st.integers(0, n_nodes - 1), min_size=1, max_size=max_len)


def pod_events(n_nodes: int = 4, max_len: int = 24):
    """Arrival/advance interleavings for the PodLedger lifecycle invariants.

    Each event is ``(node, lifetime_s, advance_s)``: bind one pod to
    ``node`` (the ledger records ``now + lifetime``), then advance the clock
    by ``advance_s`` and retire whatever fell due.  Short lifetimes against
    long advances force mid-trace retirement; ``inf``-ish long ones pin the
    never-retire path — both interleave freely within one trace.
    """
    event = st.tuples(
        st.integers(0, n_nodes - 1),
        st.floats(0.5, 600.0, allow_nan=False, allow_infinity=False),
        st.floats(0.0, 120.0, allow_nan=False, allow_infinity=False),
    )
    return st.lists(event, min_size=1, max_size=max_len)


def replay_ops(max_ops: int = 16, max_add: int = 7):
    """Add/sample interleavings for the replay-ring invariants.

    ``("add", n, drop_mask_seed)`` stores ``n`` transitions (the seed picks
    which of them are weight-0 "dropped" rows); ``("sample", batch)`` draws.
    Sequences long enough to wrap a small ring several times.
    """
    add = st.tuples(st.just("add"), st.integers(1, max_add),
                    st.integers(0, 2**16 - 1))
    sample = st.tuples(st.just("sample"), st.integers(1, 64),
                       st.integers(0, 2**16 - 1))
    return st.lists(st.one_of(add, sample), min_size=1, max_size=max_ops)


def add_sizes(max_adds: int = 12, max_add: int = 7):
    """Plain add-width sequences (the original ring size/ptr property)."""
    return st.lists(st.integers(1, max_add), min_size=1, max_size=max_adds)


def churn_traces(n_nodes: int = 6, max_pods: int = 12):
    """Random placements for the consolidator properties: a list of
    ``(node, lifetime_s)`` bindings onto an initially-empty cluster."""
    pod = st.tuples(st.integers(0, n_nodes - 1),
                    st.floats(30.0, 3000.0, allow_nan=False,
                              allow_infinity=False))
    return st.lists(pod, min_size=1, max_size=max_pods)


def daemon_ops(max_ops: int = 24):
    """Submit/poll/flush/advance interleavings for the placement daemon.

    ``("submit", size_frac)`` enqueues a pod whose requests/demands scale
    with ``size_frac`` (oversized fractions force infeasible requests and
    drops); ``("advance", dt)`` moves the fake clock (crossing max-wait cuts
    partial batches); ``("poll",)`` and ``("flush",)`` drive the loop at
    arbitrary points, so batch boundaries land on every possible prefix.
    """
    submit = st.tuples(st.just("submit"),
                       st.floats(0.05, 1.5, allow_nan=False,
                                 allow_infinity=False))
    advance = st.tuples(st.just("advance"),
                        st.floats(0.0, 0.1, allow_nan=False,
                                  allow_infinity=False))
    poll = st.tuples(st.just("poll"), st.just(0.0))
    flush = st.tuples(st.just("flush"), st.just(0.0))
    return st.lists(st.one_of(submit, advance, poll, flush),
                    min_size=1, max_size=max_ops)


def chaos_daemon_ops(max_ops: int = 28, max_node: int = 8):
    """``daemon_ops`` plus node fail/recover events (the health watchdog).

    ``("fail", node)`` marks a node NotReady mid-stream and auto-requeues
    its bound pods; ``("recover", node)`` brings it back.  Node indices are
    taken modulo the cluster size by the checker, so one strategy serves any
    cluster.  Interleaved with submits and polls, these drive the daemon
    through eviction storms, shed-under-backpressure, and rebinding onto a
    shrunken fleet — the bound+dropped+shed == submitted ledger must balance
    through all of it.
    """
    submit = st.tuples(st.just("submit"),
                       st.floats(0.05, 1.5, allow_nan=False,
                                 allow_infinity=False))
    advance = st.tuples(st.just("advance"),
                        st.floats(0.0, 0.1, allow_nan=False,
                                  allow_infinity=False))
    poll = st.tuples(st.just("poll"), st.just(0.0))
    flush = st.tuples(st.just("flush"), st.just(0.0))
    fail = st.tuples(st.just("fail"), st.integers(0, max_node - 1))
    recover = st.tuples(st.just("recover"), st.integers(0, max_node - 1))
    return st.lists(st.one_of(submit, advance, poll, flush, fail, recover),
                    min_size=1, max_size=max_ops)
