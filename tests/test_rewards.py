"""Unit tests for the Table-3 / Table-5 reward functions."""
import jax.numpy as jnp
import pytest

from repro.core import rewards


def feats(cpu=30.0, mem=1.0, pod_util=10.0, health=1.0, uptime=50.0, pods=5.0):
    return jnp.array([cpu, mem, pod_util, health, uptime, pods], jnp.float32)


class TestNodePoints:
    def test_base_and_bands(self):
        # cpu<40 (-10), mem<40 (-10), pod_util outside [60,90] (-10), uptime>=24 (+5)
        assert float(rewards.node_points(feats())) == 100 - 10 - 10 - 10 + 5

    def test_cpu_in_band(self):
        r = rewards.node_points(feats(cpu=55.0))
        assert float(r) == 100 + 10 - 10 - 10 + 5

    def test_cpu_above_threshold_penalty(self):
        r75 = rewards.node_points(feats(cpu=75.0))
        r85 = rewards.node_points(feats(cpu=85.0))
        # -2 points per percent above 70
        assert float(r75) - float(r85) == pytest.approx(20.0)

    def test_unhealthy_kills_score(self):
        r = rewards.node_points(feats(health=0.0))
        assert float(r) <= 0.0

    def test_uptime_bonus(self):
        young = rewards.node_points(feats(uptime=2.0))
        old = rewards.node_points(feats(uptime=25.0))
        assert float(old) - float(young) == pytest.approx(10.0)

    def test_pod_util_band(self):
        inband = rewards.node_points(feats(pod_util=75.0))
        outband = rewards.node_points(feats(pod_util=10.0))
        assert float(inband) - float(outband) == pytest.approx(30.0)


class TestSdqnReward:
    def test_distribution_term(self):
        after = jnp.stack([feats(pods=1), feats(pods=1), feats(pods=0), feats(pods=0)])
        exp1 = jnp.array([1, 1, 0, 0])
        exp2 = jnp.array([1, 1, 1, 1])
        r2 = rewards.sdqn_reward(after, jnp.int32(0), exp_pods=exp1)
        r4 = rewards.sdqn_reward(after, jnp.int32(0), exp_pods=exp2)
        assert float(r4) - float(r2) == pytest.approx(10.0)  # +5 per extra node

    def test_efficiency_shaping_penalizes_cpu_increase(self):
        before = jnp.stack([feats(cpu=10.0)] * 4)
        after_small = jnp.stack([feats(cpu=11.0)] + [feats(cpu=10.0)] * 3)
        after_big = jnp.stack([feats(cpu=51.0)] + [feats(cpu=10.0)] * 3)
        exp = jnp.array([1, 0, 0, 0])
        r_small = rewards.sdqn_reward(after_small, jnp.int32(1), exp_pods=exp,
                                      efficiency_weight=10.0, before_feats=before)
        r_big = rewards.sdqn_reward(after_big, jnp.int32(1), exp_pods=exp,
                                    efficiency_weight=10.0, before_feats=before)
        assert float(r_small) > float(r_big)


class TestSdqnNReward:
    def test_top2_bonus_and_penalty(self):
        after = jnp.stack([feats()] * 4)
        before = after
        ok = jnp.array([True, True, True, True])
        exp_before = jnp.array([10, 8, 1, 0])
        r_top = rewards.sdqn_n_reward(after, before, ok, jnp.int32(0), 2,
                                      exp_pods_before=exp_before)
        r_out = rewards.sdqn_n_reward(after, before, ok, jnp.int32(3), 2,
                                      exp_pods_before=exp_before)
        assert float(r_top) - float(r_out) == pytest.approx(70.0)  # +20 vs -50

    def test_fallback_when_few_candidates(self):
        after = jnp.stack([feats()] * 4)
        ok = jnp.array([True, False, False, False])
        exp_before = jnp.array([3, 0, 0, 0])
        r = rewards.sdqn_n_reward(after, after, ok, jnp.int32(0), 2,
                                  exp_pods_before=exp_before)
        r_empty = rewards.sdqn_n_reward(after, after, ok, jnp.int32(0), 2,
                                        exp_pods_before=jnp.zeros(4, jnp.int32))
        assert float(r) - float(r_empty) == pytest.approx(30.0)  # +20 vs -10
