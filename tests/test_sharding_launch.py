"""Launch-layer tests: sharding rules, input specs, roofline machinery."""
import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch import shapes as shp
from repro.launch import sharding
from repro.roofline import flops as rflops
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms


def fake_mesh(shape=(4, 2), axes=("data", "model")):
    """Spec computation only needs axis names/sizes — AbstractMesh suffices.

    jax 0.4.x wants one (name, size) tuple per axis; jax >= 0.5 takes
    (shape, axes) positionally.  Support both so the suite tracks the
    installed CPU jax.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return jax.sharding.AbstractMesh(shape, axes)


class TestParamSpecs:
    @pytest.mark.parametrize("arch", list(list_archs()))
    def test_specs_divisible_everywhere(self, arch):
        """Every sharded dim must divide by its mesh axes (the rule's job)."""
        cfg = get_config(arch)
        mesh = fake_mesh((16, 16))
        params_shape = shp.params_specs(cfg)
        specs = sharding.param_specs(params_shape, cfg, mesh)

        def check(leaf, spec):
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
                if ax is None:
                    continue
                size = mesh.shape[ax] if isinstance(ax, str) else int(
                    np.prod([mesh.shape[a] for a in ax]))
                assert dim % size == 0, (arch, leaf.shape, spec)

        jax.tree.map(check, params_shape, specs,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    def test_expert_parallel_vs_tp_within_expert(self):
        mesh = fake_mesh((16, 16))
        dbrx = get_config("dbrx-132b")      # 16 experts -> EP
        qwen = get_config("qwen2-moe-a2.7b")  # 60 experts -> TP-within-expert
        s_dbrx = sharding.param_specs(shp.params_specs(dbrx), dbrx, mesh)
        s_qwen = sharding.param_specs(shp.params_specs(qwen), qwen, mesh)
        assert s_dbrx["layers"]["sub0"]["moe"]["w_gate"][1] == "model"
        assert s_qwen["layers"]["sub0"]["moe"]["w_gate"][1] is None
        assert s_qwen["layers"]["sub0"]["moe"]["w_gate"][3] == "model"


class TestInputSpecs:
    @pytest.mark.parametrize("shape_name", list(SHAPES))
    def test_all_cells_have_specs(self, shape_name):
        for arch in list_archs():
            cfg = get_config(arch)
            shape = SHAPES[shape_name]
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                assert shape_name == "long_500k" and not cfg.sub_quadratic
                continue
            specs = shp.input_specs(cfg, shape)
            assert "batch" in specs
            if shape.kind == "train":
                assert specs["batch"]["tokens"].shape == (shape.global_batch, shape.seq_len)
            elif shape.kind == "prefill":
                assert "targets" not in specs["batch"]
            else:
                assert specs["batch"]["tokens"].shape == (shape.global_batch, 1)
                assert "cache" in specs and "index" in specs

    def test_long_500k_runs_only_subquadratic(self):
        runnable = [a for a in list_archs()
                    if shape_applicable(get_config(a), SHAPES["long_500k"])[0]]
        assert sorted(runnable) == ["falcon-mamba-7b", "jamba-1.5-large-398b"]

    def test_modality_stubs_present(self):
        wsp = shp.train_batch_specs(get_config("whisper-medium"), SHAPES["train_4k"])
        assert wsp["frames"].shape == (256, 1500, 1024)
        ivl = shp.train_batch_specs(get_config("internvl2-76b"), SHAPES["train_4k"])
        assert ivl["patch_embeds"].shape == (256, 256, 8192)


class TestRooflineMachinery:
    def test_collective_parser_trip_counts(self):
        hlo = """
HloModule test

%body.1 (arg: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %ag = f32[4,8]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[4,8]{1,0} all-reduce(%ag), to_apply=%add.1
}

%cond.1 (arg: (s32[], f32[4,8])) -> pred[] {
  %c = s32[] constant(10)
  %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main.9 (p: f32[4,8]) -> f32[4,8] {
  %w = (s32[], f32[4,8]) while(%init), condition=%cond.1, body=%body.1
  %ar2 = f32[16,16]{1,0} all-reduce(%y), to_apply=%add.1
}
"""
        out = collective_bytes_from_hlo(hlo)
        # 10 iterations x (128B ag + 128B ar) + one 1024B ar outside
        assert out["per_op_bytes"]["all-gather"] == 10 * 4 * 8 * 4
        assert out["per_op_bytes"]["all-reduce"] == 10 * 4 * 8 * 4 + 16 * 16 * 4
        assert out["entry"].startswith("main")

    def test_roofline_terms_dominance(self):
        r = roofline_terms(n_chips=256, hlo_flops_global=1e18, model_flops=8e17,
                           hbm_bytes_per_chip=1e9, collective_bytes_per_chip=1e9)
        assert r["dominant"] == "compute"
        assert 0 < r["roofline_fraction"] <= 1.0
        assert r["useful_flops_ratio"] == pytest.approx(0.8)

    def test_analytic_flops_close_to_6nd_for_dense(self):
        """Implementation FLOPs >= 6ND and within ~2.2x for dense train."""
        for arch in ("granite-8b", "llama3-405b", "command-r-plus-104b"):
            cfg = get_config(arch)
            shape = SHAPES["train_4k"]
            got = rflops.cell_flops(cfg, shape, remat_full=True)
            assert got["hlo_flops"] >= got["model_flops"] * 0.95
            assert got["hlo_flops"] <= got["model_flops"] * 2.2, arch

    def test_decode_flops_scale_with_batch(self):
        cfg = get_config("granite-8b")
        f1 = rflops.cell_flops(cfg, SHAPES["decode_32k"])
        assert f1["hlo_flops"] > 0
        assert f1["model_flops"] == 2 * cfg.active_param_count() * 128
