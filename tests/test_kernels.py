"""Pallas kernel correctness sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dqn
from repro.kernels import ops, ref
from repro.models import layers as mlayers
from repro.models import mamba as mmamba


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("b,sq,skv,hq,hkv,d", [
    (1, 64, 64, 4, 4, 32),      # MHA square
    (2, 128, 128, 4, 2, 32),    # GQA
    (2, 64, 128, 8, 1, 16),     # MQA, cross-length
    (1, 256, 256, 2, 2, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, sq, skv, hq, hkv, d, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, mode="interpret",
                              block_q=32, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               **tol(dtype))


@pytest.mark.parametrize("b,hq,hkv,skv,d", [
    (1, 4, 4, 128, 32),
    (2, 8, 2, 256, 64),
    (3, 4, 1, 512, 16),
])
@pytest.mark.parametrize("kv_len", [1, 17, -1])  # -1 = full
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, hq, hkv, skv, d, kv_len, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), dtype)
    n = jnp.int32(skv if kv_len == -1 else kv_len)
    out = ops.decode_attention(q, k, v, n, mode="interpret", block_k=64)
    want = ref.decode_attention_ref(q, k, v, n)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               **tol(dtype))


@pytest.mark.parametrize("b,s,di,n", [(1, 32, 8, 4), (2, 64, 16, 8), (1, 128, 32, 16)])
@pytest.mark.parametrize("block_s", [16, 32])
def test_mamba_scan_sweep(b, s, di, n, block_s):
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    x = jax.random.normal(ks[0], (b, s, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) * 0.3 - 1.0)
    a = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    dsk = jnp.ones((di,))
    h0 = jax.random.normal(ks[5], (b, di, n)) * 0.1
    y, hT = ops.mamba_scan(x, dt, a, bm, cm, dsk, h0, mode="interpret",
                           block_d=max(di // 2, 4), block_s=block_s)
    y_ref, h_ref = ref.mamba_scan_ref(x, dt, a, bm, cm, dsk, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=4e-5, atol=4e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref), rtol=4e-5, atol=4e-5)


@pytest.mark.parametrize("n", [1, 63, 128, 1000])
def test_sdqn_score_sweep(n):
    params = dqn.init_qnet(jax.random.PRNGKey(3))
    feats = jax.random.normal(jax.random.PRNGKey(4), (n, 6))
    out = ops.sdqn_score(feats, params, mode="interpret", block_n=64)
    want = ref.sdqn_score_ref(feats, params["w1"], params["b1"], params["w2"], params["b2"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


# N < block_n (64 -> padded to one block), N not a multiple of block_n
# (padding path), and exact multiples
@pytest.mark.parametrize("n", [1, 37, 64, 100, 1000])
@pytest.mark.parametrize("mode", ["interpret", "xla"])
def test_sdqn_score_afterstate_sweep(n, mode):
    """In-kernel afterstate scoring == hypothetical_place + qvalues (<=1e-5).

    The fused path recomputes the Table-2 afterstate features (startup
    transient, crowding, contention knee) inside the scorer from the raw
    state columns; any drift from ``env.hypothetical_place``'s arithmetic
    shows up here.
    """
    import dataclasses

    from repro.core import env as kenv
    from repro.core.types import fleet_cluster

    # unhealthy_prob > 0 exercises the healthy feature column
    cfg = dataclasses.replace(fleet_cluster(n), unhealthy_prob=0.2,
                              randomize_workload=True)
    state = kenv.reset(jax.random.PRNGKey(5), cfg)
    pod = kenv.default_pod(cfg)
    params = dqn.init_qnet(jax.random.PRNGKey(6))
    want = dqn.qvalues(params, kenv.normalize_features(
        kenv.hypothetical_place(state, pod, cfg)))
    got = ops.sdqn_score_afterstate(state, pod, cfg, params, mode=mode,
                                    block_n=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [3, 64, 129])
def test_sdqn_score_cols_sweep(n):
    """Fused column scorer (serving path) vs stack + normalize + qvalues."""
    from repro.core import env as kenv

    params = dqn.init_qnet(jax.random.PRNGKey(7))
    ks = jax.random.split(jax.random.PRNGKey(8), 6)
    cols = tuple(jax.random.uniform(k, (n,), minval=0.0, maxval=80.0) for k in ks)
    deltas = jnp.array([5.0, 2.0, 4.0, 0.0, 0.0, 1.0])
    want = dqn.qvalues(params, (jnp.stack(cols, axis=-1) + deltas[None, :])
                       / kenv.FEATURE_SCALE)
    for mode in ("interpret", "xla"):
        got = ops.sdqn_score_delta(cols, deltas, params, mode=mode, block_n=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestXlaPathsMatchOracles:
    """The jnp fallbacks used on CPU/dry-run must agree with the oracles too."""

    def test_chunked_attention(self):
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(ks[0], (2, 96, 4, 16))
        k = jax.random.normal(ks[1], (2, 96, 2, 16))
        v = jax.random.normal(ks[2], (2, 96, 2, 16))
        out = mlayers.attention(q, k, v, causal=True, q_chunk=32)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5)

    def test_chunked_attention_non_divisible(self):
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        q = jax.random.normal(ks[0], (1, 150, 2, 16))  # 150 % 32 != 0 (whisper case)
        k = jax.random.normal(ks[1], (1, 150, 2, 16))
        v = jax.random.normal(ks[2], (1, 150, 2, 16))
        out = mlayers.attention(q, k, v, causal=False, q_chunk=32)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5)

    def test_chunked_selective_scan(self):
        ks = jax.random.split(jax.random.PRNGKey(7), 6)
        b, s, di, n = 2, 64, 8, 4
        x = jax.random.normal(ks[0], (b, s, di)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) * 0.3 - 1.0)
        a = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
        bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
        cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
        dsk = jnp.ones((di,))
        h0 = jnp.zeros((b, di, n))
        y, hT = mmamba.selective_scan(x, dt, a, bm, cm, dsk, h0, chunk=16)
        y_ref, h_ref = ref.mamba_scan_ref(x, dt, a, bm, cm, dsk, h0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=4e-5, atol=4e-5)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref), rtol=4e-5, atol=4e-5)
