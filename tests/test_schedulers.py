"""Scheduler policy tests: kube baseline, SDQN machinery, selection."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, dqn, env as kenv, schedulers
from repro.core.types import paper_cluster

CFG = paper_cluster()


class TestKubeScheduler:
    def test_prefers_least_requested(self):
        state = kenv.reset(jax.random.PRNGKey(0), CFG)
        pod = kenv.default_pod(CFG)
        a = baselines.kube_select(jax.random.PRNGKey(1), state, pod, CFG)
        requested = np.asarray(state.cpu_requested)
        assert int(a) == int(np.argmin(requested))

    def test_respects_filtering(self):
        state = kenv.reset(jax.random.PRNGKey(0), CFG)
        pod = kenv.default_pod(CFG)
        # block every node but #2 via health
        state = state._replace(healthy=jnp.array([False, False, True, False]))
        for s in range(5):
            a = baselines.kube_select(jax.random.PRNGKey(s), state, pod, CFG)
            assert int(a) == 2

    def test_episode_runs(self):
        sel = schedulers.make_kube_selector(CFG)
        res = kenv.run_episode(jax.random.PRNGKey(0), CFG, sel, 50)
        assert int(res.dropped) == 0
        assert int(res.placements.sum()) >= 50  # includes tenant pods
        assert 5.0 < float(res.metric) < 60.0


class TestDQN:
    def test_qnet_shapes(self):
        params = dqn.init_qnet(jax.random.PRNGKey(0))
        q = dqn.qvalues(params, jnp.zeros((7, 6)))
        assert q.shape == (7,)

    def test_training_reduces_loss(self):
        params, opt = dqn.init_train_state(jax.random.PRNGKey(0))
        feats = jax.random.normal(jax.random.PRNGKey(1), (256, 6))
        targets = feats[:, 0] * 3.0 - feats[:, 4]
        first = None
        step = jax.jit(dqn.train_step)
        for _ in range(300):
            params, opt, loss, _ = step(params, opt, feats, targets)
            first = float(loss) if first is None else first
        assert float(loss) < first * 0.1

    def test_pallas_kernel_matches_dqn(self):
        from repro.kernels import ops

        params = dqn.init_qnet(jax.random.PRNGKey(0))
        feats = jax.random.normal(jax.random.PRNGKey(1), (300, 6))
        np.testing.assert_allclose(
            np.asarray(ops.sdqn_score(feats, params, mode="interpret", block_n=64)),
            np.asarray(dqn.qvalues(params, feats)),
            rtol=2e-5, atol=2e-5,
        )


class TestSelectors:
    def test_masked_argmax_respects_mask(self):
        scores = jnp.array([5.0, 10.0, 1.0, 0.0])
        ok = jnp.array([True, False, True, True])
        a = schedulers.masked_argmax(jax.random.PRNGKey(0), scores, ok, 0.0)
        assert int(a) == 0

    def test_epsilon_explores(self):
        scores = jnp.array([100.0, 0.0, 0.0, 0.0])
        ok = jnp.ones(4, bool)
        picks = {
            int(schedulers.masked_argmax(jax.random.PRNGKey(s), scores, ok, 1.0))
            for s in range(40)
        }
        assert len(picks) > 1  # pure exploration reaches several nodes

    def test_sdqn_selector_runs_episode(self):
        qp = dqn.init_qnet(jax.random.PRNGKey(0))
        sel = schedulers.make_sdqn_selector(qp, CFG)
        res = kenv.run_episode(jax.random.PRNGKey(0), CFG, sel, 50)
        assert float(res.metric) > 0

    def test_unhealthy_node_never_selected(self):
        qp = dqn.init_qnet(jax.random.PRNGKey(0))
        state = kenv.reset(jax.random.PRNGKey(0), CFG)
        state = state._replace(healthy=jnp.array([True, True, False, True]))
        pod = kenv.default_pod(CFG)
        sel = schedulers.make_sdqn_selector(qp, CFG)
        for s in range(8):
            assert int(sel(jax.random.PRNGKey(s), state, pod)) != 2


class TestInfeasibleBurst:
    """When filtering leaves no candidate, both selectors must emit the
    NO_NODE sentinel (not node 0 / a random node) and the episode must
    surface the drop instead of binding to a full/unhealthy node."""

    def _saturated(self):
        state = kenv.reset(jax.random.PRNGKey(0), CFG)
        return state._replace(healthy=jnp.zeros(CFG.n_nodes, bool))

    def test_masked_argmax_all_infeasible_returns_sentinel(self):
        scores = jnp.array([5.0, 10.0, 1.0, 0.0])
        ok = jnp.zeros(4, bool)
        for s in range(6):
            for eps in (0.0, 1.0):
                a = schedulers.masked_argmax(jax.random.PRNGKey(s), scores, ok, eps)
                assert int(a) == kenv.NO_NODE

    def test_kube_select_all_infeasible_returns_sentinel(self):
        state = self._saturated()
        pod = kenv.default_pod(CFG)
        for s in range(6):
            a = baselines.kube_select(jax.random.PRNGKey(s), state, pod, CFG)
            assert int(a) == kenv.NO_NODE

    def test_sdqn_select_all_infeasible_returns_sentinel(self):
        qp = dqn.init_qnet(jax.random.PRNGKey(0))
        sel = schedulers.make_sdqn_selector(qp, CFG)
        state = self._saturated()
        pod = kenv.default_pod(CFG)
        assert int(sel(jax.random.PRNGKey(1), state, pod)) == kenv.NO_NODE

    def test_place_sentinel_is_noop(self):
        state = kenv.reset(jax.random.PRNGKey(0), CFG)
        pod = kenv.default_pod(CFG)
        placed = kenv.place(state, jnp.int32(kenv.NO_NODE), pod, CFG)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(placed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_episode_surfaces_drops(self):
        import dataclasses

        # a cluster whose slots saturate mid-burst: every node takes 3 pods
        tiny = dataclasses.replace(CFG, max_pods=3, requested_frac_profile=(0.0,),
                                   requested_frac_jitter=0.0)
        for sel in (schedulers.make_kube_selector(tiny),
                    schedulers.make_sdqn_selector(
                        dqn.init_qnet(jax.random.PRNGKey(0)), tiny)):
            res = kenv.run_episode(jax.random.PRNGKey(0), tiny, sel, 20)
            assert int(res.dropped) > 0
            assert int(res.state.exp_pods.sum()) + int(res.dropped) == 20
            assert int(res.state.num_pods.max()) <= 3

    def test_training_survives_saturating_cluster(self):
        """RL training on a cluster that saturates mid-burst: dropped
        transitions are stored with weight 0 (not as fabricated last-node
        placements) and the loss stays finite."""
        import dataclasses

        from repro.core import train_rl

        tiny = dataclasses.replace(CFG, max_pods=3,
                                   requested_frac_profile=(0.0,),
                                   requested_frac_jitter=0.0,
                                   randomize_workload=True)
        rl = train_rl.RLConfig(variant="sdqn", episodes=4, pods_per_episode=20,
                               n_envs=2, buffer_capacity=128, batch_size=16)
        params, metrics = jax.jit(
            lambda k: train_rl.train(k, tiny, rl))(jax.random.PRNGKey(0))
        assert np.isfinite(float(metrics["loss"][-1]))
        for leaf in jax.tree.leaves(params):
            assert bool(jnp.all(jnp.isfinite(leaf)))

    def test_eval_engine_surfaces_drops(self):
        import dataclasses

        from repro.eval import engine as eval_engine

        tiny = dataclasses.replace(CFG, max_pods=3, requested_frac_profile=(0.0,),
                                   requested_frac_jitter=0.0)
        sel = schedulers.make_kube_selector(tiny)
        res = eval_engine.evaluate(jax.random.PRNGKey(0), tiny, sel,
                                   trials=3, n_pods=20)
        assert res["dropped_mean"] > 0.0
        assert res["dropped_max"] >= res["dropped_mean"]


class TestFusedScoringRoute:
    def test_score_afterstates_fused_threshold_matches(self, monkeypatch):
        """Above FUSED_SCORE_MIN_NODES the fused path must agree with the
        plain jnp path to <=1e-5 (threshold lowered so the test stays small)."""
        qp = dqn.init_qnet(jax.random.PRNGKey(0))
        state = kenv.reset(jax.random.PRNGKey(1), CFG)
        pod = kenv.default_pod(CFG)
        plain = schedulers.score_afterstates(qp, state, pod, CFG)
        monkeypatch.setattr(schedulers, "FUSED_SCORE_MIN_NODES", 1)
        fused = schedulers.score_afterstates(qp, state, pod, CFG)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(plain),
                                   rtol=1e-5, atol=1e-5)


class TestNeuralBaselines:
    def test_lstm_and_transformer_score_shapes(self):
        feats = jax.random.normal(jax.random.PRNGKey(0), (5, 6))
        lstm = baselines.init_lstm(jax.random.PRNGKey(1))
        tr = baselines.init_transformer(jax.random.PRNGKey(2))
        assert baselines.lstm_score(lstm, feats).shape == (5,)
        assert baselines.transformer_score(tr, feats).shape == (5,)

    def test_regression_trainer_converges(self):
        feats = jax.random.normal(jax.random.PRNGKey(0), (512, 6))
        targets = 2.0 * feats[:, 1] + 0.5
        params, opt = baselines.init_regression_state(baselines.init_lstm, jax.random.PRNGKey(1))
        step = jax.jit(baselines.make_regression_trainer(baselines.lstm_score))
        losses = []
        for _ in range(600):
            params, opt, loss = step(params, opt, feats, targets)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.5
