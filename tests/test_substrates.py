"""Optimizer, data pipeline, replay buffer, schedules, sched-layer tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dqn
from repro.core.replay import replay_add, replay_init, replay_sample
from repro.data import DataConfig, make_loader
from repro.data.synthetic import synthetic_lm_tokens
from repro.optim import AdamConfig, adam_init, adam_update
from repro.optim.schedule import cosine_warmup
from repro.sched import JobSpec, PlacementEngine, StragglerMonitor
from repro.sched.elastic import consolidation_plan
from repro.sched.placement import fresh_fleet


class TestAdam:
    def test_quadratic_convergence(self):
        cfg = AdamConfig(lr=0.1)
        params = {"x": jnp.array([5.0, -3.0])}
        state = adam_init(params, cfg)
        for _ in range(200):
            grads = {"x": 2 * params["x"]}
            params, state, _ = adam_update(params, grads, state, cfg)
        assert float(jnp.abs(params["x"]).max()) < 1e-2

    def test_grad_clipping(self):
        cfg = AdamConfig(lr=1e-3, grad_clip_norm=1.0)
        params = {"x": jnp.zeros(3)}
        state = adam_init(params, cfg)
        _, _, stats = adam_update(params, {"x": jnp.full((3,), 1e6)}, state, cfg)
        assert float(stats["grad_norm"]) > 1e5  # reported pre-clip

    def test_mixed_precision_master(self):
        cfg = AdamConfig(lr=1e-2, master_dtype="float32")
        params = {"x": jnp.zeros(4, jnp.bfloat16)}
        state = adam_init(params, cfg)
        assert state["master"]["x"].dtype == jnp.float32
        params, state, _ = adam_update(params, {"x": jnp.ones(4, jnp.bfloat16)}, state, cfg)
        assert params["x"].dtype == jnp.bfloat16

    def test_bf16_moments(self):
        cfg = AdamConfig(moment_dtype="bfloat16", master_dtype="")
        params = {"x": jnp.zeros(4, jnp.bfloat16)}
        state = adam_init(params, cfg)
        assert state["m"]["x"].dtype == jnp.bfloat16
        assert "master" not in state

    def test_cosine_warmup_shape(self):
        sched = cosine_warmup(1.0, 10, 100)
        assert float(sched(jnp.int32(0))) == pytest.approx(0.0)
        assert float(sched(jnp.int32(10))) == pytest.approx(1.0, abs=0.1)
        assert float(sched(jnp.int32(100))) == pytest.approx(0.1, abs=0.02)


class TestData:
    def test_synthetic_deterministic(self):
        a = synthetic_lm_tokens(jax.random.PRNGKey(0), 4, 64, 1000)
        b = synthetic_lm_tokens(jax.random.PRNGKey(0), 4, 64, 1000)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.shape == (4, 64)
        assert int(a.max()) < 1000

    def test_loader_seekable(self):
        cfg = DataConfig(batch=2, seq_len=16, vocab=100, seed=1)
        it0 = make_loader(cfg, start_step=0)
        _ = next(it0)
        second = next(it0)
        it1 = make_loader(cfg, start_step=1)
        again = next(it1)
        np.testing.assert_array_equal(np.asarray(second["tokens"]), np.asarray(again["tokens"]))
        it0.close(), it1.close()

    def test_memmap_loader(self, tmp_path):
        path = str(tmp_path / "toks.bin")
        np.arange(2000, dtype=np.uint16).tofile(path)
        cfg = DataConfig(batch=2, seq_len=16, vocab=65536, token_file=path)
        it = make_loader(cfg)
        batch = next(it)
        assert batch["tokens"].shape == (2, 16)
        np.testing.assert_array_equal(
            np.asarray(batch["targets"][:, :-1]), np.asarray(batch["tokens"][:, 1:]))
        it.close()

    def test_host_slicing(self):
        cfg = DataConfig(batch=8, seq_len=8, vocab=50, host_index=1, host_count=4)
        it = make_loader(cfg)
        batch = next(it)
        assert batch["tokens"].shape[0] == 2
        it.close()


class TestReplay:
    @staticmethod
    def check_size_and_ptr(adds):
        cap = 16
        buf = replay_init(cap)
        total = 0
        for i, n in enumerate(adds):
            feats = jnp.full((n, 6), float(i))
            buf = replay_add(buf, feats, jnp.full((n,), float(i)))
            total += n
        assert int(buf.size) == min(total, cap)
        assert 0 <= int(buf.ptr) < cap
        f, t, w = replay_sample(buf, jax.random.PRNGKey(0), 8)
        assert f.shape == (8, 6)
        # sampled targets must come from what was added
        vals = {float(i) for i in range(len(adds))}
        assert set(np.asarray(t).tolist()) <= vals

    def test_size_and_ptr_fixed_cases(self):
        for adds in ([3], [7, 7, 7], [1, 2, 3, 4, 5, 6], [5] * 12):
            self.check_size_and_ptr(adds)

    def test_partial_buffer_weights_all_draws(self):
        """Regression: a batch of 128 from a 64-entry buffer must train on
        all 128 draws.  The old mask tested batch *positions*
        (arange(batch) < size), zero-weighting the tail of every batch
        while the buffer was smaller than the batch."""
        buf = replay_init(256)
        buf = replay_add(buf, jnp.ones((64, 6)), jnp.ones((64,)))
        _, _, w = replay_sample(buf, jax.random.PRNGKey(0), 128)
        assert w.shape == (128,)
        np.testing.assert_array_equal(np.asarray(w), np.ones(128, np.float32))

    def test_empty_buffer_weights_zero(self):
        buf = replay_init(256)
        _, _, w = replay_sample(buf, jax.random.PRNGKey(0), 32)
        np.testing.assert_array_equal(np.asarray(w), np.zeros(32, np.float32))

    def test_zero_weight_entries_stay_masked(self):
        """Dropped transitions (stored with weight 0) never train: their
        sampled weight is 0 while normally-stored entries weigh 1."""
        buf = replay_init(8)
        buf = replay_add(buf, jnp.ones((4, 6)), jnp.full((4,), 7.0),
                         jnp.array([1.0, 0.0, 1.0, 0.0]))
        f, t, w = replay_sample(buf, jax.random.PRNGKey(1), 64)
        assert set(np.asarray(w).tolist()) <= {0.0, 1.0}
        assert 0.0 in np.asarray(w).tolist()  # masked draws do occur
        assert 1.0 in np.asarray(w).tolist()


# property-based variant only when the [test] extra (hypothesis) is
# present; the strategies and the import guard are shared across suites via
# tests/strategies.py, budgets via the conftest profiles
import strategies as strat

if strat.HAVE_HYPOTHESIS:
    from hypothesis import given

    @given(adds=strat.add_sizes())
    def test_property_size_and_ptr(adds):
        TestReplay.check_size_and_ptr(adds)


class TestSchedLayer:
    def _engine(self):
        return PlacementEngine(dqn.init_qnet(jax.random.PRNGKey(0)))

    def test_placement_respects_ceiling(self):
        eng = self._engine()
        fleet = fresh_fleet(4)
        fleet = fleet._replace(cpu_pct=jnp.array([86.0, 5.0, 5.0, 5.0]))
        job = JobSpec(cpu_pct_demand=10.0)
        host, scores = eng.select(fleet, job)
        assert host != 0  # 86 + 10 > 88 ceiling

    def test_place_batch_updates_load(self):
        eng = self._engine()
        fleet = fresh_fleet(4)
        fleet, hosts = eng.place_batch(fleet, 12, JobSpec(cpu_pct_demand=5.0))
        assert int(fleet.num_jobs.sum()) == 12
        assert len(hosts) == 12

    def test_job_util_tracks_num_jobs(self):
        """Regression: job_util_pct must advance with each binding (it stayed
        at its reset value, so the third Table-2 feature went stale after
        the first placement) and must match select's afterstate delta."""
        from repro.sched.placement import JOB_UTIL_DELTA_PCT

        eng = self._engine()
        fleet = fresh_fleet(4)
        fleet, _ = eng.place_batch(fleet, 9, JobSpec(cpu_pct_demand=3.0))
        np.testing.assert_allclose(
            np.asarray(fleet.job_util_pct),
            np.asarray(fleet.num_jobs, np.float32) * JOB_UTIL_DELTA_PCT,
            rtol=1e-6)
        assert float(fleet.job_util_pct.sum()) > 0.0

    def test_select_all_infeasible_returns_no_host(self):
        """An all-infeasible fleet must yield the NO_HOST sentinel (argmax
        over all--inf scores used to bind host 0) and place() must no-op."""
        from repro.sched.placement import NO_HOST

        eng = self._engine()
        fleet = fresh_fleet(4)._replace(healthy=jnp.zeros(4))
        host, scores = eng.select(fleet, JobSpec())
        assert host == NO_HOST
        assert not np.isfinite(np.asarray(scores)).any()
        placed = eng.place(fleet, host, JobSpec())
        for a, b in zip(fleet, placed):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_feasible_enforces_job_slot_ceiling(self):
        eng = self._engine()
        fleet = fresh_fleet(4)._replace(
            job_util_pct=jnp.array([100.0, 100.0, 50.0, 100.0]))
        ok = np.asarray(eng.feasible(fleet, JobSpec()))
        np.testing.assert_array_equal(ok, [False, False, True, False])

    def test_fused_serving_scores_match_stacked(self):
        """The fused column scorer (serving path) == stack + delta + qvalues."""
        from repro.core import env as kenv
        from repro.kernels import ops
        from repro.sched.placement import JOB_UTIL_DELTA_PCT

        params = dqn.init_qnet(jax.random.PRNGKey(0))
        fleet = fresh_fleet(37, jax.random.PRNGKey(3))
        delta = jnp.array([5.0, 2.0, JOB_UTIL_DELTA_PCT, 0.0, 0.0, 1.0])
        cols = (fleet.cpu_pct, fleet.mem_pct, fleet.job_util_pct,
                fleet.healthy.astype(jnp.float32), fleet.uptime_hours,
                fleet.num_jobs.astype(jnp.float32))
        want = dqn.qvalues(params, kenv.normalize_features(
            fleet.features() + delta[None, :]))
        for mode in ("xla", "interpret", "ref"):
            got = ops.sdqn_score_delta(cols, delta, params, mode=mode, block_n=16)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)

    def test_consolidation_frees_hosts(self):
        eng = self._engine()
        n = 6
        fleet = fresh_fleet(n)
        # two nearly-idle hosts + capacity elsewhere
        fleet = fleet._replace(
            cpu_pct=jnp.array([40.0, 40.0, 6.0, 7.0, 30.0, 30.0]),
            num_jobs=jnp.array([8, 8, 1, 1, 6, 6], jnp.int32),
        )
        plan = consolidation_plan(eng, fleet, JobSpec(cpu_pct_demand=4.0))
        assert plan.hosts_freed >= 1
        assert plan.projected_avg_cpu_after <= plan.projected_avg_cpu_before + 1e-3

    def test_straggler_detection_and_evacuation(self):
        mon = StragglerMonitor(window=8, threshold=1.5)
        for t in range(8):
            for h in range(4):
                mon.record(h, 1.0 if h != 2 else 3.0)
        assert mon.stragglers() == [2]
        eng = self._engine()
        fleet = fresh_fleet(4)
        fleet = fleet._replace(num_jobs=jnp.array([2, 2, 3, 2], jnp.int32))
        fleet2, migrations = mon.evacuate(eng, fleet, JobSpec(cpu_pct_demand=2.0))
        assert len(migrations) == 3
        assert int(fleet2.num_jobs[2]) == 0
        assert all(dst != 2 for _, dst in migrations)
