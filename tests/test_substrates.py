"""Optimizer, data pipeline, replay buffer, schedules, sched-layer tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dqn
from repro.core.replay import replay_add, replay_init, replay_sample
from repro.data import DataConfig, make_loader
from repro.data.synthetic import synthetic_lm_tokens
from repro.optim import AdamConfig, adam_init, adam_update
from repro.optim.schedule import cosine_warmup
from repro.sched import JobSpec, PlacementEngine, StragglerMonitor
from repro.sched.elastic import consolidation_plan
from repro.sched.placement import fresh_fleet


class TestAdam:
    def test_quadratic_convergence(self):
        cfg = AdamConfig(lr=0.1)
        params = {"x": jnp.array([5.0, -3.0])}
        state = adam_init(params, cfg)
        for _ in range(200):
            grads = {"x": 2 * params["x"]}
            params, state, _ = adam_update(params, grads, state, cfg)
        assert float(jnp.abs(params["x"]).max()) < 1e-2

    def test_grad_clipping(self):
        cfg = AdamConfig(lr=1e-3, grad_clip_norm=1.0)
        params = {"x": jnp.zeros(3)}
        state = adam_init(params, cfg)
        _, _, stats = adam_update(params, {"x": jnp.full((3,), 1e6)}, state, cfg)
        assert float(stats["grad_norm"]) > 1e5  # reported pre-clip

    def test_mixed_precision_master(self):
        cfg = AdamConfig(lr=1e-2, master_dtype="float32")
        params = {"x": jnp.zeros(4, jnp.bfloat16)}
        state = adam_init(params, cfg)
        assert state["master"]["x"].dtype == jnp.float32
        params, state, _ = adam_update(params, {"x": jnp.ones(4, jnp.bfloat16)}, state, cfg)
        assert params["x"].dtype == jnp.bfloat16

    def test_bf16_moments(self):
        cfg = AdamConfig(moment_dtype="bfloat16", master_dtype="")
        params = {"x": jnp.zeros(4, jnp.bfloat16)}
        state = adam_init(params, cfg)
        assert state["m"]["x"].dtype == jnp.bfloat16
        assert "master" not in state

    def test_cosine_warmup_shape(self):
        sched = cosine_warmup(1.0, 10, 100)
        assert float(sched(jnp.int32(0))) == pytest.approx(0.0)
        assert float(sched(jnp.int32(10))) == pytest.approx(1.0, abs=0.1)
        assert float(sched(jnp.int32(100))) == pytest.approx(0.1, abs=0.02)


class TestData:
    def test_synthetic_deterministic(self):
        a = synthetic_lm_tokens(jax.random.PRNGKey(0), 4, 64, 1000)
        b = synthetic_lm_tokens(jax.random.PRNGKey(0), 4, 64, 1000)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.shape == (4, 64)
        assert int(a.max()) < 1000

    def test_loader_seekable(self):
        cfg = DataConfig(batch=2, seq_len=16, vocab=100, seed=1)
        it0 = make_loader(cfg, start_step=0)
        _ = next(it0)
        second = next(it0)
        it1 = make_loader(cfg, start_step=1)
        again = next(it1)
        np.testing.assert_array_equal(np.asarray(second["tokens"]), np.asarray(again["tokens"]))
        it0.close(), it1.close()

    def test_memmap_loader(self, tmp_path):
        path = str(tmp_path / "toks.bin")
        np.arange(2000, dtype=np.uint16).tofile(path)
        cfg = DataConfig(batch=2, seq_len=16, vocab=65536, token_file=path)
        it = make_loader(cfg)
        batch = next(it)
        assert batch["tokens"].shape == (2, 16)
        np.testing.assert_array_equal(
            np.asarray(batch["targets"][:, :-1]), np.asarray(batch["tokens"][:, 1:]))
        it.close()

    def test_host_slicing(self):
        cfg = DataConfig(batch=8, seq_len=8, vocab=50, host_index=1, host_count=4)
        it = make_loader(cfg)
        batch = next(it)
        assert batch["tokens"].shape[0] == 2
        it.close()


class TestReplay:
    @staticmethod
    def check_size_and_ptr(adds):
        cap = 16
        buf = replay_init(cap)
        total = 0
        for i, n in enumerate(adds):
            feats = jnp.full((n, 6), float(i))
            buf = replay_add(buf, feats, jnp.full((n,), float(i)))
            total += n
        assert int(buf.size) == min(total, cap)
        assert 0 <= int(buf.ptr) < cap
        f, t, w = replay_sample(buf, jax.random.PRNGKey(0), 8)
        assert f.shape == (8, 6)
        # sampled targets must come from what was added
        vals = {float(i) for i in range(len(adds))}
        assert set(np.asarray(t).tolist()) <= vals

    def test_size_and_ptr_fixed_cases(self):
        for adds in ([3], [7, 7, 7], [1, 2, 3, 4, 5, 6], [5] * 12):
            self.check_size_and_ptr(adds)


# property-based variant only when the [test] extra (hypothesis) is present
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised when [test] extra absent
    st = None

if st is not None:

    @settings(max_examples=20, deadline=None)
    @given(adds=st.lists(st.integers(1, 7), min_size=1, max_size=12))
    def test_property_size_and_ptr(adds):
        TestReplay.check_size_and_ptr(adds)


class TestSchedLayer:
    def _engine(self):
        return PlacementEngine(dqn.init_qnet(jax.random.PRNGKey(0)))

    def test_placement_respects_ceiling(self):
        eng = self._engine()
        fleet = fresh_fleet(4)
        fleet = fleet._replace(cpu_pct=jnp.array([86.0, 5.0, 5.0, 5.0]))
        job = JobSpec(cpu_pct_demand=10.0)
        host, scores = eng.select(fleet, job)
        assert host != 0  # 86 + 10 > 88 ceiling

    def test_place_batch_updates_load(self):
        eng = self._engine()
        fleet = fresh_fleet(4)
        fleet, hosts = eng.place_batch(fleet, 12, JobSpec(cpu_pct_demand=5.0))
        assert int(fleet.num_jobs.sum()) == 12
        assert len(hosts) == 12

    def test_consolidation_frees_hosts(self):
        eng = self._engine()
        n = 6
        fleet = fresh_fleet(n)
        # two nearly-idle hosts + capacity elsewhere
        fleet = fleet._replace(
            cpu_pct=jnp.array([40.0, 40.0, 6.0, 7.0, 30.0, 30.0]),
            num_jobs=jnp.array([8, 8, 1, 1, 6, 6], jnp.int32),
        )
        plan = consolidation_plan(eng, fleet, JobSpec(cpu_pct_demand=4.0))
        assert plan.hosts_freed >= 1
        assert plan.projected_avg_cpu_after <= plan.projected_avg_cpu_before + 1e-3

    def test_straggler_detection_and_evacuation(self):
        mon = StragglerMonitor(window=8, threshold=1.5)
        for t in range(8):
            for h in range(4):
                mon.record(h, 1.0 if h != 2 else 3.0)
        assert mon.stragglers() == [2]
        eng = self._engine()
        fleet = fresh_fleet(4)
        fleet = fleet._replace(num_jobs=jnp.array([2, 2, 3, 2], jnp.int32))
        fleet2, migrations = mon.evacuate(eng, fleet, JobSpec(cpu_pct_demand=2.0))
        assert len(migrations) == 3
        assert int(fleet2.num_jobs[2]) == 0
        assert all(dst != 2 for _, dst in migrations)
