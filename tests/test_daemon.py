"""Placement-daemon suite: batching, one-launch scoring, optimistic binds.

Covers the serving loop's contracts (``repro.sched.daemon``): batches cut by
size AND by max-wait; the whole batch scores in ONE device launch with ONE
compilation across fill levels; racing binds to the same node resolve with
exactly one winner and the loser re-validating against fresh state; the
numpy live-buffer mirrors (``bind``/``feasible_one``) stay bit-close to the
jnp references (``env.place``/``env.feasible``, ``PlacementEngine``); plus
the unified ``repro.sched.api`` dispatch, the arrival-trace adapter, the
``EpisodeResult`` shim, and ``serve.load_qnet`` checkpoint loading.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dqn, env as kenv, policy as policy_mod, schedulers
from repro.core.types import (
    NO_PLACEMENT,
    EpisodeResult,
    paper_cluster,
)
from repro.scenarios import arrival_trace, trace_from_table
from repro.sched import api, placement
from repro.sched.daemon import (
    ClusterSubstrate,
    DaemonConfig,
    FleetSubstrate,
    PlacementDaemon,
)

CFG = paper_cluster()


@pytest.fixture(scope="module")
def qparams():
    return dqn.init_qnet(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def state():
    return kenv.reset(jax.random.PRNGKey(1), CFG)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def make_daemon(state, qparams, score_fn=None, **cfg_kw):
    clock = FakeClock()
    sub = ClusterSubstrate(state, CFG, score_fn=score_fn)
    d = PlacementDaemon(sub, qparams, DaemonConfig(**cfg_kw), clock=clock)
    return d, sub, clock


# ---------------------------------------------------------------------------
# batching semantics
# ---------------------------------------------------------------------------


class TestBatching:
    def test_batch_cut_by_size(self, state, qparams):
        d, _, clock = make_daemon(state, qparams, batch_size=4,
                                  max_wait_s=1e9)
        pod = kenv.default_pod(CFG)
        for _ in range(3):
            d.submit(pod)
            assert d.poll() == 0          # below size, wait unbounded
        d.submit(pod)
        assert d.poll() == 4              # 4th request cuts the batch
        assert d.metrics.batches == 1
        assert d.pending == 0

    def test_batch_cut_by_max_wait(self, state, qparams):
        d, _, clock = make_daemon(state, qparams, batch_size=64,
                                  max_wait_s=0.5)
        pod = kenv.default_pod(CFG)
        d.submit(pod)
        d.submit(pod)
        assert d.poll() == 0              # neither condition holds yet
        clock.t = 0.499
        assert d.poll() == 0
        clock.t = 0.5                     # oldest waited max_wait_s
        assert d.poll() == 2              # partial batch ships
        assert d.metrics.batches == 1

    def test_drain_finishes_everything(self, state, qparams):
        d, _, _ = make_daemon(state, qparams, batch_size=8, max_wait_s=1e9)
        pod = kenv.default_pod(CFG)
        for _ in range(11):
            d.submit(pod)
        assert d.drain() == 11
        assert len(d.decisions) == 11
        assert d.metrics.bound + d.metrics.dropped == 11

    def test_latency_measured_from_submission(self, state, qparams):
        d, _, clock = make_daemon(state, qparams, batch_size=64,
                                  max_wait_s=0.1)
        d.submit(kenv.default_pod(CFG))   # t=0
        clock.t = 0.25
        assert d.poll() == 1
        assert d.decisions[0].latency_s == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# one device launch per batch
# ---------------------------------------------------------------------------


class TestOneLaunch:
    def test_one_launch_one_compile_across_fills(self, state, qparams):
        d, _, _ = make_daemon(state, qparams, batch_size=4, max_wait_s=1e9)
        d.warmup()
        pod = kenv.default_pod(CFG)
        # full batch, then two partial fills (3, 1) via drain
        for _ in range(4):
            d.submit(pod)
        d.poll()
        for _ in range(3):
            d.submit(pod)
        d.flush()
        d.submit(pod)
        d.flush()
        assert d.metrics.batches == 3
        # ONE jitted call per batch...
        assert d.metrics.device_launches == d.metrics.batches
        # ...and ONE compilation total: partial fills pad to the static
        # batch shape instead of recompiling
        assert d.scorer_cache_size() == 1

    def test_fleet_substrate_one_compile(self, qparams):
        sub = FleetSubstrate(placement.fresh_fleet(8))
        d = PlacementDaemon(sub, qparams,
                            DaemonConfig(batch_size=4, max_wait_s=1e9),
                            clock=FakeClock())
        d.warmup()
        for _ in range(6):
            d.submit(placement.JobSpec())
        d.drain()
        assert d.metrics.device_launches == d.metrics.batches == 2
        assert d.scorer_cache_size() == 1

    @pytest.mark.parametrize("policy", sorted(policy_mod.names()))
    def test_cluster_one_launch_one_compile_per_policy_class(
            self, state, policy):
        """The one-launch / one-compile invariant must hold for EVERY
        registered policy class: sequence specs advance their history carry
        inside the single jitted launch, and the traced ``n_real`` pad mask
        means fill levels 4/3/1 all reuse one executable."""
        spec = policy_mod.get(policy)
        params = spec.init(jax.random.PRNGKey(0))
        sub = ClusterSubstrate(state, CFG, policy=spec)
        d = PlacementDaemon(sub, params,
                            DaemonConfig(batch_size=4, max_wait_s=1e9),
                            clock=FakeClock())
        d.warmup()
        pod = kenv.default_pod(CFG)
        for fill in (4, 3, 1):
            for _ in range(fill):
                d.submit(pod)
            d.flush()
        assert d.metrics.batches == 3
        assert d.metrics.device_launches == d.metrics.batches
        assert d.scorer_cache_size() == 1
        assert d.metrics.bound + d.metrics.dropped == 8

    @pytest.mark.parametrize("policy", sorted(policy_mod.names()))
    def test_fleet_one_launch_one_compile_per_policy_class(self, policy):
        spec = policy_mod.get(policy)
        params = spec.init(jax.random.PRNGKey(0))
        sub = FleetSubstrate(placement.fresh_fleet(8), policy=spec)
        d = PlacementDaemon(sub, params,
                            DaemonConfig(batch_size=4, max_wait_s=1e9),
                            clock=FakeClock())
        d.warmup()
        for _ in range(6):
            d.submit(placement.JobSpec())
        d.drain()
        assert d.metrics.device_launches == d.metrics.batches == 2
        assert d.scorer_cache_size() == 1


# ---------------------------------------------------------------------------
# optimistic concurrency
# ---------------------------------------------------------------------------


def _two_node_race(qparams, conflict_policy="requeue", max_retries=4):
    """Two requests, one batch, both scored against the same snapshot and
    both preferring node 0 — which only has room for ONE more pod."""
    cfg = dataclasses.replace(paper_cluster(), n_nodes=2)
    state = kenv.reset(jax.random.PRNGKey(2), cfg)
    # prefer the lowest-CPU afterstate, deterministically
    score_fn = lambda params, feats: -feats[:, 0]
    clock = FakeClock()
    sub = ClusterSubstrate(state, cfg, score_fn=score_fn)
    lv = sub.live
    lv.healthy[:] = True
    lv.base_cpu[:] = (1.0, 30.0)          # node 0 is the attractive one
    lv.cpu_requested[:] = 0.0
    lv.mem_requested[:] = 0.0
    lv.max_pods[0] = lv.num_pods[0] + 1   # ...but fits exactly one more pod
    lv.max_pods[1] = lv.num_pods[1] + 10
    d = PlacementDaemon(
        sub, qparams,
        DaemonConfig(batch_size=2, max_wait_s=1e9, max_retries=max_retries,
                     conflict_policy=conflict_policy),
        clock=clock)
    pod = kenv.default_pod(cfg)
    d.submit(pod)
    d.submit(pod)
    return d


class TestOptimisticConcurrency:
    def test_racing_binds_one_winner_loser_requeues(self, qparams):
        d = _two_node_race(qparams)
        assert d.poll() == 1              # winner bound; loser re-queued
        assert d.metrics.conflicts == 1
        assert d.metrics.requeued == 1
        assert d.pending == 1
        assert d.decisions[0].node == 0
        # the re-queued loser re-validates against FRESH state next batch:
        # node 0 is now full in the new snapshot, so it lands on node 1
        assert d.drain() == 1
        assert d.decisions[1].node == 1
        assert d.decisions[1].attempts == 2
        assert d.metrics.bound == 2

    def test_next_best_policy_resolves_in_one_batch(self, qparams):
        d = _two_node_race(qparams, conflict_policy="next-best")
        assert d.poll() == 2              # loser falls through to node 1
        assert d.metrics.conflicts == 1
        assert d.metrics.requeued == 0
        assert sorted(dec.node for dec in d.decisions) == [0, 1]

    def test_max_retries_drops_conflicted_request(self, qparams):
        d = _two_node_race(qparams, max_retries=1)
        # make node 1 infeasible too, AFTER the snapshot preference is set:
        # the loser's only alternative vanishes and retries run out
        d.poll()
        d._sub.live.max_pods[1] = d._sub.live.num_pods[1]
        d.drain()
        assert d.decisions[1].node == NO_PLACEMENT
        assert d.metrics.dropped == 1

    def test_infeasible_batch_drops_with_sentinel(self, state, qparams):
        d, sub, _ = make_daemon(state, qparams, batch_size=1)
        sub.live.healthy[:] = False       # nothing passes the filter phase
        d.submit(kenv.default_pod(CFG))
        assert d.flush() == 1
        assert d.decisions[0].node == NO_PLACEMENT
        assert d.metrics.dropped == 1
        assert d.metrics.conflicts == 0   # a drop, not a lost race


# ---------------------------------------------------------------------------
# live-buffer mirrors vs the jnp references
# ---------------------------------------------------------------------------


class TestMirrorParity:
    def test_cluster_bind_matches_env_place(self, state, qparams):
        sub = ClusterSubstrate(state, CFG)
        pod = kenv.default_pod(CFG)
        for node in (0, 3, 0):            # includes a warm re-bind
            ref = kenv.place(
                jax.tree.map(jnp.asarray, sub.live), jnp.int32(node), pod,
                CFG)
            sub.bind(node, pod)
            for name, a, b in zip(ref._fields, jax.tree.map(
                    np.asarray, sub.live), ref):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5,
                    err_msg=f"{name} after bind({node})")

    def test_cluster_feasible_one_matches_env_feasible(self, state, qparams):
        sub = ClusterSubstrate(state, CFG)
        lv = sub.live
        lv.healthy[1] = False
        lv.cpu_requested[2] = lv.cpu_capacity[2]          # CPU-full
        lv.num_pods[3] = lv.max_pods[3]                   # at max-pods
        pod = kenv.default_pod(CFG)
        ref = np.asarray(kenv.feasible(
            jax.tree.map(jnp.asarray, lv), pod, CFG))
        got = np.array([sub.feasible_one(i, pod)
                        for i in range(CFG.n_nodes)])
        np.testing.assert_array_equal(got, ref)

    def test_fleet_bind_matches_engine_place(self, qparams):
        fleet = placement.fresh_fleet(6)
        sub = FleetSubstrate(fleet)
        eng = placement.PlacementEngine(qparams)
        job = placement.JobSpec()
        ref = eng.place(eng.place(fleet, 2, job), 4, job)
        sub.bind(2, job)
        sub.bind(4, job)
        for name, a, b in zip(ref._fields, jax.tree.map(
                np.asarray, sub.live), ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, err_msg=name)

    def test_fleet_feasible_one_matches_engine(self, qparams):
        fleet = placement.fresh_fleet(6)._replace(
            cpu_pct=jnp.asarray([10.0, 90.0, 10.0, 10.0, 10.0, 10.0]),
            mem_pct=jnp.asarray([5.0, 5.0, 96.0, 5.0, 5.0, 5.0]),
            healthy=jnp.asarray([1.0, 1.0, 1.0, 0.0, 1.0, 1.0]),
            job_util_pct=jnp.asarray([0.0, 0.0, 0.0, 0.0, 100.0, 0.0]),
        )
        sub = FleetSubstrate(fleet)
        eng = placement.PlacementEngine(qparams)
        job = placement.JobSpec()
        ref = np.asarray(eng.feasible(fleet, job))
        got = np.array([sub.feasible_one(i, job) for i in range(6)])
        np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# the unified public scheduling API
# ---------------------------------------------------------------------------


class TestApi:
    def test_cluster_dispatch_matches_schedulers(self, state, qparams):
        pod = kenv.default_pod(CFG)
        got = api.score(state, pod, params=qparams, cfg=CFG)
        ref = schedulers.score_afterstates(qparams, state, pod, CFG)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref))

    def test_cluster_requires_cfg(self, state, qparams):
        with pytest.raises(ValueError, match="cfg"):
            api.score(state, kenv.default_pod(CFG), params=qparams)

    def test_fleet_dispatch_matches_engine_select_scores(self, qparams):
        fleet = placement.fresh_fleet(16)
        job = placement.JobSpec()
        got = api.score(fleet, job, params=qparams, fused=False)
        eng = placement.PlacementEngine(qparams, use_kernel=False)
        _, ref = eng.select(fleet, job)
        ok = np.asarray(eng.feasible(fleet, job))
        np.testing.assert_allclose(np.asarray(got)[ok],
                                   np.asarray(ref)[ok], rtol=1e-5)

    def test_score_batch_rows_match_score(self, state, qparams):
        pods = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (3,)), kenv.default_pod(CFG))
        qb = api.score_batch(state, pods, params=qparams, cfg=CFG)
        q1 = api.score(state, kenv.default_pod(CFG), params=qparams, cfg=CFG)
        assert qb.shape == (3, CFG.n_nodes)
        np.testing.assert_allclose(np.asarray(qb[0]), np.asarray(q1),
                                   rtol=1e-5)

    def test_select_returns_sentinel_when_fleet_full(self, qparams):
        fleet = placement.fresh_fleet(4)._replace(
            healthy=jnp.zeros((4,)))
        assert int(api.select(fleet, placement.JobSpec(),
                              params=qparams)) == NO_PLACEMENT

    def test_bad_fused_value_rejected(self, qparams):
        with pytest.raises(ValueError, match="fused"):
            api.score(placement.fresh_fleet(4), placement.JobSpec(),
                      params=qparams, fused="bogus")

    def test_sentinels_are_unified(self):
        assert kenv.NO_NODE is NO_PLACEMENT
        assert placement.NO_HOST is NO_PLACEMENT
        assert api.NO_PLACEMENT is NO_PLACEMENT


# ---------------------------------------------------------------------------
# arrival traces + EpisodeResult shim + checkpoint loading
# ---------------------------------------------------------------------------


class TestArrivals:
    def test_trace_reproducible_and_monotone(self):
        a = arrival_trace(jax.random.PRNGKey(5), CFG, 40)
        b = arrival_trace(jax.random.PRNGKey(5), CFG, 40)
        np.testing.assert_array_equal(a.t_s, b.t_s)
        assert a.t_s[0] == 0.0
        assert np.all(np.diff(a.t_s) >= 0)
        assert len(a.pods) == 40

    def test_rate_rescaling(self):
        tr = arrival_trace(jax.random.PRNGKey(6), CFG, 50,
                           rate_per_s=2000.0)
        assert tr.offered_rate_per_s == pytest.approx(2000.0, rel=1e-6)

    def test_burst_table_spreads_at_offered_rate(self):
        table = kenv.sample_pod_table(jax.random.PRNGKey(7), CFG, 10)
        zero = table._replace(dt_s=jnp.zeros_like(table.dt_s))
        tr = trace_from_table(zero, rate_per_s=100.0)
        np.testing.assert_allclose(np.diff(tr.t_s), 0.01)


class TestEpisodeResultShim:
    def test_tuple_unpacking_still_works(self):
        sel = schedulers.make_kube_selector(CFG)
        res = kenv.run_episode(jax.random.PRNGKey(0), CFG, sel, 10)
        assert isinstance(res, EpisodeResult)
        # the deprecation shim: legacy positional order is preserved
        state, placements, metric, dropped, stats = res
        assert state is res.state
        assert placements is res.placements
        assert metric is res.metric
        assert dropped is res.dropped
        assert stats is res.stats
        assert res._fields == ("state", "placements", "metric", "dropped",
                               "stats")


# ---------------------------------------------------------------------------
# self-healing: health watchdog, backpressure, backoff, degradation
# ---------------------------------------------------------------------------


class TickTimer:
    """Fake deadline stopwatch: every read advances by ``step`` seconds, so
    a scoring launch appears to take exactly ``step`` regardless of the
    (pinned) logical clock."""

    def __init__(self, step):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


class TestHealthWatchdog:
    def test_fail_node_evicts_and_requeues(self, state, qparams):
        d, sub, _ = make_daemon(state, qparams, batch_size=4, max_wait_s=1e9)
        pod = kenv.default_pod(CFG)
        for _ in range(4):
            d.submit(pod)
        d.poll()
        bound = [x for x in d.decisions if x.node != NO_PLACEMENT]
        assert bound, "setup: nothing bound"
        victim = bound[0].node
        n_on_victim = sum(1 for x in bound if x.node == victim)
        pods_before = int(sub.live.num_pods[victim])
        evicted = d.fail_node(victim)
        assert evicted == n_on_victim
        assert d.metrics.evictions == n_on_victim
        assert not sub.live.healthy[victim]
        # evicted pods released their live-buffer resources...
        assert int(sub.live.num_pods[victim]) == pods_before - n_on_victim
        # ...and re-entered the queue as fresh submissions
        assert d.pending == n_on_victim
        assert d.metrics.submitted == 4 + n_on_victim
        d.drain()
        # rebound decisions never land on the failed node
        for dec in d.decisions[len(bound):]:
            assert dec.node != victim
        m = d.metrics
        assert m.bound + m.dropped + m.shed == m.submitted
        assert len(d.decisions) == m.submitted

    def test_recover_node_rejoins_feasible_set(self, state, qparams):
        d, sub, _ = make_daemon(state, qparams, batch_size=1)
        pod = kenv.default_pod(CFG)
        for n in range(CFG.n_nodes):
            if n != 2:
                d.fail_node(n)
        d.submit(pod)
        d.flush()
        assert d.decisions[-1].node == 2      # only node left standing
        d.fail_node(2)
        d.recover_node(3)
        assert sub.live.healthy[3]
        d.drain()                              # the evictee rebinds onto 3
        rebound = d.decisions[-1]
        assert rebound.node == 3

    def test_fail_empty_node_is_noop_eviction(self, state, qparams):
        d, sub, _ = make_daemon(state, qparams)
        assert d.fail_node(3) == 0
        assert d.metrics.evictions == 0
        assert not sub.live.healthy[3]


class TestBackpressure:
    def test_full_queue_sheds_oldest(self, state, qparams):
        d, _, _ = make_daemon(state, qparams, batch_size=64, max_wait_s=1e9,
                              queue_cap=2)
        pod = kenv.default_pod(CFG)
        first = d.submit(pod)
        d.submit(pod)
        d.submit(pod)                          # cap hit: oldest shed
        assert d.metrics.shed == 1
        assert d.pending == 2
        shed = d.decisions[0]
        assert shed.req_id == first
        assert shed.shed and shed.node == NO_PLACEMENT
        d.drain()
        m = d.metrics
        assert m.bound + m.dropped + m.shed == m.submitted == 3
        assert len(d.decisions) == 3

    def test_unbounded_by_default(self, state, qparams):
        d, _, _ = make_daemon(state, qparams, batch_size=64, max_wait_s=1e9)
        pod = kenv.default_pod(CFG)
        for _ in range(100):
            d.submit(pod)
        assert d.metrics.shed == 0
        assert d.pending == 100


class TestConflictBackoff:
    def _conflicted(self, state, qparams, **cfg_kw):
        d, sub, clock = make_daemon(state, qparams, batch_size=1,
                                    max_wait_s=0.0, **cfg_kw)
        real = sub.feasible_one
        sub.feasible_one = lambda node, pod: False   # every bind loses
        d.submit(kenv.default_pod(CFG))
        assert d.poll() == 0                   # conflicted; re-queued
        sub.feasible_one = real
        return d, clock

    def test_poll_honors_backoff_hold(self, state, qparams):
        d, clock = self._conflicted(state, qparams, backoff_base_s=5.0)
        assert d.pending == 1
        clock.t = 4.9
        assert d.poll() == 0                   # still inside the hold
        clock.t = 5.0
        assert d.poll() == 1                   # hold expired: re-scored
        assert d.decisions[0].attempts == 2

    def test_flush_overrides_hold(self, state, qparams):
        d, clock = self._conflicted(state, qparams, backoff_base_s=1e9)
        assert d.flush() == 1                  # force: shutdown terminates
        assert d.metrics.bound == 1

    def test_backoff_doubles_per_attempt(self, state, qparams):
        d, sub, clock = make_daemon(state, qparams, batch_size=1,
                                    max_wait_s=0.0, max_retries=3,
                                    backoff_base_s=1.0)
        sub.feasible_one = lambda node, pod: False
        d.submit(kenv.default_pod(CFG))
        d.poll()                               # attempt 1 -> hold 1s
        assert d._pending[0].not_before == pytest.approx(1.0)
        clock.t = 1.0
        d.poll()                               # attempt 2 -> hold 2s
        assert d._pending[0].not_before == pytest.approx(3.0)
        clock.t = 3.0
        d.poll()                               # attempt 3 -> hold 4s
        assert d._pending[0].not_before == pytest.approx(7.0)


class TestRetryExhaustion:
    @pytest.mark.parametrize("policy", ["requeue", "next-best"])
    def test_exhausted_retries_drop_under_both_policies(
            self, state, qparams, policy):
        d, sub, _ = make_daemon(state, qparams, batch_size=1, max_wait_s=0.0,
                                max_retries=2, conflict_policy=policy)
        sub.feasible_one = lambda node, pod: False   # permanent bind race
        d.submit(kenv.default_pod(CFG))
        d.drain()
        assert d.metrics.dropped == 1
        assert d.metrics.conflicts == 3        # initial + 2 retries
        assert d.metrics.requeued == 2
        dec = d.decisions[0]
        assert dec.node == NO_PLACEMENT
        assert dec.attempts == 3
        m = d.metrics
        assert m.bound + m.dropped + m.shed == m.submitted == 1


class TestGracefulDegradation:
    def test_deadline_breach_degrades_to_heuristic(self, state, qparams):
        clock = FakeClock()
        sub = ClusterSubstrate(state, CFG)
        d = PlacementDaemon(
            sub, qparams,
            DaemonConfig(batch_size=2, max_wait_s=1e9, score_deadline_s=0.5,
                         degrade_batches=2),
            clock=clock, timer=TickTimer(1.0))   # every launch "takes" 1s
        pod = kenv.default_pod(CFG)
        for batch in range(4):
            d.submit(pod)
            d.submit(pod)
            d.flush()
        m = d.metrics
        assert m.batches == 4
        # batch 1 probes the net (breach), 2-3 skip it, 4 probes again
        assert m.device_launches == 2
        assert m.fallback_batches == 4
        assert m.bound + m.dropped + m.shed == m.submitted == 8

    def test_nan_scores_fall_back_same_batch(self, state, qparams):
        bad_fn = lambda params, feats: jnp.full((feats.shape[0],), jnp.nan)
        d, _, _ = make_daemon(state, qparams, score_fn=bad_fn, batch_size=2,
                              max_wait_s=1e9)
        pod = kenv.default_pod(CFG)
        d.submit(pod)
        d.submit(pod)
        assert d.flush() == 2
        assert d.metrics.fallback_batches == 1
        # NaN scores still place pods: the heuristic served the batch
        assert d.metrics.bound == 2

    def test_diverged_scores_fall_back(self, state, qparams):
        hot_fn = lambda params, feats: jnp.full((feats.shape[0],), 1e9)
        d, _, _ = make_daemon(state, qparams, score_fn=hot_fn, batch_size=1)
        d.submit(kenv.default_pod(CFG))
        assert d.flush() == 1
        assert d.metrics.fallback_batches == 1
        assert d.metrics.bound == 1

    def test_heuristic_only_never_launches(self, state, qparams):
        d, _, _ = make_daemon(state, qparams, heuristic_only=True,
                              batch_size=4, max_wait_s=1e9)
        pod = kenv.default_pod(CFG)
        for _ in range(9):
            d.submit(pod)
        d.drain()
        m = d.metrics
        assert m.device_launches == 0
        assert m.fallback_batches == m.batches == 3
        assert m.bound + m.dropped == 9

    def test_healthy_scores_never_degrade(self, state, qparams):
        d, _, _ = make_daemon(state, qparams, batch_size=2, max_wait_s=1e9,
                              score_deadline_s=1e9)
        pod = kenv.default_pod(CFG)
        d.submit(pod)
        d.submit(pod)
        d.flush()
        assert d.metrics.fallback_batches == 0
        assert d.metrics.device_launches == d.metrics.batches == 1


class TestLatencyReservoir:
    def test_memory_stays_bounded(self):
        from repro.sched.daemon import LatencyReservoir

        r = LatencyReservoir(capacity=8, seed=1)
        for i in range(1000):
            r.append(float(i))
        assert len(r) == 8
        assert r.seen == 1000
        assert np.asarray(r).shape == (8,)

    def test_percentiles_exact_below_capacity(self):
        from repro.sched.daemon import LatencyReservoir

        r = LatencyReservoir(capacity=256)
        vals = np.arange(100, dtype=np.float64)
        for v in vals:
            r.append(float(v))
        assert r.p50() == pytest.approx(np.percentile(vals, 50))
        assert r.p99() == pytest.approx(np.percentile(vals, 99))
        assert r.percentile(0.0) == 0.0

    def test_empty_reservoir_is_nan(self):
        from repro.sched.daemon import LatencyReservoir

        r = LatencyReservoir()
        assert np.isnan(r.p99())

    def test_sample_stays_representative(self):
        from repro.sched.daemon import LatencyReservoir

        r = LatencyReservoir(capacity=512, seed=7)
        for v in np.linspace(0.0, 1.0, 20_000):
            r.append(float(v))
        # uniform stream: the retained sample's median stays near 0.5
        assert abs(r.p50() - 0.5) < 0.1

    def test_daemon_metrics_use_reservoir(self, state, qparams):
        from repro.sched.daemon import LatencyReservoir

        d, _, _ = make_daemon(state, qparams)
        assert isinstance(d.metrics.latencies_s, LatencyReservoir)


class TestServeCheckpointLoading:
    def test_load_qnet_roundtrips_through_ckpt(self, tmp_path, qparams):
        from repro.checkpoint import ckpt
        from repro.launch import serve

        ckpt.save(str(tmp_path), 7, qparams)
        loaded = serve.load_qnet(str(tmp_path), jax.random.PRNGKey(9))
        for name in qparams:
            np.testing.assert_array_equal(np.asarray(loaded[name]),
                                          np.asarray(qparams[name]))

    def test_load_qnet_npz_legacy(self, tmp_path, qparams):
        from repro.launch import serve

        path = tmp_path / "q.npz"
        np.savez(path, **{k: np.asarray(v) for k, v in qparams.items()})
        loaded = serve.load_qnet(str(path), jax.random.PRNGKey(9))
        np.testing.assert_array_equal(
            np.asarray(loaded["w1"]), np.asarray(qparams["w1"]))

    def test_load_qnet_empty_is_fresh_init(self):
        from repro.launch import serve

        a = serve.load_qnet("", jax.random.PRNGKey(3))
        b = dqn.init_qnet(jax.random.PRNGKey(3))
        np.testing.assert_array_equal(np.asarray(a["w1"]),
                                      np.asarray(b["w1"]))
