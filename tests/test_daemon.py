"""Placement-daemon suite: batching, one-launch scoring, optimistic binds.

Covers the serving loop's contracts (``repro.sched.daemon``): batches cut by
size AND by max-wait; the whole batch scores in ONE device launch with ONE
compilation across fill levels; racing binds to the same node resolve with
exactly one winner and the loser re-validating against fresh state; the
numpy live-buffer mirrors (``bind``/``feasible_one``) stay bit-close to the
jnp references (``env.place``/``env.feasible``, ``PlacementEngine``); plus
the unified ``repro.sched.api`` dispatch, the arrival-trace adapter, the
``EpisodeResult`` shim, and ``serve.load_qnet`` checkpoint loading.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dqn, env as kenv, policy as policy_mod, schedulers
from repro.core.types import (
    NO_PLACEMENT,
    EpisodeResult,
    paper_cluster,
)
from repro.scenarios import arrival_trace, trace_from_table
from repro.sched import api, placement
from repro.sched.daemon import (
    ClusterSubstrate,
    DaemonConfig,
    FleetSubstrate,
    PlacementDaemon,
)

CFG = paper_cluster()


@pytest.fixture(scope="module")
def qparams():
    return dqn.init_qnet(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def state():
    return kenv.reset(jax.random.PRNGKey(1), CFG)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def make_daemon(state, qparams, score_fn=None, **cfg_kw):
    clock = FakeClock()
    sub = ClusterSubstrate(state, CFG, score_fn=score_fn)
    d = PlacementDaemon(sub, qparams, DaemonConfig(**cfg_kw), clock=clock)
    return d, sub, clock


# ---------------------------------------------------------------------------
# batching semantics
# ---------------------------------------------------------------------------


class TestBatching:
    def test_batch_cut_by_size(self, state, qparams):
        d, _, clock = make_daemon(state, qparams, batch_size=4,
                                  max_wait_s=1e9)
        pod = kenv.default_pod(CFG)
        for _ in range(3):
            d.submit(pod)
            assert d.poll() == 0          # below size, wait unbounded
        d.submit(pod)
        assert d.poll() == 4              # 4th request cuts the batch
        assert d.metrics.batches == 1
        assert d.pending == 0

    def test_batch_cut_by_max_wait(self, state, qparams):
        d, _, clock = make_daemon(state, qparams, batch_size=64,
                                  max_wait_s=0.5)
        pod = kenv.default_pod(CFG)
        d.submit(pod)
        d.submit(pod)
        assert d.poll() == 0              # neither condition holds yet
        clock.t = 0.499
        assert d.poll() == 0
        clock.t = 0.5                     # oldest waited max_wait_s
        assert d.poll() == 2              # partial batch ships
        assert d.metrics.batches == 1

    def test_drain_finishes_everything(self, state, qparams):
        d, _, _ = make_daemon(state, qparams, batch_size=8, max_wait_s=1e9)
        pod = kenv.default_pod(CFG)
        for _ in range(11):
            d.submit(pod)
        assert d.drain() == 11
        assert len(d.decisions) == 11
        assert d.metrics.bound + d.metrics.dropped == 11

    def test_latency_measured_from_submission(self, state, qparams):
        d, _, clock = make_daemon(state, qparams, batch_size=64,
                                  max_wait_s=0.1)
        d.submit(kenv.default_pod(CFG))   # t=0
        clock.t = 0.25
        assert d.poll() == 1
        assert d.decisions[0].latency_s == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# one device launch per batch
# ---------------------------------------------------------------------------


class TestOneLaunch:
    def test_one_launch_one_compile_across_fills(self, state, qparams):
        d, _, _ = make_daemon(state, qparams, batch_size=4, max_wait_s=1e9)
        d.warmup()
        pod = kenv.default_pod(CFG)
        # full batch, then two partial fills (3, 1) via drain
        for _ in range(4):
            d.submit(pod)
        d.poll()
        for _ in range(3):
            d.submit(pod)
        d.flush()
        d.submit(pod)
        d.flush()
        assert d.metrics.batches == 3
        # ONE jitted call per batch...
        assert d.metrics.device_launches == d.metrics.batches
        # ...and ONE compilation total: partial fills pad to the static
        # batch shape instead of recompiling
        assert d.scorer_cache_size() == 1

    def test_fleet_substrate_one_compile(self, qparams):
        sub = FleetSubstrate(placement.fresh_fleet(8))
        d = PlacementDaemon(sub, qparams,
                            DaemonConfig(batch_size=4, max_wait_s=1e9),
                            clock=FakeClock())
        d.warmup()
        for _ in range(6):
            d.submit(placement.JobSpec())
        d.drain()
        assert d.metrics.device_launches == d.metrics.batches == 2
        assert d.scorer_cache_size() == 1

    @pytest.mark.parametrize("policy", sorted(policy_mod.names()))
    def test_cluster_one_launch_one_compile_per_policy_class(
            self, state, policy):
        """The one-launch / one-compile invariant must hold for EVERY
        registered policy class: sequence specs advance their history carry
        inside the single jitted launch, and the traced ``n_real`` pad mask
        means fill levels 4/3/1 all reuse one executable."""
        spec = policy_mod.get(policy)
        params = spec.init(jax.random.PRNGKey(0))
        sub = ClusterSubstrate(state, CFG, policy=spec)
        d = PlacementDaemon(sub, params,
                            DaemonConfig(batch_size=4, max_wait_s=1e9),
                            clock=FakeClock())
        d.warmup()
        pod = kenv.default_pod(CFG)
        for fill in (4, 3, 1):
            for _ in range(fill):
                d.submit(pod)
            d.flush()
        assert d.metrics.batches == 3
        assert d.metrics.device_launches == d.metrics.batches
        assert d.scorer_cache_size() == 1
        assert d.metrics.bound + d.metrics.dropped == 8

    @pytest.mark.parametrize("policy", sorted(policy_mod.names()))
    def test_fleet_one_launch_one_compile_per_policy_class(self, policy):
        spec = policy_mod.get(policy)
        params = spec.init(jax.random.PRNGKey(0))
        sub = FleetSubstrate(placement.fresh_fleet(8), policy=spec)
        d = PlacementDaemon(sub, params,
                            DaemonConfig(batch_size=4, max_wait_s=1e9),
                            clock=FakeClock())
        d.warmup()
        for _ in range(6):
            d.submit(placement.JobSpec())
        d.drain()
        assert d.metrics.device_launches == d.metrics.batches == 2
        assert d.scorer_cache_size() == 1


# ---------------------------------------------------------------------------
# optimistic concurrency
# ---------------------------------------------------------------------------


def _two_node_race(qparams, conflict_policy="requeue", max_retries=4):
    """Two requests, one batch, both scored against the same snapshot and
    both preferring node 0 — which only has room for ONE more pod."""
    cfg = dataclasses.replace(paper_cluster(), n_nodes=2)
    state = kenv.reset(jax.random.PRNGKey(2), cfg)
    # prefer the lowest-CPU afterstate, deterministically
    score_fn = lambda params, feats: -feats[:, 0]
    clock = FakeClock()
    sub = ClusterSubstrate(state, cfg, score_fn=score_fn)
    lv = sub.live
    lv.healthy[:] = True
    lv.base_cpu[:] = (1.0, 30.0)          # node 0 is the attractive one
    lv.cpu_requested[:] = 0.0
    lv.mem_requested[:] = 0.0
    lv.max_pods[0] = lv.num_pods[0] + 1   # ...but fits exactly one more pod
    lv.max_pods[1] = lv.num_pods[1] + 10
    d = PlacementDaemon(
        sub, qparams,
        DaemonConfig(batch_size=2, max_wait_s=1e9, max_retries=max_retries,
                     conflict_policy=conflict_policy),
        clock=clock)
    pod = kenv.default_pod(cfg)
    d.submit(pod)
    d.submit(pod)
    return d


class TestOptimisticConcurrency:
    def test_racing_binds_one_winner_loser_requeues(self, qparams):
        d = _two_node_race(qparams)
        assert d.poll() == 1              # winner bound; loser re-queued
        assert d.metrics.conflicts == 1
        assert d.metrics.requeued == 1
        assert d.pending == 1
        assert d.decisions[0].node == 0
        # the re-queued loser re-validates against FRESH state next batch:
        # node 0 is now full in the new snapshot, so it lands on node 1
        assert d.drain() == 1
        assert d.decisions[1].node == 1
        assert d.decisions[1].attempts == 2
        assert d.metrics.bound == 2

    def test_next_best_policy_resolves_in_one_batch(self, qparams):
        d = _two_node_race(qparams, conflict_policy="next-best")
        assert d.poll() == 2              # loser falls through to node 1
        assert d.metrics.conflicts == 1
        assert d.metrics.requeued == 0
        assert sorted(dec.node for dec in d.decisions) == [0, 1]

    def test_max_retries_drops_conflicted_request(self, qparams):
        d = _two_node_race(qparams, max_retries=1)
        # make node 1 infeasible too, AFTER the snapshot preference is set:
        # the loser's only alternative vanishes and retries run out
        d.poll()
        d._sub.live.max_pods[1] = d._sub.live.num_pods[1]
        d.drain()
        assert d.decisions[1].node == NO_PLACEMENT
        assert d.metrics.dropped == 1

    def test_infeasible_batch_drops_with_sentinel(self, state, qparams):
        d, sub, _ = make_daemon(state, qparams, batch_size=1)
        sub.live.healthy[:] = False       # nothing passes the filter phase
        d.submit(kenv.default_pod(CFG))
        assert d.flush() == 1
        assert d.decisions[0].node == NO_PLACEMENT
        assert d.metrics.dropped == 1
        assert d.metrics.conflicts == 0   # a drop, not a lost race


# ---------------------------------------------------------------------------
# live-buffer mirrors vs the jnp references
# ---------------------------------------------------------------------------


class TestMirrorParity:
    def test_cluster_bind_matches_env_place(self, state, qparams):
        sub = ClusterSubstrate(state, CFG)
        pod = kenv.default_pod(CFG)
        for node in (0, 3, 0):            # includes a warm re-bind
            ref = kenv.place(
                jax.tree.map(jnp.asarray, sub.live), jnp.int32(node), pod,
                CFG)
            sub.bind(node, pod)
            for name, a, b in zip(ref._fields, jax.tree.map(
                    np.asarray, sub.live), ref):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5,
                    err_msg=f"{name} after bind({node})")

    def test_cluster_feasible_one_matches_env_feasible(self, state, qparams):
        sub = ClusterSubstrate(state, CFG)
        lv = sub.live
        lv.healthy[1] = False
        lv.cpu_requested[2] = lv.cpu_capacity[2]          # CPU-full
        lv.num_pods[3] = lv.max_pods[3]                   # at max-pods
        pod = kenv.default_pod(CFG)
        ref = np.asarray(kenv.feasible(
            jax.tree.map(jnp.asarray, lv), pod, CFG))
        got = np.array([sub.feasible_one(i, pod)
                        for i in range(CFG.n_nodes)])
        np.testing.assert_array_equal(got, ref)

    def test_fleet_bind_matches_engine_place(self, qparams):
        fleet = placement.fresh_fleet(6)
        sub = FleetSubstrate(fleet)
        eng = placement.PlacementEngine(qparams)
        job = placement.JobSpec()
        ref = eng.place(eng.place(fleet, 2, job), 4, job)
        sub.bind(2, job)
        sub.bind(4, job)
        for name, a, b in zip(ref._fields, jax.tree.map(
                np.asarray, sub.live), ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, err_msg=name)

    def test_fleet_feasible_one_matches_engine(self, qparams):
        fleet = placement.fresh_fleet(6)._replace(
            cpu_pct=jnp.asarray([10.0, 90.0, 10.0, 10.0, 10.0, 10.0]),
            mem_pct=jnp.asarray([5.0, 5.0, 96.0, 5.0, 5.0, 5.0]),
            healthy=jnp.asarray([1.0, 1.0, 1.0, 0.0, 1.0, 1.0]),
            job_util_pct=jnp.asarray([0.0, 0.0, 0.0, 0.0, 100.0, 0.0]),
        )
        sub = FleetSubstrate(fleet)
        eng = placement.PlacementEngine(qparams)
        job = placement.JobSpec()
        ref = np.asarray(eng.feasible(fleet, job))
        got = np.array([sub.feasible_one(i, job) for i in range(6)])
        np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# the unified public scheduling API
# ---------------------------------------------------------------------------


class TestApi:
    def test_cluster_dispatch_matches_schedulers(self, state, qparams):
        pod = kenv.default_pod(CFG)
        got = api.score(state, pod, params=qparams, cfg=CFG)
        ref = schedulers.score_afterstates(qparams, state, pod, CFG)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref))

    def test_cluster_requires_cfg(self, state, qparams):
        with pytest.raises(ValueError, match="cfg"):
            api.score(state, kenv.default_pod(CFG), params=qparams)

    def test_fleet_dispatch_matches_engine_select_scores(self, qparams):
        fleet = placement.fresh_fleet(16)
        job = placement.JobSpec()
        got = api.score(fleet, job, params=qparams, fused=False)
        eng = placement.PlacementEngine(qparams, use_kernel=False)
        _, ref = eng.select(fleet, job)
        ok = np.asarray(eng.feasible(fleet, job))
        np.testing.assert_allclose(np.asarray(got)[ok],
                                   np.asarray(ref)[ok], rtol=1e-5)

    def test_score_batch_rows_match_score(self, state, qparams):
        pods = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (3,)), kenv.default_pod(CFG))
        qb = api.score_batch(state, pods, params=qparams, cfg=CFG)
        q1 = api.score(state, kenv.default_pod(CFG), params=qparams, cfg=CFG)
        assert qb.shape == (3, CFG.n_nodes)
        np.testing.assert_allclose(np.asarray(qb[0]), np.asarray(q1),
                                   rtol=1e-5)

    def test_select_returns_sentinel_when_fleet_full(self, qparams):
        fleet = placement.fresh_fleet(4)._replace(
            healthy=jnp.zeros((4,)))
        assert int(api.select(fleet, placement.JobSpec(),
                              params=qparams)) == NO_PLACEMENT

    def test_bad_fused_value_rejected(self, qparams):
        with pytest.raises(ValueError, match="fused"):
            api.score(placement.fresh_fleet(4), placement.JobSpec(),
                      params=qparams, fused="bogus")

    def test_sentinels_are_unified(self):
        assert kenv.NO_NODE is NO_PLACEMENT
        assert placement.NO_HOST is NO_PLACEMENT
        assert api.NO_PLACEMENT is NO_PLACEMENT


# ---------------------------------------------------------------------------
# arrival traces + EpisodeResult shim + checkpoint loading
# ---------------------------------------------------------------------------


class TestArrivals:
    def test_trace_reproducible_and_monotone(self):
        a = arrival_trace(jax.random.PRNGKey(5), CFG, 40)
        b = arrival_trace(jax.random.PRNGKey(5), CFG, 40)
        np.testing.assert_array_equal(a.t_s, b.t_s)
        assert a.t_s[0] == 0.0
        assert np.all(np.diff(a.t_s) >= 0)
        assert len(a.pods) == 40

    def test_rate_rescaling(self):
        tr = arrival_trace(jax.random.PRNGKey(6), CFG, 50,
                           rate_per_s=2000.0)
        assert tr.offered_rate_per_s == pytest.approx(2000.0, rel=1e-6)

    def test_burst_table_spreads_at_offered_rate(self):
        table = kenv.sample_pod_table(jax.random.PRNGKey(7), CFG, 10)
        zero = table._replace(dt_s=jnp.zeros_like(table.dt_s))
        tr = trace_from_table(zero, rate_per_s=100.0)
        np.testing.assert_allclose(np.diff(tr.t_s), 0.01)


class TestEpisodeResultShim:
    def test_tuple_unpacking_still_works(self):
        sel = schedulers.make_kube_selector(CFG)
        res = kenv.run_episode(jax.random.PRNGKey(0), CFG, sel, 10)
        assert isinstance(res, EpisodeResult)
        # the deprecation shim: legacy positional order is preserved
        state, placements, metric, dropped, stats = res
        assert state is res.state
        assert placements is res.placements
        assert metric is res.metric
        assert dropped is res.dropped
        assert stats is res.stats
        assert res._fields == ("state", "placements", "metric", "dropped",
                               "stats")


class TestServeCheckpointLoading:
    def test_load_qnet_roundtrips_through_ckpt(self, tmp_path, qparams):
        from repro.checkpoint import ckpt
        from repro.launch import serve

        ckpt.save(str(tmp_path), 7, qparams)
        loaded = serve.load_qnet(str(tmp_path), jax.random.PRNGKey(9))
        for name in qparams:
            np.testing.assert_array_equal(np.asarray(loaded[name]),
                                          np.asarray(qparams[name]))

    def test_load_qnet_npz_legacy(self, tmp_path, qparams):
        from repro.launch import serve

        path = tmp_path / "q.npz"
        np.savez(path, **{k: np.asarray(v) for k, v in qparams.items()})
        loaded = serve.load_qnet(str(path), jax.random.PRNGKey(9))
        np.testing.assert_array_equal(
            np.asarray(loaded["w1"]), np.asarray(qparams["w1"]))

    def test_load_qnet_empty_is_fresh_init(self):
        from repro.launch import serve

        a = serve.load_qnet("", jax.random.PRNGKey(3))
        b = dqn.init_qnet(jax.random.PRNGKey(3))
        np.testing.assert_array_equal(np.asarray(a["w1"]),
                                      np.asarray(b["w1"]))
