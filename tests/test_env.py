"""Environment unit + hypothesis property tests (cluster invariants).

The property-based tests degrade gracefully when `hypothesis` is absent
(it ships via the package's [test] extra): the unit tests still run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import env as kenv
from repro.core.types import paper_cluster, training_cluster


CFG = paper_cluster()


def fresh(seed=0, cfg=CFG):
    return kenv.reset(jax.random.PRNGKey(seed), cfg)


class TestReset:
    def test_shapes_and_ranges(self):
        st_ = fresh()
        assert st_.n_nodes == CFG.n_nodes
        assert bool(jnp.all(st_.base_cpu >= 0))
        assert bool(jnp.all(st_.cpu_requested <= 0.98 * CFG.cpu_capacity))
        assert bool(jnp.all(st_.exp_pods == 0))
        # bookings come from tenant pods
        np.testing.assert_array_equal(
            np.asarray(st_.num_pods),
            (np.asarray(st_.cpu_requested) / CFG.pod_cpu_request).astype(np.int32),
        )

    def test_profiles_are_permutations(self):
        bases = sorted(np.asarray(fresh(1).base_cpu).tolist())
        expect = sorted(CFG.base_cpu_profile)
        assert np.allclose(bases, expect, atol=CFG.base_cpu_jitter + 1e-3)

    def test_randomized_training_reset(self):
        tcfg = training_cluster()
        st_ = kenv.reset(jax.random.PRNGKey(3), tcfg)
        assert int(st_.exp_pods.sum()) >= 0
        cached = np.asarray(st_.image_cached)
        has_pods = np.asarray(st_.exp_pods) > 0
        assert bool(np.all(cached[has_pods]))  # pods imply a warm image


class TestPlace:
    def test_placement_updates_counts(self):
        st_ = fresh()
        pod = kenv.default_pod(CFG)
        st2 = kenv.place(st_, jnp.int32(1), pod, CFG)
        assert int(st2.exp_pods[1]) == 1
        assert int(st2.num_pods[1]) == int(st_.num_pods[1]) + 1
        assert float(st2.cpu_requested[1]) == pytest.approx(
            float(st_.cpu_requested[1]) + CFG.pod_cpu_request)
        assert bool(st2.image_cached[1])

    def test_cold_pull_costs_more_than_warm(self):
        st_ = fresh()
        pod = kenv.default_pod(CFG)
        st_cold = kenv.place(st_, jnp.int32(0), pod, CFG)
        cold_spike = float(st_cold.startup_cpu[0])
        st_warm = kenv.place(st_cold, jnp.int32(0), pod, CFG)
        warm_spike = float(st_warm.startup_cpu[0]) - cold_spike
        assert cold_spike >= CFG.image_pull_cost
        assert warm_spike == pytest.approx(CFG.warm_start_cost)

    def test_concurrent_pulls_inflate(self):
        st_ = fresh()
        pod = kenv.default_pod(CFG)
        st1 = kenv.place(st_, jnp.int32(0), pod, CFG)
        st2 = kenv.place(st1, jnp.int32(1), pod, CFG)
        first = float(st1.startup_cpu[0])
        second = float(st2.startup_cpu[1])
        assert second > first  # concurrency multiplier

    def test_tick_decays_startup(self):
        st_ = fresh()
        pod = kenv.default_pod(CFG)
        st_ = kenv.place(st_, jnp.int32(0), pod, CFG)
        before = float(st_.startup_cpu[0])
        st_ = kenv.tick(st_, CFG, 2.0)
        assert float(st_.startup_cpu[0]) == pytest.approx(before * CFG.startup_decay)
        assert float(st_.uptime_hours[0]) > 0

    def test_tick_decay_follows_wallclock_not_call_count(self):
        """One 4 s tick must decay transients exactly like two 2 s ticks
        (variable Poisson/diurnal gaps would otherwise stretch pull spikes)."""
        st_ = fresh()
        pod = kenv.default_pod(CFG)
        st_ = kenv.place(st_, jnp.int32(0), pod, CFG)
        one_big = kenv.tick(st_, CFG, 2.0 * CFG.schedule_dt_s)
        two_small = kenv.tick(kenv.tick(st_, CFG, CFG.schedule_dt_s), CFG, CFG.schedule_dt_s)
        assert float(one_big.startup_cpu[0]) == pytest.approx(
            float(two_small.startup_cpu[0]), rel=1e-6)


class TestMetric:
    def test_paper_example_uniform_vs_consolidated(self):
        """Paper §4.3.2: (20+20+20)/3 = 20 vs (10+25+20)/3 = 18.3."""
        st_ = fresh()
        uniform = jnp.array([800.0, 800.0, 800.0, 800.0])
        st_u = st_._replace(base_cpu=uniform, startup_cpu=jnp.zeros(4))
        m = float(kenv.average_cpu_utilization(st_u, CFG))
        assert m == pytest.approx(20.0, abs=0.5)

    def test_cpu_capped_at_capacity(self):
        st_ = fresh()
        st_ = st_._replace(base_cpu=jnp.full((4,), 99999.0))
        assert bool(jnp.all(kenv.cpu_pct(st_, CFG) <= 100.0))


def _check_env_invariants(seed, actions):
    """Conservation + monotonicity under arbitrary placements."""
    cfg = CFG
    state = kenv.reset(jax.random.PRNGKey(seed), cfg)
    pod = kenv.default_pod(cfg)
    placed = 0
    for a in actions:
        ok = kenv.feasible(state, pod, cfg)
        if not bool(ok[a]):
            continue
        state = kenv.place(state, jnp.int32(a), pod, cfg)
        state = kenv.tick(state, cfg, cfg.schedule_dt_s)
        placed += 1
    assert int(state.exp_pods.sum()) == placed           # every placement counted
    assert bool(jnp.all(state.exp_pods >= 0))
    assert bool(jnp.all(state.cpu_requested <= state.cpu_capacity + 1e-3))
    feats = kenv.features(state, cfg)
    assert feats.shape == (cfg.n_nodes, 6)
    assert bool(jnp.all(jnp.isfinite(feats)))
    assert bool(jnp.all(feats[:, 0] <= 100.0 + 1e-3))    # cpu% capped


# The hypothesis guard lives in tests/strategies.py (shared by every
# property suite): a bare module-level `pytest.importorskip("hypothesis")`
# would skip this whole module, unit tests included, so only the randomized
# tier degrades when the [test] extra is absent.  Example budgets come from
# the profiles in tests/conftest.py (HYPOTHESIS_PROFILE=ci|nightly|dev).
import strategies as strat

if strat.HAVE_HYPOTHESIS:
    from hypothesis import given

    @given(seed=strat.seeds(), actions=strat.action_traces())
    def test_property_env_invariants(seed, actions):
        _check_env_invariants(seed, actions)

else:

    def test_property_env_invariants():
        pytest.importorskip("hypothesis")


def test_env_invariants_fixed_cases():
    """Hypothesis-free fallback: pin a few action traces so the invariants
    are always exercised, even without the [test] extra installed."""
    _check_env_invariants(0, [0, 1, 2, 3] * 5)
    _check_env_invariants(7, [3, 3, 3, 0, 0, 1])
    _check_env_invariants(11, [2])
