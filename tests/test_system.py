"""End-to-end behaviour tests for the paper's system.

The headline integration test trains SDQN and SDQN-n from scratch (short
budget), evaluates them on the paper cluster against the default scheduler,
and asserts the paper's qualitative claims: both RL schedulers at or below
default average CPU, and SDQN-n consolidating onto ~n=2 nodes.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import env as kenv, schedulers, train_rl
from repro.core.types import paper_cluster, training_cluster


CFG = paper_cluster()


def evaluate(select, trials=3, n_pods=50):
    mets, dists = [], []
    ep = jax.jit(lambda kk: kenv.run_episode(kk, CFG, select, n_pods))
    for t in range(trials):
        res = ep(jax.random.PRNGKey(100 + t))
        state = res.state
        mets.append(float(res.metric))
        dists.append(np.asarray(state.exp_pods))
    return float(np.mean(mets)), dists


@pytest.fixture(scope="module")
def trained_policies():
    tcfg = training_cluster()
    rl = train_rl.RLConfig(variant="sdqn", episodes=250, n_envs=16, eps_end=0.05,
                           batch_size=256, efficiency_weight=5.0)
    qp, _ = train_rl.train_and_select(jax.random.PRNGKey(0), tcfg, CFG, rl,
                                      n_seeds=3, val_trials=4)
    rln = dataclasses.replace(rl, variant="sdqn_n", efficiency_weight=10.0)
    qpn, _ = train_rl.train_and_select(jax.random.PRNGKey(1), tcfg, CFG, rln,
                                       n_seeds=3, val_trials=4)
    return qp, qpn


class TestEndToEnd:
    def test_sdqn_beats_or_matches_default(self, trained_policies):
        qp, _ = trained_policies
        d, _ = evaluate(schedulers.make_kube_selector(CFG))
        s, _ = evaluate(schedulers.make_sdqn_selector(qp, CFG))
        assert s <= d * 1.02, (s, d)  # at-or-below default (paper: -10%)

    def test_sdqn_n_consolidates(self, trained_policies):
        _, qpn = trained_policies
        m, dists = evaluate(schedulers.make_sdqn_selector(qpn, CFG))
        active = np.mean([(d > 0).sum() for d in dists])
        assert active <= 3.2, dists  # paper: pods concentrated on ~2 nodes

    def test_sdqn_n_saves_over_20pct_vs_default_trend(self, trained_policies):
        _, qpn = trained_policies
        d, _ = evaluate(schedulers.make_kube_selector(CFG))
        s, _ = evaluate(schedulers.make_sdqn_selector(qpn, CFG))
        # short-budget test: require a clear saving; the full benchmark
        # (benchmarks/paper_tables.py) reproduces the >20% claim
        assert s < d * 0.93, (s, d)

    def test_all_pods_scheduled(self, trained_policies):
        qp, qpn = trained_policies
        for params in (qp, qpn):
            _, dists = evaluate(schedulers.make_sdqn_selector(params, CFG), trials=2)
            for dist in dists:
                assert dist.sum() == 50


class TestLiteralAblation:
    def test_table4_bandit_mode_trains(self):
        """The literal Table-4 update (no bootstrap, no shaping) must run."""
        tcfg = training_cluster()
        rl = train_rl.RLConfig(variant="sdqn", episodes=30, n_envs=4,
                               bootstrap=False, efficiency_weight=0.0)
        qp, metrics = jax.jit(lambda k: train_rl.train(k, tcfg, rl))(jax.random.PRNGKey(0))
        assert np.isfinite(float(metrics["loss"][-1]))
        sel = schedulers.make_sdqn_selector(qp, CFG)
        res = kenv.run_episode(jax.random.PRNGKey(5), CFG, sel, 50)
        assert np.isfinite(float(res.metric))


class TestServeIntegration:
    def test_serve_driver(self):
        from repro.launch import serve as serve_mod

        counts = serve_mod.main([
            "--arch", "olmo-1b", "--smoke", "--replicas", "3",
            "--requests", "12", "--wave-size", "4", "--gen-tokens", "4",
            "--prompt-len", "8",
        ])
        assert counts.sum() == 3  # 3 waves routed
